"""Fleet-analytics throughput benchmark (streaming engine tentpole).

Compares the implementations of the §2.1 fleet analysis on one seeded
cluster sample:

* ``masked``    — the seed implementation: one boolean mask over the full
                  frame per (job, host, device) group, O(groups x rows).
* ``grouped``   — monolithic ``analyze_fleet`` on the lexsort grouping,
                  O(rows log rows) with one gather.
* ``streaming`` — ``FleetAccumulator`` fed bounded chunks (the out-of-core
                  path used by ``analyze_store``).
* ``runs``      — ``analyze_store`` reducing the run-level IR
                  (:mod:`repro.whatif.ir`) instead of re-classifying rows:
                  the "one IR to rule the stack" steady state, O(runs) per
                  pass after the one-off compaction.

Plus the incremental-append cycle: ``IRBuilder.extend`` folding one new
shard into the cached IR vs a from-scratch rebuild.

Acceptance: grouped >= 3x masked rows/s at >= 64 groups; all row paths
agree exactly on the fleet breakdown and interval count; analyze-on-runs
matches the row oracle (times/counts bit-identical, energies <= 1e-9) and
clears 3x the committed row-path floor; a 1-shard append is >= 10x faster
than a rebuild and the appended IR still matches the row oracle.

Run:  PYTHONPATH=src python -m benchmarks.run --only fleet \
          [--json BENCH_fleet_analyze.json]
"""
from __future__ import annotations

import math
import tempfile
import time

import numpy as np

from benchmarks import common
from benchmarks.common import Bench
from repro.telemetry import FleetAccumulator, analyze_fleet, analyze_job
from repro.telemetry.pipeline import FleetAnalysis
from repro.core.energy import merge

#: bench corpus: enough (job, host, device) groups to show the O(G x N)
#: blow-up of the seed path, small enough to keep the bench quick
N_DEVICES = 64
HORIZON_S = 3 * 3600
SEED = 3
CHUNK_ROWS = 7200          # streaming chunk ~ one (device, 2h-day) shard

#: --quick (CI): tiny corpus, timing targets disabled
QUICK_N_DEVICES = 8
QUICK_HORIZON_S = 2700

#: one-sided regression floors (full corpus). The row-path floor sits at
#: ~1/3 of the committed ``streaming_rows_per_s`` baseline to absorb
#: container noise; analyze-on-runs must clear 3x the row-path floor (the
#: ISSUE 9 acceptance bar), and a 1-shard incremental append must beat a
#: from-scratch rebuild 10x.
ROW_PATH_FLOOR = 1.2e6
ANALYZE_RUNS_FLOOR = 3.0 * ROW_PATH_FLOOR
IR_APPEND_SPEEDUP_FLOOR = 10.0


def _timed(fn, reps):
    """(min wall seconds over ``reps`` runs, last result)."""
    best = math.inf
    result = None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def _runs_match_rows(run, row) -> bool:
    """The twin-path contract: per-job/platform times, durations, interval
    lists and counts bit-identical; energies <= 1e-9 relative;
    ``unattributed_energy_j`` exact."""
    if len(run.jobs) != len(row.jobs) or run.n_intervals != row.n_intervals:
        return False
    for a, b in zip(run.jobs, row.jobs):
        if (a.job_id != b.job_id or a.platform != b.platform
                or a.duration_s != b.duration_s
                or a.breakdown.time_s != b.breakdown.time_s
                or a.intervals != b.intervals):
            return False
        if not all(np.isclose(a.breakdown.energy_j[s],
                              b.breakdown.energy_j[s],
                              rtol=1e-9, atol=1e-9)
                   for s in a.breakdown.energy_j):
            return False
    if run.fleet.time_s != row.fleet.time_s:
        return False
    if sorted(run.platforms) != sorted(row.platforms) or any(
            run.platforms[p].time_s != row.platforms[p].time_s
            for p in run.platforms):
        return False
    return run.unattributed_energy_j == row.unattributed_energy_j


def _analyze_fleet_masked(frame, min_job_duration_s: float = 0.0,
                          min_interval_s: float = 5.0) -> FleetAnalysis:
    """Faithful copy of the seed per-group-mask implementation (kept here so
    the benchmark keeps measuring it after the pipeline moved on)."""
    job_ids = frame["job_id"]
    device_ids = frame["device_id"]
    hostnames = frame["hostname"]

    unattributed = float(np.sum(frame["power"][job_ids < 0]))

    jobs = []
    keys = np.stack([job_ids, hostnames, device_ids], axis=1)
    attributed = keys[job_ids >= 0]
    if attributed.size:
        uniq = np.unique(attributed, axis=0)
        for jid, host, dev in uniq:
            mask = (job_ids == jid) & (hostnames == host) & (device_ids == dev)
            sub = frame.select(mask)
            order = np.argsort(sub["timestamp"], kind="stable")
            sub = sub.select(order)
            span = float(sub["timestamp"][-1] - sub["timestamp"][0]) + 1.0
            if span < min_job_duration_s:
                continue
            jobs.append(analyze_job(sub, int(jid), min_interval_s))

    fleet = merge([j.breakdown for j in jobs])
    return FleetAnalysis(jobs=jobs, fleet=fleet,
                         unattributed_energy_j=unattributed,
                         n_intervals=sum(len(j.intervals) for j in jobs))


def bench_fleet_analyze() -> Bench:
    from repro.cluster import generate_cluster

    quick = common.QUICK
    n_devices = QUICK_N_DEVICES if quick else N_DEVICES
    horizon_s = QUICK_HORIZON_S if quick else HORIZON_S

    b = Bench("fleet_analyze")
    cs = generate_cluster(n_devices=n_devices, horizon_s=horizon_s, seed=SEED)
    frame = cs.frame
    n = len(frame)

    t0 = time.perf_counter()
    masked = _analyze_fleet_masked(frame, 0.0)
    t_masked = time.perf_counter() - t0

    t0 = time.perf_counter()
    grouped = analyze_fleet(frame, min_job_duration_s=0.0)
    t_grouped = time.perf_counter() - t0

    t0 = time.perf_counter()
    acc = FleetAccumulator(min_job_duration_s=0.0)
    for chunk in frame.iter_chunks(CHUNK_ROWS):
        acc.update(chunk)
    streaming = acc.finalize()
    t_streaming = time.perf_counter() - t0

    n_groups = len(grouped.jobs)
    b.add("rows", float(n))
    b.add("n_groups", float(n_groups))
    if not quick:
        b.add("groups_target_64", float(n_groups >= 64), (1.0, 0.01))
    b.add("masked_rows_per_s", n / t_masked, seconds=t_masked)
    b.add("grouped_rows_per_s", n / t_grouped, seconds=t_grouped)
    b.add("streaming_rows_per_s", n / t_streaming, seconds=t_streaming)
    speedup = t_masked / t_grouped
    b.add("speedup_grouped_vs_masked", speedup)
    b.add("speedup_target_3x", float(speedup >= 3.0),
          None if quick else (1.0, 0.01))

    agree = (
        masked.fleet.time_s == grouped.fleet.time_s == streaming.fleet.time_s
        and masked.fleet.energy_j == grouped.fleet.energy_j == streaming.fleet.energy_j
        and masked.n_intervals == grouped.n_intervals == streaming.n_intervals
        and [j.job_id for j in masked.jobs] == [j.job_id for j in grouped.jobs]
        == [j.job_id for j in streaming.jobs]
    )
    b.add("paths_agree_exactly", float(agree), (1.0, 0.01))
    if not quick:
        b.add("streaming_rows_per_s_floor",
              float(n / t_streaming >= ROW_PATH_FLOOR), (1.0, 0.01))

    # ---- analyze on runs + incremental IR append (ISSUE 9 tentpole) ----
    from repro.telemetry import TelemetryStore
    from repro.telemetry.pipeline import analyze_store
    from repro.whatif.ir import IRBuilder, IRConfig, get_ir

    reps = 1 if quick else 3
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        for chunk in frame.iter_chunks(CHUNK_ROWS):
            store.write_shard(chunk, host="all", flush_manifest=False)
        store.save_manifest()

        t_row, row_fa = _timed(
            lambda: analyze_store(store, min_job_duration_s=0.0,
                                  compact=False), 1)
        # one-off compaction (untimed here; whatif_bench tracks ir_build_s),
        # then the steady state every repeat analysis pays: run reduction
        # over the shared handle
        ir_handle = get_ir(store, IRConfig())
        t_runs, runs_fa = _timed(
            lambda: analyze_store(store, min_job_duration_s=0.0,
                                  compact=True, ir=ir_handle), reps)
        b.add("analyze_runs_rows_per_s", n / t_runs,
              None if quick else (ANALYZE_RUNS_FLOOR, 0.0), mode="min",
              seconds=t_runs)
        b.add("analyze_runs_speedup_vs_rows", t_row / t_runs,
              seconds=t_row)
        # bit-exactness oracle gate: runs in --quick CI too
        b.add("analyze_runs_matches_rows",
              float(_runs_match_rows(runs_fa, row_fa)), (1.0, 0.01))

        # append-then-analyze cycle: fold the newest shard into the IR
        # (O(new rows + affected suffixes)) vs rebuilding from scratch
        chunks = [(store.read_shard(s["file"]), s["host"])
                  for s in store.manifest["shards"]]

        def build_all():
            builder = IRBuilder(IRConfig())
            for f, h in chunks:
                builder.update(f, host_label=h)
            return builder.finalize(source_rows=store.total_rows,
                                    source_shards=len(chunks))

        base_builder = IRBuilder(IRConfig())
        for f, h in chunks[:-1]:
            base_builder.update(f, host_label=h)
        base = base_builder.finalize(
            source_rows=store.total_rows - len(chunks[-1][0]),
            source_shards=len(chunks) - 1)

        t_append, appended = _timed(
            lambda: IRBuilder(IRConfig()).extend(base, chunks[-1:]), reps)
        t_rebuild, _ = _timed(build_all, 1)
        b.add("ir_append_rows_per_s", len(chunks[-1][0]) / t_append,
              seconds=t_append)
        b.add("ir_rebuild_rows_per_s", n / t_rebuild, seconds=t_rebuild)
        b.add("ir_append_speedup_vs_rebuild", t_rebuild / t_append,
              None if quick else (IR_APPEND_SPEEDUP_FLOOR, 0.0), mode="min")
        # the appended IR feeds the same analysis and still matches the
        # row oracle — the --quick CI append-then-analyze gate
        t_runs2, runs_fa2 = _timed(
            lambda: analyze_store(store, min_job_duration_s=0.0,
                                  compact=True, ir=appended), 1)
        b.add("analyze_runs_matches_rows_appended",
              float(_runs_match_rows(runs_fa2, row_fa)), (1.0, 0.01))
    return b

"""Fleet-analytics throughput benchmark (streaming engine tentpole).

Compares three implementations of the §2.1 fleet analysis on one seeded
cluster sample:

* ``masked``    — the seed implementation: one boolean mask over the full
                  frame per (job, host, device) group, O(groups x rows).
* ``grouped``   — monolithic ``analyze_fleet`` on the lexsort grouping,
                  O(rows log rows) with one gather.
* ``streaming`` — ``FleetAccumulator`` fed bounded chunks (the out-of-core
                  path used by ``analyze_store``).

Acceptance: grouped >= 3x masked rows/s at >= 64 groups, and all three paths
agree exactly on the fleet breakdown and interval count.

Run:  PYTHONPATH=src python -m benchmarks.run --only fleet \
          [--json BENCH_fleet_analyze.json]
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import Bench
from repro.telemetry import FleetAccumulator, analyze_fleet, analyze_job
from repro.telemetry.pipeline import FleetAnalysis
from repro.core.energy import merge

#: bench corpus: enough (job, host, device) groups to show the O(G x N)
#: blow-up of the seed path, small enough to keep the bench quick
N_DEVICES = 64
HORIZON_S = 3 * 3600
SEED = 3
CHUNK_ROWS = 7200          # streaming chunk ~ one (device, 2h-day) shard

#: --quick (CI): tiny corpus, timing targets disabled
QUICK_N_DEVICES = 8
QUICK_HORIZON_S = 2700


def _analyze_fleet_masked(frame, min_job_duration_s: float = 0.0,
                          min_interval_s: float = 5.0) -> FleetAnalysis:
    """Faithful copy of the seed per-group-mask implementation (kept here so
    the benchmark keeps measuring it after the pipeline moved on)."""
    job_ids = frame["job_id"]
    device_ids = frame["device_id"]
    hostnames = frame["hostname"]

    unattributed = float(np.sum(frame["power"][job_ids < 0]))

    jobs = []
    keys = np.stack([job_ids, hostnames, device_ids], axis=1)
    attributed = keys[job_ids >= 0]
    if attributed.size:
        uniq = np.unique(attributed, axis=0)
        for jid, host, dev in uniq:
            mask = (job_ids == jid) & (hostnames == host) & (device_ids == dev)
            sub = frame.select(mask)
            order = np.argsort(sub["timestamp"], kind="stable")
            sub = sub.select(order)
            span = float(sub["timestamp"][-1] - sub["timestamp"][0]) + 1.0
            if span < min_job_duration_s:
                continue
            jobs.append(analyze_job(sub, int(jid), min_interval_s))

    fleet = merge([j.breakdown for j in jobs])
    return FleetAnalysis(jobs=jobs, fleet=fleet,
                         unattributed_energy_j=unattributed,
                         n_intervals=sum(len(j.intervals) for j in jobs))


def bench_fleet_analyze() -> Bench:
    from repro.cluster import generate_cluster

    quick = common.QUICK
    n_devices = QUICK_N_DEVICES if quick else N_DEVICES
    horizon_s = QUICK_HORIZON_S if quick else HORIZON_S

    b = Bench("fleet_analyze")
    cs = generate_cluster(n_devices=n_devices, horizon_s=horizon_s, seed=SEED)
    frame = cs.frame
    n = len(frame)

    t0 = time.perf_counter()
    masked = _analyze_fleet_masked(frame, 0.0)
    t_masked = time.perf_counter() - t0

    t0 = time.perf_counter()
    grouped = analyze_fleet(frame, min_job_duration_s=0.0)
    t_grouped = time.perf_counter() - t0

    t0 = time.perf_counter()
    acc = FleetAccumulator(min_job_duration_s=0.0)
    for chunk in frame.iter_chunks(CHUNK_ROWS):
        acc.update(chunk)
    streaming = acc.finalize()
    t_streaming = time.perf_counter() - t0

    n_groups = len(grouped.jobs)
    b.add("rows", float(n))
    b.add("n_groups", float(n_groups))
    if not quick:
        b.add("groups_target_64", float(n_groups >= 64), (1.0, 0.01))
    b.add("masked_rows_per_s", n / t_masked, seconds=t_masked)
    b.add("grouped_rows_per_s", n / t_grouped, seconds=t_grouped)
    b.add("streaming_rows_per_s", n / t_streaming, seconds=t_streaming)
    speedup = t_masked / t_grouped
    b.add("speedup_grouped_vs_masked", speedup)
    b.add("speedup_target_3x", float(speedup >= 3.0),
          None if quick else (1.0, 0.01))

    agree = (
        masked.fleet.time_s == grouped.fleet.time_s == streaming.fleet.time_s
        and masked.fleet.energy_j == grouped.fleet.energy_j == streaming.fleet.energy_j
        and masked.n_intervals == grouped.n_intervals == streaming.n_intervals
        and [j.job_id for j in masked.jobs] == [j.job_id for j in grouped.jobs]
        == [j.job_id for j in streaming.jobs]
    )
    b.add("paths_agree_exactly", float(agree), (1.0, 0.01))
    return b

"""Shared benchmark helpers: result rows, validation, cluster-sample cache."""
from __future__ import annotations

import dataclasses
import functools
import time

#: set by ``benchmarks.run --quick`` (CI): benches shrink their corpora and
#: drop timing targets, keeping only correctness targets — the hot paths run
#: on every PR without the full-size timing burden.
QUICK = False


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: float
    target: float | None = None
    ok: bool | None = None

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived:.6g}"


def check_abs(value: float, target: tuple[float, float]) -> bool:
    mean, tol = target
    return abs(value - mean) <= tol


def check_rel(value: float, target: tuple[float, float]) -> bool:
    mean, tol = target
    return abs(value - mean) <= tol * abs(mean)


class Bench:
    """Collects rows and wall time for one paper artifact."""

    def __init__(self, name: str):
        self.name = name
        self.rows: list[Row] = []
        self._t0 = time.time()

    @property
    def us(self) -> float:
        return (time.time() - self._t0) * 1e6

    def add(self, metric: str, value: float, target=None, mode="abs"):
        ok = None
        tval = None
        if target is not None:
            tval = target[0]
            ok = check_abs(value, target) if mode == "abs" else check_rel(value, target)
        self.rows.append(Row(f"{self.name}/{metric}", self.us, float(value),
                             tval, ok))

    def summary(self) -> str:
        n_ok = sum(1 for r in self.rows if r.ok)
        n_checked = sum(1 for r in self.rows if r.ok is not None)
        return f"{self.name}: {n_ok}/{n_checked} targets hit, {len(self.rows)} metrics"


# --------------------------------------------------------------------------- #
# shared cluster sample (several figures read the same simulated deployment)
# --------------------------------------------------------------------------- #
@functools.lru_cache(maxsize=2)
def cluster_sample(n_devices: int = 112, horizon_s: int = 10 * 3600, seed: int = 1):
    from repro.cluster import generate_cluster
    return generate_cluster(n_devices=n_devices, horizon_s=horizon_s, seed=seed)


@functools.lru_cache(maxsize=2)
def fleet_analysis(min_job_s: float = 7200.0, min_interval_s: float = 5.0):
    from repro.telemetry import analyze_fleet
    cs = cluster_sample()
    return analyze_fleet(cs.frame, min_job_duration_s=min_job_s,
                         min_interval_s=min_interval_s)

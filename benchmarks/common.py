"""Shared benchmark helpers: result rows, validation, cluster-sample cache."""
from __future__ import annotations

import dataclasses
import functools

#: set by ``benchmarks.run --quick`` (CI): benches shrink their corpora and
#: drop timing targets, keeping only correctness targets — the hot paths run
#: on every PR without the full-size timing burden.
QUICK = False


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float | None        # owning stage's wall time; None = n/a
    derived: float
    target: float | None = None
    ok: bool | None = None
    #: device count the row was measured on (jax-backend rows: mesh size;
    #: None = host-only / not device-dependent). Committed baselines carry
    #: it so a regression on an N-device row is compared like-for-like.
    devices: int | None = None

    def csv(self) -> str:
        us = "" if self.us_per_call is None else f"{self.us_per_call:.1f}"
        dev = "" if self.devices is None else str(self.devices)
        return f"{self.name},{us},{self.derived:.6g},{dev}"


def check_abs(value: float, target: tuple[float, float]) -> bool:
    mean, tol = target
    return abs(value - mean) <= tol


def check_rel(value: float, target: tuple[float, float]) -> bool:
    mean, tol = target
    return abs(value - mean) <= tol * abs(mean)


def check_min(value: float, target: tuple[float, float]) -> bool:
    """Regression floor: ok iff ``value >= floor`` (the tolerance slot is
    unused — floors are one-sided)."""
    floor, _ = target
    return value >= floor


_CHECKS = {"abs": check_abs, "rel": check_rel, "min": check_min}


class Bench:
    """Collects result rows for one paper artifact.

    ``us_per_call`` records the row's *owning stage* wall time, passed
    explicitly via ``seconds=`` by benches that timed a stage; derived
    metrics (counts, ratios, pass/fail flags) leave it None/null — the old
    behaviour of stamping cumulative harness wall-clock on every row made
    the column meaningless for them.
    """

    def __init__(self, name: str):
        self.name = name
        self.rows: list[Row] = []

    def add(self, metric: str, value: float, target=None, mode="abs",
            seconds: float | None = None, devices: int | None = None):
        ok = None
        tval = None
        if target is not None:
            tval = target[0]
            ok = _CHECKS[mode](value, target)
        us = None if seconds is None else seconds * 1e6
        self.rows.append(Row(f"{self.name}/{metric}", us, float(value),
                             tval, ok, devices))

    def summary(self) -> str:
        n_ok = sum(1 for r in self.rows if r.ok)
        n_checked = sum(1 for r in self.rows if r.ok is not None)
        return f"{self.name}: {n_ok}/{n_checked} targets hit, {len(self.rows)} metrics"


# --------------------------------------------------------------------------- #
# shared cluster sample (several figures read the same simulated deployment)
# --------------------------------------------------------------------------- #
@functools.lru_cache(maxsize=2)
def cluster_sample(n_devices: int = 112, horizon_s: int = 10 * 3600, seed: int = 1):
    from repro.cluster import generate_cluster
    return generate_cluster(n_devices=n_devices, horizon_s=horizon_s, seed=seed)


@functools.lru_cache(maxsize=2)
def fleet_analysis(min_job_s: float = 7200.0, min_interval_s: float = 5.0):
    from repro.telemetry import analyze_fleet
    cs = cluster_sample()
    return analyze_fleet(cs.frame, min_job_duration_s=min_job_s,
                         min_interval_s=min_interval_s)

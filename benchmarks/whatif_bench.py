"""What-if sweep throughput benchmark (counterfactual policy engine).

Generates the 96-group bench corpus (64 devices x 3 h, the fleet_bench
deployment) straight into a shard store, then sweeps the legacy 48-config
policy grid three ways — per-policy reference (serial), config-axis batched
(serial), batched process-pool — plus the dense 200-config default grid
through the batched path, and reports configs/s for each alongside the
bit-identity checks.

Acceptance: the sweep streams shard-by-shard (peak memory ~ one shard), the
batched path is bit-identical to the per-policy reference AND to itself
under ``workers=2``, the no-op config anchors the frontier at zero saving /
zero penalty, and ``configs_per_s_batched / configs_per_s_serial >= 5`` on
the 48-config x 691k-row corpus (the committed baseline row). The dense-grid
row demonstrates the pass is O(rows + configs): throughput in configs/s
*rises* with grid size as the per-row work amortizes.

Run:  PYTHONPATH=src python -m benchmarks.run --only whatif \
          [--json BENCH_whatif_sweep.json] [--quick]

``--quick`` (CI) shrinks the corpus and drops the timing targets; the
correctness targets (bit-identity, frontier anchoring) still validate.
"""
from __future__ import annotations

import math
import tempfile
import time

from benchmarks import common
from benchmarks.common import Bench

#: same deployment as fleet_bench, emitted chunked: 96 analyzable groups.
#: One shard per device stream (npy_dir): shard reads cost one open per
#: column instead of a deflate pass, so the timings measure the replay
#: engines, not decompression.
N_DEVICES = 64
HORIZON_S = 3 * 3600
SEED = 3
SHARD_S = HORIZON_S

#: min-of-N timing — container timing noise is multi-second, so single-shot
#: ratios are unstable; the minimum is the standard de-noised estimate
REPS_BATCHED = 3
REPS_SERIAL = 2

#: --quick (CI): tiny store, timing targets disabled. The horizon must
#: clear the jobs' deep-idle setup phase (~24% of duration) so policies
#: actually have execution-idle time to mitigate.
QUICK_N_DEVICES = 8
QUICK_HORIZON_S = 2700
QUICK_SHARD_S = 900


def _timed(fn, reps):
    """(min wall seconds over ``reps`` runs, last result)."""
    best = math.inf
    result = None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def bench_whatif_sweep() -> Bench:
    from repro.cluster import generate_cluster
    from repro.telemetry import TelemetryStore
    from repro.whatif import default_policy_grid, frontier_to_dict, run_sweep

    quick = common.QUICK
    n_devices = QUICK_N_DEVICES if quick else N_DEVICES
    horizon_s = QUICK_HORIZON_S if quick else HORIZON_S
    shard_s = QUICK_SHARD_S if quick else SHARD_S
    reps_b = 1 if quick else REPS_BATCHED
    reps_s = 1 if quick else REPS_SERIAL

    b = Bench("whatif_sweep")
    grid = default_policy_grid(dense=False)
    dense_grid = default_policy_grid()
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        generate_cluster(n_devices=n_devices, horizon_s=horizon_s, seed=SEED,
                         store=store, shard_s=shard_s)
        rows = store.total_rows

        t_serial, serial = _timed(
            lambda: run_sweep(store, grid, workers=1, min_job_duration_s=0.0,
                              batched=False), reps_s)
        t_batched, batched = _timed(
            lambda: run_sweep(store, grid, workers=1, min_job_duration_s=0.0,
                              batched=True), reps_b)
        t_pooled, pooled = _timed(
            lambda: run_sweep(store, grid, workers=2, min_job_duration_s=0.0,
                              batched=True), 1)
        t_dense, _ = _timed(
            lambda: run_sweep(store, dense_grid, workers=1,
                              min_job_duration_s=0.0, batched=True), reps_b)

    n_cfg = len(grid)
    b.add("rows", float(rows))
    b.add("n_configs", float(n_cfg), (48.0, 0.01))
    b.add("n_groups", float(serial.n_jobs))
    if not quick:
        b.add("groups_target_96", float(serial.n_jobs >= 96), (1.0, 0.01))
    b.add("configs_per_s_serial", n_cfg / t_serial)
    b.add("configs_per_s_batched", n_cfg / t_batched)
    b.add("configs_per_s_workers2", n_cfg / t_pooled)
    b.add("row_configs_per_s_batched", rows * n_cfg / t_batched)

    speedup = t_serial / t_batched
    b.add("batched_speedup_vs_serial", speedup)
    b.add("batched_speedup_target_5x", float(speedup >= 5.0),
          None if quick else (1.0, 0.01))

    b.add("batched_bit_identical",
          float(frontier_to_dict(batched) == frontier_to_dict(serial)),
          (1.0, 0.01))
    b.add("workers_bit_identical",
          float(frontier_to_dict(pooled) == frontier_to_dict(batched)),
          (1.0, 0.01))

    b.add("dense_grid_configs", float(len(dense_grid)), (200.0, 0.01))
    b.add("configs_per_s_batched_dense", len(dense_grid) / t_dense)

    noop = next(o for o in serial.outcomes if o.name == "noop")
    anchored = noop.energy_saved_j == 0.0 and noop.penalty_s == 0.0
    b.add("noop_anchors_frontier", float(anchored), (1.0, 0.01))
    b.add("pareto_set_size", float(len(serial.pareto_set())))
    best = max(serial.outcomes, key=lambda o: o.energy_saved_j)
    b.add("best_saved_fraction", best.saved_fraction)
    return b

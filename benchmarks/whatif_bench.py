"""What-if engine benchmarks: sweep throughput and closed-loop search.

``bench_whatif_sweep`` tracks the batched config-axis sweep;
``bench_whatif_search`` tracks :func:`repro.whatif.search_frontier` against
the dense 200-config sweep (configs evaluated to reach the knee, configs/s,
knee-match tolerance). Both run in ``--quick`` CI mode on every PR.

Generates the 96-group bench corpus (64 devices x 3 h, the fleet_bench
deployment) straight into a shard store, then sweeps the legacy 48-config
policy grid three ways — per-policy reference (serial), config-axis batched
(serial), batched process-pool — plus the dense 200-config default grid
through the batched path, and reports configs/s for each alongside the
bit-identity checks.

Acceptance: the sweep streams shard-by-shard (peak memory ~ one shard), the
batched path is bit-identical to the per-policy reference AND to itself
under ``workers=2``, the no-op config anchors the frontier at zero saving /
zero penalty, and ``configs_per_s_batched / configs_per_s_serial >= 5`` on
the 48-config x 691k-row corpus (the committed baseline row). The dense-grid
row demonstrates the pass is O(rows + configs): throughput in configs/s
*rises* with grid size as the per-row work amortizes.

Run:  PYTHONPATH=src python -m benchmarks.run --only whatif \
          [--json BENCH_whatif_sweep.json] [--quick]

``--quick`` (CI) shrinks the corpus and drops the timing targets; the
correctness targets (bit-identity, frontier anchoring) still validate.
"""
from __future__ import annotations

import math
import tempfile
import time

from benchmarks import common
from benchmarks.common import Bench

#: same deployment as fleet_bench, emitted chunked: 96 analyzable groups.
#: One shard per device stream (npy_dir): shard reads cost one open per
#: column instead of a deflate pass, so the timings measure the replay
#: engines, not decompression.
N_DEVICES = 64
HORIZON_S = 3 * 3600
SEED = 3
SHARD_S = HORIZON_S

#: min-of-N timing — container timing noise is multi-second, so single-shot
#: ratios are unstable; the minimum is the standard de-noised estimate
REPS_BATCHED = 3
REPS_SERIAL = 2

#: --quick (CI): tiny store, timing targets disabled. The horizon must
#: clear the jobs' deep-idle setup phase (~24% of duration) so policies
#: actually have execution-idle time to mitigate.
QUICK_N_DEVICES = 8
QUICK_HORIZON_S = 2700
QUICK_SHARD_S = 900


def _timed(fn, reps):
    """(min wall seconds over ``reps`` runs, last result)."""
    best = math.inf
    result = None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def bench_whatif_sweep() -> Bench:
    from repro.cluster import generate_cluster
    from repro.telemetry import TelemetryStore
    from repro.whatif import default_policy_grid, frontier_to_dict, run_sweep

    quick = common.QUICK
    n_devices = QUICK_N_DEVICES if quick else N_DEVICES
    horizon_s = QUICK_HORIZON_S if quick else HORIZON_S
    shard_s = QUICK_SHARD_S if quick else SHARD_S
    reps_b = 1 if quick else REPS_BATCHED
    reps_s = 1 if quick else REPS_SERIAL

    b = Bench("whatif_sweep")
    grid = default_policy_grid(dense=False)
    dense_grid = default_policy_grid()
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        generate_cluster(n_devices=n_devices, horizon_s=horizon_s, seed=SEED,
                         store=store, shard_s=shard_s)
        rows = store.total_rows

        t_serial, serial = _timed(
            lambda: run_sweep(store, grid, workers=1, min_job_duration_s=0.0,
                              batched=False), reps_s)
        t_batched, batched = _timed(
            lambda: run_sweep(store, grid, workers=1, min_job_duration_s=0.0,
                              batched=True), reps_b)
        t_pooled, pooled = _timed(
            lambda: run_sweep(store, grid, workers=2, min_job_duration_s=0.0,
                              batched=True), 1)
        t_dense, _ = _timed(
            lambda: run_sweep(store, dense_grid, workers=1,
                              min_job_duration_s=0.0, batched=True), reps_b)

    n_cfg = len(grid)
    b.add("rows", float(rows))
    b.add("n_configs", float(n_cfg), (48.0, 0.01))
    b.add("n_groups", float(serial.n_jobs))
    if not quick:
        b.add("groups_target_96", float(serial.n_jobs >= 96), (1.0, 0.01))
    b.add("configs_per_s_serial", n_cfg / t_serial)
    b.add("configs_per_s_batched", n_cfg / t_batched)
    b.add("configs_per_s_workers2", n_cfg / t_pooled)
    b.add("row_configs_per_s_batched", rows * n_cfg / t_batched)

    speedup = t_serial / t_batched
    b.add("batched_speedup_vs_serial", speedup)
    b.add("batched_speedup_target_5x", float(speedup >= 5.0),
          None if quick else (1.0, 0.01))

    b.add("batched_bit_identical",
          float(frontier_to_dict(batched) == frontier_to_dict(serial)),
          (1.0, 0.01))
    b.add("workers_bit_identical",
          float(frontier_to_dict(pooled) == frontier_to_dict(batched)),
          (1.0, 0.01))

    b.add("dense_grid_configs", float(len(dense_grid)), (200.0, 0.01))
    b.add("configs_per_s_batched_dense", len(dense_grid) / t_dense)

    noop = next(o for o in serial.outcomes if o.name == "noop")
    anchored = noop.energy_saved_j == 0.0 and noop.penalty_s == 0.0
    b.add("noop_anchors_frontier", float(anchored), (1.0, 0.01))
    b.add("pareto_set_size", float(len(serial.pareto_set())))
    best = max(serial.outcomes, key=lambda o: o.energy_saved_j)
    b.add("best_saved_fraction", best.saved_fraction)
    return b


def bench_whatif_search() -> Bench:
    """Closed-loop Pareto search vs the dense fixed-grid sweep.

    Same corpus as :func:`bench_whatif_sweep` (64 devices x 3 h, 691k
    rows). Acceptance (full mode): :func:`repro.whatif.search_frontier`
    over the composite-free default families reaches a Pareto front whose
    knee matches the dense 200-config sweep's — knee ``saved_fraction``
    within 0.01 absolute and knee ``penalty_s`` within 5% relative (the
    documented tolerance) — while evaluating <= 50% of the dense grid, and
    the search terminates by knee convergence, not budget exhaustion.
    ``--quick`` (CI) shrinks the corpus and keeps only the structural
    targets: on a tiny fleet the trade-off front is sparse enough that the
    two knee constructions may legitimately pick different elbows.
    """
    from repro.cluster import generate_cluster
    from repro.telemetry import TelemetryStore
    from repro.whatif import (PenaltyBudget, default_families, find_knee,
                              run_sweep, search_frontier)

    quick = common.QUICK
    n_devices = QUICK_N_DEVICES if quick else N_DEVICES
    horizon_s = QUICK_HORIZON_S if quick else HORIZON_S
    shard_s = QUICK_SHARD_S if quick else SHARD_S

    b = Bench("whatif_search")
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        generate_cluster(n_devices=n_devices, horizon_s=horizon_s, seed=SEED,
                         store=store, shard_s=shard_s)
        rows = store.total_rows

        t_dense, dense = _timed(
            lambda: run_sweep(store, min_job_duration_s=0.0), 1)
        t_search, res = _timed(
            lambda: search_frontier(store,
                                    families=default_families(
                                        composites=False),
                                    min_job_duration_s=0.0), 1)
        t_comp, res_comp = _timed(
            lambda: search_frontier(store,
                                    budget=PenaltyBudget(
                                        max_penalty_fraction=0.01),
                                    min_job_duration_s=0.0), 1)

    n_dense = len(dense.outcomes)
    b.add("rows", float(rows))
    b.add("dense_configs", float(n_dense), (200.0, 0.01))
    b.add("dense_sweep_s", t_dense)
    b.add("search_s", t_search)
    b.add("search_evals", float(res.n_evals))
    b.add("search_rounds", float(res.n_rounds))
    b.add("search_configs_per_s", res.n_evals / t_search)
    b.add("evals_fraction_of_dense", res.n_evals / n_dense)
    b.add("evals_le_half_dense", float(res.n_evals <= n_dense // 2),
          (1.0, 0.01))
    b.add("search_converged", float(res.converged), (1.0, 0.01))

    # configs evaluated to reach the final knee (first round it appeared)
    evals_to_knee = next(
        (r.n_evals_total for r in res.history
         if r.knee_params == res.knee.params), float(res.n_evals))
    b.add("evals_to_knee", float(evals_to_knee))

    knee_dense = find_knee(list(dense.outcomes))
    b.add("knee_saved_fraction_dense", knee_dense.saved_fraction)
    b.add("knee_saved_fraction_search", res.knee.saved_fraction)
    b.add("knee_penalty_s_dense", knee_dense.penalty_s)
    b.add("knee_penalty_s_search", res.knee.penalty_s)
    saved_ok = abs(res.knee.saved_fraction
                   - knee_dense.saved_fraction) <= 0.01
    pen_ok = (abs(res.knee.penalty_s - knee_dense.penalty_s)
              <= 0.05 * abs(knee_dense.penalty_s))
    b.add("knee_saved_match_0p01", float(saved_ok),
          None if quick else (1.0, 0.01))
    b.add("knee_penalty_match_5pct", float(pen_ok),
          None if quick else (1.0, 0.01))

    # composite-enabled search under an operator budget (1% of active time)
    b.add("composite_search_evals", float(res_comp.n_evals))
    n_comp_front = sum(1 for o in res_comp.frontier.pareto_set()
                       if o.params.get("policy") == "composite")
    b.add("composite_configs_on_front", float(n_comp_front))
    if res_comp.best is not None:
        b.add("budget_best_saved_fraction", res_comp.best.saved_fraction)
        b.add("budget_best_penalty_fraction", res_comp.best.penalty_fraction)
        b.add("budget_respected",
              float(res_comp.best.penalty_fraction <= 0.01), (1.0, 0.01))
    return b

"""What-if sweep throughput benchmark (counterfactual policy engine).

Generates the 96-group bench corpus (64 devices x 3 h, the fleet_bench
deployment) straight into a shard store, then sweeps the default 48-config
policy grid twice — serial and process-pool — and reports configs/s plus
the bit-identity check between the two.

Acceptance: the sweep streams shard-by-shard (peak memory ~ one shard),
``workers=2`` matches ``workers=1`` exactly, and the no-op config anchors
the frontier at zero saving / zero penalty.

Run:  PYTHONPATH=src python -m benchmarks.run --only whatif \
          [--json BENCH_whatif_sweep.json]
"""
from __future__ import annotations

import tempfile
import time

from benchmarks.common import Bench

#: same deployment as fleet_bench, emitted chunked: 96 analyzable groups
N_DEVICES = 64
HORIZON_S = 3 * 3600
SEED = 3
SHARD_S = 3600


def bench_whatif_sweep() -> Bench:
    from repro.cluster import generate_cluster
    from repro.telemetry import TelemetryStore
    from repro.whatif import default_policy_grid, frontier_to_dict, run_sweep

    b = Bench("whatif_sweep")
    grid = default_policy_grid()
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=N_DEVICES, horizon_s=HORIZON_S, seed=SEED,
                         store=store, shard_s=SHARD_S)
        rows = store.total_rows

        t0 = time.perf_counter()
        serial = run_sweep(store, grid, workers=1, min_job_duration_s=0.0)
        t_serial = time.perf_counter() - t0

        t0 = time.perf_counter()
        pooled = run_sweep(store, grid, workers=2, min_job_duration_s=0.0)
        t_pooled = time.perf_counter() - t0

    n_cfg = len(grid)
    b.add("rows", float(rows))
    b.add("n_configs", float(n_cfg), (48.0, 0.01))
    b.add("n_groups", float(serial.n_jobs))
    b.add("groups_target_96", float(serial.n_jobs >= 96), (1.0, 0.01))
    b.add("configs_per_s_serial", n_cfg / t_serial)
    b.add("configs_per_s_workers2", n_cfg / t_pooled)
    b.add("row_configs_per_s_serial", rows * n_cfg / t_serial)

    identical = frontier_to_dict(serial) == frontier_to_dict(pooled)
    b.add("workers_bit_identical", float(identical), (1.0, 0.01))

    noop = next(o for o in serial.outcomes if o.name == "noop")
    anchored = noop.energy_saved_j == 0.0 and noop.penalty_s == 0.0
    b.add("noop_anchors_frontier", float(anchored), (1.0, 0.01))
    b.add("pareto_set_size", float(len(serial.pareto_set())))
    best = max(serial.outcomes, key=lambda o: o.energy_saved_j)
    b.add("best_saved_fraction", best.saved_fraction)
    return b

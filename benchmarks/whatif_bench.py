"""What-if engine benchmarks: sweep throughput and closed-loop search.

``bench_whatif_sweep`` tracks the batched config-axis sweep and the
run-level-IR compact sweep ("compact once, replay many");
``bench_whatif_search`` tracks :func:`repro.whatif.search_frontier` against
the dense 200-config sweep (configs evaluated to reach the knee, configs/s,
knee-match tolerance), its IR fast path, and the warm-started re-search.
Both run in ``--quick`` CI mode on every PR, exercising the compact AND the
row-exact sweep paths.

Generates the 96-group bench corpus (64 devices x 3 h, the fleet_bench
deployment) straight into a shard store, then sweeps the legacy 48-config
policy grid three ways — per-policy reference (serial), config-axis batched
(serial), batched process-pool — plus the dense 200-config default grid
through the batched row path and through the run-level IR (build timed
separately; replays hit the in-memory/sidecar cache, which is the
steady-state of repeat sweeps).

Acceptance: the row-path sweeps stream shard-by-shard (peak memory ~ one
shard; the compact path instead holds the run tables + power column — see
the memory note in :mod:`repro.whatif.ir`), the
batched path is bit-identical to the per-policy reference AND to itself
under ``workers=2``, the compact path matches the batched path exactly on
time/count metrics and to <= 1e-9 relative on energies/penalties, the no-op
config anchors the frontier at zero saving / zero penalty, and on the
48-config x 691k-row corpus ``configs_per_s_batched / configs_per_s_serial
>= 5`` (PR 3 baseline) while the dense compact sweep reaches ``>= 3x`` the
dense batched throughput (``compact_speedup_target_3x``).
``configs_per_s_batched_dense`` carries a one-sided regression floor
(``mode="min"``) instead of an informational null target.

The jax replay backend (:mod:`repro.whatif.backend`) adds
``configs_per_s_compact_dense_jax`` (floored at the committed NumPy
compact baseline, ``mode="min"``, with the measuring device count in the
``devices`` column), a ``jax_matches_numpy_oracle`` exactness gate that
runs in ``--quick`` CI too, and — full mode only — a 10^4-config grid
replayed end-to-end (``configs_per_s_compact_jax_10k``).

The observability layer (:mod:`repro.obs`) adds its acceptance gates:
``obs_overhead_le_5pct`` (obs-on vs obs-off dense compact sweep, min-of-5),
``obs_bit_identical`` (frontier dicts equal either way),
``obs_prom_lint_errors`` (the exposition parses), ``obs_distinct_metrics``
(>= 15 ``repro_*`` families when the whole run is instrumented via
``run.py --obs``), the span-derived jax stage split
(``jax_kernel_stage_s`` / ``jax_assembly_stage_s`` — the vectorized
host-assembly evidence), and ``jax_mesh_matches_single_device`` when >1
device is visible (the CI lane forces 4).

Run:  PYTHONPATH=src python -m benchmarks.run --only whatif \
          [--json BENCH_whatif_sweep.json] [--quick]

``--quick`` (CI) shrinks the corpus and drops the timing targets; the
correctness targets (bit-identity, compact equivalence, frontier anchoring)
still validate.
"""
from __future__ import annotations

import math
import tempfile
import time

import numpy as np

from benchmarks import common
from benchmarks.common import Bench

#: same deployment as fleet_bench, emitted chunked: 96 analyzable groups.
#: One shard per device stream (npy_dir): shard reads cost one open per
#: column instead of a deflate pass, so the timings measure the replay
#: engines, not decompression.
N_DEVICES = 64
HORIZON_S = 3 * 3600
SEED = 3
SHARD_S = HORIZON_S

#: min-of-N timing — container timing noise is multi-second, so single-shot
#: ratios are unstable; the minimum is the standard de-noised estimate
REPS_BATCHED = 3
REPS_SERIAL = 2

#: min-of-N reps for the obs-overhead pair (off vs on): the <= 5% gate
#: compares two sub-second timings, so it needs more de-noising than the
#: throughput rows
REPS_OBS = 5

#: one-sided throughput floor for the dense batched row path (configs/s on
#: the full corpus; committed baseline ~29, floor at ~1/3 to absorb
#: container noise without letting a real regression through)
DENSE_BATCHED_FLOOR = 10.0

#: --quick (CI): tiny store, timing targets disabled. The horizon must
#: clear the jobs' deep-idle setup phase (~24% of duration) so policies
#: actually have execution-idle time to mitigate.
QUICK_N_DEVICES = 8
QUICK_HORIZON_S = 2700
QUICK_SHARD_S = 900

#: one-sided floor for the jax-backend dense compact sweep: the committed
#: NumPy ``configs_per_s_compact_dense`` baseline. The acceptance target
#: is >= 5x this; flooring at 1x lets CI absorb container noise while
#: still catching a backend that regresses below the path it replaces.
JAX_DENSE_FLOOR = 500.9266642388074


def _timed(fn, reps):
    """(min wall seconds over ``reps`` runs, last result)."""
    best = math.inf
    result = None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def _frontiers_equivalent(ref, cmp_, rtol=1e-9, atol=1e-9) -> bool:
    """The compact-path contract: every time/count metric bit-identical to
    the row path, every energy/penalty metric within ``rtol`` relative."""
    if len(ref.outcomes) != len(cmp_.outcomes) or ref.n_rows != cmp_.n_rows:
        return False
    exact = ("name", "params", "n_jobs", "wake_events", "downscale_events",
             "throttled_time_s", "pareto")
    close = ("baseline_energy_j", "counterfactual_energy_j", "penalty_s",
             "saved_fraction", "penalty_fraction")
    for a, b in zip(ref.outcomes, cmp_.outcomes):
        if any(getattr(a, f) != getattr(b, f) for f in exact):
            return False
        if not all(np.isclose(getattr(a, f), getattr(b, f),
                              rtol=rtol, atol=atol) for f in close):
            return False
        if not np.allclose(a.per_job_saved_fraction,
                           b.per_job_saved_fraction, rtol=rtol, atol=atol):
            return False
        if not np.allclose(a.per_job_penalty_s, b.per_job_penalty_s,
                           rtol=rtol, atol=atol):
            return False
    return True


def _grid_10k():
    """A dense per-platform 10^4-config grid (the arXiv 2004.08177-style
    deadline-sweep scale): 1 no-op + 2048 Algorithm-1 downscale (32 X x
    32 Y x 2 modes) + 50 consolidation pools + 7901 power caps."""
    from repro.core.controller import ControllerConfig, DownscaleMode
    from repro.core.imbalance import PoolConfig, PoolPolicy
    from repro.whatif import (DownscalePolicy, NoOpPolicy, ParkingPolicy,
                              PowerCapPolicy)
    grid = [NoOpPolicy()]
    for x in np.linspace(0.5, 16.0, 32):
        for y in np.linspace(1.0, 12.0, 32):
            for mode in (DownscaleMode.SM_ONLY, DownscaleMode.SM_AND_MEM):
                grid.append(DownscalePolicy(config=ControllerConfig(
                    threshold_x_s=round(float(x), 4),
                    cooldown_y_s=round(float(y), 4), mode=mode)))
    for n_devices in (4, 8):
        for k in range(1, n_devices):
            for resume_s in (2.0, 5.0, 10.0, 30.0, 60.0):
                grid.append(ParkingPolicy(
                    pool=PoolConfig(n_devices=n_devices,
                                    policy=PoolPolicy.CONSOLIDATED,
                                    n_active=k),
                    resume_latency_s=resume_s))
    for frac in np.linspace(0.2, 0.99, 10_000 - len(grid)):
        grid.append(PowerCapPolicy(cap_fraction=round(float(frac), 6)))
    return grid


def bench_whatif_sweep() -> Bench:
    from repro.cluster import generate_cluster
    from repro.telemetry import TelemetryStore
    from repro.whatif import (default_policy_grid, frontier_to_dict, get_ir,
                              ir_config_for, run_sweep)

    quick = common.QUICK
    n_devices = QUICK_N_DEVICES if quick else N_DEVICES
    horizon_s = QUICK_HORIZON_S if quick else HORIZON_S
    shard_s = QUICK_SHARD_S if quick else SHARD_S
    reps_b = 1 if quick else REPS_BATCHED
    reps_s = 1 if quick else REPS_SERIAL

    b = Bench("whatif_sweep")
    grid = default_policy_grid(dense=False)
    dense_grid = default_policy_grid()
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        generate_cluster(n_devices=n_devices, horizon_s=horizon_s, seed=SEED,
                         store=store, shard_s=shard_s)
        rows = store.total_rows

        t_serial, serial = _timed(
            lambda: run_sweep(store, grid, workers=1, min_job_duration_s=0.0,
                              batched=False), reps_s)
        t_batched, batched = _timed(
            lambda: run_sweep(store, grid, workers=1, min_job_duration_s=0.0,
                              batched=True, compact=False), reps_b)
        t_pooled, pooled = _timed(
            lambda: run_sweep(store, grid, workers=2, min_job_duration_s=0.0,
                              batched=True, compact=False), 1)
        t_dense, dense_row = _timed(
            lambda: run_sweep(store, dense_grid, workers=1,
                              min_job_duration_s=0.0, batched=True,
                              compact=False), reps_b)

        # run-level IR: one O(rows) build (timed cold), then compact sweeps
        # replay O(runs) per config against the cached IR — the steady
        # state of "compact once, replay many"
        t_ir_build, ir = _timed(
            lambda: get_ir(store, ir_config_for(dense_grid)), 1)
        t_compact, compact = _timed(
            lambda: run_sweep(store, dense_grid, workers=1,
                              min_job_duration_s=0.0, compact=True), reps_b)

        # jax backend: warm-up pays compilation + pack, then the timed
        # replays measure the steady state — same protocol as the compact
        # rows above (the IR cache is already warm)
        try:
            import jax as _jax

            import repro.whatif.backend  # noqa: F401
            n_jax_devices = len(_jax.devices())
        except Exception:
            n_jax_devices = 0
        if n_jax_devices:
            def jax_sweep(pols):
                return run_sweep(store, pols, workers=1,
                                 min_job_duration_s=0.0, backend="jax")
            jax_sweep(dense_grid)
            t_jax, jax_front = _timed(lambda: jax_sweep(dense_grid), reps_b)
            if not quick:
                grid_10k = _grid_10k()
                jax_sweep(grid_10k)
                t_10k, front_10k = _timed(lambda: jax_sweep(grid_10k), 1)

        # ---- observability contract: overhead, bit-identity, exposition.
        # Save/restore the enabled flag (run.py --obs may have turned obs
        # on globally) and never reset the registry — it may hold the
        # whole run's metrics.
        import repro.obs as obs

        def compact_sweep():
            return run_sweep(store, dense_grid, workers=1,
                             min_job_duration_s=0.0, compact=True)

        reps_obs = 1 if quick else REPS_OBS
        prev_obs = obs.enabled()
        obs.disable()
        t_obs_off, front_obs_off = _timed(compact_sweep, reps_obs)
        obs.enable()
        t_obs_on, front_obs_on = _timed(compact_sweep, reps_obs)

        # per-stage split of the jax replay (kernels vs host assembly),
        # from the spans of one obs-on sweep — the vectorized-assembly
        # before/after evidence rides in the bench JSON
        jax_kernel_s = jax_assembly_s = 0.0
        if n_jax_devices:
            n0 = len(obs.spans())
            jax_sweep(grid_10k if not quick else dense_grid)
            totals = obs.stage_totals(obs.spans()[n0:])
            jax_kernel_s = totals.get("backend.kernels",
                                      {}).get("total_s", 0.0)
            jax_assembly_s = totals.get("backend.assembly",
                                        {}).get("total_s", 0.0)
            # config-mesh lane: shard the config axis over every visible
            # device; must match the single-device sweep under the oracle
            # contract (counts exact, energies <= 1e-9) and record the
            # device count in the gauge CI asserts on
            mesh_matches = 0.0
            t_mesh = 0.0
            if n_jax_devices > 1:
                from repro.whatif.backend import config_mesh

                # shared IR handle: the same RunIR every consumer in this
                # bench replays (analyze/sweep/search all accept ir=), so
                # the mesh row times the sharded kernels, not acquisition
                def mesh_sweep():
                    return run_sweep(store, dense_grid, workers=1,
                                     min_job_duration_s=0.0, backend="jax",
                                     dist=config_mesh(), ir=ir)
                mesh_front = mesh_sweep()       # warm-up: compile + pack
                t_mesh, mesh_front = _timed(mesh_sweep, reps_b)
                mesh_matches = float(
                    _frontiers_equivalent(jax_front, mesh_front))

        obs_prom_errors = len(obs.lint_exposition(obs.render_prometheus()))
        n_obs_metrics = len([n for n in obs.REGISTRY.names()
                             if n.startswith("repro_")])
        if not prev_obs:
            obs.disable()

    n_cfg = len(grid)
    b.add("rows", float(rows))
    b.add("n_configs", float(n_cfg), (48.0, 0.01))
    b.add("n_groups", float(serial.n_jobs))
    if not quick:
        b.add("groups_target_96", float(serial.n_jobs >= 96), (1.0, 0.01))
    b.add("configs_per_s_serial", n_cfg / t_serial, seconds=t_serial)
    b.add("configs_per_s_batched", n_cfg / t_batched, seconds=t_batched)
    b.add("configs_per_s_workers2", n_cfg / t_pooled, seconds=t_pooled)
    b.add("row_configs_per_s_batched", rows * n_cfg / t_batched,
          seconds=t_batched)

    speedup = t_serial / t_batched
    b.add("batched_speedup_vs_serial", speedup)
    b.add("batched_speedup_target_5x", float(speedup >= 5.0),
          None if quick else (1.0, 0.01))

    b.add("batched_bit_identical",
          float(frontier_to_dict(batched) == frontier_to_dict(serial)),
          (1.0, 0.01))
    b.add("workers_bit_identical",
          float(frontier_to_dict(pooled) == frontier_to_dict(batched)),
          (1.0, 0.01))

    b.add("dense_grid_configs", float(len(dense_grid)), (200.0, 0.01))
    b.add("configs_per_s_batched_dense", len(dense_grid) / t_dense,
          None if quick else (DENSE_BATCHED_FLOOR, 0.0), mode="min",
          seconds=t_dense)

    # ---- run-level IR (compact) rows ----
    b.add("ir_build_s", t_ir_build, seconds=t_ir_build)
    b.add("ir_runs", float(ir.n_runs))
    b.add("compaction_ratio", ir.compaction_ratio)
    b.add("configs_per_s_compact_dense", len(dense_grid) / t_compact,
          seconds=t_compact)
    compact_speedup = t_dense / t_compact
    b.add("compact_speedup_vs_batched_dense", compact_speedup)
    b.add("compact_speedup_target_3x", float(compact_speedup >= 3.0),
          None if quick else (1.0, 0.01))
    b.add("compact_matches_reference",
          float(_frontiers_equivalent(dense_row, compact)), (1.0, 0.01))
    b.add("compact_reports_runs", float(compact.n_runs == ir.n_runs),
          (1.0, 0.01))

    # ---- jax backend (jit'd run-level evaluators) rows ----
    b.add("jax_devices", float(n_jax_devices))
    if n_jax_devices:
        b.add("configs_per_s_compact_dense_jax", len(dense_grid) / t_jax,
              None if quick else (JAX_DENSE_FLOOR, 0.0), mode="min",
              seconds=t_jax, devices=n_jax_devices)
        jax_speedup = t_compact / t_jax
        b.add("jax_speedup_vs_compact_dense", jax_speedup,
              devices=n_jax_devices)
        b.add("jax_speedup_target_5x", float(jax_speedup >= 5.0),
              None if quick else (1.0, 0.01))
        # the oracle gate runs in --quick too: exactness is corpus-size
        # independent, so CI always checks it even with timings disabled
        b.add("jax_matches_numpy_oracle",
              float(_frontiers_equivalent(compact, jax_front)), (1.0, 0.01))
        if not quick:
            b.add("grid10k_configs", float(len(grid_10k)), (10000.0, 0.01))
            b.add("configs_per_s_compact_jax_10k", len(grid_10k) / t_10k,
                  seconds=t_10k, devices=n_jax_devices)
            b.add("grid10k_pareto_set_size",
                  float(len(front_10k.pareto_set())))

    # ---- observability rows (tentpole acceptance gates) ----
    obs_overhead = t_obs_on / t_obs_off - 1.0
    b.add("obs_overhead_frac", obs_overhead,
          seconds=t_obs_on)
    b.add("obs_overhead_le_5pct", float(obs_overhead <= 0.05),
          None if quick else (1.0, 0.01))
    b.add("obs_bit_identical",
          float(frontier_to_dict(front_obs_on)
                == frontier_to_dict(front_obs_off)), (1.0, 0.01))
    b.add("obs_prom_lint_errors", float(obs_prom_errors), (0.0, 0.5))
    # the >= 15 gate needs the whole run instrumented (run.py --obs); a
    # bare bench only enables obs for the overhead window above, so the
    # count is informational there
    b.add("obs_distinct_metrics", float(n_obs_metrics),
          (15.0, 0.0) if prev_obs else None, mode="min")
    if n_jax_devices:
        b.add("jax_kernel_stage_s", jax_kernel_s, seconds=jax_kernel_s)
        b.add("jax_assembly_stage_s", jax_assembly_s,
              seconds=jax_assembly_s)
        if jax_kernel_s + jax_assembly_s > 0:
            b.add("jax_assembly_fraction",
                  jax_assembly_s / (jax_kernel_s + jax_assembly_s))
        if n_jax_devices > 1:
            b.add("jax_mesh_matches_single_device", mesh_matches,
                  (1.0, 0.01), devices=n_jax_devices)
            # multi-device timing over the shared IR handle: informational
            # (no target) — host-count CI runners make mesh timings too
            # noisy to gate, but the row closes the PR 7 follow-on and the
            # committed baseline records the device count for
            # like-for-like comparison
            b.add("configs_per_s_compact_dense_jax_mesh",
                  len(dense_grid) / t_mesh, seconds=t_mesh,
                  devices=n_jax_devices)

    noop = next(o for o in serial.outcomes if o.name == "noop")
    anchored = noop.energy_saved_j == 0.0 and noop.penalty_s == 0.0
    b.add("noop_anchors_frontier", float(anchored), (1.0, 0.01))
    b.add("pareto_set_size", float(len(serial.pareto_set())))
    best = max(serial.outcomes, key=lambda o: o.energy_saved_j)
    b.add("best_saved_fraction", best.saved_fraction)
    return b


def bench_whatif_search() -> Bench:
    """Closed-loop Pareto search vs the dense fixed-grid sweep.

    Same corpus as :func:`bench_whatif_sweep` (64 devices x 3 h, 691k
    rows). Acceptance (full mode): :func:`repro.whatif.search_frontier`
    over the composite-free default families reaches a Pareto front whose
    knee matches the dense 200-config sweep's — knee ``saved_fraction``
    within 0.01 absolute and knee ``penalty_s`` within 5% relative (the
    documented tolerance) — while evaluating <= 50% of the dense grid, and
    the search terminates by knee convergence, not budget exhaustion. The
    compact (run-IR) search must cut wall-clock >= 2x against the row-path
    search at an unchanged knee, and a warm start from the cold search's
    frontier must reach the knee in no more evaluations than the cold
    start. ``--quick`` (CI) shrinks the corpus and keeps only the
    structural targets: on a tiny fleet the trade-off front is sparse
    enough that the two knee constructions may legitimately pick different
    elbows.
    """
    from repro.cluster import generate_cluster
    from repro.telemetry import TelemetryStore
    from repro.whatif import (PenaltyBudget, default_families,
                              default_policy_grid, find_knee, get_ir,
                              ir_config_for, run_sweep, search_frontier)

    quick = common.QUICK
    n_devices = QUICK_N_DEVICES if quick else N_DEVICES
    horizon_s = QUICK_HORIZON_S if quick else HORIZON_S
    shard_s = QUICK_SHARD_S if quick else SHARD_S

    def evals_to_knee(res) -> float:
        """Configs evaluated up to the round the final knee first appeared."""
        return float(next(
            (r.n_evals_total for r in res.history
             if r.knee_params == res.knee.params), res.n_evals))

    b = Bench("whatif_search")
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        generate_cluster(n_devices=n_devices, horizon_s=horizon_s, seed=SEED,
                         store=store, shard_s=shard_s)
        rows = store.total_rows

        # pay the IR build explicitly (the default grid and the search
        # families share the default thresholds, hence one IR) so every
        # timed stage below measures warm compact replay, independent of
        # stage order
        t_ir_build, _ = _timed(
            lambda: get_ir(store, ir_config_for(default_policy_grid())), 1)
        t_dense, dense = _timed(
            lambda: run_sweep(store, min_job_duration_s=0.0), 1)
        t_row_search, res_row = _timed(
            lambda: search_frontier(store,
                                    families=default_families(
                                        composites=False),
                                    min_job_duration_s=0.0,
                                    compact=False), 1)
        t_search, res = _timed(
            lambda: search_frontier(store,
                                    families=default_families(
                                        composites=False),
                                    min_job_duration_s=0.0), 1)
        t_comp, res_comp = _timed(
            lambda: search_frontier(store,
                                    budget=PenaltyBudget(
                                        max_penalty_fraction=0.01),
                                    min_job_duration_s=0.0), 1)
        t_warm, res_warm = _timed(
            lambda: search_frontier(store,
                                    families=default_families(
                                        composites=False),
                                    min_job_duration_s=0.0,
                                    init_frontier=res.frontier), 1)

    n_dense = len(dense.outcomes)
    b.add("rows", float(rows))
    b.add("dense_configs", float(n_dense), (200.0, 0.01))
    b.add("ir_build_s", t_ir_build, seconds=t_ir_build)
    b.add("dense_sweep_s", t_dense, seconds=t_dense)
    b.add("search_s", t_search, seconds=t_search)
    b.add("search_evals", float(res.n_evals))
    b.add("search_rounds", float(res.n_rounds))
    b.add("search_configs_per_s", res.n_evals / t_search, seconds=t_search)
    b.add("evals_fraction_of_dense", res.n_evals / n_dense)
    b.add("evals_le_half_dense", float(res.n_evals <= n_dense // 2),
          (1.0, 0.01))
    b.add("search_converged", float(res.converged), (1.0, 0.01))

    # compact (run-IR) search: build once, replay every round against runs
    b.add("search_row_path_s", t_row_search, seconds=t_row_search)
    search_speedup = t_row_search / t_search
    b.add("search_speedup_compact", search_speedup)
    b.add("search_speedup_target_2x", float(search_speedup >= 2.0),
          None if quick else (1.0, 0.01))
    b.add("search_knee_unchanged_compact",
          float(res.knee.params == res_row.knee.params
                and res.n_evals == res_row.n_evals), (1.0, 0.01))

    b.add("evals_to_knee", evals_to_knee(res))

    # warm start from the cold search's frontier (ROADMAP: week-over-week
    # re-search starts at last week's knee)
    b.add("warm_evals_to_knee", evals_to_knee(res_warm), seconds=t_warm)
    b.add("warm_start_no_more_evals_to_knee",
          float(evals_to_knee(res_warm) <= evals_to_knee(res)),
          None if quick else (1.0, 0.01))

    knee_dense = find_knee(list(dense.outcomes))
    b.add("knee_saved_fraction_dense", knee_dense.saved_fraction)
    b.add("knee_saved_fraction_search", res.knee.saved_fraction)
    b.add("knee_penalty_s_dense", knee_dense.penalty_s)
    b.add("knee_penalty_s_search", res.knee.penalty_s)
    saved_ok = abs(res.knee.saved_fraction
                   - knee_dense.saved_fraction) <= 0.01
    pen_ok = (abs(res.knee.penalty_s - knee_dense.penalty_s)
              <= 0.05 * abs(knee_dense.penalty_s))
    b.add("knee_saved_match_0p01", float(saved_ok),
          None if quick else (1.0, 0.01))
    b.add("knee_penalty_match_5pct", float(pen_ok),
          None if quick else (1.0, 0.01))

    # composite-enabled search under an operator budget (1% of active time)
    b.add("composite_search_evals", float(res_comp.n_evals), seconds=t_comp)
    n_comp_front = sum(1 for o in res_comp.frontier.pareto_set()
                       if o.params.get("policy") == "composite")
    b.add("composite_configs_on_front", float(n_comp_front))
    if res_comp.best is not None:
        b.add("budget_best_saved_fraction", res_comp.best.saved_fraction)
        b.add("budget_best_penalty_fraction", res_comp.best.penalty_fraction)
        b.add("budget_respected",
              float(res_comp.best.penalty_fraction <= 0.01), (1.0, 0.01))
    return b

"""Live-controller benchmark: recommendation staleness at fleet scale.

The live loop's figure of merit is **staleness**: seconds from a telemetry
shard landing in the store to the refreshed knee being published. The
bench drives :class:`repro.live.LiveController` over a 10^4-stream
synthetic fleet (:class:`repro.live.SyntheticProducer` — one shard per
60 s window, constant-state streams, so the run-level IR compacts each
window to ~1 run/stream) and reports:

* ``staleness_s_first`` — the cold tick (IR build + cold search);
* ``staleness_s_steady_mean`` / ``_max`` — steady state (incremental IR
  extend + warm-started search), the number an operator's SLO is about;
* ``streams_per_s_steady`` — fleet streams served per second of steady
  staleness, with a committed one-sided regression floor (``mode="min"``,
  full mode only: quick CI shrinks the corpus so timing floors are off);
* ``coalesced_backlog_single_tick`` — backpressure: a 3-window backlog is
  folded by ONE tick (one extend + one search), coalesced count == 2;
* ``resume_bit_identical`` — the crash-safety acceptance gate in bench
  form: a controller restarted from its checkpoint after every tick ends
  with a frontier byte-identical to the uninterrupted controller's
  (1.0 == identical; gated exactly, quick mode included).

Run:  PYTHONPATH=src python -m benchmarks.run --only live \
          [--json BENCH_live_controller.json] [--quick]
"""
from __future__ import annotations

import json
import pathlib
import tempfile

from benchmarks import common
from benchmarks.common import Bench

#: committed steady-state throughput floor (streams / second of staleness)
#: from the 10^4-stream run on the baseline box (~324 streams/s, ~31 s
#: steady staleness), set ~1/3 of measured so only a real regression (not
#: scheduler jitter) trips it
STREAMS_PER_S_FLOOR = 100.0


def _fast_search_kwargs():
    from repro.whatif.search import default_families
    fams = [f for f in default_families(composites=False)
            if f.name == "downscale"]
    return {"max_rounds": 1, "families": fams}


def _fkey(frontier) -> str:
    from repro.whatif import frontier_to_dict
    return json.dumps(frontier_to_dict(frontier), sort_keys=True)


def bench_live_controller() -> Bench:
    from repro.live import LiveConfig, LiveController, SyntheticProducer
    from repro.telemetry import TelemetryStore

    b = Bench("live_controller")
    n_streams = 200 if common.QUICK else 10_000
    n_windows = 3
    cfg = LiveConfig(max_evals=24, search_kwargs=_fast_search_kwargs())

    with tempfile.TemporaryDirectory() as d:
        root = pathlib.Path(d)

        # ---- staleness: one shard lands, how old is the fresh knee? ---- #
        store = TelemetryStore(root / "store")
        prod = SyntheticProducer(store, n_streams=n_streams, window_s=60,
                                 dt_s=5.0)
        ctrl = LiveController(store, root / "ckpt.json", cfg,
                              publish_path=root / "knee.json")
        staleness = []
        for _ in range(n_windows):
            prod.step()
            r = ctrl.tick()
            assert r.result == "refreshed", r.error
            staleness.append(r.staleness_s)
        steady = staleness[1:]
        mean_steady = sum(steady) / len(steady)
        b.add("staleness_s_first", staleness[0], seconds=staleness[0])
        b.add("staleness_s_steady_mean", mean_steady, seconds=mean_steady)
        b.add("staleness_s_steady_max", max(steady), seconds=max(steady))
        b.add("streams_per_s_steady", n_streams / mean_steady,
              target=None if common.QUICK else (STREAMS_PER_S_FLOOR, 0.0),
              mode="min", seconds=mean_steady)

        # ---- backpressure: a backlog coalesces into ONE tick ---------- #
        for _ in range(3):
            prod.step()
        r = ctrl.tick()
        assert r.result == "refreshed", r.error
        b.add("coalesced_backlog_single_tick",
              float(r.n_new_shards == 3 and r.coalesced == 2), (1.0, 0.0))

        # ---- crash safety: restart-per-tick == uninterrupted ---------- #
        tiny = dict(n_streams=16, window_s=30, dt_s=5.0, seed=3)
        base_store = TelemetryStore(root / "base")
        base_prod = SyntheticProducer(base_store, **tiny)
        base = LiveController(base_store, root / "base_ckpt.json", cfg)
        res_store = TelemetryStore(root / "res")
        res_prod = SyntheticProducer(res_store, **tiny)
        for _ in range(n_windows):
            base_prod.step()
            assert base.tick().result == "refreshed"
            res_prod.step()
            # a fresh controller per tick IS the restart-from-checkpoint
            resumed = LiveController(res_store, root / "res_ckpt.json", cfg)
            assert resumed.tick().result == "refreshed"
        b.add("resume_bit_identical",
              float(_fkey(base.frontier) == _fkey(resumed.frontier)),
              (1.0, 0.0))
    return b

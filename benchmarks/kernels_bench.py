"""Kernel-suite benchmark: the run-replay cap-bucket scan.

The only Pallas kernel on the telemetry hot path is the PowerCap
cap-bucket scan (:mod:`repro.kernels.run_replay`); this bench validates
the dispatcher stack on whatever backend CI has — the interpret-mode
Pallas kernel and the jnp reference against a NumPy ``searchsorted``
oracle — and records the reference path's throughput (the path the jax
replay backend actually uses off-TPU). ``--quick`` keeps the correctness
gates and shrinks shapes; there are no timing targets in either mode
(the scan is memory-bound and container noise swamps it).

Run:  PYTHONPATH=src python -m benchmarks.run --only kernels [--quick]
"""
from __future__ import annotations

import math
import time

import numpy as np

from benchmarks import common
from benchmarks.common import Bench


def _np_counts(sorted_p, caps):
    sp = np.asarray(sorted_p)
    cv = np.asarray(caps)
    return np.stack([
        sp.shape[1] - np.searchsorted(sp[r], cv[r], side="right")
        for r in range(sp.shape[0])]).astype(np.int32)


def bench_kernels() -> Bench:
    import jax
    import jax.numpy as jnp

    from repro.kernels import run_replay as rr

    quick = common.QUICK
    rows, n, c = (32, 512, 64) if quick else (256, 4096, 1024)

    b = Bench("kernels")
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    sp = jnp.sort(jax.random.normal(k1, (rows, n)) * 100.0, axis=1)
    caps = jax.random.normal(k2, (rows, c)) * 100.0
    expect = _np_counts(sp, caps)

    interp = np.asarray(rr.cap_bucket_scan(sp, caps,
                                           interpret=rr.default_interpret()))
    refv = np.asarray(rr.cap_bucket_scan_reference(sp, caps))
    disp = np.asarray(rr.cap_bucket_counts(sp, caps))

    b.add("cap_scan_rows_x_configs", float(rows * c))
    b.add("cap_scan_matches_oracle",
          float(np.array_equal(interp, expect)), (1.0, 0.01))
    b.add("cap_scan_reference_matches_oracle",
          float(np.array_equal(refv, expect)), (1.0, 0.01))
    b.add("cap_scan_dispatcher_matches_oracle",
          float(np.array_equal(disp, expect)), (1.0, 0.01))
    b.add("cap_scan_default_interpret", float(rr.default_interpret()))

    fn = jax.jit(rr.cap_bucket_counts)
    fn(sp, caps).block_until_ready()
    best = math.inf
    for _ in range(1 if quick else 5):
        t0 = time.perf_counter()
        fn(sp, caps).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    b.add("cap_scan_mlookups_per_s", rows * c / best / 1e6, seconds=best,
          devices=1)
    return b

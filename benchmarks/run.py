"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus target/ok columns when a
paper number exists) and a per-bench validation summary. The §Roofline bench
reads the dry-run reports if present (reports/dryrun/*.json).

Run:  PYTHONPATH=src python -m benchmarks.run [--only fig5,fig10]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def bench_roofline():
    """Summarize dry-run roofline cells (§Roofline) if reports exist."""
    from benchmarks.common import Bench
    b = Bench("roofline")
    report_dir = pathlib.Path("reports/dryrun")
    if not report_dir.exists():
        return b
    cells = sorted(report_dir.glob("*.json"))
    n_ok = n_skip = n_err = 0
    for path in cells:
        r = json.loads(path.read_text())
        if r["status"] == "ok":
            n_ok += 1
            rf = r["roofline"]
            cell = f"{r['arch']}_{r['shape']}_{r['mesh']}"
            b.add(f"{cell}_bound_s", rf.get("roofline_bound_s", 0.0))
            b.add(f"{cell}_useful_fraction", rf["useful_fraction"])
        elif r["status"] == "skipped":
            n_skip += 1
        else:
            n_err += 1
    b.add("cells_ok", float(n_ok))
    b.add("cells_skipped", float(n_skip))
    b.add("cells_error", float(n_err), (0.0, 0.5))
    return b


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench-name substrings")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the result rows as JSON (committed "
                         "baselines, e.g. BENCH_fleet_analyze.json)")
    ap.add_argument("--obs", default=None, metavar="DIR",
                    help="enable the repro.obs observability layer for the "
                         "run and write DIR/metrics.prom (Prometheus text "
                         "exposition) + DIR/spans.jsonl (span trace); the "
                         "per-stage breakdown is attached to --json output "
                         "and a stage tree is printed to stderr")
    ap.add_argument("--quick", action="store_true",
                    help="CI mode for the throughput benches (fleet, "
                         "whatif, kernels): tiny corpora, timing targets "
                         "disabled, correctness targets kept, jax pinned "
                         "to CPU. Paper-figure benches ignore it (their "
                         "targets are paper numbers that only hold at full "
                         "corpus size) — combine with "
                         "--only fleet,whatif,kernels for a fast CI pass")
    args = ap.parse_args()

    if args.quick:
        import os

        from benchmarks import common
        common.QUICK = True
        # hermetic CI: pin jax to the host CPU before anything imports it,
        # so the quick jax-backend rows behave identically on machines
        # with and without accelerators
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import repro.obs as obs
    if args.obs:
        obs.enable()
        # zero-register the degradation ladder, the incremental-IR and the
        # live-controller families so a fault-free / append-free / tickless
        # exposition still carries them (CI lints on presence)
        obs.init_degradation_metrics()
        obs.init_ir_append_metrics()
        obs.init_live_metrics()

    from benchmarks.fleet_bench import bench_fleet_analyze
    from benchmarks.kernels_bench import bench_kernels
    from benchmarks.live_bench import bench_live_controller
    from benchmarks.paper_benches import ALL_BENCHES
    from benchmarks.whatif_bench import bench_whatif_search, bench_whatif_sweep
    benches = list(ALL_BENCHES) + [bench_roofline, bench_fleet_analyze,
                                   bench_whatif_sweep, bench_whatif_search,
                                   bench_live_controller, bench_kernels]
    if args.only:
        keys = args.only.split(",")
        benches = [fn for fn in benches
                   if any(k in fn.__name__ for k in keys)]

    print("name,us_per_call,derived,devices,target,ok")
    summaries = []
    all_rows = []
    all_ok = True
    for fn in benches:
        # no-op span when --obs is absent (obs stays disabled)
        with obs.span("bench." + fn.__name__):
            bench = fn()
        for row in bench.rows:
            target = "" if row.target is None else f"{row.target:.6g}"
            ok = "" if row.ok is None else str(row.ok)
            print(f"{row.csv()},{target},{ok}", flush=True)
            all_rows.append({"name": row.name, "us_per_call": row.us_per_call,
                             "derived": row.derived, "devices": row.devices,
                             "target": row.target, "ok": row.ok})
        summaries.append(bench.summary())
        if any(r.ok is False for r in bench.rows):
            all_ok = False

    payload = {"rows": all_rows, "all_ok": all_ok}
    if args.obs:
        obs_dir = pathlib.Path(args.obs)
        obs.write_textfile(obs_dir / "metrics.prom")
        obs.dump_spans_jsonl(obs_dir / "spans.jsonl")
        payload["stages"] = obs.stage_breakdown()
        print("\n== stage tree ==", file=sys.stderr)
        print(obs.stage_report(min_dur_s=1e-3), file=sys.stderr)
        # degradation ladder: quarantines / retries / fallbacks / coverage —
        # all zero (or 1.0 coverage) on a healthy run, by construction
        fam_names = {name for name, _, _ in obs.DEGRADATION_FAMILIES}
        print("\n== degradation ladder ==", file=sys.stderr)
        for line in obs.render_prometheus().splitlines():
            if line.startswith("#"):
                continue
            sample_name = line.split("{")[0].split(" ")[0]
            if sample_name in fam_names:
                print("  " + line, file=sys.stderr)

    if args.json:
        pathlib.Path(args.json).write_text(
            json.dumps(payload, indent=1) + "\n")

    print("\n== validation summary ==", file=sys.stderr)
    for s in summaries:
        print("  " + s, file=sys.stderr)
    print(f"overall: {'ALL TARGETS HIT' if all_ok else 'SOME TARGETS MISSED'}",
          file=sys.stderr)


if __name__ == "__main__":
    main()

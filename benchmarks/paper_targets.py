"""Every number the paper reports, as validation targets with tolerances.

Tolerances are absolute for fractions (simulation + sampling noise) and
relative for powers/latencies.
"""

# Fig 3b — job-attributed time/energy split
FIG3 = {
    "deep_idle_time": (0.24, 0.06),
    "deep_idle_energy": (0.07, 0.04),
    "exec_idle_time": (0.15, 0.05),
    "exec_idle_energy": (0.10, 0.04),
    "active_time": (0.61, 0.07),
    "active_energy": (0.83, 0.06),
}

# §3 headline (11,791 long jobs)
HEADLINE = {
    "in_exec_time_fraction": (0.197, 0.04),   # §4.3 baseline 19.17–19.7%
    "in_exec_energy_fraction": (0.107, 0.03),
}

# Fig 5 (left) — academic classes: (time_frac, energy_frac)
FIG5_ACADEMIC = {
    "serving": ((0.61, 0.08), (0.48, 0.08)),
    "training": ((0.13, 0.06), (0.065, 0.04)),
    "batch_inference": ((0.12, 0.06), (0.07, 0.04)),
    "other": ((0.05, 0.05), (0.03, 0.03)),
}

# Fig 5 (right) — industry trace replays: (time_frac, energy_frac)
FIG5_TRACES = {
    "azure_chat": ((0.29, 0.06), (0.17, 0.06)),
    "azure_code": ((0.76, 0.05), (0.65, 0.06)),
    "burstgpt_chat": ((0.72, 0.06), (0.52, 0.07)),
    "qwen_reason": ((0.18, 0.06), (0.08, 0.04)),
    "qwen_chat": ((0.14, 0.05), (0.07, 0.04)),
}

# Fig 6 — per-GPU inter-request medians: 4–8 s; heavy tails for
# burstgpt_chat / qwen_reason (p90 > 10 s)
FIG6_MEDIAN_RANGE = (3.0, 14.0)
FIG6_HEAVY_TAIL_TRACES = ("burstgpt_chat", "qwen_reason")

# Fig 7 — per-job CDF tail shares
FIG7 = {
    "time>0.1": (0.334, 0.08), "time>0.2": (0.252, 0.07),
    "time>0.5": (0.154, 0.06),
    "energy>0.1": (0.271, 0.07), "energy>0.2": (0.212, 0.06),
    "energy>0.5": (0.128, 0.05),
}

# Fig 8 — interval duration percentiles (s)
FIG8 = {"p50": (9.0, 3.0), "p90": (44.0, 15.0), "p99": (836.0, 400.0)}

# Table 2 — sensitivity (time_frac, energy_frac)
TABLE2 = {
    "baseline_5s": ((0.1917, 0.05), (0.1067, 0.035)),
    "permissive_1s": ((0.2377, 0.06), (0.1391, 0.045)),
    "conservative_10s": ((0.156, 0.05), (0.0795, 0.03)),
    "broader_1h": ((0.1922, 0.05), (0.1071, 0.035)),
}

# Fig 9 — pre-idle cause shares
FIG9 = {
    "pcie_heavy": (0.48, 0.10),
    "compute_to_idle": (0.33, 0.10),
    "nic_heavy": (0.17, 0.08),
    "nvlink_heavy": (0.02, 0.03),
}

# Fig 10 — load imbalance (relative to 8-active balanced baseline)
FIG10 = {
    "energy_ratio_4active": (0.75, 0.18),   # interpolating the paper's trend
    "energy_ratio_2active": (0.56, 0.12),
    "p95_increase_4active": (0.80, 0.55),
    "p95_increase_2active": (0.93, 0.60),
    "util_ratio_2active": (1.0, 0.35),      # pool SM util stays similar
}

# Figs 11/12 — Algorithm 1 on the Azure Code replay (L40S)
FIG11_12 = {
    "baseline_avg_w": (123.9, 0.15),        # relative tol
    "sm_only_avg_w": (96.4, 0.15),
    "sm_mem_avg_w": (82.2, 0.15),
    "sm_only_power_reduction": (0.22, 0.10),   # absolute
    "sm_mem_power_reduction": (0.34, 0.12),
    "baseline_p95_s": (2.31, 0.5),          # relative
    "sm_only_p95_increase": (0.29, 0.45),   # absolute (+29%)
    "sm_mem_p95_increase": (1.60, 1.2),     # absolute (+160%)
    "exec_idle_power_baseline": (105.0, 0.1),
    "exec_idle_power_sm_only": (61.0, 0.1),
    "exec_idle_power_sm_mem": (35.0, 0.1),
}

# §3 — controlled experiment: exec-idle power stays elevated 4 s..2048 s
PROLONGED_IDLE_MAX_DROP = 0.1   # default DVFS: < 10% drop over 2048 s

# Fig 3a — observed energy 41.6% of TDP upper bound
FIG3A_TDP_FRACTION = (0.416, 0.12)

"""One benchmark per paper table/figure. Each returns a Bench of rows with
derived metrics validated against benchmarks.paper_targets."""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

from benchmarks import paper_targets as T
from benchmarks.common import Bench, cluster_sample, fleet_analysis
from repro.core.attribution import attribute_causes, extract_pre_idle_windows
from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.energy import fraction_of_tdp
from repro.core.imbalance import PoolConfig, PoolPolicy
from repro.core.power_model import PLATFORMS, SimulatedDevice, get_platform
from repro.core.states import DeviceState
from repro.serving.des import simulate_pool
from repro.serving.latency import inter_arrival_cdf
from repro.serving.perf_model import LLAMA13B_L40S
from repro.telemetry import per_job_fraction_cdf, tail_share
from repro.traces import TRACES, generate_trace


# --------------------------------------------------------------------------- #
# Fig 3 — cluster-scale accounting
# --------------------------------------------------------------------------- #
def bench_fig3() -> Bench:
    b = Bench("fig3_accounting")
    fa = fleet_analysis()
    fl = fa.fleet
    tt, te = fl.total_time_s, fl.total_energy_j
    b.add("deep_idle_time", fl.time_s[DeviceState.DEEP_IDLE] / tt,
          T.FIG3["deep_idle_time"])
    b.add("deep_idle_energy", fl.energy_j[DeviceState.DEEP_IDLE] / te,
          T.FIG3["deep_idle_energy"])
    b.add("exec_idle_time", fl.time_s[DeviceState.EXECUTION_IDLE] / tt,
          T.FIG3["exec_idle_time"])
    b.add("exec_idle_energy", fl.energy_j[DeviceState.EXECUTION_IDLE] / te,
          T.FIG3["exec_idle_energy"])
    b.add("active_time", fl.time_s[DeviceState.ACTIVE] / tt, T.FIG3["active_time"])
    b.add("active_energy", fl.energy_j[DeviceState.ACTIVE] / te,
          T.FIG3["active_energy"])
    b.add("in_exec_time_fraction", fa.in_execution_time_fraction,
          T.HEADLINE["in_exec_time_fraction"])
    b.add("in_exec_energy_fraction", fa.in_execution_energy_fraction,
          T.HEADLINE["in_exec_energy_fraction"])
    # Fig 3a: total energy vs TDP upper bound (per-device-weighted TDP)
    cs = cluster_sample()
    frame = cs.frame
    total_j = float(frame["power"].sum())
    # per-sample TDP
    names = [n for n, _ in
             __import__("repro.cluster.simulator", fromlist=["FLEET_MIX"]).FLEET_MIX]
    tdp_by_id = {i: PLATFORMS[n].tdp_w for i, n in enumerate(names)}
    tdp_j = float(sum(tdp_by_id.get(int(p), 300.0) for p in frame["platform"]))
    b.add("fraction_of_tdp", total_j / tdp_j, T.FIG3A_TDP_FRACTION)
    return b


# --------------------------------------------------------------------------- #
# Fig 4 — exec-idle vs deep-idle power per platform
# --------------------------------------------------------------------------- #
def bench_fig4() -> Bench:
    b = Bench("fig4_platforms")
    for name, plat in PLATFORMS.items():
        ratio = plat.exec_idle_w / plat.deep_idle_w
        b.add(f"{name}_gap_ratio", ratio, (max(ratio, 1.2), max(ratio, 1.2) * 0.5),
              mode="rel")
        b.add(f"{name}_exec_idle_w", plat.exec_idle_w)
        b.add(f"{name}_deep_idle_w", plat.deep_idle_w)
    return b


# --------------------------------------------------------------------------- #
# §3 — prolonged execution-idle stays power-disproportionate
# --------------------------------------------------------------------------- #
def bench_prolonged_idle() -> Bench:
    b = Bench("prolonged_idle")
    dev = SimulatedDevice(get_platform("l40s"))
    powers = []
    for t in (4, 64, 512, 2048):
        powers.append(dev.power_w(float(t), 0.0, resident=True))
    drop = (powers[0] - powers[-1]) / powers[0]
    b.add("power_at_4s_w", powers[0])
    b.add("power_at_2048s_w", powers[-1])
    b.add("relative_drop", drop, (0.0, T.PROLONGED_IDLE_MAX_DROP))
    return b


# --------------------------------------------------------------------------- #
# Fig 5 — per-class + per-trace exec-idle fractions
# --------------------------------------------------------------------------- #
def bench_fig5() -> Bench:
    b = Bench("fig5_workloads")
    cs = cluster_sample()
    fa = fleet_analysis()
    agg_t = defaultdict(float)
    agg_i = defaultdict(float)
    agg_e = defaultdict(float)
    agg_ei = defaultdict(float)
    for j in fa.jobs:
        c = cs.job_classes[j.job_id]
        agg_t[c] += j.breakdown.in_execution_time_s
        agg_i[c] += j.breakdown.time_s[DeviceState.EXECUTION_IDLE]
        agg_e[c] += j.breakdown.in_execution_energy_j
        agg_ei[c] += j.breakdown.energy_j[DeviceState.EXECUTION_IDLE]
    for cls, (t_target, e_target) in T.FIG5_ACADEMIC.items():
        if agg_t[cls] > 0:
            b.add(f"{cls}_time", agg_i[cls] / agg_t[cls], t_target)
            b.add(f"{cls}_energy", agg_ei[cls] / agg_e[cls], e_target)

    for name, (t_target, e_target) in T.FIG5_TRACES.items():
        spec = TRACES[name]
        trace = generate_trace(spec, 1800.0, 1, seed=0)
        perf = dataclasses.replace(LLAMA13B_L40S, busy_util=spec.busy_util)
        res = simulate_pool(trace, get_platform("l40s"), perf,
                            PoolConfig(n_devices=1), 1800.0, tick_s=0.1)
        b.add(f"{name}_time", res.exec_idle_time_fraction, t_target)
        b.add(f"{name}_energy", res.exec_idle_energy_fraction, e_target)
    return b


# --------------------------------------------------------------------------- #
# Fig 6 — inter-request interval CDFs
# --------------------------------------------------------------------------- #
def bench_fig6() -> Bench:
    b = Bench("fig6_interarrival")
    lo, hi = T.FIG6_MEDIAN_RANGE
    for name, spec in TRACES.items():
        trace = generate_trace(spec, 1800.0, n_devices=4, seed=0)
        gaps = inter_arrival_cdf(trace)
        med = float(np.median(gaps))
        p90 = float(np.percentile(gaps, 90))
        b.add(f"{name}_median_s", med, ((lo + hi) / 2, (hi - lo) / 2))
        b.add(f"{name}_p90_s", p90)
        if name in T.FIG6_HEAVY_TAIL_TRACES:
            b.add(f"{name}_tail_gt_10s", float(p90 > 10.0), (1.0, 0.01))
    return b


# --------------------------------------------------------------------------- #
# Fig 7 — per-job CDFs
# --------------------------------------------------------------------------- #
def bench_fig7() -> Bench:
    b = Bench("fig7_perjob")
    fa = fleet_analysis()
    cdf = per_job_fraction_cdf(fa.jobs)
    for thr in (0.1, 0.2, 0.5):
        b.add(f"time>{thr}", tail_share(cdf["time_fraction"], thr),
              T.FIG7[f"time>{thr}"])
        b.add(f"energy>{thr}", tail_share(cdf["energy_fraction"], thr),
              T.FIG7[f"energy>{thr}"])
    return b


# --------------------------------------------------------------------------- #
# Fig 8 — interval durations
# --------------------------------------------------------------------------- #
def bench_fig8() -> Bench:
    b = Bench("fig8_durations")
    fa = fleet_analysis()
    durs = np.array([iv.duration for j in fa.jobs for iv in j.intervals],
                    dtype=float)
    b.add("n_intervals", float(durs.size))
    b.add("p50", float(np.percentile(durs, 50)), T.FIG8["p50"])
    b.add("p90", float(np.percentile(durs, 90)), T.FIG8["p90"])
    b.add("p99", float(np.percentile(durs, 99)), T.FIG8["p99"])
    return b


# --------------------------------------------------------------------------- #
# Table 2 — sensitivity to interval / job-length thresholds
# --------------------------------------------------------------------------- #
def bench_table2() -> Bench:
    from repro.telemetry import analyze_fleet
    b = Bench("table2_sensitivity")
    cs = cluster_sample()
    settings = {
        "baseline_5s": (7200.0, 5.0),
        "permissive_1s": (7200.0, 1.0),
        "conservative_10s": (7200.0, 10.0),
        "broader_1h": (3600.0, 5.0),
    }
    values = {}
    for name, (job_s, int_s) in settings.items():
        fa = analyze_fleet(cs.frame, min_job_duration_s=job_s,
                           min_interval_s=int_s)
        values[name] = (fa.in_execution_time_fraction,
                        fa.in_execution_energy_fraction)
        t_target, e_target = T.TABLE2[name]
        b.add(f"{name}_time", values[name][0], t_target)
        b.add(f"{name}_energy", values[name][1], e_target)
    # qualitative orderings the paper stresses
    b.add("permissive_gt_baseline",
          float(values["permissive_1s"][0] > values["baseline_5s"][0]), (1.0, 0.01))
    b.add("conservative_lt_baseline",
          float(values["conservative_10s"][0] < values["baseline_5s"][0]), (1.0, 0.01))
    b.add("job_cutoff_insensitive",
          float(abs(values["broader_1h"][0] - values["baseline_5s"][0]) < 0.02),
          (1.0, 0.01))
    return b


# --------------------------------------------------------------------------- #
# Fig 9 — pre-idle cause attribution
# --------------------------------------------------------------------------- #
def bench_fig9() -> Bench:
    b = Bench("fig9_preidle")
    cs = cluster_sample()
    frame = cs.frame
    from repro.telemetry.pipeline import classify_frame
    windows = []
    job_ids = frame["job_id"]
    for jid in np.unique(job_ids):
        if jid < 0:
            continue
        sub = frame.select(job_ids == jid)
        if len(sub) < 3600:
            continue
        states = classify_frame(sub)
        signals = {
            "sm": sub["sm"], "dram": sub["dram"],
            "pcie": np.nan_to_num(sub["pcie_rx"]),
            "nic": np.nan_to_num(sub["nic_rx"]),
            "nvlink": np.nan_to_num(sub["nvlink_tx"]),
            "cpu": sub["cpu_util"],
        }
        windows.extend(extract_pre_idle_windows(states, signals, window_s=10))
    result = attribute_causes(windows, min_cluster_size=25)
    b.add("n_windows", float(len(windows)))
    b.add("n_clusters", float(result.n_clusters))
    shares = result.category_shares
    # fold "other" into compute_to_idle (paper's manual labeling absorbs it)
    shares = dict(shares)
    shares["compute_to_idle"] += shares.pop("other", 0.0)
    for cat, target in T.FIG9.items():
        b.add(cat, shares.get(cat, 0.0), target)
    return b


# --------------------------------------------------------------------------- #
# Fig 10 — deliberate load imbalance
# --------------------------------------------------------------------------- #
def bench_fig10() -> Bench:
    b = Bench("fig10_imbalance")
    # paper: 96-GPU Azure Code downsampled to an 8-GPU pool. The pool is
    # more lightly loaded than the Fig 5 per-GPU replay streams (that is what
    # makes 2-of-8 consolidation feasible at +93% p95) — scale arrivals down.
    spec = dataclasses.replace(TRACES["azure_code"],
                               gap_median_s=TRACES["azure_code"].gap_median_s * 1.9)
    trace = generate_trace(spec, 1800.0, n_devices=8, seed=2)
    perf = dataclasses.replace(LLAMA13B_L40S, busy_util=spec.busy_util)
    plat = get_platform("l40s")

    results = {}
    for label, policy, n_active in (("8active", PoolPolicy.BALANCED, 8),
                                    ("4active", PoolPolicy.CONSOLIDATED, 4),
                                    ("2active", PoolPolicy.CONSOLIDATED, 2)):
        pool = PoolConfig(n_devices=8, policy=policy, n_active=n_active,
                          park_inactive=False,   # paper: lightly loaded + downscaled
                          spill_every=13)        # ~8% light traffic to parked set
        results[label] = simulate_pool(
            [dataclasses.replace(r) for r in trace], plat, perf, pool,
            1800.0, tick_s=0.1)

    base = results["8active"]
    for label in ("4active", "2active"):
        r = results[label]
        b.add(f"energy_ratio_{label}", r.energy_j / base.energy_j,
              T.FIG10[f"energy_ratio_{label}"])
        b.add(f"p95_increase_{label}",
              r.latency.p95_s / base.latency.p95_s - 1.0,
              T.FIG10[f"p95_increase_{label}"])
        b.add(f"util_ratio_{label}", r.avg_sm_util / max(base.avg_sm_util, 1e-9),
              T.FIG10.get(f"util_ratio_{label}"))
        b.add(f"completed_{label}", float(r.latency.n))
    return b


# --------------------------------------------------------------------------- #
# Figs 11/12 — Algorithm 1 frequency control on the Azure Code replay
# --------------------------------------------------------------------------- #
def bench_fig11_12() -> Bench:
    b = Bench("fig11_12_controller")
    spec = TRACES["azure_code"]
    trace = generate_trace(spec, 1175.0, 1, seed=3)   # paper: 1175 s replay
    perf = dataclasses.replace(LLAMA13B_L40S, busy_util=spec.busy_util)
    plat = get_platform("l40s")

    def run(mode):
        cfg = None if mode is None else ControllerConfig(mode=mode)
        return simulate_pool([dataclasses.replace(r) for r in trace], plat,
                             perf, PoolConfig(n_devices=1), 1175.0,
                             controller_cfg=cfg, tick_s=0.05)

    base = run(None)
    sm = run(DownscaleMode.SM_ONLY)
    smmem = run(DownscaleMode.SM_AND_MEM)

    b.add("baseline_avg_w", base.avg_power_w, T.FIG11_12["baseline_avg_w"], "rel")
    b.add("sm_only_avg_w", sm.avg_power_w, T.FIG11_12["sm_only_avg_w"], "rel")
    b.add("sm_mem_avg_w", smmem.avg_power_w, T.FIG11_12["sm_mem_avg_w"], "rel")
    b.add("sm_only_power_reduction", 1 - sm.avg_power_w / base.avg_power_w,
          T.FIG11_12["sm_only_power_reduction"])
    b.add("sm_mem_power_reduction", 1 - smmem.avg_power_w / base.avg_power_w,
          T.FIG11_12["sm_mem_power_reduction"])
    b.add("baseline_p95_s", base.latency.p95_s, T.FIG11_12["baseline_p95_s"], "rel")
    b.add("sm_only_p95_increase",
          sm.latency.p95_s / base.latency.p95_s - 1.0,
          T.FIG11_12["sm_only_p95_increase"])
    b.add("sm_mem_p95_increase",
          smmem.latency.p95_s / base.latency.p95_s - 1.0,
          T.FIG11_12["sm_mem_p95_increase"])

    # Fig 11: power while execution-idle under each mode
    def idle_power(res):
        f = res.telemetry
        mask = (f["program_resident"] == 1) & (f["sm"] < 5.0)
        # steady downscaled idle: use the 20th percentile (transients excluded)
        return float(np.percentile(f["power"][mask], 20)) if mask.any() else 0.0

    b.add("exec_idle_power_baseline", idle_power(base),
          T.FIG11_12["exec_idle_power_baseline"], "rel")
    b.add("exec_idle_power_sm_only", idle_power(sm),
          T.FIG11_12["exec_idle_power_sm_only"], "rel")
    b.add("exec_idle_power_sm_mem", idle_power(smmem),
          T.FIG11_12["exec_idle_power_sm_mem"], "rel")
    b.add("same_requests_served",
          float(base.latency.n == sm.latency.n == smmem.latency.n), (1.0, 0.01))
    return b


ALL_BENCHES = (
    bench_fig3, bench_fig4, bench_prolonged_idle, bench_fig5, bench_fig6,
    bench_fig7, bench_fig8, bench_table2, bench_fig9, bench_fig10,
    bench_fig11_12,
)

"""Re-derive roofline terms for existing dry-run reports from cached HLO.

Accounting-model updates (hlo_parse.py) apply retroactively without
recompiling:  PYTHONPATH=src python -m repro.roofline.rederive [--dir ...]
"""
from __future__ import annotations

import argparse
import gzip
import json
import pathlib

from repro.configs import LM_SHAPES, get_config
from repro.launch.dryrun import model_flops_global
from repro.roofline import analysis as roofline
from repro.roofline import hlo_parse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="reports/dryrun")
    args = ap.parse_args()

    n = 0
    for jpath in sorted(pathlib.Path(args.dir).glob("*.json")):
        r = json.loads(jpath.read_text())
        if r["status"] != "ok":
            continue
        hpath = jpath.with_suffix("").with_suffix("")  # strip .json
        hpath = jpath.parent / (jpath.stem + ".hlo.gz")
        if not hpath.exists():
            continue
        hlo = gzip.open(hpath, "rt").read()
        stats = hlo_parse.analyze_hlo(hlo)
        cfg = get_config(r["arch"])
        shape = LM_SHAPES[r["shape"]]
        cost = {"flops": r["roofline"].get("cost_analysis_flops", 0.0),
                "bytes accessed": r["roofline"].get("cost_analysis_bytes", 0.0)}
        terms = roofline.derive_terms(cost, stats, r["n_chips"],
                                      model_flops_global(cfg, shape))
        r["roofline"] = terms.as_dict()
        r["collectives"] = {"total_bytes": stats.collective_bytes,
                            "by_op": stats.collective_by_op,
                            "counts": stats.collective_counts}
        jpath.write_text(json.dumps(r, indent=1, default=str))
        n += 1
    print(f"re-derived {n} reports")


if __name__ == "__main__":
    main()

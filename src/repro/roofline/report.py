"""Generate the §Roofline markdown table from dry-run reports.

    PYTHONPATH=src python -m repro.roofline.report [--dir reports/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib


def one_liner(r: dict) -> str:
    """One sentence on what would move the dominant term down."""
    rf = r["roofline"]
    b = rf["bottleneck"]
    shape = r["shape"]
    if b == "memory" and shape in ("train_4k", "prefill_32k"):
        return ("attention-score traffic dominates: wire the Pallas flash "
                "kernel / grouped-GQA contraction (see §Perf)")
    if b == "memory":
        return ("KV/weight streaming bound: grouped GQA contraction avoids "
                "expanded-cache copies; batch more sequences per step")
    if b == "collective":
        return ("TP/FSDP collectives dominate: sequence-parallel residual "
                "stream + reduce-scatter gradients; overlap via latency-hiding "
                "scheduler on TPU")
    return "compute-bound: increase arithmetic intensity via larger blocks"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="reports/dryrun")
    ap.add_argument("--out", default="reports/roofline_table.md")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()

    rows = []
    for path in sorted(pathlib.Path(args.dir).glob(f"*__{args.mesh}.json")):
        r = json.loads(path.read_text())
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], "skipped", None, r.get("reason", "")))
        elif r["status"] == "ok":
            rows.append((r["arch"], r["shape"], "ok", r, one_liner(r)))
        else:
            rows.append((r["arch"], r["shape"], "error", None,
                         r.get("error", "")[:80]))

    lines = [
        f"# §Roofline — baseline table ({args.mesh}-pod mesh, "
        f"{256 if args.mesh == 'single' else 512} chips)",
        "",
        "Terms in seconds per step/device; TPU-v5e constants "
        "(197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI).",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck | "
        "MODEL_FLOPS/HLO_FLOPs | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch, shape, status, r, note in rows:
        if status != "ok":
            lines.append(f"| {arch} | {shape} | — | — | — | {status} | — | {note} |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {arch} | {shape} | {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | **{rf['bottleneck']}** "
            f"| {rf['useful_fraction']:.3f} | {note} |")
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text("\n".join(lines) + "\n")
    print(f"wrote {out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()

"""Loop-aware mini HLO analyzer for the roofline terms.

``compiled.cost_analysis()`` and a naive text scan both count `while` bodies
(jax scans) ONCE; real execution runs them trip-count times. This module
parses the optimized HLO text into computations, recovers loop trip counts
from loop-condition constants, and accumulates per-device:

* **flops** — 2 x prod(out) x prod(contracting dims) per `dot` (symbol-table
  lookup for operand shapes), trip-multiplied. Elementwise flops are ignored
  (dots dominate transformer cost; the raw cost_analysis value is reported
  alongside for reference).
* **hbm bytes** — sum of operand + output bytes per materializing op
  (fusions = kernels; inputs + outputs bound HBM traffic), trip-multiplied.
* **collective bytes** — per-device transmitted bytes per collective op with
  group-size-aware operand derivation, trip-multiplied.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s")

#: ops whose inputs/outputs bound HBM traffic on a fused (TPU-like) pipeline.
#: The CPU-backend HLO we analyze leaves elementwise chains unfused; counting
#: them would overstate traffic ~10x vs a TPU compilation, so only
#: materializing ops are charged (converts/broadcasts/arithmetic are treated
#: as fused into their consumers).
_MATERIALIZING_OPS = frozenset({
    "fusion", "dot", "convolution", "copy", "copy-start",
    "dynamic-update-slice", "dynamic-slice", "scatter", "gather",
    "reduce", "reduce-window", "sort", "select-and-scatter",
    "concatenate", "pad", "cholesky", "triangular-solve",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
})


def _shape_elems(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(_shape_elems(dims) * _DTYPE_BYTES[dt]
               for dt, dims in _SHAPE_RE.findall(type_str))


def _type_dims(type_str: str) -> list[int] | None:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims.strip() else []


@dataclasses.dataclass
class _Op:
    name: str
    out_type: str
    opcode: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    params: dict[str, str]          # param name -> type string
    ops: list[_Op]


def _parse(hlo_text: str) -> tuple[dict[str, _Computation], str]:
    comps: dict[str, _Computation] = {}
    entry = ""
    current: _Computation | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if line.endswith("{"):
            m = _HEADER_RE.match(line.strip())
            if m:
                is_entry, name, params_str = m.group(1), m.group(2), m.group(3)
                params: dict[str, str] = {}
                # split "a: T, b: T" at top level (types may contain commas
                # inside brackets/parens — walk with depth counting)
                depth = 0
                start = 0
                parts = []
                for i, ch in enumerate(params_str):
                    if ch in "([":
                        depth += 1
                    elif ch in ")]":
                        depth -= 1
                    elif ch == "," and depth == 0:
                        parts.append(params_str[start:i])
                        start = i + 1
                parts.append(params_str[start:])
                for part in parts:
                    if ":" in part:
                        pname, ptype = part.split(":", 1)
                        params[pname.strip().lstrip("%")] = ptype.strip()
                current = _Computation(name=name, params=params, ops=[])
                comps[name] = current
                if is_entry:
                    entry = name
                continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _DEF_RE.match(line)
        if m:
            current.ops.append(_Op(name=m.group(1), out_type=m.group(2),
                                   opcode=m.group(3), line=line))
    return comps, entry


@dataclasses.dataclass
class HloStats:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_by_op: dict[str, float]
    collective_counts: dict[str, float]

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze_hlo(hlo_text: str) -> HloStats:
    comps, entry = _parse(hlo_text)
    if not entry and comps:
        entry = max(comps, key=lambda n: len(comps[n].ops))

    def symbols(comp: _Computation) -> dict[str, str]:
        table = dict(comp.params)
        for op in comp.ops:
            table[op.name] = op.out_type
        return table

    def trip_count(cond_name: str) -> int:
        cond = comps.get(cond_name)
        if cond is None:
            return 1
        best = 1
        for op in cond.ops:
            for m in _CONST_RE.finditer(op.line):
                best = max(best, int(m.group(1)))
        return best

    cache: dict[str, HloStats] = {}

    def analyze(comp_name: str, depth: int = 0) -> HloStats:
        if comp_name in cache:
            return cache[comp_name]
        zero = HloStats(0.0, 0.0, 0.0,
                        {o: 0.0 for o in COLLECTIVE_OPS},
                        {o: 0.0 for o in COLLECTIVE_OPS})
        comp = comps.get(comp_name)
        if comp is None or depth > 24:
            return zero
        table = symbols(comp)
        st = zero
        for op in comp.ops:
            # while: recurse with trip multiplication
            if op.opcode == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                if cm and bm:
                    trips = trip_count(cm.group(1))
                    sub = analyze(bm.group(1), depth + 1)
                    st = _add(st, _scale(sub, trips))
                continue
            if op.opcode in ("call", "conditional", "fusion") and op.opcode != "fusion":
                m = re.search(r"to_apply=%?([\w.\-]+)", op.line)
                if m:
                    st = _add(st, analyze(m.group(1), depth + 1))
            # flops (dot)
            if op.opcode == "dot":
                out_dims = _type_dims(op.out_type) or []
                operands = _operands(op)
                lhs_type = table.get(operands[0]) if operands else None
                lhs_dims = _type_dims(lhs_type) if lhs_type else None
                cm2 = _CONTRACT_RE.search(op.line)
                if lhs_dims is not None and cm2 and cm2.group(1).strip():
                    contract = [int(i) for i in cm2.group(1).split(",")]
                    k = 1
                    for i in contract:
                        if i < len(lhs_dims):
                            k *= lhs_dims[i]
                    out_n = 1
                    for d in out_dims:
                        out_n *= d
                    st.flops += 2.0 * out_n * k
            # bytes
            if op.opcode in _MATERIALIZING_OPS:
                operand_names = _operands(op)
                slice_costs = (_fusion_param_costs(op, comps)
                               if op.opcode == "fusion" else {})
                out_full = _type_bytes(op.out_type)
                nbytes = min(out_full, slice_costs.get(-1, out_full))
                for i, operand in enumerate(operand_names):
                    t = table.get(operand)
                    if t:
                        full = _type_bytes(t)
                        nbytes += min(full, slice_costs.get(i, full))
                st.hbm_bytes += nbytes
            # collectives
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVE_OPS and not op.opcode.endswith("-done"):
                out_bytes = _type_bytes(op.out_type)
                g = _GROUPS_RE.search(op.line)
                group = int(g.group(2)) if g else 1
                if base == "all-gather":
                    moved = out_bytes / max(group, 1)
                elif base == "reduce-scatter":
                    moved = out_bytes * max(group, 1)
                else:
                    moved = out_bytes
                # CPU XLA promotes bf16 reduction accumulators to f32
                # ("..._promoted" apply computations); TPU all-reduces run
                # native bf16 — charge the bf16 wire cost.
                if base == "all-reduce" and "promoted" in op.line:
                    moved /= 2
                st.collective_bytes += moved
                st.collective_by_op[base] += moved
                st.collective_counts[base] += 1
        cache[comp_name] = st
        return st

    return analyze(entry)


def _fusion_param_costs(op: _Op, comps: dict[str, _Computation]) -> dict[int, int]:
    """For a fusion op, parameters that are only dynamic-sliced inside the
    fused computation cost their slice size, not the full operand (the
    backward-over-scan pattern reads one layer slice of the stacked
    residuals per trip). Parameters whose single consumer is a
    dynamic-UPDATE-slice cost the update size: TPU XLA aliases the while-
    carried buffer in place, so a scan-carried KV-cache update touches only
    the written slice, not the whole stack."""
    m = re.search(r"calls=%?([\w.\-]+)", op.line)
    if not m:
        return {}
    sub = comps.get(m.group(1))
    if sub is None:
        return {}
    table: dict[str, str] = {}
    param_idx: dict[str, int] = {}
    ds_cost: dict[str, int] = {}
    consumers: dict[str, int] = {}
    for sop in sub.ops:
        table[sop.name] = sop.out_type
        if sop.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", sop.line)
            if pm:
                param_idx[sop.name] = int(pm.group(1))
            continue
        operands = _operands(sop)
        for operand in operands:
            if operand in param_idx:
                consumers[operand] = consumers.get(operand, 0) + 1
        if operands and operands[0] in param_idx:
            target = operands[0]
            if sop.opcode == "dynamic-slice":
                ds_cost[target] = min(ds_cost.get(target, 1 << 62),
                                      _type_bytes(sop.out_type))
            elif sop.opcode == "dynamic-update-slice" and len(operands) > 1:
                update_t = table.get(operands[1])
                if update_t:
                    ds_cost[target] = min(ds_cost.get(target, 1 << 62),
                                          _type_bytes(update_t))
    out: dict[int, int] = {}
    for pname, idx in param_idx.items():
        if pname in ds_cost and consumers.get(pname, 0) == 1:
            out[idx] = ds_cost[pname]
    # aliased output: if the fusion root is a dynamic-update-slice, the
    # output buffer aliases the input; only the update slice is written
    root_update = None
    for sop in sub.ops:
        if "ROOT" in sop.line and sop.opcode == "dynamic-update-slice":
            ops_ = _operands(sop)
            if len(ops_) > 1 and ops_[1] in table:
                root_update = _type_bytes(table[ops_[1]])
    if root_update is not None:
        out[-1] = root_update
    return out


def _operands(op: _Op) -> list[str]:
    # operand list = %names inside the first paren group after the opcode
    idx = op.line.find(op.opcode + "(")
    if idx < 0:
        return []
    rest = op.line[idx + len(op.opcode) + 1:]
    depth = 1
    out = []
    buf = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    return _OPERAND_RE.findall("".join(buf))


def _scale(s: HloStats, k: float) -> HloStats:
    return HloStats(s.flops * k, s.hbm_bytes * k, s.collective_bytes * k,
                    {o: v * k for o, v in s.collective_by_op.items()},
                    {o: v * k for o, v in s.collective_counts.items()})


def _add(a: HloStats, b: HloStats) -> HloStats:
    return HloStats(a.flops + b.flops, a.hbm_bytes + b.hbm_bytes,
                    a.collective_bytes + b.collective_bytes,
                    {o: a.collective_by_op[o] + b.collective_by_op[o]
                     for o in COLLECTIVE_OPS},
                    {o: a.collective_counts[o] + b.collective_counts[o]
                     for o in COLLECTIVE_OPS})

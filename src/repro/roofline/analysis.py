"""Roofline-term derivation from compiled dry-run artifacts (§Roofline).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes; collective bytes by
parsing the optimized HLO (``compiled.as_text()``) and summing operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops. cost_analysis is per-program (already per-device under SPMD); the HLO is
likewise the per-device program, so no further division by chip count is
applied to parsed collective bytes.

Hardware constants (TPU-v5e class): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(.*?)\s+(" + "|".join(COLLECTIVE_OPS) + r")(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)(?:\.clone)?\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(r"=\s*.*?\s+while\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_op: dict[str, int]
    counts: dict[str, int]


def _operand_bytes(op: str, out_bytes: int, group_size: int) -> int:
    """Derive per-device operand (transmitted) bytes from the output type."""
    if op == "all-gather":
        return out_bytes // max(group_size, 1)
    if op == "reduce-scatter":
        return out_bytes * max(group_size, 1)
    return out_bytes  # all-reduce / all-to-all / collective-permute


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """computation name -> its body lines (optimized-HLO textual format)."""
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        if stripped.endswith("{"):
            m = _COMP_RE.match(stripped)
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        if stripped == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    """Per-device collective bytes from optimized HLO, *loop-aware*:
    collectives inside `while` bodies (jax scans) are multiplied by the trip
    count recovered from the loop condition's bound constant."""
    comps = _split_computations(hlo_text)

    def trip_count(cond_name: str) -> int:
        best = 1
        for line in comps.get(cond_name, []):
            if "compare" not in line:
                continue
            for m in _CONST_RE.finditer(line):
                best = max(best, int(m.group(1)))
        if best == 1:  # bound may live in a separate constant line
            for line in comps.get(cond_name, []):
                for m in _CONST_RE.finditer(line):
                    best = max(best, int(m.group(1)))
        return max(best, 1)

    cache: dict[str, tuple[dict[str, int], dict[str, int]]] = {}

    def accumulate(comp: str, depth: int = 0):
        if comp in cache:
            return cache[comp]
        by_op = {op: 0 for op in COLLECTIVE_OPS}
        counts = {op: 0.0 for op in COLLECTIVE_OPS}
        if depth > 16:
            return by_op, counts
        for line in comps.get(comp, []):
            w = _WHILE_RE.search(line)
            if w:
                cond, body = w.group(1), w.group(2)
                trips = trip_count(cond)
                sub_b, sub_c = accumulate(body, depth + 1)
                for op in COLLECTIVE_OPS:
                    by_op[op] += trips * sub_b[op]
                    counts[op] += trips * sub_c[op]
                continue
            m = _OP_RE.search(line)
            if not m or m.group(3) == "-done":
                continue
            op = m.group(2)
            out_bytes = sum(_shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(m.group(1)))
            g = _GROUPS_RE.search(line)
            group_size = int(g.group(2)) if g else 1
            by_op[op] += _operand_bytes(op, out_bytes, group_size)
            counts[op] += 1
        cache[comp] = (by_op, counts)
        return cache[comp]

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
            break
    names = list(comps)
    if entry is None:
        # fall back: the computation with the most lines
        entry = max(names, key=lambda n: len(comps[n])) if names else ""
    by_op, counts = accumulate(entry)
    return CollectiveStats(total_bytes=int(sum(by_op.values())),
                           by_op={k: int(v) for k, v in by_op.items()},
                           counts={k: int(v) for k, v in counts.items()})


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device HLO flops (loop-aware)
    hbm_bytes: float             # per-device HBM traffic estimate (loop-aware)
    collective_bytes: float      # per-device collective transmitted bytes
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # useful flops per device (6*N*D etc.)
    useful_fraction: float       # model_flops / hlo_flops
    roofline_bound_s: float      # max of the three terms
    cost_analysis_flops: float   # raw (loop-unaware) cost_analysis values
    cost_analysis_bytes: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def derive_terms(cost: dict, hlo_stats, n_chips: int,
                 model_flops_global: float,
                 peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW,
                 ici_bw: float = ICI_BW) -> RooflineTerms:
    """hlo_stats: ``hlo_parse.analyze_hlo`` output for the per-device program.

    model_flops_global: useful math for the step across ALL chips
    (6*N_active*tokens for training; 2*N_active*tokens for inference).
    ``cost`` keeps the raw (loop-unaware) cost_analysis numbers for reference.
    """
    flops = float(hlo_stats.flops)
    hbm = float(hlo_stats.hbm_bytes)
    cbytes = float(hlo_stats.collective_bytes)
    compute_s = flops / peak_flops
    memory_s = hbm / hbm_bw
    collective_s = cbytes / ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)  # type: ignore[arg-type]
    model_flops = model_flops_global / n_chips
    useful = model_flops / flops if flops else 0.0
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm, collective_bytes=cbytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_fraction=useful,
        roofline_bound_s=max(terms.values()),
        cost_analysis_flops=float(cost.get("flops", 0.0)),
        cost_analysis_bytes=float(cost.get("bytes accessed", 0.0)))

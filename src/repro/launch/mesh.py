"""Production mesh construction (single-pod 16x16 and multi-pod 2x16x16).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state. The dry-run entry point sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE importing jax;
normal runs (tests, benches) see the container's single CPU device.
"""
from __future__ import annotations

import jax

from repro.distributed.context import DistContext


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dist(*, multi_pod: bool = False) -> DistContext:
    mesh = make_production_mesh(multi_pod=multi_pod)
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return DistContext(mesh=mesh, batch_axes=batch_axes, model_axis="model")


def make_local_dist(data: int = 1, model: int = 1) -> DistContext:
    """Small mesh over however many (host) devices exist — used by tests."""
    if data * model == 1:
        return DistContext()
    mesh = jax.make_mesh((data, model), ("data", "model"))
    return DistContext(mesh=mesh, batch_axes=("data",), model_axis="model")

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the step function (train_step / prefill / decode_step) is
jit-compiled against abstract inputs (ShapeDtypeStruct — no allocation) under
the production mesh shardings; we record memory_analysis, cost_analysis, and
the collective bytes parsed from the optimized HLO (roofline inputs).

Usage:
    python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out reports/dryrun
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ASSIGNED_ARCHS, LM_SHAPES, cell_is_applicable, get_config
from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed import sharding as shd
from repro.distributed.context import DistContext
from repro.launch.mesh import make_dist
from repro.models import api
from repro.roofline import analysis as roofline
from repro.roofline import hlo_parse
from repro.train import optimizer as opt_mod
from repro.train.trainer import make_train_step


# --------------------------------------------------------------------------- #
# abstract inputs
# --------------------------------------------------------------------------- #
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        if cfg.family == "encdec":
            batch["frames"] = sds((b, cfg.n_frames, cfg.d_model), f32)
        if cfg.family == "vlm":
            batch["vision"] = sds((b, cfg.n_vision_tokens, cfg.d_model), f32)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((b, s), i32)}
        if cfg.family == "encdec":
            out["frames"] = sds((b, cfg.n_frames, cfg.d_model), f32)
        if cfg.family == "vlm":
            out["vision"] = sds((b, cfg.n_vision_tokens, cfg.d_model), f32)
        return out
    # decode: KV cache filled to seq_len, one new token
    cache = jax.eval_shape(lambda: api.init_cache(cfg, b, s))
    return {"cache": cache, "tokens": sds((b, 1), i32)}


def model_flops_global(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Useful-math floor: 6*N_active*tokens (train) / 2*N_active*tokens."""
    n_active = api.active_params_abstract(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


# --------------------------------------------------------------------------- #
# per-cell lowering
# --------------------------------------------------------------------------- #
def lower_cell(arch: str, shape_name: str, dist: DistContext,
               donate: bool = True):
    cfg = get_config(arch)
    shape = LM_SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    from repro.models import common as cm
    cm.set_shard_hook(shd.make_shard_hook(cfg, dist))
    abstract = api.abstract_params(cfg, ep_size=dist.ep_size)
    p_specs = shd.param_specs(abstract, dist)
    p_sh = shd.named(dist, p_specs)

    if shape.kind == "train":
        optimizer = opt_mod.for_arch(cfg.name)
        step = make_train_step(cfg, optimizer, dist)
        opt_abstract = jax.eval_shape(optimizer.init, abstract)
        lowered = step.lower(abstract, opt_abstract, specs["batch"])
    elif shape.kind == "prefill":
        def prefill_fn(params, tokens, frames=None, vision=None):
            return api.prefill(params, tokens, cfg, dist=dist,
                               frames=frames, vision=vision)

        cache_abs = jax.eval_shape(
            lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len))
        c_specs = shd.cache_specs(cfg, cache_abs, dist)
        tok_sh = dist.sharding(shd.token_specs(dist, shape.global_batch))
        in_sh = [p_sh, tok_sh]
        args = [abstract, specs["tokens"]]
        kw_sh = {}
        if cfg.family == "encdec":
            in_sh.append(dist.sharding(
                shd.batch_specs(cfg, dist, shape.global_batch)["frames"]))
            args.append(specs["frames"])
        if cfg.family == "vlm":
            in_sh.append(dist.sharding(
                shd.batch_specs(cfg, dist, shape.global_batch)["vision"]))
            args.append(specs["vision"])
        jitted = jax.jit(prefill_fn, in_shardings=tuple(in_sh),
                         out_shardings=(shd.named(dist, c_specs), None))
        lowered = jitted.lower(*args)
    else:  # decode
        def decode_fn(params, cache, tokens):
            return api.decode_step(params, cache, tokens, cfg, dist=dist)

        cache_abs = specs["cache"]
        c_specs = shd.cache_specs(cfg, cache_abs, dist)
        c_sh = shd.named(dist, c_specs)
        tok_sh = dist.sharding(shd.token_specs(dist, shape.global_batch))
        jitted = jax.jit(decode_fn, in_shardings=(p_sh, c_sh, tok_sh),
                         out_shardings=(c_sh, None),
                         donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(abstract, cache_abs, specs["tokens"])
    return lowered, cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path | None = None, tune: str = "") -> dict:
    from repro.models import tuning as tuning_mod
    kwargs = {}
    for part in filter(None, tune.split(",")):
        if part.startswith("q_block="):
            kwargs["q_block"] = int(part.split("=")[1])
        else:
            kwargs[part] = True
    tuning_mod.set_tuning(**kwargs)
    mesh_name = "multi" if multi_pod else "single"
    if tune:
        mesh_name += "__tuned-" + tuning_mod.ACTIVE.describe()
    t0 = time.time()
    result: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "tune": tuning_mod.ACTIVE.describe()}
    if not cell_is_applicable(arch, shape_name):
        result["status"] = "skipped"
        result["reason"] = ("long_500k needs sub-quadratic attention; "
                            "full-attention arch — see DESIGN.md §4")
        result["wall_s"] = 0.0
        if out_dir is not None:
            out_dir.mkdir(parents=True, exist_ok=True)
            (out_dir / f"{arch.replace('.', '_')}__{shape_name}__{mesh_name}.json"
             ).write_text(json.dumps(result, indent=1))
        return result
    try:
        dist = make_dist(multi_pod=multi_pod)
        lowered, cfg, shape = lower_cell(arch, shape_name, dist)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        if out_dir is not None:
            import gzip
            out_dir.mkdir(parents=True, exist_ok=True)
            hlo_path = out_dir / (
                f"{arch.replace('.', '_')}__{shape_name}__{mesh_name}.hlo.gz")
            with gzip.open(hlo_path, "wt") as f:
                f.write(hlo)
        stats = hlo_parse.analyze_hlo(hlo)
        n_chips = 512 if multi_pod else 256
        terms = roofline.derive_terms(cost or {}, stats, n_chips,
                                      model_flops_global(cfg, shape))
        result.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "n_chips": n_chips,
            "memory": _memory_dict(mem),
            "collectives": {"total_bytes": stats.collective_bytes,
                            "by_op": stats.collective_by_op,
                            "counts": stats.collective_counts},
            "roofline": terms.as_dict(),
        })
    except Exception as e:  # noqa: BLE001 — record, don't abort the sweep
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    result["wall_s"] = round(time.time() - t0, 1)
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        path = out_dir / f"{arch.replace('.', '_')}__{shape_name}__{mesh_name}.json"
        path.write_text(json.dumps(result, indent=1, default=str))
    return result


def _memory_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*LM_SHAPES, None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tune", default="",
                    help="comma list of tuning knobs, e.g. attn_probs_bf16,seq_parallel")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    archs = list(ASSIGNED_ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(LM_SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                name = f"{arch.replace('.', '_')}__{shape}__{'multi' if multi else 'single'}"
                if args.skip_existing and (out_dir / f"{name}.json").exists():
                    prev = json.loads((out_dir / f"{name}.json").read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[skip] {name} ({prev['status']})", flush=True)
                        continue
                r = run_cell(arch, shape, multi, out_dir, tune=args.tune)
                msg = r.get("error", "")[:120]
                extra = ""
                if r["status"] == "ok":
                    rf = r["roofline"]
                    extra = (f"bottleneck={rf['bottleneck']} "
                             f"c={rf['compute_s']:.4f}s m={rf['memory_s']:.4f}s "
                             f"x={rf['collective_s']:.4f}s")
                print(f"[{r['status']:7s}] {name} wall={r['wall_s']}s {extra}{msg}",
                      flush=True)


if __name__ == "__main__":
    main()

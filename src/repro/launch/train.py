"""Training launcher.

Single-host runs execute directly (smoke-size on CPU; full configs on TPU).
The execution-idle telemetry + Algorithm-1 controller are first-class flags.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --steps 20 --batch 8 --seq 128 --controller --checkpoint-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config, get_smoke_config
from repro.core.states import DeviceState
from repro.telemetry import analyze_job
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--controller", action="store_true",
                    help="enable the Algorithm-1 execution-idle controller")
    ap.add_argument("--platform", default="tpu_v5e")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tc = TrainerConfig(steps=args.steps, checkpoint_every=args.checkpoint_every,
                       checkpoint_dir=args.checkpoint_dir, lr=args.lr)
    trainer = Trainer(cfg, tc, global_batch=args.batch, seq_len=args.seq,
                      platform=args.platform, controller=args.controller,
                      seed=args.seed)
    report = trainer.run()

    frame = trainer.sampler.frame()
    telemetry = {}
    if len(frame):
        ja = analyze_job(frame, job_id=1, min_duration_s=1.0)
        telemetry = {
            "exec_idle_time_fraction": round(ja.exec_idle_time_fraction, 4),
            "exec_idle_energy_fraction": round(ja.exec_idle_energy_fraction, 4),
            "active_s": ja.breakdown.time_s[DeviceState.ACTIVE],
            "exec_idle_s": ja.breakdown.time_s[DeviceState.EXECUTION_IDLE],
            "energy_j": round(ja.breakdown.total_energy_j, 1),
        }
    print(json.dumps({
        "arch": cfg.name,
        "steps": report.steps_run,
        "final_loss": round(report.final_loss, 4),
        "loss_first": round(report.losses[0], 4) if report.losses else None,
        "resumed_from": report.resumed_from,
        "stragglers": report.straggler_events,
        "wall_s": round(report.wall_s, 1),
        "telemetry": telemetry,
        "controller_downscales": (trainer.controller.stats.downscale_events
                                  if trainer.controller else None),
    }, indent=1))


if __name__ == "__main__":
    main()

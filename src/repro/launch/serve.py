"""Serving launcher: replay a (synthetic) industry trace on the live JAX
engine, with execution-idle telemetry and the Algorithm-1 controller.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --trace azure_code --duration 60 --controller
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import api
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.latency import Request
from repro.telemetry import analyze_job
from repro.traces import generate_trace, get_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", default="azure_code",
                    choices=["azure_code", "azure_chat", "burstgpt_chat",
                             "qwen_reason", "qwen_chat"])
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--controller", action="store_true")
    ap.add_argument("--platform", default="tpu_v5e")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(cfg, params, EngineConfig(
        n_slots=args.slots, max_seq_len=args.max_seq,
        prefill_bucket=min(32, args.max_seq // 2),
        max_new_tokens=args.max_new_tokens,
        controller=args.controller, platform=args.platform))

    spec = get_trace(args.trace)
    trace = generate_trace(spec, args.duration, n_devices=1, seed=args.seed)
    # engine-scale the requests (smoke models decode a few tokens per request)
    rng = np.random.default_rng(args.seed)
    prompts = {}
    for r in trace:
        r.prompt_tokens = min(r.prompt_tokens, args.max_seq // 2)
        r.output_tokens = min(r.output_tokens, args.max_new_tokens)
        prompts[r.req_id] = rng.integers(
            2, cfg.vocab_size, r.prompt_tokens).astype(np.int32)

    stats = engine.run(trace, prompts)
    frame = engine.sampler.frame()
    telemetry = {}
    if len(frame):
        ja = analyze_job(frame, job_id=1, min_duration_s=1.0)
        telemetry = {
            "exec_idle_time_fraction": round(ja.exec_idle_time_fraction, 4),
            "exec_idle_energy_fraction": round(ja.exec_idle_energy_fraction, 4),
            "avg_power_w": round(float(frame["power"].mean()), 1),
        }
    print(json.dumps({
        "arch": cfg.name,
        "trace": args.trace,
        "completed": stats.n,
        "p50_s": round(stats.p50_s, 3),
        "p95_s": round(stats.p95_s, 3),
        "telemetry": telemetry,
        "controller_downscales": (engine.controller.stats.downscale_events
                                  if engine.controller else None),
    }, indent=1))


if __name__ == "__main__":
    main()

"""Candidate execution-idle mitigation policies for counterfactual replay.

Each policy answers, per telemetry sample of one (job, host, device) stream:
*what would the device have done under this mitigation*, expressed as a
counterfactual board power (and optionally residency) series plus a modeled
performance penalty. Policies are **vectorized** and **streaming**: ``apply``
consumes time-ordered segments of any size and carries state across segment
boundaries, so a replay over 1-row chunks, storage shards, or the whole
stream produces the exact same decision sequence.

The policy set mirrors the paper's mitigation space:

* :class:`DownscalePolicy` — Algorithm 1 (§5.3) frequency control, a
  vectorized re-derivation of
  :class:`repro.core.controller.ExecutionIdleController` whose decision
  sequence is verified identical to the step-by-step controller
  (tests/test_whatif.py);
* :class:`ParkingPolicy` — §5.1 consolidation: k-of-n devices serve, the
  rest park their execution-idle time at deep-idle power, paying a
  model-reload tax per wake (the "Model Parking Tax" trade-off);
* :class:`PowerCapPolicy` — board power capping with a cube-law slowdown on
  capped active samples (deadline-aware frequency-scaling baseline);
* :class:`NoOpPolicy` — the recorded fleet, unchanged (frontier origin).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import numpy as np

from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.imbalance import PoolConfig
from repro.core.power_model import ClockLevel, PlatformSpec
from repro.core.states import COMMUNICATION_SIGNALS, COMPUTE_SIGNALS
from repro.telemetry.records import TelemetryFrame


def _threshold_params(config: ControllerConfig) -> dict:
    """Signal-threshold knobs shared by every policy's ``describe()`` —
    ``describe()`` doubles as the merge-compatibility key, so every knob
    that changes decisions must appear in it."""
    return {
        "interval_eps_s": config.interval_eps_s,
        "activity_threshold": config.activity_threshold,
        "comm_threshold_gbs": config.comm_threshold_gbs,
    }


def low_activity_series(seg: TelemetryFrame, config: ControllerConfig) -> np.ndarray:
    """Vectorized Algorithm-1 low-activity predicate over one segment.

    Matches :meth:`ExecutionIdleController._low_activity` exactly when the
    controller is fed the same samples with activity as fractions
    (percent / 100) and NaN (signal unavailable) replaced by 0.0.

    Memoized per segment object and threshold pair: a sweep feeds the same
    segment to every grid config (``replay_chunk``), and most configs share
    thresholds, so the ~12 full-array passes run once, not once per config.
    """
    key = (config.activity_threshold, config.comm_threshold_gbs)
    cache = getattr(seg, "_low_cache", None)
    if cache is None:
        cache = seg._low_cache = {}
    cached = cache.get(key)
    if cached is not None:
        return cached
    n = len(seg)
    comp = np.zeros(n)
    for k in COMPUTE_SIGNALS:
        comp = np.maximum(comp, np.nan_to_num(seg[k], nan=0.0))
    mem = np.nan_to_num(seg["dram"], nan=0.0)
    comm = np.zeros(n)
    for k in COMMUNICATION_SIGNALS:
        comm = np.maximum(comm, np.nan_to_num(seg[k], nan=0.0))
    low = ((comp / 100.0 < config.activity_threshold)
           & (mem / 100.0 < config.activity_threshold)
           & (comm < config.comm_threshold_gbs))
    cache[key] = low
    return low


@dataclasses.dataclass
class SegmentEffect:
    """One policy's counterfactual for one time-ordered segment."""

    #: counterfactual board power per sample (W)
    power_w: np.ndarray
    #: counterfactual residency, or None when unchanged from the recording
    resident: np.ndarray | None
    #: samples the policy affected (downscaled / parked / capped)
    throttled: np.ndarray
    #: penalty partial-sum for sample-proportional penalty models; partials
    #: are fsum'd at finalize so totals are chunking-invariant
    penalty_partial_s: float = 0.0
    #: events priced at finalize via ``Policy.event_penalty_s`` (restores,
    #: wake-ups); integer counts keep the pricing chunking-invariant
    wake_events: int = 0
    downscale_events: int = 0


@runtime_checkable
class Policy(Protocol):
    """What the replayer needs from a mitigation policy."""

    @property
    def name(self) -> str: ...
    def describe(self) -> dict: ...
    def init_carry(self) -> Any: ...
    def apply(self, seg: TelemetryFrame, plat: PlatformSpec, carry: Any,
              dt_s: float = 1.0) -> tuple[SegmentEffect, Any]: ...
    def event_penalty_s(self, plat: PlatformSpec) -> float: ...


# --------------------------------------------------------------------------- #
# No-op baseline
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class NoOpPolicy:
    """The recorded fleet, unchanged — anchors the frontier at (0, 0)."""

    @property
    def name(self) -> str:
        return "noop"

    def describe(self) -> dict:
        return {"policy": self.name}

    def init_carry(self) -> None:
        return None

    def apply(self, seg: TelemetryFrame, plat: PlatformSpec, carry: None,
              dt_s: float = 1.0) -> tuple[SegmentEffect, None]:
        n = len(seg)
        return SegmentEffect(
            power_w=np.asarray(seg["power"], dtype=np.float64),
            resident=None,
            throttled=np.zeros(n, dtype=bool),
        ), None

    def event_penalty_s(self, plat: PlatformSpec) -> float:
        return 0.0


# --------------------------------------------------------------------------- #
# Algorithm-1 downscaling, vectorized
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class DownscaleCarry:
    """Controller state carried across segment boundaries.

    ``c`` is the consecutive low-activity accumulator *as the step controller
    would hold it* (left-fold float additions of ``interval_eps_s``), so the
    trigger comparison ``c > X`` lands on the same sample for every chunking.
    """

    c: float = 0.0
    t_cooldown: float = 0.0
    downscaled: bool = False


def downscale_decisions(
    ts: np.ndarray,
    low: np.ndarray,
    config: ControllerConfig,
    carry: DownscaleCarry,
) -> tuple[np.ndarray, DownscaleCarry, int, int]:
    """Vectorized Algorithm-1 decision sequence over one segment.

    Returns ``(downscaled_after_step, carry_out, n_downscales, n_restores)``
    where ``downscaled_after_step[i]`` equals the return value of
    :meth:`ExecutionIdleController.step` at sample ``i`` — verified exactly
    in tests/test_whatif.py over simulator and DES telemetry.

    The recurrence is vectorized by low/busy *runs*: within a low run the
    accumulator ``c`` is a strict left-fold (``np.add.accumulate``) matching
    the controller's repeated float addition, and the trigger index is the
    max of the first ``c > X`` sample and the first ``t >= t_cooldown``
    sample (both thresholds are monotone within a run). The Python loop is
    O(runs), not O(samples).
    """
    low = np.asarray(low, dtype=bool)
    ts = np.asarray(ts, dtype=np.float64)
    n = low.shape[0]
    out = np.zeros(n, dtype=bool)
    if n == 0:
        return out, carry, 0, 0
    c, t_cd, ds = carry.c, carry.t_cooldown, carry.downscaled
    eps, x, y = config.interval_eps_s, config.threshold_x_s, config.cooldown_y_s

    change = np.flatnonzero(np.diff(low)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [n]])
    n_down = n_rest = 0

    for s, e in zip(starts, ends):
        if not low[s]:
            # activity: c resets; restore (and start the cooldown clock) if
            # the device was downscaled — both happen at the run's first step
            if ds:
                ds = False
                n_rest += 1
                t_cd = float(ts[s]) + y
            c = 0.0
        elif ds:
            # already downscaled: stays downscaled for the whole low run.
            # c keeps accumulating in the controller but is unobservable
            # until the next activity resets it, so its value is dead here.
            out[s:e] = True
        else:
            m = e - s
            buf = np.empty(m + 1)
            buf[0] = c
            buf[1:] = eps
            cs = np.add.accumulate(buf)[1:]        # strict left-fold, as step()
            if cs[-1] > x:                          # cs is strictly increasing
                i_c = int(np.argmax(cs > x))
                i_t = int(np.searchsorted(ts[s:e], t_cd, side="left"))
                i = max(i_c, i_t)
                if i < m:
                    out[s + i:e] = True
                    ds = True
                    n_down += 1
            c = float(cs[-1])
    return out, DownscaleCarry(c=c, t_cooldown=t_cd, downscaled=ds), n_down, n_rest


@dataclasses.dataclass(frozen=True)
class DownscalePolicy:
    """Algorithm-1 frequency control replayed counterfactually (§5.3).

    Energy model: while downscaled (and the program is resident) the board
    power drops by the residency-floor gap
    ``exec_idle_w - residency_floor_w(f_min clocks)`` — downscaling attacks
    the floor, not the activity term — clipped below at deep-idle power.

    Penalty model: each downscale episode stalls the device for two clock
    switches (down + up, Velicka et al. [52]) plus one control interval of
    ramp at ``perf_scale(f_min)``; priced per *restore* event so totals are
    chunking-invariant.
    """

    config: ControllerConfig = ControllerConfig()
    switch_latency_s: float = 0.2
    compute_bound_fraction: float = 0.7

    @property
    def name(self) -> str:
        return "downscale"

    def describe(self) -> dict:
        return {
            "policy": self.name,
            "threshold_x_s": self.config.threshold_x_s,
            "cooldown_y_s": self.config.cooldown_y_s,
            "mode": self.config.mode.value,
            "switch_latency_s": self.switch_latency_s,
            "compute_bound_fraction": self.compute_bound_fraction,
            **_threshold_params(self.config),
        }

    def init_carry(self) -> DownscaleCarry:
        return DownscaleCarry()

    def _min_clocks(self) -> tuple[ClockLevel, ClockLevel]:
        if self.config.mode == DownscaleMode.SM_AND_MEM:
            return ClockLevel.MIN, ClockLevel.MIN
        return ClockLevel.MIN, ClockLevel.MAX

    def apply(self, seg: TelemetryFrame, plat: PlatformSpec,
              carry: DownscaleCarry,
              dt_s: float = 1.0) -> tuple[SegmentEffect, DownscaleCarry]:
        low = low_activity_series(seg, self.config)
        decisions, carry, n_down, n_rest = downscale_decisions(
            seg["timestamp"], low, self.config, carry)
        sm, mem = self._min_clocks()
        delta = plat.exec_idle_w - plat.residency_floor_w(sm, mem)
        resident = seg["program_resident"].astype(bool)
        throttled = decisions & resident
        power = np.asarray(seg["power"], dtype=np.float64)
        cf = np.where(throttled, np.maximum(power - delta, plat.deep_idle_w), power)
        return SegmentEffect(
            power_w=cf,
            resident=None,
            throttled=throttled,
            wake_events=n_rest,
            downscale_events=n_down,
        ), carry

    def event_penalty_s(self, plat: PlatformSpec) -> float:
        sm, mem = self._min_clocks()
        r = plat.perf_scale(sm, mem, self.compute_bound_fraction)
        return 2.0 * self.switch_latency_s + self.config.interval_eps_s * (1.0 - r)


# --------------------------------------------------------------------------- #
# Consolidation / parking (§5.1, k-of-n via core.imbalance)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class ParkCarry:
    prev_idle: bool = False


@dataclasses.dataclass(frozen=True)
class ParkingPolicy:
    """Deliberate-imbalance consolidation: park the n-k inactive devices.

    Device membership follows :meth:`repro.core.imbalance.PoolConfig
    .active_set` applied to consecutive blocks of ``pool.n_devices`` device
    ids (``device_id % n_devices``); parked devices drop their
    execution-idle samples to deep-idle power and residency (the program is
    evicted). Recorded active work on a parked device stays in place —
    a conservative counterfactual, since real consolidation migrates it —
    but each idle-to-active transition pays ``resume_latency_s`` of model
    reload (the Model Parking Tax).
    """

    pool: PoolConfig
    resume_latency_s: float = 10.0
    config: ControllerConfig = ControllerConfig()

    @property
    def name(self) -> str:
        return "parking"

    def describe(self) -> dict:
        return {
            "policy": self.name,
            "n_devices": self.pool.n_devices,
            "n_active": len(self.pool.active_set()),
            "resume_latency_s": self.resume_latency_s,
            **_threshold_params(self.config),
        }

    def init_carry(self) -> ParkCarry:
        return ParkCarry()

    def apply(self, seg: TelemetryFrame, plat: PlatformSpec, carry: ParkCarry,
              dt_s: float = 1.0) -> tuple[SegmentEffect, ParkCarry]:
        n = len(seg)
        power = np.asarray(seg["power"], dtype=np.float64)
        dev = int(seg["device_id"][0])
        if dev % self.pool.n_devices in self.pool.active_set():
            return SegmentEffect(
                power_w=power, resident=None, throttled=np.zeros(n, bool),
            ), carry
        low = low_activity_series(seg, self.config)
        resident = seg["program_resident"].astype(bool)
        idle = resident & low
        active = resident & ~low
        prev_idle = np.empty(n, dtype=bool)
        prev_idle[0] = carry.prev_idle
        prev_idle[1:] = idle[:-1]
        wakes = int(np.sum(active & prev_idle))
        return SegmentEffect(
            power_w=np.where(idle, plat.deep_idle_w, power),
            resident=resident & ~idle,
            throttled=idle,
            wake_events=wakes,
        ), ParkCarry(prev_idle=bool(idle[-1]))

    def event_penalty_s(self, plat: PlatformSpec) -> float:
        return self.resume_latency_s


# --------------------------------------------------------------------------- #
# Power capping
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PowerCapPolicy:
    """Cap board power at ``cap_fraction * tdp_w``.

    Capped *active* samples slow down by the cube-law frequency/power
    relation (perf ∝ f, power ∝ f³): each such sample loses
    ``dt_s * ((power/cap)^(1/3) - 1)`` seconds of progress, priced at the
    replayer's sampling interval. Penalty partials are fsum'd at finalize:
    identical for any fixed chunking (hence across worker counts), within
    one ulp across different chunkings (per-chunk ``np.sum`` rounding).
    """

    cap_fraction: float = 0.6
    config: ControllerConfig = ControllerConfig()

    @property
    def name(self) -> str:
        return "powercap"

    def describe(self) -> dict:
        return {"policy": self.name, "cap_fraction": self.cap_fraction,
                **_threshold_params(self.config)}

    def init_carry(self) -> None:
        return None

    def apply(self, seg: TelemetryFrame, plat: PlatformSpec, carry: None,
              dt_s: float = 1.0) -> tuple[SegmentEffect, None]:
        power = np.asarray(seg["power"], dtype=np.float64)
        cap_w = self.cap_fraction * plat.tdp_w
        over = power > cap_w
        low = low_activity_series(seg, self.config)
        resident = seg["program_resident"].astype(bool)
        capped_active = over & resident & ~low
        slow = np.cbrt(power[capped_active] / cap_w) - 1.0
        return SegmentEffect(
            power_w=np.minimum(power, cap_w),
            resident=None,
            throttled=over,
            penalty_partial_s=dt_s * float(np.sum(slow)),
        ), None

    def event_penalty_s(self, plat: PlatformSpec) -> float:
        return 0.0

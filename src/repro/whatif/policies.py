"""Candidate execution-idle mitigation policies for counterfactual replay.

Each policy answers, per telemetry sample of one (job, host, device) stream:
*what would the device have done under this mitigation*, expressed as a
counterfactual board power (and optionally residency) series plus a modeled
performance penalty. Policies are **vectorized** and **streaming**: ``apply``
consumes time-ordered segments of any size and carries state across segment
boundaries, so a replay over 1-row chunks, storage shards, or the whole
stream produces the exact same decision sequence.

The policy set mirrors the paper's mitigation space:

* :class:`DownscalePolicy` — Algorithm 1 (§5.3) frequency control, a
  vectorized re-derivation of
  :class:`repro.core.controller.ExecutionIdleController` whose decision
  sequence is verified identical to the step-by-step controller
  (tests/test_whatif.py);
* :class:`ParkingPolicy` — §5.1 consolidation: k-of-n devices serve, the
  rest park their execution-idle time at deep-idle power, paying a
  model-reload tax per wake (the "Model Parking Tax" trade-off);
* :class:`PowerCapPolicy` — board power capping with a cube-law slowdown on
  capped active samples (deadline-aware frequency-scaling baseline);
* :class:`NoOpPolicy` — the recorded fleet, unchanged (frontier origin);
* :class:`CompositePolicy` — any sequence of the above applied in order
  (e.g. park the n-k inactive devices, downscale the rest), a first-class
  policy in the :mod:`repro.whatif.effects` algebra.

Every policy validates its knobs at construction — a malformed grid point
raises a ``ValueError`` naming the knob, instead of failing deep inside the
replay.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.energy import EnergyBreakdown, integrate_runs
from repro.core.imbalance import PoolConfig
from repro.core.power_model import ClockLevel, PlatformSpec
from repro.core.states import (COMMUNICATION_SIGNALS, COMPUTE_SIGNALS,
                               DeviceState)
from repro.telemetry.records import TelemetryFrame
from repro.whatif.effects import (BatchEffect, SegmentEffect, compose,
                                  effect_view, identity_effect,
                                  policy_event_channels, policy_event_prices)


def _threshold_params(config: ControllerConfig) -> dict:
    """Signal-threshold knobs shared by every policy's ``describe()`` —
    ``describe()`` doubles as the merge-compatibility key, so every knob
    that changes decisions must appear in it."""
    return {
        "interval_eps_s": config.interval_eps_s,
        "activity_threshold": config.activity_threshold,
        "comm_threshold_gbs": config.comm_threshold_gbs,
    }


def low_activity_series(seg: TelemetryFrame, config: ControllerConfig) -> np.ndarray:
    """Vectorized Algorithm-1 low-activity predicate over one segment.

    Matches :meth:`ExecutionIdleController._low_activity` exactly when the
    controller is fed the same samples with activity as fractions
    (percent / 100) and NaN (signal unavailable) replaced by 0.0.

    Memoized per segment object and threshold pair: a sweep feeds the same
    segment to every grid config (``replay_chunk``), and most configs share
    thresholds, so the ~12 full-array passes run once, not once per config.
    """
    key = (config.activity_threshold, config.comm_threshold_gbs)
    cache = getattr(seg, "_low_cache", None)
    if cache is None:
        cache = seg._low_cache = {}
    cached = cache.get(key)
    if cached is not None:
        return cached
    n = len(seg)
    comp = np.zeros(n)
    for k in COMPUTE_SIGNALS:
        comp = np.maximum(comp, np.nan_to_num(seg[k], nan=0.0))
    mem = np.nan_to_num(seg["dram"], nan=0.0)
    comm = np.zeros(n)
    for k in COMMUNICATION_SIGNALS:
        comm = np.maximum(comm, np.nan_to_num(seg[k], nan=0.0))
    low = ((comp / 100.0 < config.activity_threshold)
           & (mem / 100.0 < config.activity_threshold)
           & (comm < config.comm_threshold_gbs))
    cache[key] = low
    return low


@runtime_checkable
class Policy(Protocol):
    """What the replayer needs from a mitigation policy."""

    @property
    def name(self) -> str: ...
    def describe(self) -> dict: ...
    def init_carry(self) -> Any: ...
    def apply(self, seg: TelemetryFrame, plat: PlatformSpec, carry: Any,
              dt_s: float = 1.0) -> tuple[SegmentEffect, Any]: ...
    def event_penalty_s(self, plat: PlatformSpec) -> float: ...


# --------------------------------------------------------------------------- #
# No-op baseline
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class NoOpPolicy:
    """The recorded fleet, unchanged — anchors the frontier at (0, 0)."""

    @property
    def name(self) -> str:
        return "noop"

    def describe(self) -> dict:
        return {"policy": self.name}

    def init_carry(self) -> None:
        return None

    def apply(self, seg: TelemetryFrame, plat: PlatformSpec, carry: None,
              dt_s: float = 1.0) -> tuple[SegmentEffect, None]:
        n = len(seg)
        return SegmentEffect(
            power_w=np.asarray(seg["power"], dtype=np.float64),
            resident=None,
            throttled=np.zeros(n, dtype=bool),
        ), None

    def event_penalty_s(self, plat: PlatformSpec) -> float:
        return 0.0


# --------------------------------------------------------------------------- #
# Algorithm-1 downscaling, vectorized
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class DownscaleCarry:
    """Controller state carried across segment boundaries.

    ``c`` is the consecutive low-activity accumulator *as the step controller
    would hold it* (left-fold float additions of ``interval_eps_s``), so the
    trigger comparison ``c > X`` lands on the same sample for every chunking.
    """

    c: float = 0.0
    t_cooldown: float = 0.0
    downscaled: bool = False


def downscale_decisions(
    ts: np.ndarray,
    low: np.ndarray,
    config: ControllerConfig,
    carry: DownscaleCarry,
) -> tuple[np.ndarray, DownscaleCarry, int, int]:
    """Vectorized Algorithm-1 decision sequence over one segment.

    Returns ``(downscaled_after_step, carry_out, n_downscales, n_restores)``
    where ``downscaled_after_step[i]`` equals the return value of
    :meth:`ExecutionIdleController.step` at sample ``i`` — verified exactly
    in tests/test_whatif.py over simulator and DES telemetry.

    The recurrence is vectorized by low/busy *runs*: within a low run the
    accumulator ``c`` is a strict left-fold (``np.add.accumulate``) matching
    the controller's repeated float addition, and the trigger index is the
    max of the first ``c > X`` sample and the first ``t >= t_cooldown``
    sample (both thresholds are monotone within a run). The Python loop is
    O(runs), not O(samples).
    """
    low = np.asarray(low, dtype=bool)
    ts = np.asarray(ts, dtype=np.float64)
    n = low.shape[0]
    out = np.zeros(n, dtype=bool)
    if n == 0:
        return out, carry, 0, 0
    c, t_cd, ds = carry.c, carry.t_cooldown, carry.downscaled
    eps, x, y = config.interval_eps_s, config.threshold_x_s, config.cooldown_y_s

    change = np.flatnonzero(np.diff(low)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [n]])
    n_down = n_rest = 0

    for s, e in zip(starts, ends):
        if not low[s]:
            # activity: c resets; restore (and start the cooldown clock) if
            # the device was downscaled — both happen at the run's first step
            if ds:
                ds = False
                n_rest += 1
                t_cd = float(ts[s]) + y
            c = 0.0
        elif ds:
            # already downscaled: stays downscaled for the whole low run.
            # c keeps accumulating in the controller but is unobservable
            # until the next activity resets it, so its value is dead here.
            out[s:e] = True
        else:
            m = e - s
            buf = np.empty(m + 1)
            buf[0] = c
            buf[1:] = eps
            cs = np.add.accumulate(buf)[1:]        # strict left-fold, as step()
            if cs[-1] > x:                          # cs is strictly increasing
                i_c = int(np.argmax(cs > x))
                i_t = int(np.searchsorted(ts[s:e], t_cd, side="left"))
                i = max(i_c, i_t)
                if i < m:
                    out[s + i:e] = True
                    ds = True
                    n_down += 1
            c = float(cs[-1])
    return out, DownscaleCarry(c=c, t_cooldown=t_cd, downscaled=ds), n_down, n_rest


@dataclasses.dataclass(frozen=True)
class DownscalePolicy:
    """Algorithm-1 frequency control replayed counterfactually (§5.3).

    Energy model: while downscaled (and the program is resident) the board
    power drops by the residency-floor gap
    ``exec_idle_w - residency_floor_w(f_min clocks)`` — downscaling attacks
    the floor, not the activity term — clipped below at deep-idle power.

    Penalty model: each downscale episode stalls the device for two clock
    switches (down + up, Velicka et al. [52]) plus one control interval of
    ramp at ``perf_scale(f_min)``; priced per *restore* event so totals are
    chunking-invariant.
    """

    config: ControllerConfig = ControllerConfig()
    switch_latency_s: float = 0.2
    compute_bound_fraction: float = 0.7

    def __post_init__(self) -> None:
        if not self.config.threshold_x_s > 0:
            raise ValueError(
                f"DownscalePolicy threshold_x_s must be positive, got "
                f"{self.config.threshold_x_s}")
        if not self.config.cooldown_y_s > 0:
            raise ValueError(
                f"DownscalePolicy cooldown_y_s must be positive, got "
                f"{self.config.cooldown_y_s}")
        if not self.config.interval_eps_s > 0:
            raise ValueError(
                f"DownscalePolicy interval_eps_s must be positive, got "
                f"{self.config.interval_eps_s}")
        if self.switch_latency_s < 0:
            raise ValueError(
                f"DownscalePolicy switch_latency_s must be >= 0, got "
                f"{self.switch_latency_s}")

    @property
    def name(self) -> str:
        return "downscale"

    def describe(self) -> dict:
        return {
            "policy": self.name,
            "threshold_x_s": self.config.threshold_x_s,
            "cooldown_y_s": self.config.cooldown_y_s,
            "mode": self.config.mode.value,
            "switch_latency_s": self.switch_latency_s,
            "compute_bound_fraction": self.compute_bound_fraction,
            **_threshold_params(self.config),
        }

    def init_carry(self) -> DownscaleCarry:
        return DownscaleCarry()

    def _min_clocks(self) -> tuple[ClockLevel, ClockLevel]:
        if self.config.mode == DownscaleMode.SM_AND_MEM:
            return ClockLevel.MIN, ClockLevel.MIN
        return ClockLevel.MIN, ClockLevel.MAX

    def apply(self, seg: TelemetryFrame, plat: PlatformSpec,
              carry: DownscaleCarry,
              dt_s: float = 1.0) -> tuple[SegmentEffect, DownscaleCarry]:
        low = low_activity_series(seg, self.config)
        decisions, carry, n_down, n_rest = downscale_decisions(
            seg["timestamp"], low, self.config, carry)
        sm, mem = self._min_clocks()
        delta = plat.exec_idle_w - plat.residency_floor_w(sm, mem)
        resident = seg["program_resident"].astype(bool)
        throttled = decisions & resident
        power = np.asarray(seg["power"], dtype=np.float64)
        cf = np.where(throttled, np.maximum(power - delta, plat.deep_idle_w), power)
        return SegmentEffect(
            power_w=cf,
            resident=None,
            throttled=throttled,
            wake_events=n_rest,
            downscale_events=n_down,
        ), carry

    def event_penalty_s(self, plat: PlatformSpec) -> float:
        sm, mem = self._min_clocks()
        r = plat.perf_scale(sm, mem, self.compute_bound_fraction)
        return 2.0 * self.switch_latency_s + self.config.interval_eps_s * (1.0 - r)


# --------------------------------------------------------------------------- #
# Consolidation / parking (§5.1, k-of-n via core.imbalance)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class ParkCarry:
    prev_idle: bool = False


@dataclasses.dataclass(frozen=True)
class ParkingPolicy:
    """Deliberate-imbalance consolidation: park the n-k inactive devices.

    Device membership follows :meth:`repro.core.imbalance.PoolConfig
    .active_set` applied to consecutive blocks of ``pool.n_devices`` device
    ids (``device_id % n_devices``); parked devices drop their
    execution-idle samples to deep-idle power and residency (the program is
    evicted). Recorded active work on a parked device stays in place —
    a conservative counterfactual, since real consolidation migrates it —
    but each idle-to-active transition pays ``resume_latency_s`` of model
    reload (the Model Parking Tax).
    """

    pool: PoolConfig
    resume_latency_s: float = 10.0
    config: ControllerConfig = ControllerConfig()

    def __post_init__(self) -> None:
        if self.pool.n_devices < 1:
            raise ValueError(
                f"ParkingPolicy pool must have >= 1 device, got "
                f"{self.pool.n_devices}")
        if self.pool.n_active is not None and not (
                1 <= self.pool.n_active <= self.pool.n_devices):
            raise ValueError(
                f"ParkingPolicy requires 1 <= n_active <= n_devices, got "
                f"n_active={self.pool.n_active} for a pool of "
                f"{self.pool.n_devices}")
        self.pool.active_set()   # BALANCED/CONSOLIDATED consistency check
        if self.resume_latency_s < 0:
            raise ValueError(
                f"ParkingPolicy resume_latency_s must be >= 0, got "
                f"{self.resume_latency_s}")

    @property
    def name(self) -> str:
        return "parking"

    def describe(self) -> dict:
        return {
            "policy": self.name,
            "n_devices": self.pool.n_devices,
            "n_active": len(self.pool.active_set()),
            "resume_latency_s": self.resume_latency_s,
            **_threshold_params(self.config),
        }

    def init_carry(self) -> ParkCarry:
        return ParkCarry()

    def apply(self, seg: TelemetryFrame, plat: PlatformSpec, carry: ParkCarry,
              dt_s: float = 1.0) -> tuple[SegmentEffect, ParkCarry]:
        n = len(seg)
        power = np.asarray(seg["power"], dtype=np.float64)
        dev = int(seg["device_id"][0])
        if dev % self.pool.n_devices in self.pool.active_set():
            return SegmentEffect(
                power_w=power, resident=None, throttled=np.zeros(n, bool),
            ), carry
        low = low_activity_series(seg, self.config)
        resident = seg["program_resident"].astype(bool)
        idle = resident & low
        active = resident & ~low
        prev_idle = np.empty(n, dtype=bool)
        prev_idle[0] = carry.prev_idle
        prev_idle[1:] = idle[:-1]
        wakes = int(np.sum(active & prev_idle))
        return SegmentEffect(
            power_w=np.where(idle, plat.deep_idle_w, power),
            resident=resident & ~idle,
            throttled=idle,
            wake_events=wakes,
        ), ParkCarry(prev_idle=bool(idle[-1]))

    def event_penalty_s(self, plat: PlatformSpec) -> float:
        return self.resume_latency_s


# --------------------------------------------------------------------------- #
# Power capping
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PowerCapPolicy:
    """Cap board power at ``cap_fraction * tdp_w``.

    Capped *active* samples slow down by the cube-law frequency/power
    relation (perf ∝ f, power ∝ f³): each such sample loses
    ``dt_s * ((power/cap)^(1/3) - 1)`` seconds of progress, priced at the
    replayer's sampling interval. Penalty partials are fsum'd at finalize:
    identical for any fixed chunking (hence across worker counts), within
    one ulp across different chunkings (per-chunk ``np.sum`` rounding).
    """

    cap_fraction: float = 0.6
    config: ControllerConfig = ControllerConfig()

    def __post_init__(self) -> None:
        if not 0.0 < self.cap_fraction <= 1.0:
            raise ValueError(
                f"PowerCapPolicy cap_fraction must be in (0, 1], got "
                f"{self.cap_fraction}")

    @property
    def name(self) -> str:
        return "powercap"

    def describe(self) -> dict:
        return {"policy": self.name, "cap_fraction": self.cap_fraction,
                **_threshold_params(self.config)}

    def init_carry(self) -> None:
        return None

    def apply(self, seg: TelemetryFrame, plat: PlatformSpec, carry: None,
              dt_s: float = 1.0) -> tuple[SegmentEffect, None]:
        power = np.asarray(seg["power"], dtype=np.float64)
        cap_w = self.cap_fraction * plat.tdp_w
        over = power > cap_w
        low = low_activity_series(seg, self.config)
        resident = seg["program_resident"].astype(bool)
        capped_active = over & resident & ~low
        slow = np.cbrt(power[capped_active] / cap_w) - 1.0
        return SegmentEffect(
            power_w=np.minimum(power, cap_w),
            resident=None,
            throttled=over,
            penalty_partial_s=dt_s * float(np.sum(slow)),
        ), None

    def event_penalty_s(self, plat: PlatformSpec) -> float:
        return 0.0


# --------------------------------------------------------------------------- #
# Sequential composition (the effect algebra's product)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class CompositePolicy:
    """Apply ``parts`` in sequence: each part sees the previous part's
    counterfactual (power and residency overridden, every signal column
    recorded) and the effects fold through
    :func:`repro.whatif.effects.compose`.

    The motivating composite is the operator's real mitigation: park the
    pool's inactive devices and downscale the ones that keep serving —
    ``CompositePolicy((ParkingPolicy(pool), DownscalePolicy(cfg)))``. The
    two parts act on disjoint device sets (parking no-ops on active devices;
    on parked devices the idle samples lose residency, so downscale's
    ``throttled = decisions & resident`` no-ops there), and each part prices
    its own events: part ``i``'s wake counts occupy their own pricing
    channel, so parking wakes cost the resume latency while downscale
    restores cost the clock-switch stall (see
    :func:`repro.whatif.effects.policy_event_prices`).

    Composition is sequential, not commutative in general — parts that touch
    the same samples (e.g. downscale then power-cap) compose like the real
    controllers would, downstream of each other's output.
    """

    parts: tuple[Policy, ...]

    def __post_init__(self) -> None:
        if not self.parts:
            raise ValueError("CompositePolicy requires at least one part")
        for p in self.parts:
            if not isinstance(p, Policy):
                raise ValueError(
                    f"CompositePolicy parts must implement the Policy "
                    f"protocol, got {type(p).__name__}")
        object.__setattr__(self, "parts", tuple(self.parts))

    @property
    def name(self) -> str:
        return "+".join(p.name for p in self.parts)

    def describe(self) -> dict:
        return {"policy": "composite",
                "parts": [p.describe() for p in self.parts]}

    @property
    def n_event_channels(self) -> int:
        return sum(policy_event_channels(p) for p in self.parts)

    def event_prices_s(self, plat: PlatformSpec) -> np.ndarray:
        """Concatenated per-part price vectors, in part order."""
        return np.concatenate(
            [policy_event_prices(p, plat) for p in self.parts])

    def event_penalty_s(self, plat: PlatformSpec) -> float:
        """Unused: composite events are priced per channel via
        :meth:`event_prices_s` (each part keeps its own per-event cost)."""
        return 0.0

    def init_carry(self) -> tuple:
        return tuple(p.init_carry() for p in self.parts)

    def apply(self, seg: TelemetryFrame, plat: PlatformSpec, carry: tuple,
              dt_s: float = 1.0) -> tuple[SegmentEffect, tuple]:
        k_total = self.n_event_channels
        eff = identity_effect(seg, n_channels=k_total)
        cur = seg
        out_carries = []
        k0 = 0
        for i, (p, c) in enumerate(zip(self.parts, carry)):
            if i > 0:
                cur = effect_view(cur, part_eff)
            part_eff, c2 = p.apply(cur, plat, c, dt_s=dt_s)
            out_carries.append(c2)
            kp = policy_event_channels(p)
            events = np.zeros(k_total, dtype=np.int64)
            events[k0:k0 + kp] = part_eff.event_vector(kp)
            eff = compose(eff, dataclasses.replace(part_eff, events=events))
            k0 += kp
        return eff, tuple(out_carries)


# --------------------------------------------------------------------------- #
# Family-batched evaluators (config-axis replay)
# --------------------------------------------------------------------------- #
@runtime_checkable
class PolicyBatch(Protocol):
    """A family of policy configs evaluated in one pass per segment.

    The config-axis analogue of :class:`Policy`: ``apply_batch`` consumes the
    same time-ordered segments, carries one (vectorized) state across segment
    boundaries for the whole family, and must be **bit-identical**, per
    member config, to that config's scalar :meth:`Policy.apply` replay.
    """

    @property
    def policies(self) -> tuple[Policy, ...]: ...
    def init_carry(self) -> Any: ...
    def apply_batch(self, seg: TelemetryFrame, plat: PlatformSpec, carry: Any,
                    dt_s: float = 1.0) -> tuple[BatchEffect, Any]: ...


def _identity_effect(n: int, n_configs: int) -> BatchEffect:
    return BatchEffect(
        power_rows=np.empty((0, n)),
        throttled_rows=np.empty((0, n), dtype=bool),
        row_of=np.full(n_configs, -1, dtype=np.int64),
        resident_rows=None,
        penalty_partial_s=np.zeros(n_configs),
        wake_events=np.zeros(n_configs, dtype=np.int64),
        downscale_events=np.zeros(n_configs, dtype=np.int64),
    )


@dataclasses.dataclass(frozen=True)
class NoOpBatch:
    """All members are the recorded fleet: every config aliases baseline."""

    policies: tuple[NoOpPolicy, ...]

    def init_carry(self) -> None:
        return None

    def apply_batch(self, seg: TelemetryFrame, plat: PlatformSpec, carry: None,
                    dt_s: float = 1.0) -> tuple[BatchEffect, None]:
        return _identity_effect(len(seg), len(self.policies)), None

    def apply_runs(self, stream, plat: PlatformSpec, min_samples: int,
                   dt_s: float) -> "RunBatchResult":
        return _identity_run_result(len(self.policies))


@dataclasses.dataclass
class BatchDownscaleCarry:
    """Per-config controller state, carried across segment boundaries.

    The vector form of :class:`DownscaleCarry`: element ``c`` of each array
    is exactly what the scalar carry would hold after the same samples.
    """

    c: np.ndarray            # [C] consecutive low-activity accumulators
    t_cooldown: np.ndarray   # [C]
    downscaled: np.ndarray   # [C] bool


def batched_downscale_decisions(
    ts: np.ndarray,
    low: np.ndarray,
    eps: np.ndarray,
    x: np.ndarray,
    y: np.ndarray,
    carry: BatchDownscaleCarry,
) -> tuple[np.ndarray, BatchDownscaleCarry, np.ndarray, np.ndarray]:
    """Config-axis Algorithm-1 decision sequences over one segment.

    The same low/busy-run loop as :func:`downscale_decisions`, advanced for
    every config of the family per run with vector ops over the config axis —
    O(runs) Python for the *whole grid* instead of per config. Bit-identical
    per config: the in-run accumulator is the same strict left-fold
    (``np.add.accumulate`` along the sample axis is sequential per row), the
    trigger index the same max of first ``c > X`` and first ``t >=
    t_cooldown`` sample, and the restore/cooldown updates the same elementwise
    float ops the scalar recurrence performs.

    Returns ``(downscaled_after_step [C, n], carry_out, n_downscales [C],
    n_restores [C])``.
    """
    low = np.asarray(low, dtype=bool)
    ts = np.asarray(ts, dtype=np.float64)
    n = low.shape[0]
    n_cfg = eps.shape[0]
    out = np.zeros((n_cfg, n), dtype=bool)
    n_down = np.zeros(n_cfg, dtype=np.int64)
    n_rest = np.zeros(n_cfg, dtype=np.int64)
    if n == 0:
        return out, carry, n_down, n_rest
    c = carry.c.copy()
    t_cd = carry.t_cooldown.copy()
    ds = carry.downscaled.copy()

    change = np.flatnonzero(np.diff(low)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [n]])

    for s, e in zip(starts, ends):
        if not low[s]:
            # activity: c resets; configs that were downscaled restore (and
            # start their cooldown clock) at the run's first step
            n_rest += ds
            t_cd[ds] = float(ts[s]) + y[ds]
            ds[:] = False
            c[:] = 0.0
        else:
            m = e - s
            # already-downscaled configs stay downscaled for the whole run
            # (their c is unobservable until the next activity resets it)
            out[ds, s:e] = True
            idle = np.flatnonzero(~ds)
            if idle.size:
                buf = np.empty((idle.size, m + 1))
                buf[:, 0] = c[idle]
                buf[:, 1:] = eps[idle, None]
                cs = np.add.accumulate(buf, axis=1)[:, 1:]  # left-fold per row
                trig = cs[:, -1] > x[idle]                  # strictly increasing
                if np.any(trig):
                    i_c = np.argmax(cs > x[idle, None], axis=1)
                    i_t = np.searchsorted(ts[s:e], t_cd[idle], side="left")
                    i = np.maximum(i_c, i_t)
                    fire = trig & (i < m)
                    rows = idle[fire]
                    if rows.size:
                        out[rows, s:e] = np.arange(m) >= i[fire][:, None]
                        ds[rows] = True
                        n_down[rows] += 1
                c[idle] = cs[:, -1]
    return out, BatchDownscaleCarry(c=c, t_cooldown=t_cd, downscaled=ds), \
        n_down, n_rest


@dataclasses.dataclass(frozen=True)
class DownscaleBatch:
    """Every downscale config sharing one low-activity series, one pass.

    Members must agree on ``(activity_threshold, comm_threshold_gbs)`` (the
    low-series key — enforced by :func:`make_batches`); X, Y, eps and the
    clock mode vary freely along the config axis.
    """

    policies: tuple[DownscalePolicy, ...]

    def __post_init__(self) -> None:
        pols = self.policies
        object.__setattr__(self, "_eps",
                           np.array([p.config.interval_eps_s for p in pols]))
        object.__setattr__(self, "_x",
                           np.array([p.config.threshold_x_s for p in pols]))
        object.__setattr__(self, "_y",
                           np.array([p.config.cooldown_y_s for p in pols]))
        object.__setattr__(self, "_trig", _trigger_indices(self._eps, self._x))
        object.__setattr__(self, "_delta_cache", {})

    def init_carry(self) -> BatchDownscaleCarry:
        n_cfg = len(self.policies)
        return BatchDownscaleCarry(
            c=np.zeros(n_cfg),
            t_cooldown=np.zeros(n_cfg),
            downscaled=np.zeros(n_cfg, dtype=bool),
        )

    def _delta(self, plat: PlatformSpec) -> np.ndarray:
        delta = self._delta_cache.get(plat.name)
        if delta is None:
            delta = self._delta_cache[plat.name] = np.array([
                plat.exec_idle_w - plat.residency_floor_w(*p._min_clocks())
                for p in self.policies])
        return delta

    def apply_batch(self, seg: TelemetryFrame, plat: PlatformSpec,
                    carry: BatchDownscaleCarry,
                    dt_s: float = 1.0) -> tuple[BatchEffect, BatchDownscaleCarry]:
        pols = self.policies
        low = low_activity_series(seg, pols[0].config)
        decisions, carry, n_down, n_rest = batched_downscale_decisions(
            seg["timestamp"], low, self._eps, self._x, self._y, carry)
        delta = self._delta(plat)
        resident = seg["program_resident"].astype(bool)
        throttled = decisions & resident[None, :]
        power = np.asarray(seg["power"], dtype=np.float64)
        cf = np.where(throttled,
                      np.maximum(power[None, :] - delta[:, None],
                                 plat.deep_idle_w),
                      power[None, :])
        n_cfg = len(pols)
        return BatchEffect(
            power_rows=cf,
            throttled_rows=throttled,
            row_of=np.arange(n_cfg, dtype=np.int64),
            resident_rows=None,
            penalty_partial_s=np.zeros(n_cfg),
            wake_events=n_rest,
            downscale_events=n_down,
        ), carry

    def apply_runs(self, stream, plat: PlatformSpec, min_samples: int,
                   dt_s: float) -> "RunBatchResult":
        """Whole-stream replay against the run axis: O(low runs) decisions
        for the whole family, savings gathered from shared prefix sums —
        no ``(n_configs, n_samples)`` block is ever built."""
        n_cfg = len(self.policies)
        n_down, n_rest, throttled, sav_exec, sav_act = _run_downscale(
            stream, plat, min_samples, dt_s, self._eps, self._x, self._y,
            self._trig, self._delta(plat))
        base = stream.baseline(min_samples)
        return RunBatchResult(
            row_of=np.arange(n_cfg, dtype=np.int64),
            cf_rows=_downscale_breakdowns(base, sav_exec, sav_act, dt_s),
            penalty_partial_s=np.zeros(n_cfg),
            wake_events=n_rest,
            downscale_events=n_down,
            throttled_samples=throttled,
        )


@dataclasses.dataclass(frozen=True)
class ParkingBatch:
    """Every parking config, one pass: a device stream is either parked or
    untouched, and *all* parked configs share one counterfactual row — the
    parked power/residency series is independent of the pool shape and the
    resume latency (which only prices the shared wake count at finalize).
    Members must agree on the low-series thresholds (:func:`make_batches`).
    """

    policies: tuple[ParkingPolicy, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "_pools", tuple(
            (p.pool.n_devices, frozenset(p.pool.active_set()))
            for p in self.policies))

    def init_carry(self) -> ParkCarry:
        return ParkCarry()

    def apply_batch(self, seg: TelemetryFrame, plat: PlatformSpec,
                    carry: ParkCarry,
                    dt_s: float = 1.0) -> tuple[BatchEffect, ParkCarry]:
        n = len(seg)
        n_cfg = len(self.policies)
        dev = int(seg["device_id"][0])
        parked = np.array([dev % nd not in act for nd, act in self._pools],
                          dtype=bool)
        if not parked.any():
            return _identity_effect(n, n_cfg), carry
        low = low_activity_series(seg, self.policies[0].config)
        resident = seg["program_resident"].astype(bool)
        idle = resident & low
        active = resident & ~low
        prev_idle = np.empty(n, dtype=bool)
        prev_idle[0] = carry.prev_idle
        prev_idle[1:] = idle[:-1]
        wakes = int(np.sum(active & prev_idle))
        power = np.asarray(seg["power"], dtype=np.float64)
        return BatchEffect(
            power_rows=np.where(idle, plat.deep_idle_w, power)[None, :],
            throttled_rows=idle[None, :],
            row_of=np.where(parked, 0, -1).astype(np.int64),
            resident_rows=(resident & ~idle)[None, :],
            penalty_partial_s=np.zeros(n_cfg),
            wake_events=np.where(parked, wakes, 0).astype(np.int64),
            downscale_events=np.zeros(n_cfg, dtype=np.int64),
        ), ParkCarry(prev_idle=bool(idle[-1]))

    def apply_runs(self, stream, plat: PlatformSpec, min_samples: int,
                   dt_s: float) -> "RunBatchResult":
        """Run-level parking: the parked counterfactual is pure run algebra
        (idle runs drop to deep-idle power and residency; wakes are
        idle-to-active run adjacencies), and — as in the row path — every
        parked config shares the one counterfactual breakdown."""
        n_cfg = len(self.policies)
        dev = stream.key[2]
        parked = np.array([dev % nd not in act for nd, act in self._pools],
                          dtype=bool)
        if not parked.any():
            return _identity_run_result(n_cfg)
        bd, pk = _parking_breakdown(stream, plat, min_samples, dt_s)
        return RunBatchResult(
            row_of=np.where(parked, 0, -1).astype(np.int64),
            cf_rows=[bd],
            penalty_partial_s=np.zeros(n_cfg),
            wake_events=np.where(parked, pk["wakes"], 0).astype(np.int64),
            downscale_events=np.zeros(n_cfg, dtype=np.int64),
            throttled_samples=np.where(parked, pk["idle_samples"],
                                       0).astype(np.int64),
        )


@dataclasses.dataclass(frozen=True)
class PowerCapBatch:
    """Every cap fraction in one pass: the [C, n] capped power grid is two
    broadcast ops; the per-config cube-law penalty gathers the shared
    active-sample power once and masks it per cap (the one O(configs) loop,
    kept scalar so each config's ``np.sum`` reduces exactly the array the
    scalar policy reduces). Members must agree on the low-series thresholds.
    """

    policies: tuple[PowerCapPolicy, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "_fracs", np.array(
            [p.cap_fraction for p in self.policies]))

    def init_carry(self) -> None:
        return None

    def apply_batch(self, seg: TelemetryFrame, plat: PlatformSpec, carry: None,
                    dt_s: float = 1.0) -> tuple[BatchEffect, None]:
        pols = self.policies
        n_cfg = len(pols)
        power = np.asarray(seg["power"], dtype=np.float64)
        cap_w = self._fracs * plat.tdp_w
        over = power[None, :] > cap_w[:, None]
        cf = np.minimum(power[None, :], cap_w[:, None])
        low = low_activity_series(seg, pols[0].config)
        resident = seg["program_resident"].astype(bool)
        pw_active = power[resident & ~low]
        penalty = np.empty(n_cfg)
        for i in range(n_cfg):
            slow = np.cbrt(pw_active[pw_active > cap_w[i]] / cap_w[i]) - 1.0
            penalty[i] = dt_s * float(np.sum(slow))
        return BatchEffect(
            power_rows=cf,
            throttled_rows=over,
            row_of=np.arange(n_cfg, dtype=np.int64),
            resident_rows=None,
            penalty_partial_s=penalty,
            wake_events=np.zeros(n_cfg, dtype=np.int64),
            downscale_events=np.zeros(n_cfg, dtype=np.int64),
        ), None

    def apply_runs(self, stream, plat: PlatformSpec, min_samples: int,
                   dt_s: float) -> "RunBatchResult":
        """Every cap fraction against sorted-power prefix structures: a
        cap's clipped energy, throttle count and cube-law penalty are each
        one vectorized ``searchsorted`` per accounting bucket — O(log n)
        per config after a shared O(n log n) build, instead of an
        O(n_samples) ``minimum``/``cbrt`` pass per config."""
        n_cfg = len(self.policies)
        caps = self._fracs * plat.tdp_w
        buckets = stream.cap_buckets(min_samples)
        base = stream.baseline(min_samples)
        throttled = np.zeros(n_cfg, dtype=np.int64)
        energy_cf: dict[DeviceState, np.ndarray] = {}
        for s in DeviceState:
            sorted_p, top_sum = buckets[int(s)]
            k = sorted_p.shape[0] - np.searchsorted(sorted_p, caps,
                                                    side="right")
            energy_cf[s] = base.energy_j[s] - (top_sum[k] - k * caps) * dt_s
            throttled += k
        sorted_p, _, top_cbrt = buckets["penalty"]
        kp = sorted_p.shape[0] - np.searchsorted(sorted_p, caps, side="right")
        penalty = dt_s * (top_cbrt[kp] / np.cbrt(caps) - kp)
        cf_rows = [
            EnergyBreakdown(
                time_s=base.time_s,
                energy_j={s: float(energy_cf[s][c]) for s in DeviceState})
            for c in range(n_cfg)
        ]
        return RunBatchResult(
            row_of=np.arange(n_cfg, dtype=np.int64),
            cf_rows=cf_rows,
            penalty_partial_s=penalty,
            wake_events=np.zeros(n_cfg, dtype=np.int64),
            downscale_events=np.zeros(n_cfg, dtype=np.int64),
            throttled_samples=throttled,
        )


@dataclasses.dataclass(frozen=True)
class FallbackBatch:
    """Config axis of one: any :class:`Policy` implementation, replayed via
    its own scalar ``apply``. Keeps the batched replayer total over arbitrary
    grids — unknown policy types lose the sharing, not correctness.
    """

    policies: tuple[Policy, ...]     # always length 1

    def init_carry(self) -> Any:
        return self.policies[0].init_carry()

    def apply_batch(self, seg: TelemetryFrame, plat: PlatformSpec, carry: Any,
                    dt_s: float = 1.0) -> tuple[BatchEffect, Any]:
        effect, carry = self.policies[0].apply(seg, plat, carry, dt_s=dt_s)
        # always report a residency row (recorded residency when the policy
        # leaves it unchanged): a custom policy may alternate between None
        # and an override across segments, and the replayer requires a
        # stream-stable row structure. Classifying the recorded residency
        # reproduces the baseline states exactly, so this costs one extra
        # classification, never correctness.
        resident = (seg["program_resident"].astype(bool)
                    if effect.resident is None else effect.resident)
        return BatchEffect(
            power_rows=np.asarray(effect.power_w, dtype=np.float64)[None, :],
            throttled_rows=np.asarray(effect.throttled, dtype=bool)[None, :],
            row_of=np.zeros(1, dtype=np.int64),
            resident_rows=np.asarray(resident, dtype=bool)[None, :],
            penalty_partial_s=np.array([effect.penalty_partial_s]),
            wake_events=np.array([effect.wake_events], dtype=np.int64),
            downscale_events=np.array([effect.downscale_events],
                                      dtype=np.int64),
            events_rows=(None if effect.events is None
                         else effect.events[None, :]),
        ), carry


@dataclasses.dataclass(frozen=True)
class CompositeBatch:
    """Config axis over composites sharing one part structure.

    Members apply their parts sequentially through the scalar
    :meth:`CompositePolicy.apply` (each member's downstream parts see *that
    member's* intermediate counterfactual, so their series differ per member
    and cannot share rows), but the batch still rides the replayer's shared
    per-segment work: one stream grouping, one baseline classification and
    integration, and one low-activity series per distinct threshold pair —
    the memo in :func:`low_activity_series` is shared across members and
    parts via :func:`repro.whatif.effects.effect_view`. Bit-identical to
    sequential scalar application (tests/test_whatif_effects.py).

    Residency rows are reported only when some member actually overrides
    residency on this stream; when every part is a known leaf family that
    decision is stream-stable (parking is the only resident-changer and its
    parked set is device-keyed), so streams on never-parked devices — the
    majority under k-of-n pools — keep the replayer's shared classification
    and config-axis integrator instead of one reclassification per member.
    Composites containing *unknown* part types always materialize residency
    rows, like :class:`FallbackBatch` (a custom part may alternate between
    None and an override across segments, and the replayer requires a
    stream-stable row structure).
    """

    policies: tuple[CompositePolicy, ...]

    def __post_init__(self) -> None:
        def stable(policy) -> bool:
            if isinstance(policy, CompositePolicy):
                return all(stable(p) for p in policy.parts)
            return isinstance(policy, (NoOpPolicy, DownscalePolicy,
                                       ParkingPolicy, PowerCapPolicy))
        object.__setattr__(self, "_stable_residency",
                           all(stable(p) for p in self.policies))
        # run-level (IR) support: exactly the parking-then-downscale shape,
        # whose parts act on disjoint residency (see apply_runs)
        ir_ok = all(
            len(p.parts) == 2
            and isinstance(p.parts[0], ParkingPolicy)
            and isinstance(p.parts[1], DownscalePolicy)
            for p in self.policies)
        object.__setattr__(self, "_ir_ok", ir_ok)
        if ir_ok:
            object.__setattr__(self, "_park_pools", tuple(
                (p.parts[0].pool.n_devices,
                 frozenset(p.parts[0].pool.active_set()))
                for p in self.policies))
            # reuse DownscaleBatch's knob-array / trigger / delta-cache
            # precomputation for the downscale parts (one member each)
            object.__setattr__(self, "_ds_batch", DownscaleBatch(
                tuple(p.parts[1] for p in self.policies)))

    def apply_runs(self, stream, plat: PlatformSpec, min_samples: int,
                   dt_s: float) -> "RunBatchResult":
        """Run-level park-then-downscale: the two parts touch disjoint
        residency, so the composite decomposes exactly on the run axis.

        On a stream a member parks, idle samples lose residency, and the
        downstream downscale's ``throttled = decisions & resident`` is
        empty (decisions are true only on low samples, which are exactly
        the evicted ones) — parking's counterfactual IS the member's
        counterfactual there, while the Algorithm-1 decision sequence (and
        its restore events) is unchanged because the low-activity predicate
        reads only signal columns. On unparked streams parking is the
        identity and the member degenerates to its downscale part. Both
        cases are pure run algebra; each part prices its own event channel
        as in the row path.
        """
        if not self._ir_ok:
            raise ValueError(
                "run-level replay supports only parking+downscale "
                "composites; route this batch through the row path")
        n_cfg = len(self.policies)
        dev = stream.key[2]
        parked = np.array([dev % nd not in act for nd, act in
                           self._park_pools], dtype=bool)
        ds = self._ds_batch
        n_down, n_rest, ds_throttled, sav_exec, sav_act = _run_downscale(
            stream, plat, min_samples, dt_s, ds._eps, ds._x, ds._y,
            ds._trig, ds._delta(plat))
        base = stream.baseline(min_samples)
        ds_rows = _downscale_breakdowns(base, sav_exec, sav_act, dt_s)
        park_wakes = np.zeros(n_cfg, dtype=np.int64)
        if parked.any():
            park_bd, pk = _parking_breakdown(stream, plat, min_samples, dt_s)
            park_wakes = np.where(parked, pk["wakes"], 0).astype(np.int64)
            throttled = np.where(parked, pk["idle_samples"], ds_throttled)
            cf_rows = [park_bd if parked[c] else ds_rows[c]
                       for c in range(n_cfg)]
        else:
            throttled = ds_throttled
            cf_rows = ds_rows
        events = np.stack([park_wakes, n_rest], axis=1)
        return RunBatchResult(
            row_of=np.arange(n_cfg, dtype=np.int64),
            cf_rows=cf_rows,
            penalty_partial_s=np.zeros(n_cfg),
            wake_events=park_wakes + n_rest,
            downscale_events=n_down,
            throttled_samples=throttled.astype(np.int64),
            events_rows=events.astype(np.int64),
        )

    def init_carry(self) -> list:
        return [p.init_carry() for p in self.policies]

    def apply_batch(self, seg: TelemetryFrame, plat: PlatformSpec,
                    carry: list,
                    dt_s: float = 1.0) -> tuple[BatchEffect, list]:
        n = len(seg)
        n_cfg = len(self.policies)
        n_ch = self.policies[0].n_event_channels
        power_rows = np.empty((n_cfg, n))
        throttled_rows = np.empty((n_cfg, n), dtype=bool)
        events_rows = np.empty((n_cfg, n_ch), dtype=np.int64)
        partials = np.empty(n_cfg)
        wakes = np.empty(n_cfg, dtype=np.int64)
        downs = np.empty(n_cfg, dtype=np.int64)
        out_carries = []
        effects = []
        for i, (pol, c) in enumerate(zip(self.policies, carry)):
            eff, c2 = pol.apply(seg, plat, c, dt_s=dt_s)
            out_carries.append(c2)
            effects.append(eff)
            power_rows[i] = eff.power_w
            throttled_rows[i] = eff.throttled
            events_rows[i] = eff.events
            partials[i] = eff.penalty_partial_s
            wakes[i] = eff.wake_events
            downs[i] = eff.downscale_events
        if self._stable_residency and all(e.resident is None for e in effects):
            resident_rows = None
        else:
            resident_rows = np.empty((n_cfg, n), dtype=bool)
            rec_resident = seg["program_resident"].astype(bool)
            for i, eff in enumerate(effects):
                resident_rows[i] = (rec_resident if eff.resident is None
                                    else eff.resident)
        return BatchEffect(
            power_rows=power_rows,
            throttled_rows=throttled_rows,
            row_of=np.arange(n_cfg, dtype=np.int64),
            resident_rows=resident_rows,
            penalty_partial_s=partials,
            wake_events=wakes,
            downscale_events=downs,
            events_rows=events_rows,
        ), out_carries


# --------------------------------------------------------------------------- #
# Run-level evaluators (the IR fast path; see repro.whatif.ir)
# --------------------------------------------------------------------------- #
_NEVER_TRIGGERS = 1 << 62


@functools.lru_cache(maxsize=65536)
def downscale_trigger_index(eps: float, x: float) -> int:
    """Samples of consecutive low activity before Algorithm 1 triggers.

    Equals the number of strict left-fold additions of ``eps`` (from
    ``c = 0.0``) whose accumulator stays ``<= x`` — the same float sequence
    ``np.add.accumulate`` produces in :func:`downscale_decisions`, so the
    trigger lands on the same sample bit-for-bit. In a whole-stream replay
    every low run starts from ``c = 0`` (any activity resets the
    accumulator), so this index is a *constant per config*: the run-level
    replay never materializes the accumulator series at all. Returns a
    sentinel larger than any run when the accumulator saturates below
    ``x`` (it can then never trigger, exactly as the scalar recurrence).
    """
    c = 0.0
    k = 0
    while True:
        nxt = c + eps
        if nxt > x:
            return k
        if nxt == c:
            return _NEVER_TRIGGERS
        c = nxt
        k += 1


def _trigger_indices(eps: np.ndarray, x: np.ndarray) -> np.ndarray:
    return np.array([downscale_trigger_index(float(e), float(xx))
                     for e, xx in zip(eps, x)], dtype=np.int64)


@dataclasses.dataclass
class RunBatchResult:
    """One family batch's counterfactual for one IR *stream*.

    The run-level analogue of :class:`~repro.whatif.effects.BatchEffect`
    with the integration already folded: distinct counterfactual
    :class:`~repro.core.energy.EnergyBreakdown` rows instead of power rows
    (``row_of[c] == -1`` aliases the shared baseline breakdown), exact
    integer event/throttle counts, and per-config penalty partials.
    """

    row_of: np.ndarray               # [C] -> index into cf_rows, -1 = baseline
    cf_rows: list                    # distinct counterfactual breakdowns
    penalty_partial_s: np.ndarray    # [C] sample-proportional penalties
    wake_events: np.ndarray          # [C] int
    downscale_events: np.ndarray     # [C] int
    throttled_samples: np.ndarray    # [C] int
    events_rows: np.ndarray | None = None   # [C, K] multi-channel counts


def _identity_run_result(n_configs: int) -> RunBatchResult:
    return RunBatchResult(
        row_of=np.full(n_configs, -1, dtype=np.int64),
        cf_rows=[],
        penalty_partial_s=np.zeros(n_configs),
        wake_events=np.zeros(n_configs, dtype=np.int64),
        downscale_events=np.zeros(n_configs, dtype=np.int64),
        throttled_samples=np.zeros(n_configs, dtype=np.int64),
    )


def _run_downscale(stream, plat: PlatformSpec, min_samples: int, dt_s: float,
                   eps: np.ndarray, x: np.ndarray, y: np.ndarray,
                   trig: np.ndarray, deltas: np.ndarray):
    """Config-axis Algorithm-1 replay over one stream's *low-activity runs*.

    The run-level core shared by :meth:`DownscaleBatch.apply_runs` and
    :meth:`CompositeBatch.apply_runs`: O(low runs) Python for the whole
    config axis, with per-run vector ops — no per-sample decision series is
    ever materialized. Per low run the trigger index is
    ``max(trigger_index, cooldown searchsorted)`` exactly as the row
    kernels compute it; restores (and their cooldown stamps) land on the
    busy run separating consecutive low runs. Savings are gathered from the
    stream's precomputed per-sample clip-saving prefix sums, bucketed by
    accounting state.

    Returns ``(n_down, n_rest, throttled, sav_exec, sav_active)``, each
    ``[C]``: exact event/sample counts, savings in W·samples.
    """
    n_cfg = eps.shape[0]
    n_down = np.zeros(n_cfg, dtype=np.int64)
    n_rest = np.zeros(n_cfg, dtype=np.int64)
    throttled = np.zeros(n_cfg, dtype=np.int64)
    sav_exec = np.zeros(n_cfg)
    sav_act = np.zeros(n_cfg)
    off, low_flags = stream.controller_runs()
    low_j = np.flatnonzero(low_flags)
    n_low = low_j.size
    if n_low == 0:
        return n_down, n_rest, throttled, sav_exec, sav_act

    s0s = off[low_j]
    e0s = off[low_j + 1]
    lens = e0s - s0s
    ts0s = stream.ts_first + dt_s * s0s.astype(np.float64)
    # runs are contiguous, so the busy run following low run k starts at
    # the low run's end sample — where its restores (and cooldown clocks)
    # land; this matches float(ts[off]) of the row kernels bit-for-bit
    busy_after = stream.ts_first + dt_s * e0s.astype(np.float64)

    # phase 1 — history-free decisions for the whole (run x config) grid:
    # with c = 0 at every low-run start, a config fires iff the run outlives
    # its trigger index. Cooldown can only *suppress* some of these.
    fire = lens[:, None] > trig[None, :]                   # [K, C]
    # cooldown from a fire before run k reaches into run k only if the busy
    # run right before k is shorter than the largest cooldown: t_cd <=
    # busy_after[k-1] + max(y), so a longer busy gap clears every config
    risky = np.zeros(n_low, dtype=bool)
    risky[1:] = (ts0s[1:] - busy_after[:-1]) < float(y.max())

    # phase 2 — resolve cooldown suppression sequentially. Only *risky*
    # runs (busy gap shorter than the family's largest cooldown) can have
    # phase-1 fires suppressed: with none, every trigger index is the
    # family constant ``trig`` and the whole sequential pass is skipped.
    # Inside the loop, only risky runs with a recent fire pay for the
    # searchsorted (exact row-kernel trigger index)
    i_rows: dict[int, np.ndarray] = {}
    if risky.any():
        last_fire = np.full(n_cfg, -1, dtype=np.int64)
        any_fire = False
        ts_full = None
        for k in range(n_low):
            if any_fire and risky[k]:
                t_cd = np.where(last_fire >= 0,
                                busy_after[np.maximum(last_fire, 0)] + y,
                                -np.inf)
                aff = t_cd > ts0s[k]
                if aff.any():
                    if ts_full is None:
                        ts_full = stream.ts()
                    # configs whose cooldown ends at or before the run start
                    # keep the phase-1 trigger index: searchsorted would
                    # return 0 and max(trig, 0) == trig, so only the
                    # affected subset pays
                    i_row = trig.copy()
                    i_row[aff] = np.maximum(trig[aff], np.searchsorted(
                        ts_full[s0s[k]:e0s[k]], t_cd[aff], side="left"))
                    fire[k] &= i_row < lens[k]
                    i_rows[k] = i_row
            row = fire[k]
            if row.any():
                any_fire = True
                np.copyto(last_fire, k, where=row)

    # phase 3 — bulk event counts and prefix-sum gathers over [K, C]
    n_down = fire.sum(axis=0).astype(np.int64)
    n_rest = n_down.copy()
    if int(low_j[-1]) == low_flags.shape[0] - 1:
        # a trailing fired low run never restores (no busy run follows)
        n_rest -= fire[-1]
    trig_i = np.broadcast_to(trig, (n_low, n_cfg))
    if i_rows:
        trig_i = trig_i.copy()
        for k, i_row in i_rows.items():
            trig_i[k] = i_row
    gpos = s0s[:, None] + np.where(fire, trig_i, 0)
    cum_res = stream.cum_resident()
    throttled = np.where(fire, cum_res[e0s][:, None] - cum_res[gpos],
                         0).sum(axis=0)
    for d in np.unique(deltas):
        cfg_idx = np.flatnonzero(deltas == d)
        cum_e, cum_a = stream.downscale_cums(float(d), plat.deep_idle_w,
                                             min_samples)
        sub_f = fire[:, cfg_idx]
        sub_g = gpos[:, cfg_idx]
        sav_exec[cfg_idx] = np.where(
            sub_f, cum_e[e0s][:, None] - cum_e[sub_g], 0.0).sum(axis=0)
        sav_act[cfg_idx] = np.where(
            sub_f, cum_a[e0s][:, None] - cum_a[sub_g], 0.0).sum(axis=0)
    return n_down, n_rest, throttled, sav_exec, sav_act


def _downscale_breakdowns(base: EnergyBreakdown, sav_exec: np.ndarray,
                          sav_act: np.ndarray, dt_s: float) -> list:
    """Per-config counterfactual breakdowns: downscaling never changes the
    state series, so times are the baseline's and only the EXECUTION_IDLE /
    ACTIVE energy buckets shed the clipped savings."""
    out = []
    for c in range(sav_exec.shape[0]):
        energy = dict(base.energy_j)
        energy[DeviceState.EXECUTION_IDLE] -= sav_exec[c] * dt_s
        energy[DeviceState.ACTIVE] -= sav_act[c] * dt_s
        out.append(EnergyBreakdown(time_s=base.time_s, energy_j=energy))
    return out


def _parking_breakdown(stream, plat: PlatformSpec, min_samples: int,
                       dt_s: float) -> tuple[EnergyBreakdown, dict]:
    """The single counterfactual breakdown every parked config shares."""
    pk = stream.parking_counterfactual(min_samples)
    energy = pk["keep_sum"] + pk["idle_len"] * plat.deep_idle_w
    bd = integrate_runs(pk["cf_state"], energy[None, :], stream.length,
                        min_samples, dt_s)[0]
    return bd, pk


def _part_structure(policy: Policy) -> tuple:
    """Recursive part-type signature of a composite — members of one
    :class:`CompositeBatch` must share it so their event-channel layouts
    (and hence the batch's rectangular ``events_rows``) line up."""
    if isinstance(policy, CompositePolicy):
        return tuple(_part_structure(p) for p in policy.parts)
    return (type(policy).__name__,)


def _batch_key(policy: Policy, index: int) -> tuple:
    """Family grouping key: policies sharing a key batch together. Downscale /
    parking / powercap group by their low-activity thresholds (the shared
    per-segment precompute); composites group by part structure; anything
    else stays a singleton."""
    if isinstance(policy, DownscalePolicy):
        cfg = policy.config
        return ("downscale", cfg.activity_threshold, cfg.comm_threshold_gbs)
    if isinstance(policy, ParkingPolicy):
        cfg = policy.config
        return ("parking", cfg.activity_threshold, cfg.comm_threshold_gbs)
    if isinstance(policy, PowerCapPolicy):
        cfg = policy.config
        return ("powercap", cfg.activity_threshold, cfg.comm_threshold_gbs)
    if isinstance(policy, NoOpPolicy):
        return ("noop",)
    if isinstance(policy, CompositePolicy):
        return ("composite", _part_structure(policy))
    return ("other", index)


_BATCH_TYPES = {"downscale": DownscaleBatch, "parking": ParkingBatch,
                "powercap": PowerCapBatch, "noop": NoOpBatch,
                "composite": CompositeBatch, "other": FallbackBatch}


def make_batches(
    policies: Sequence[Policy],
) -> list[tuple[PolicyBatch, list[int]]]:
    """Group a policy grid into family batches for the config-axis replay.

    Returns ``(batch, grid_indices)`` pairs in first-occurrence order;
    ``grid_indices`` maps each batch member back to its position in the
    input grid (order-preserving within a batch), so sweep results can be
    reassembled in grid order.
    """
    grouped: dict[tuple, list[int]] = {}
    for i, p in enumerate(policies):
        grouped.setdefault(_batch_key(p, i), []).append(i)
    out: list[tuple[PolicyBatch, list[int]]] = []
    for key, idxs in grouped.items():
        batch_cls = _BATCH_TYPES[key[0]]
        out.append((batch_cls(tuple(policies[i] for i in idxs)), idxs))
    return out

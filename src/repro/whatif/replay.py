"""Streaming counterfactual replay of policies over stored telemetry.

:class:`PolicyReplayer` is the what-if analogue of
:class:`repro.telemetry.pipeline.FleetAccumulator`: feed time-ordered chunks
(storage shards, simulator chunks, DES frames) of any size, finalize once.
Per (job, host, device) stream it runs the policy's vectorized decision
kernel (carrying policy state across chunk boundaries), re-prices power via
the platform's :class:`repro.core.power_model.PlatformSpec`, and
re-integrates both the recorded and the counterfactual series through
:class:`repro.core.energy.StreamingIntegrator` — so baseline and
counterfactual energy are **bit-identical under any chunking**, and peak
memory stays bounded by one chunk.

:class:`BatchedPolicyReplayer` replays a whole policy *grid* the same way
but along a config axis: one shared classification / run-length encoding /
baseline integration per stream segment, each policy family evaluated as a
``(n_configs, n_samples)`` block. It is the sweep's fast path and is
verified bit-identical to per-config :class:`PolicyReplayer` replays.

Penalties: event-priced penalties (downscale restores, parking wakes) are
integer counts priced once at finalize, so they are chunking-invariant too.
Policies with several pricing channels (composites — see
:mod:`repro.whatif.effects`) carry a per-channel count vector and are priced
per channel, each part's events at that part's own per-event cost.
Sample-proportional penalties (power capping) are per-chunk ``np.sum``
partials ``math.fsum``'d at finalize: exact for any *fixed* chunking —
``workers=N`` matches ``workers=1`` bit-for-bit since the shard partition
is the same — but, like ``FleetAccumulator.unattributed_energy_j``, they
may differ in the last ulp between *different* chunkings of one stream.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Any, Iterable, Mapping, Sequence

import numpy as np

import repro.obs as obs
from repro.core.energy import (BatchedStreamingIntegrator, EnergyBreakdown,
                               StreamingIntegrator, merge)
from repro.core.power_model import PlatformSpec, get_platform
from repro.core.states import (ClassifierConfig, DEFAULT_CLASSIFIER,
                               DeviceState, classify_series)
from repro.telemetry.records import TelemetryFrame
from repro.whatif.effects import policy_event_prices, price_events
from repro.whatif.policies import Policy, PolicyBatch, make_batches

if TYPE_CHECKING:
    from repro.telemetry.storage import TelemetryStore


def _default_platform_ids() -> dict[int, str]:
    from repro.cluster.simulator import PLATFORM_IDS
    return {i: name for name, i in PLATFORM_IDS.items()}


def _resolve_platform(
    platform_of: str | Mapping[int, str] | None,
    cache: dict[int, PlatformSpec],
    platform_id: int,
) -> PlatformSpec:
    """Shared ``platform`` column resolution (see :class:`PolicyReplayer`)."""
    plat = cache.get(platform_id)
    if plat is None:
        if isinstance(platform_of, str):
            plat = get_platform(platform_of)
        else:
            table = (platform_of if platform_of is not None
                     else _default_platform_ids())
            plat = get_platform(table[platform_id])
        cache[platform_id] = plat
    return plat


@dataclasses.dataclass
class _WhatIfGroup:
    """Per-(job, host, device) partial replay state carried across chunks."""

    carry: Any
    base: StreamingIntegrator
    cf: StreamingIntegrator
    platform_id: int
    n_rows: int = 0
    ts_first: float = math.inf
    ts_last: float = -math.inf
    penalty_partials: list[float] = dataclasses.field(default_factory=list)
    wake_events: int = 0
    downscale_events: int = 0
    throttled_samples: int = 0
    #: per-channel event counts for multi-channel pricing (composites);
    #: None while the policy emits only the legacy single-channel form
    events: np.ndarray | None = None


@dataclasses.dataclass(frozen=True)
class JobReplay:
    """One stream's recorded vs counterfactual accounting."""

    job_id: int
    platform: str
    duration_s: float
    baseline: EnergyBreakdown
    counterfactual: EnergyBreakdown
    penalty_s: float
    wake_events: int
    downscale_events: int
    throttled_time_s: float

    @property
    def energy_saved_j(self) -> float:
        return self.baseline.total_energy_j - self.counterfactual.total_energy_j

    @property
    def saved_fraction(self) -> float:
        base = self.baseline.total_energy_j
        return self.energy_saved_j / base if base else 0.0

    @property
    def penalty_fraction(self) -> float:
        """Perf penalty relative to the job's recorded active time."""
        active = self.baseline.time_s[DeviceState.ACTIVE]
        return self.penalty_s / active if active else 0.0


@dataclasses.dataclass(frozen=True)
class ReplayResult:
    """Fleet-level outcome of replaying one policy config."""

    policy_name: str
    policy_params: dict
    jobs: list[JobReplay]
    baseline: EnergyBreakdown
    counterfactual: EnergyBreakdown
    penalty_s: float
    wake_events: int
    downscale_events: int
    throttled_time_s: float
    n_rows: int

    @property
    def energy_saved_j(self) -> float:
        return self.baseline.total_energy_j - self.counterfactual.total_energy_j

    @property
    def saved_fraction(self) -> float:
        base = self.baseline.total_energy_j
        return self.energy_saved_j / base if base else 0.0

    @property
    def penalty_fraction(self) -> float:
        active = self.baseline.time_s[DeviceState.ACTIVE]
        return self.penalty_s / active if active else 0.0


class PolicyReplayer:
    """Out-of-core what-if replay: feed chunks, finalize once.

    Same streaming contract as :class:`FleetAccumulator`: chunks may mix
    streams freely, but per stream they must arrive in time order. Samples
    with ``job_id < 0`` (unallocated deep idle) pass through untouched —
    policies mitigate *jobs*; the unattributed floor is out of scope here.

    ``platform_of`` resolves the ``platform`` column to a
    :class:`PlatformSpec`: None uses the cluster simulator's interning, a
    str forces one platform for every stream (e.g. DES output), a mapping
    gives explicit id -> name.
    """

    def __init__(
        self,
        policy: Policy,
        platform_of: str | Mapping[int, str] | None = None,
        min_job_duration_s: float = 2 * 3600.0,
        min_interval_s: float = 5.0,
        classifier: ClassifierConfig = DEFAULT_CLASSIFIER,
        dt_s: float = 1.0,
    ):
        self.policy = policy
        self.platform_of = platform_of
        self.min_job_duration_s = min_job_duration_s
        self.min_interval_s = min_interval_s
        self.classifier = classifier
        self.dt_s = dt_s
        self._groups: dict[tuple[int, int, int], _WhatIfGroup] = {}
        self._plat_cache: dict[int, PlatformSpec] = {}
        self.n_rows = 0

    def _platform(self, platform_id: int) -> PlatformSpec:
        return _resolve_platform(self.platform_of, self._plat_cache,
                                 platform_id)

    # ------------------------------------------------------------------ #
    def update(self, chunk: TelemetryFrame) -> None:
        """Fold one chunk of telemetry into the running replay."""
        replay_chunk([self], chunk)

    def _update_segment(
        self,
        key: tuple[int, int, int],
        seg: TelemetryFrame,
        states: np.ndarray | None = None,
    ) -> None:
        """One time-sorted segment of one stream. ``states`` lets a sweep
        share the baseline classification across replayers with the same
        classifier config (see :func:`replay_chunk`)."""
        g = self._groups.get(key)
        if g is None:
            g = self._groups[key] = _WhatIfGroup(
                carry=self.policy.init_carry(),
                base=StreamingIntegrator(
                    min_duration_s=self.min_interval_s, dt_s=self.dt_s),
                cf=StreamingIntegrator(
                    min_duration_s=self.min_interval_s, dt_s=self.dt_s),
                platform_id=int(seg["platform"][0]),
            )
        ts = seg["timestamp"]
        if float(ts[0]) < g.ts_last:
            raise ValueError(
                f"chunks for stream {key} are not time-ordered: got "
                f"t={float(ts[0])} after t={g.ts_last}")
        g.ts_first = min(g.ts_first, float(ts[0]))
        g.ts_last = float(ts[-1])
        g.n_rows += len(seg)
        self.n_rows += len(seg)

        if states is None:
            states = classify_series(
                seg["program_resident"].astype(bool),
                seg.activity_pct(),
                seg.comm_gbs(),
                self.classifier,
            )
        effect, g.carry = self.policy.apply(seg, self._platform(g.platform_id),
                                            g.carry, dt_s=self.dt_s)
        if effect.resident is None:
            cf_states = states
        else:
            cf_states = classify_series(
                effect.resident, seg.activity_pct(), seg.comm_gbs(),
                self.classifier)
        g.base.update(states, seg["power"])
        g.cf.update(cf_states, effect.power_w)
        if effect.penalty_partial_s:
            g.penalty_partials.append(effect.penalty_partial_s)
        g.wake_events += effect.wake_events
        g.downscale_events += effect.downscale_events
        g.throttled_samples += int(np.sum(effect.throttled))
        if effect.events is not None:
            g.events = (effect.events.copy() if g.events is None
                        else g.events + effect.events)

    # ------------------------------------------------------------------ #
    def merge(self, other: "PolicyReplayer") -> "PolicyReplayer":
        """Absorb a replayer that processed a *disjoint* set of streams —
        the reduction step of the process-pool sweep. Raises on overlap
        (per-stream carry state cannot be joined after the fact)."""
        overlap = self._groups.keys() & other._groups.keys()
        if overlap:
            raise ValueError(
                f"cannot merge replayers with overlapping streams: "
                f"{sorted(overlap)[:3]}...")
        if (other.policy.describe(), other.min_job_duration_s,
                other.min_interval_s, other.classifier, other.dt_s,
                other.platform_of) != (
                self.policy.describe(), self.min_job_duration_s,
                self.min_interval_s, self.classifier, self.dt_s,
                self.platform_of):
            raise ValueError("cannot merge replayers with different configs")
        self._groups.update(other._groups)
        self.n_rows += other.n_rows
        return self

    def finalize(self) -> ReplayResult:
        """Flush carried state and price the policy fleet-wide."""
        jobs: list[JobReplay] = []
        penalty_total = 0.0
        wake_total = down_total = 0
        throttled_total = 0
        for key in sorted(self._groups):
            g = self._groups[key]
            base, _ = g.base.finalize()
            cf, _ = g.cf.finalize()
            span_s = g.ts_last - g.ts_first + self.dt_s
            if span_s < self.min_job_duration_s:
                continue
            plat = self._platform(g.platform_id)
            if g.events is not None:
                event_pen = price_events(
                    policy_event_prices(self.policy, plat), g.events)
            else:
                event_pen = g.wake_events * self.policy.event_penalty_s(plat)
            penalty = math.fsum(g.penalty_partials) + event_pen
            jobs.append(JobReplay(
                job_id=key[0],
                platform=plat.name,
                duration_s=float(span_s),
                baseline=base,
                counterfactual=cf,
                penalty_s=penalty,
                wake_events=g.wake_events,
                downscale_events=g.downscale_events,
                throttled_time_s=float(g.throttled_samples * self.dt_s),
            ))
            penalty_total += penalty
            wake_total += g.wake_events
            down_total += g.downscale_events
            throttled_total += g.throttled_samples
        n_rows = self.n_rows
        self._groups.clear()
        self.n_rows = 0
        return ReplayResult(
            policy_name=self.policy.name,
            policy_params=self.policy.describe(),
            jobs=jobs,
            baseline=merge([j.baseline for j in jobs]),
            counterfactual=merge([j.counterfactual for j in jobs]),
            penalty_s=penalty_total,
            wake_events=wake_total,
            downscale_events=down_total,
            throttled_time_s=float(throttled_total * self.dt_s),
            n_rows=n_rows,
        )


# --------------------------------------------------------------------------- #
# Config-axis batched replay
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _BatchState:
    """Per-(stream, batch) partial replay state carried across chunks.

    ``row_of`` (config -> counterfactual row, -1 = identity) is fixed by the
    stream's first segment and must stay stable — it only depends on
    stream-constant inputs (device id, thresholds), which is validated on
    every subsequent segment.
    """

    carry: Any
    row_of: np.ndarray | None = None
    cf: BatchedStreamingIntegrator | None = None       # rows on baseline states
    cf_rows: list[StreamingIntegrator] | None = None   # rows with own residency
    penalty_partials: list[np.ndarray] = dataclasses.field(default_factory=list)
    wake_events: np.ndarray | None = None              # [C_b] int
    downscale_events: np.ndarray | None = None         # [C_b] int
    throttled_counts: np.ndarray | None = None         # [R] int, per row
    events: np.ndarray | None = None                   # [C_b, K] int (composites)


@dataclasses.dataclass
class _BatchedGroup:
    """Per-(job, host, device) partial state for the whole grid: ONE baseline
    integration shared by every config, plus one :class:`_BatchState` per
    family batch."""

    base: StreamingIntegrator
    batch_states: list[_BatchState]
    platform_id: int
    n_rows: int = 0
    ts_first: float = math.inf
    ts_last: float = -math.inf


class BatchedPolicyReplayer:
    """Replay an entire policy grid in one pass per stream segment.

    The config-axis counterpart of running one :class:`PolicyReplayer` per
    grid point: the grid is grouped into family batches
    (:func:`repro.whatif.policies.make_batches`), and each stream segment is
    processed once — one lexsort grouping (in :meth:`update`), one baseline
    classification, one idle run-length encoding / low-activity series (the
    segment-level cache in :func:`~repro.whatif.policies.low_activity_series`),
    and one baseline power integration — with every family evaluated as a
    ``(n_configs, n_samples)`` block. Per-config carry state crosses chunk
    boundaries exactly as the scalar replayers' does, so results are
    **bit-identical** to the per-policy reference path for any chunking and
    any process-pool width (tests/test_whatif_batched.py).

    ``finalize`` returns one :class:`ReplayResult` per policy, in grid order.
    """

    def __init__(
        self,
        policies: Sequence[Policy],
        platform_of: str | Mapping[int, str] | None = None,
        min_job_duration_s: float = 2 * 3600.0,
        min_interval_s: float = 5.0,
        classifier: ClassifierConfig = DEFAULT_CLASSIFIER,
        dt_s: float = 1.0,
    ):
        self.policies = list(policies)
        self.platform_of = platform_of
        self.min_job_duration_s = min_job_duration_s
        self.min_interval_s = min_interval_s
        self.classifier = classifier
        self.dt_s = dt_s
        self._batches: list[tuple[PolicyBatch, list[int]]] = make_batches(
            self.policies)
        self._groups: dict[tuple[int, int, int], _BatchedGroup] = {}
        self._plat_cache: dict[int, PlatformSpec] = {}
        self.n_rows = 0

    def _platform(self, platform_id: int) -> PlatformSpec:
        return _resolve_platform(self.platform_of, self._plat_cache,
                                 platform_id)

    # ------------------------------------------------------------------ #
    def update(self, chunk: TelemetryFrame) -> None:
        """Fold one chunk of telemetry into the running grid replay."""
        if len(chunk) == 0:
            return
        for key, seg in chunk.group_streams():
            if key[0] < 0:
                continue
            self._update_segment(key, seg)

    def _new_integrator(self, n_configs: int | None = None):
        """Scalar integrator (1-D power) by default; a config-axis one for
        row blocks when ``n_configs`` is given (even ``n_configs=1`` — row
        blocks are always 2-D)."""
        if n_configs is None:
            return StreamingIntegrator(
                min_duration_s=self.min_interval_s, dt_s=self.dt_s)
        return BatchedStreamingIntegrator(
            n_configs=n_configs, min_duration_s=self.min_interval_s,
            dt_s=self.dt_s)

    def _update_segment(self, key: tuple[int, int, int],
                        seg: TelemetryFrame) -> None:
        g = self._groups.get(key)
        if g is None:
            g = self._groups[key] = _BatchedGroup(
                base=self._new_integrator(),
                batch_states=[_BatchState(carry=batch.init_carry())
                              for batch, _ in self._batches],
                platform_id=int(seg["platform"][0]),
            )
        ts = seg["timestamp"]
        if float(ts[0]) < g.ts_last:
            raise ValueError(
                f"chunks for stream {key} are not time-ordered: got "
                f"t={float(ts[0])} after t={g.ts_last}")
        g.ts_first = min(g.ts_first, float(ts[0]))
        g.ts_last = float(ts[-1])
        g.n_rows += len(seg)
        self.n_rows += len(seg)

        states = classify_series(
            seg["program_resident"].astype(bool),
            seg.activity_pct(),
            seg.comm_gbs(),
            self.classifier,
        )
        plat = self._platform(g.platform_id)
        g.base.update(states, seg["power"])
        for (batch, idxs), bs in zip(self._batches, g.batch_states):
            effect, bs.carry = batch.apply_batch(seg, plat, bs.carry,
                                                 dt_s=self.dt_s)
            n_rows_cf = effect.power_rows.shape[0]
            if bs.row_of is None:
                bs.row_of = effect.row_of
                bs.wake_events = np.zeros(len(idxs), dtype=np.int64)
                bs.downscale_events = np.zeros(len(idxs), dtype=np.int64)
                bs.throttled_counts = np.zeros(n_rows_cf, dtype=np.int64)
                if n_rows_cf:
                    if effect.resident_rows is None:
                        bs.cf = self._new_integrator(n_rows_cf)
                    else:
                        bs.cf_rows = [self._new_integrator()
                                      for _ in range(n_rows_cf)]
            elif not np.array_equal(bs.row_of, effect.row_of):
                raise ValueError(
                    f"batch {type(batch).__name__} changed its config->row "
                    f"mapping mid-stream for {key}")
            if n_rows_cf:
                if effect.resident_rows is None:
                    if bs.cf_rows is not None:
                        raise ValueError(
                            f"batch {type(batch).__name__} changed residency "
                            f"structure mid-stream for {key}")
                    bs.cf.update(states, effect.power_rows)
                else:
                    if bs.cf is not None:
                        raise ValueError(
                            f"batch {type(batch).__name__} changed residency "
                            f"structure mid-stream for {key}")
                    for r in range(n_rows_cf):
                        cf_states = classify_series(
                            effect.resident_rows[r], seg.activity_pct(),
                            seg.comm_gbs(), self.classifier)
                        bs.cf_rows[r].update(cf_states, effect.power_rows[r])
                bs.throttled_counts += effect.throttled_rows.sum(axis=1)
            bs.penalty_partials.append(effect.penalty_partial_s)
            bs.wake_events += effect.wake_events
            bs.downscale_events += effect.downscale_events
            if effect.events_rows is not None:
                bs.events = (effect.events_rows.copy() if bs.events is None
                             else bs.events + effect.events_rows)

    # ------------------------------------------------------------------ #
    def merge(self, other: "BatchedPolicyReplayer") -> "BatchedPolicyReplayer":
        """Absorb a replayer that processed a *disjoint* set of streams —
        the reduction step of the process-pool sweep."""
        overlap = self._groups.keys() & other._groups.keys()
        if overlap:
            raise ValueError(
                f"cannot merge replayers with overlapping streams: "
                f"{sorted(overlap)[:3]}...")
        if ([p.describe() for p in other.policies], other.min_job_duration_s,
                other.min_interval_s, other.classifier, other.dt_s,
                other.platform_of) != (
                [p.describe() for p in self.policies],
                self.min_job_duration_s, self.min_interval_s, self.classifier,
                self.dt_s, self.platform_of):
            raise ValueError("cannot merge replayers with different configs")
        self._groups.update(other._groups)
        self.n_rows += other.n_rows
        return self

    def finalize(self) -> list[ReplayResult]:
        """Flush carried state; one :class:`ReplayResult` per grid config,
        field-for-field identical to the scalar reference path's."""
        n_cfg = len(self.policies)
        jobs: list[list[JobReplay]] = [[] for _ in range(n_cfg)]
        penalty_tot = [0.0] * n_cfg
        wake_tot = [0] * n_cfg
        down_tot = [0] * n_cfg
        throttled_tot = [0] * n_cfg
        for key in sorted(self._groups):
            g = self._groups[key]
            base_bd, _ = g.base.finalize()
            span_s = g.ts_last - g.ts_first + self.dt_s
            plat = self._platform(g.platform_id)
            for (batch, idxs), bs in zip(self._batches, g.batch_states):
                if bs.cf is not None:
                    row_bds, _ = bs.cf.finalize_batch()
                elif bs.cf_rows is not None:
                    row_bds = [r.finalize()[0] for r in bs.cf_rows]
                else:
                    row_bds = []
                if span_s < self.min_job_duration_s:
                    continue
                for j, gi in enumerate(idxs):
                    pol = self.policies[gi]
                    row = int(bs.row_of[j]) if bs.row_of is not None else -1
                    cf_bd = base_bd if row < 0 else row_bds[row]
                    wakes = int(bs.wake_events[j])
                    if bs.events is not None:
                        event_pen = price_events(
                            policy_event_prices(pol, plat), bs.events[j])
                    else:
                        event_pen = wakes * pol.event_penalty_s(plat)
                    penalty = (math.fsum(p[j] for p in bs.penalty_partials)
                               + event_pen)
                    throttled = (0 if row < 0
                                 else int(bs.throttled_counts[row]))
                    jobs[gi].append(JobReplay(
                        job_id=key[0],
                        platform=plat.name,
                        duration_s=float(span_s),
                        baseline=base_bd,
                        counterfactual=cf_bd,
                        penalty_s=penalty,
                        wake_events=wakes,
                        downscale_events=int(bs.downscale_events[j]),
                        throttled_time_s=float(throttled * self.dt_s),
                    ))
                    penalty_tot[gi] += penalty
                    wake_tot[gi] += wakes
                    down_tot[gi] += int(bs.downscale_events[j])
                    throttled_tot[gi] += throttled
        n_rows = self.n_rows
        self._groups.clear()
        self.n_rows = 0
        return [
            ReplayResult(
                policy_name=pol.name,
                policy_params=pol.describe(),
                jobs=jobs[gi],
                baseline=merge([j.baseline for j in jobs[gi]]),
                counterfactual=merge([j.counterfactual for j in jobs[gi]]),
                penalty_s=penalty_tot[gi],
                wake_events=wake_tot[gi],
                downscale_events=down_tot[gi],
                throttled_time_s=float(throttled_tot[gi] * self.dt_s),
                n_rows=n_rows,
            )
            for gi, pol in enumerate(self.policies)
        ]


def replay_chunk(replayers: Iterable[PolicyReplayer],
                 chunk: TelemetryFrame) -> None:
    """Feed one chunk to many replayers, sharing the grouping pass and the
    baseline classification (per distinct classifier config) — the sweep's
    inner loop, so a 48-config sweep lexsorts and classifies each shard once,
    not 48 times."""
    replayers = list(replayers)
    if len(chunk) == 0 or not replayers:
        return
    for key, seg in chunk.group_streams():
        if key[0] < 0:
            continue
        states_cache: dict[ClassifierConfig, np.ndarray] = {}
        for r in replayers:
            states = states_cache.get(r.classifier)
            if states is None:
                states = classify_series(
                    seg["program_resident"].astype(bool),
                    seg.activity_pct(),
                    seg.comm_gbs(),
                    r.classifier,
                )
                states_cache[r.classifier] = states
            r._update_segment(key, seg, states=states)


def replay_store(
    store: "TelemetryStore",
    policy: Policy,
    hosts: Iterable[str] | None = None,
    mmap: bool = False,
    **kwargs,
) -> ReplayResult:
    """Replay one policy over a whole store, one shard in memory at a time."""
    replayer = PolicyReplayer(policy, **kwargs)
    for shard in store.iter_shards(hosts, mmap=mmap):
        replayer.update(shard)
    return replayer.finalize()


# --------------------------------------------------------------------------- #
# Run-axis replay (the IR fast path; see repro.whatif.ir)
# --------------------------------------------------------------------------- #
def _replay_ir_streams(
    streams: list,
    policies: Sequence[Policy],
    platform_of: str | Mapping[int, str] | None,
    min_job_duration_s: float,
    min_samples: int,
    dt_s: float,
) -> tuple[list[list[tuple]], int]:
    """Replay a policy grid against a list of :class:`StreamIR` streams
    (process-pool worker body; module-level picklable). Returns
    ``(jobs_per_config, n_rows)`` where each job entry is ``(stream key,
    JobReplay)`` — keys travel along so the parent can reassemble in
    sorted-stream order regardless of partitioning."""
    batches = make_batches(policies)
    plat_cache: dict[int, PlatformSpec] = {}
    n_cfg = len(policies)
    jobs: list[list[tuple]] = [[] for _ in range(n_cfg)]
    n_rows = 0
    for stream in streams:
        n_rows += stream.n_rows
        span_s = stream.ts_last - stream.ts_first + dt_s
        if span_s < min_job_duration_s:
            continue
        plat = _resolve_platform(platform_of, plat_cache, stream.platform_id)
        base_bd = stream.baseline(min_samples)
        for batch, idxs in batches:
            res = batch.apply_runs(stream, plat, min_samples, dt_s)
            for j, gi in enumerate(idxs):
                pol = policies[gi]
                row = int(res.row_of[j])
                cf_bd = base_bd if row < 0 else res.cf_rows[row]
                wakes = int(res.wake_events[j])
                if res.events_rows is not None:
                    event_pen = price_events(
                        policy_event_prices(pol, plat), res.events_rows[j])
                else:
                    event_pen = wakes * pol.event_penalty_s(plat)
                penalty = float(res.penalty_partial_s[j]) + event_pen
                jobs[gi].append((stream.key, int(res.throttled_samples[j]),
                                 JobReplay(
                    job_id=stream.key[0],
                    platform=plat.name,
                    duration_s=float(span_s),
                    baseline=base_bd,
                    counterfactual=cf_bd,
                    penalty_s=penalty,
                    wake_events=wakes,
                    downscale_events=int(res.downscale_events[j]),
                    throttled_time_s=float(res.throttled_samples[j] * dt_s),
                )))
    return jobs, n_rows


def replay_ir(
    ir,
    policies: Sequence[Policy],
    platform_of: str | Mapping[int, str] | None = None,
    min_job_duration_s: float = 2 * 3600.0,
    min_interval_s: float = 5.0,
    classifier: ClassifierConfig = DEFAULT_CLASSIFIER,
    dt_s: float = 1.0,
    hosts: Iterable[str] | None = None,
    workers: int = 1,
    fault=None,
) -> list[ReplayResult]:
    """Replay a whole policy grid against a :class:`repro.whatif.ir.RunIR`.

    The run-axis counterpart of streaming the store through
    :class:`BatchedPolicyReplayer`: every family evaluates
    ``(n_configs, n_runs)`` blocks via its ``apply_runs`` method, so the
    per-config cost is O(runs), and the only O(rows) work ever done was the
    IR build. Contract vs the row path (tests/test_whatif_ir.py): per-state
    times, event counts, throttled time and decision-derived metrics are
    **bit-identical**; energies and penalties agree to <= 1e-9 relative.
    Results are identical for any ``workers`` (streams are partitioned by
    host label and reassembled in sorted-key order). Note ``workers > 1``
    ships each partition's :class:`StreamIR` arrays — including the raw
    power column, ~8 bytes/row — to the pool on every call and rebuilds
    the per-stream memoized aggregates there, so it only pays off when
    per-config run work dominates (very large grids); the serial path is
    the right default for the compact replay.

    Every policy must be run-level capable for the IR's config
    (:func:`repro.whatif.ir.ir_supported`); the sweep kernel routes
    unsupported configs through the row path instead.
    """
    if classifier != ir.config.classifier:
        raise ValueError(
            f"IR was built for classifier {ir.config.classifier}, replay "
            f"requested {classifier}; rebuild the IR or use compact=False")
    if dt_s != ir.config.dt_s:
        raise ValueError(f"IR dt_s {ir.config.dt_s} != replay dt_s {dt_s}")
    policies = list(policies)
    min_samples = (0 if min_interval_s is None
                   else int(np.ceil(min_interval_s / dt_s)))
    streams = ir.select(hosts)
    by_host: dict[str, list] = {}
    for s in streams:
        by_host.setdefault(s.host_label, []).append(s)
    if workers > 1 and len(by_host) > 1:
        # greedy row-balanced host partitions, heaviest first (the same
        # partition rule as TelemetryStore.partition_hosts)
        ordered = sorted(by_host, key=lambda h: (-sum(
            s.n_rows for s in by_host[h]), h))
        n_parts = min(workers, len(ordered))
        parts: list[list] = [[] for _ in range(n_parts)]
        loads = [0] * n_parts
        for h in ordered:
            i = loads.index(min(loads))
            parts[i].extend(by_host[h])
            loads[i] += sum(s.n_rows for s in by_host[h])
        from repro.telemetry.pipeline import (_fault_plan, _partition_body,
                                              run_supervised)
        obs.gauge("repro_pool_workers", float(n_parts), stage="replay_ir",
                  help="process-pool fan-out per stage (1 = in-process)")
        # same crash/hang supervisor as the shard pipelines; _partition_body
        # gives the fault harness its "replay_ir" stage hook
        pieces = run_supervised(
            _partition_body,
            [("replay_ir", _fault_plan(), _replay_ir_streams, part, policies,
              platform_of, min_job_duration_s, min_samples, dt_s)
             for part in parts],
            stage="replay_ir", fault=fault)
        jobs = [[j for piece in pieces for j in piece[0][gi]]
                for gi in range(len(policies))]
        n_rows = sum(piece[1] for piece in pieces)
    else:
        with obs.span("replay_ir.streams", configs=len(policies)):
            jobs, n_rows = _replay_ir_streams(
                streams, policies, platform_of, min_job_duration_s,
                min_samples, dt_s)
    results = []
    base_fleet = None       # the kept-job set is config-independent, so the
    for gi, pol in enumerate(policies):     # fleet baseline merges once
        entries = sorted(jobs[gi], key=lambda kj: kj[0])
        ordered_jobs = [jr for _, _, jr in entries]
        if base_fleet is None:
            base_fleet = merge([j.baseline for j in ordered_jobs])
        results.append(ReplayResult(
            policy_name=pol.name,
            policy_params=pol.describe(),
            jobs=ordered_jobs,
            baseline=base_fleet,
            counterfactual=merge([j.counterfactual for j in ordered_jobs]),
            penalty_s=math.fsum(j.penalty_s for j in ordered_jobs),
            wake_events=sum(j.wake_events for j in ordered_jobs),
            downscale_events=sum(j.downscale_events for j in ordered_jobs),
            throttled_time_s=float(
                sum(t for _, t, _ in entries) * dt_s),
            n_rows=n_rows,
        ))
    return results

"""Streaming counterfactual replay of one policy over stored telemetry.

:class:`PolicyReplayer` is the what-if analogue of
:class:`repro.telemetry.pipeline.FleetAccumulator`: feed time-ordered chunks
(storage shards, simulator chunks, DES frames) of any size, finalize once.
Per (job, host, device) stream it runs the policy's vectorized decision
kernel (carrying policy state across chunk boundaries), re-prices power via
the platform's :class:`repro.core.power_model.PlatformSpec`, and
re-integrates both the recorded and the counterfactual series through
:class:`repro.core.energy.StreamingIntegrator` — so baseline and
counterfactual energy are **bit-identical under any chunking**, and peak
memory stays bounded by one chunk.

Penalties: event-priced penalties (downscale restores, parking wakes) are
integer counts priced once at finalize, so they are chunking-invariant too.
Sample-proportional penalties (power capping) are per-chunk ``np.sum``
partials ``math.fsum``'d at finalize: exact for any *fixed* chunking —
``workers=N`` matches ``workers=1`` bit-for-bit since the shard partition
is the same — but, like ``FleetAccumulator.unattributed_energy_j``, they
may differ in the last ulp between *different* chunkings of one stream.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Any, Iterable, Mapping

import numpy as np

from repro.core.energy import EnergyBreakdown, StreamingIntegrator, merge
from repro.core.power_model import PlatformSpec, get_platform
from repro.core.states import (ClassifierConfig, DEFAULT_CLASSIFIER,
                               DeviceState, classify_series)
from repro.telemetry.records import TelemetryFrame
from repro.whatif.policies import Policy

if TYPE_CHECKING:
    from repro.telemetry.storage import TelemetryStore


def _default_platform_ids() -> dict[int, str]:
    from repro.cluster.simulator import PLATFORM_IDS
    return {i: name for name, i in PLATFORM_IDS.items()}


@dataclasses.dataclass
class _WhatIfGroup:
    """Per-(job, host, device) partial replay state carried across chunks."""

    carry: Any
    base: StreamingIntegrator
    cf: StreamingIntegrator
    platform_id: int
    n_rows: int = 0
    ts_first: float = math.inf
    ts_last: float = -math.inf
    penalty_partials: list[float] = dataclasses.field(default_factory=list)
    wake_events: int = 0
    downscale_events: int = 0
    throttled_samples: int = 0


@dataclasses.dataclass(frozen=True)
class JobReplay:
    """One stream's recorded vs counterfactual accounting."""

    job_id: int
    platform: str
    duration_s: float
    baseline: EnergyBreakdown
    counterfactual: EnergyBreakdown
    penalty_s: float
    wake_events: int
    downscale_events: int
    throttled_time_s: float

    @property
    def energy_saved_j(self) -> float:
        return self.baseline.total_energy_j - self.counterfactual.total_energy_j

    @property
    def saved_fraction(self) -> float:
        base = self.baseline.total_energy_j
        return self.energy_saved_j / base if base else 0.0

    @property
    def penalty_fraction(self) -> float:
        """Perf penalty relative to the job's recorded active time."""
        active = self.baseline.time_s[DeviceState.ACTIVE]
        return self.penalty_s / active if active else 0.0


@dataclasses.dataclass(frozen=True)
class ReplayResult:
    """Fleet-level outcome of replaying one policy config."""

    policy_name: str
    policy_params: dict
    jobs: list[JobReplay]
    baseline: EnergyBreakdown
    counterfactual: EnergyBreakdown
    penalty_s: float
    wake_events: int
    downscale_events: int
    throttled_time_s: float
    n_rows: int

    @property
    def energy_saved_j(self) -> float:
        return self.baseline.total_energy_j - self.counterfactual.total_energy_j

    @property
    def saved_fraction(self) -> float:
        base = self.baseline.total_energy_j
        return self.energy_saved_j / base if base else 0.0

    @property
    def penalty_fraction(self) -> float:
        active = self.baseline.time_s[DeviceState.ACTIVE]
        return self.penalty_s / active if active else 0.0


class PolicyReplayer:
    """Out-of-core what-if replay: feed chunks, finalize once.

    Same streaming contract as :class:`FleetAccumulator`: chunks may mix
    streams freely, but per stream they must arrive in time order. Samples
    with ``job_id < 0`` (unallocated deep idle) pass through untouched —
    policies mitigate *jobs*; the unattributed floor is out of scope here.

    ``platform_of`` resolves the ``platform`` column to a
    :class:`PlatformSpec`: None uses the cluster simulator's interning, a
    str forces one platform for every stream (e.g. DES output), a mapping
    gives explicit id -> name.
    """

    def __init__(
        self,
        policy: Policy,
        platform_of: str | Mapping[int, str] | None = None,
        min_job_duration_s: float = 2 * 3600.0,
        min_interval_s: float = 5.0,
        classifier: ClassifierConfig = DEFAULT_CLASSIFIER,
        dt_s: float = 1.0,
    ):
        self.policy = policy
        self.platform_of = platform_of
        self.min_job_duration_s = min_job_duration_s
        self.min_interval_s = min_interval_s
        self.classifier = classifier
        self.dt_s = dt_s
        self._groups: dict[tuple[int, int, int], _WhatIfGroup] = {}
        self._plat_cache: dict[int, PlatformSpec] = {}
        self.n_rows = 0

    def _platform(self, platform_id: int) -> PlatformSpec:
        plat = self._plat_cache.get(platform_id)
        if plat is None:
            if isinstance(self.platform_of, str):
                plat = get_platform(self.platform_of)
            else:
                table = (self.platform_of if self.platform_of is not None
                         else _default_platform_ids())
                plat = get_platform(table[platform_id])
            self._plat_cache[platform_id] = plat
        return plat

    # ------------------------------------------------------------------ #
    def update(self, chunk: TelemetryFrame) -> None:
        """Fold one chunk of telemetry into the running replay."""
        replay_chunk([self], chunk)

    def _update_segment(
        self,
        key: tuple[int, int, int],
        seg: TelemetryFrame,
        states: np.ndarray | None = None,
    ) -> None:
        """One time-sorted segment of one stream. ``states`` lets a sweep
        share the baseline classification across replayers with the same
        classifier config (see :func:`replay_chunk`)."""
        g = self._groups.get(key)
        if g is None:
            g = self._groups[key] = _WhatIfGroup(
                carry=self.policy.init_carry(),
                base=StreamingIntegrator(
                    min_duration_s=self.min_interval_s, dt_s=self.dt_s),
                cf=StreamingIntegrator(
                    min_duration_s=self.min_interval_s, dt_s=self.dt_s),
                platform_id=int(seg["platform"][0]),
            )
        ts = seg["timestamp"]
        if float(ts[0]) < g.ts_last:
            raise ValueError(
                f"chunks for stream {key} are not time-ordered: got "
                f"t={float(ts[0])} after t={g.ts_last}")
        g.ts_first = min(g.ts_first, float(ts[0]))
        g.ts_last = float(ts[-1])
        g.n_rows += len(seg)
        self.n_rows += len(seg)

        if states is None:
            states = classify_series(
                seg["program_resident"].astype(bool),
                seg.activity_pct(),
                seg.comm_gbs(),
                self.classifier,
            )
        effect, g.carry = self.policy.apply(seg, self._platform(g.platform_id),
                                            g.carry, dt_s=self.dt_s)
        if effect.resident is None:
            cf_states = states
        else:
            cf_states = classify_series(
                effect.resident, seg.activity_pct(), seg.comm_gbs(),
                self.classifier)
        g.base.update(states, seg["power"])
        g.cf.update(cf_states, effect.power_w)
        if effect.penalty_partial_s:
            g.penalty_partials.append(effect.penalty_partial_s)
        g.wake_events += effect.wake_events
        g.downscale_events += effect.downscale_events
        g.throttled_samples += int(np.sum(effect.throttled))

    # ------------------------------------------------------------------ #
    def merge(self, other: "PolicyReplayer") -> "PolicyReplayer":
        """Absorb a replayer that processed a *disjoint* set of streams —
        the reduction step of the process-pool sweep. Raises on overlap
        (per-stream carry state cannot be joined after the fact)."""
        overlap = self._groups.keys() & other._groups.keys()
        if overlap:
            raise ValueError(
                f"cannot merge replayers with overlapping streams: "
                f"{sorted(overlap)[:3]}...")
        if (other.policy.describe(), other.min_job_duration_s,
                other.min_interval_s, other.classifier, other.dt_s,
                other.platform_of) != (
                self.policy.describe(), self.min_job_duration_s,
                self.min_interval_s, self.classifier, self.dt_s,
                self.platform_of):
            raise ValueError("cannot merge replayers with different configs")
        self._groups.update(other._groups)
        self.n_rows += other.n_rows
        return self

    def finalize(self) -> ReplayResult:
        """Flush carried state and price the policy fleet-wide."""
        jobs: list[JobReplay] = []
        penalty_total = 0.0
        wake_total = down_total = 0
        throttled_total = 0
        for key in sorted(self._groups):
            g = self._groups[key]
            base, _ = g.base.finalize()
            cf, _ = g.cf.finalize()
            span_s = g.ts_last - g.ts_first + self.dt_s
            if span_s < self.min_job_duration_s:
                continue
            plat = self._platform(g.platform_id)
            penalty = (math.fsum(g.penalty_partials)
                       + g.wake_events * self.policy.event_penalty_s(plat))
            jobs.append(JobReplay(
                job_id=key[0],
                platform=plat.name,
                duration_s=float(span_s),
                baseline=base,
                counterfactual=cf,
                penalty_s=penalty,
                wake_events=g.wake_events,
                downscale_events=g.downscale_events,
                throttled_time_s=float(g.throttled_samples * self.dt_s),
            ))
            penalty_total += penalty
            wake_total += g.wake_events
            down_total += g.downscale_events
            throttled_total += g.throttled_samples
        n_rows = self.n_rows
        self._groups.clear()
        self.n_rows = 0
        return ReplayResult(
            policy_name=self.policy.name,
            policy_params=self.policy.describe(),
            jobs=jobs,
            baseline=merge([j.baseline for j in jobs]),
            counterfactual=merge([j.counterfactual for j in jobs]),
            penalty_s=penalty_total,
            wake_events=wake_total,
            downscale_events=down_total,
            throttled_time_s=float(throttled_total * self.dt_s),
            n_rows=n_rows,
        )


def replay_chunk(replayers: Iterable[PolicyReplayer],
                 chunk: TelemetryFrame) -> None:
    """Feed one chunk to many replayers, sharing the grouping pass and the
    baseline classification (per distinct classifier config) — the sweep's
    inner loop, so a 48-config sweep lexsorts and classifies each shard once,
    not 48 times."""
    replayers = list(replayers)
    if len(chunk) == 0 or not replayers:
        return
    for key, seg in chunk.group_streams():
        if key[0] < 0:
            continue
        states_cache: dict[ClassifierConfig, np.ndarray] = {}
        for r in replayers:
            states = states_cache.get(r.classifier)
            if states is None:
                states = classify_series(
                    seg["program_resident"].astype(bool),
                    seg.activity_pct(),
                    seg.comm_gbs(),
                    r.classifier,
                )
                states_cache[r.classifier] = states
            r._update_segment(key, seg, states=states)


def replay_store(
    store: "TelemetryStore",
    policy: Policy,
    hosts: Iterable[str] | None = None,
    mmap: bool = False,
    **kwargs,
) -> ReplayResult:
    """Replay one policy over a whole store, one shard in memory at a time."""
    replayer = PolicyReplayer(policy, **kwargs)
    for shard in store.iter_shards(hosts, mmap=mmap):
        replayer.update(shard)
    return replayer.finalize()

"""JAX execution backend for the run-level replay path.

The run-level IR (:mod:`repro.whatif.ir`) made policy grids O(runs) per
config on one CPU core; this module moves the ``(n_configs, n_runs)``
evaluators onto JAX so dense per-platform grids — the 10^4-config
deadline-aware sweeps of arXiv 2004.08177-style studies — are routine:

* :func:`pack_ir` packs the ragged per-stream run tables into padded,
  **power-of-two bucketed** dense tensors with validity masks.  Streams
  sharing a padded-shape bucket share one compiled kernel, so jit
  retraces O(log n) times (once per distinct bucket), not per stream;
* the ``apply_runs`` kernels of ``NoOpBatch`` / ``DownscaleBatch`` /
  ``ParkingBatch`` / ``PowerCapBatch`` / ``CompositeBatch`` and the
  run-weighted integrator (:meth:`BatchedStreamingIntegrator.update_runs`
  / :func:`integrate_runs`) are ported to ``jax.jit``-compiled functions
  vectorized over ``(n_configs, n_runs)``; the config axis is sharded via
  ``shard_map`` over a :class:`repro.distributed.context.DistContext`
  mesh (:func:`config_mesh`), so multi-device scales near-linearly —
  every per-config op is elementwise along the axis, so sharding needs no
  cross-device communication at all;
* the PowerCap sorted-power cap-bucket scan runs through
  :func:`repro.kernels.run_replay.cap_bucket_counts` — the Pallas kernel
  on TPU, the vmapped ``searchsorted`` reference elsewhere.

Oracle contract (the NumPy path stays the bit-exactness oracle, enforced
by tests/test_whatif_backend.py over random grids x chunkings x device
counts): **time and count metrics are bit-identical** to
:func:`repro.whatif.replay.replay_ir` — per-state times are integer
sample sums, Algorithm-1 decision sequences reduce to the same trigger
indices (the cooldown ``searchsorted`` is replicated exactly by an
8-probe window around the float-predicted crossing), event and throttle
counts are exact i64 — while **energies and penalties agree to <= 1e-9
relative** (float summation order differs: ``lax.scan`` accumulates
left-to-right where NumPy reduces pairwise).

Host/device split: decisions, gathers and reductions over
``(n_streams, n_configs)`` run on the device; per-stream prefix-sum
construction stays on the host and *shares the StreamIR memos with the
NumPy path* (same arrays bit-for-bit), and the final fleet fold mirrors
:func:`repro.core.energy.merge`'s left fold in sorted-stream order.
"""
from __future__ import annotations

import collections.abc
import dataclasses
from typing import Iterable, Mapping, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

import repro.obs as obs
from repro.core.energy import EnergyBreakdown
from repro.core.power_model import ClockLevel, PlatformSpec
from repro.core.states import ClassifierConfig, DEFAULT_CLASSIFIER, DeviceState
from repro.distributed.context import DistContext
from repro.kernels.run_replay import cap_bucket_counts
from repro.whatif.policies import (CompositeBatch, DownscaleBatch, NoOpBatch,
                                   ParkingBatch, PowerCapBatch,
                                   _NEVER_TRIGGERS, make_batches)
from repro.whatif.replay import _resolve_platform
from repro.whatif.sweep import PolicyOutcome

_DEEP = int(DeviceState.DEEP_IDLE)
_EXEC = int(DeviceState.EXECUTION_IDLE)
_ACTIVE = int(DeviceState.ACTIVE)
_STATES = (_DEEP, _EXEC, _ACTIVE)

class _TraceCountsView(collections.abc.Mapping):
    """Read-only live view of per-kernel jit trace counts.

    Retrace telemetry lives in the ``repro_backend_jit_traces_total``
    counter family of :data:`repro.obs.REGISTRY` (recorded *always-on*:
    the counts are a behavioural contract — the pack_ir property tests
    assert a replay retraces at most once per distinct padding bucket —
    so they bypass the default-off gate). This mapping keeps the
    historical ``dict(TRACE_COUNTS)`` call sites and test assertions
    working over the registry-backed counts.
    """

    _NAME = "repro_backend_jit_traces_total"

    def _snapshot(self) -> dict[str, int]:
        fam = obs.REGISTRY.family(self._NAME)
        if fam is None:
            return {}
        return {dict(key).get("kernel", ""): int(m.value)
                for key, m in fam.metrics.items()}

    def __getitem__(self, name: str) -> int:
        return self._snapshot()[name]

    def __iter__(self):
        return iter(self._snapshot())

    def __len__(self) -> int:
        return len(self._snapshot())

    def __repr__(self) -> str:
        return f"TRACE_COUNTS({self._snapshot()!r})"


#: retrace telemetry: kernel name -> number of jit traces so far. Each
#: kernel body bumps its counter at *trace* time only, so after warmup a
#: replay adds zero.
TRACE_COUNTS = _TraceCountsView()


def _mark_trace(name: str) -> None:
    # always-on: talks to the registry directly, never the gated helpers
    obs.REGISTRY.counter(
        _TraceCountsView._NAME,
        "jit kernel traces, bumped at trace time only", kernel=name).inc()


def _pow2(n: int, floor: int) -> int:
    return max(int(floor), 1 << max(int(n) - 1, 0).bit_length())


# --------------------------------------------------------------------------- #
# Mesh helper
# --------------------------------------------------------------------------- #
def config_mesh(n_devices: int | None = None,
                axis: str = "data") -> DistContext:
    """A 1-D config-axis mesh over the first ``n_devices`` local devices.

    Simulate multi-device on CPU with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the test
    suite runs with 4). ``DistContext(mesh=None)`` — the default
    everywhere — keeps the backend single-device.
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else min(int(n_devices), len(devs))
    return DistContext(mesh=Mesh(np.array(devs[:n]), (axis,)),
                       batch_axes=(axis,))


# --------------------------------------------------------------------------- #
# Packed IR
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class PackedBucket:
    """Streams sharing one padded shape ``(K_pad, R_pad, N_pad, P_pad)``.

    All arrays are dense ``[S_b, ...]`` with per-stream validity carried
    by masks/sizes, so one compiled kernel serves the whole bucket:

    * ``lr_*``: the controller's low-activity runs (the downscale axis) —
      start offset, length, following-busy-run timestamp, valid mask and
      the trailing-run flag (a fired trailing low run never restores);
    * ``cum_res``: resident-sample prefix counts, edge-padded;
    * ``ds_cum``: downscale clip-saving prefix sums, 4 planes per stream
      (clock mode x accounting bucket), sharing the
      :meth:`StreamIR.downscale_cums` memo with the NumPy path;
    * ``pk_*``: the run table under the parking counterfactual (state
      padded ``-1`` so padded runs never match a real state);
    * ``cap_sorted`` / ``cap_top``: sorted-power cap buckets (3 states +
      the cube-law penalty bucket), ``-inf`` **front**-padded so
      ``#{p > cap}`` stays exact, prefix ``top`` tables end-padded.
    """

    key: tuple[int, int, int, int]
    idx: np.ndarray                  # [S_b] positions in the packed stream list
    arrays: dict[str, np.ndarray]
    _jnp: dict[str, jax.Array] | None = None

    def device_arrays(self) -> dict[str, jax.Array]:
        """Lazily transferred device copies (cached: repeat sweeps and
        search rounds must not re-upload the packed tensors)."""
        if self._jnp is None:
            self._jnp = {k: jnp.asarray(v) for k, v in self.arrays.items()}
        return self._jnp


@dataclasses.dataclass
class PackedIR:
    """A kept-stream set packed for the JAX evaluators (see
    :func:`pack_ir`). Stream order is the IR's sorted-key order, so host
    folds over ``[S]`` axes mirror the NumPy fleet merge exactly."""

    streams: list                    # kept StreamIR objects, sorted-key order
    platforms: list[PlatformSpec]    # [S] resolved per stream
    buckets: list[PackedBucket]
    min_samples: int
    dt_s: float
    # per-stream scalars, [S]-aligned with ``streams``
    base_time: np.ndarray            # [S, 3] f8 per-state baseline seconds
    base_energy: np.ndarray          # [S, 3] f8 per-state baseline joules
    devs: np.ndarray                 # [S] i8 device ids (parking membership)
    tdp: np.ndarray                  # [S] f8
    pk_wakes: np.ndarray             # [S] i8 parking wake events
    pk_idle: np.ndarray              # [S] i8 parked/throttled samples
    # real (unpadded) sizes, for unpack and the property tests
    lr_n: np.ndarray                 # [S] low-run counts
    n_runs: np.ndarray               # [S]
    n_rows: np.ndarray               # [S]
    cap_n: np.ndarray                # [S, 4] cap-bucket sample counts
    bucket_of: np.ndarray            # [S] bucket index per stream
    pos_in_bucket: np.ndarray        # [S] row within the bucket
    # parking counterfactual tables (config-independent), filled lazily
    park_time: np.ndarray | None = None    # [S, 3] f8 seconds
    park_energy: np.ndarray | None = None  # [S, 3] f8 joules

    @property
    def n_streams(self) -> int:
        return len(self.streams)

    def unpack(self) -> list[dict[str, np.ndarray]]:
        """Per-stream real-sized views of the packed tensors (padding
        stripped) — the round-trip side of :func:`pack_ir`, property-
        tested bit-identical against the StreamIR memos."""
        out = []
        for s in range(self.n_streams):
            b = self.buckets[int(self.bucket_of[s])]
            r = int(self.pos_in_bucket[s])
            k = int(self.lr_n[s])
            nr = int(self.n_runs[s])
            n = int(self.n_rows[s])
            a = b.arrays
            caps = {}
            for j, name in enumerate((_DEEP, _EXEC, _ACTIVE, "penalty")):
                p_real = int(self.cap_n[s, j])
                p_pad = a["cap_sorted"].shape[2]
                caps[name] = (a["cap_sorted"][r, j, p_pad - p_real:],
                              a["cap_top"][r, j, :p_real + 1])
            out.append({
                "lr_s0": a["lr_s0"][r, :k],
                "lr_len": a["lr_len"][r, :k],
                "lr_busy": a["lr_busy"][r, :k],
                "lr_trail": a["lr_trail"][r, :k],
                "cum_res": a["cum_res"][r, :n + 1],
                "ds_cum": a["ds_cum"][r, :, :n + 1],
                "pk_state": a["pk_state"][r, :nr],
                "pk_energy": a["pk_energy"][r, :nr],
                "pk_len": a["pk_len"][r, :nr],
                "cap_buckets": caps,
                "ts_first": a["ts_first"][r],
            })
        return out


def _platform_cache_key(platform_of) -> object:
    if platform_of is None or isinstance(platform_of, str):
        return platform_of
    return tuple(sorted(platform_of.items()))


def pack_ir(ir, min_samples: int, min_job_duration_s: float = 2 * 3600.0,
            hosts: Iterable[str] | None = None,
            platform_of: str | Mapping[int, str] | None = None,
            pad_floor: int = 8) -> PackedIR:
    """Pack a :class:`repro.whatif.ir.RunIR` for the JAX evaluators.

    Streams are duration-filtered exactly like
    :func:`repro.whatif.replay.replay_ir` and grouped into power-of-two
    padding buckets on ``(low runs, runs, rows, cap-bucket width)`` —
    each distinct bucket shape compiles once, so retraces stay O(log n)
    in the largest stream, not O(n_streams). All per-sample prefix
    structures come from the :class:`StreamIR` memos (``cum_resident``,
    ``downscale_cums``, ``cap_buckets``, ``parking_counterfactual``,
    ``baseline``), so they are *bitwise the same arrays* the NumPy
    oracle gathers from. ``pad_floor`` sets the minimum padded size per
    axis (tests raise it to force bucket merging).

    The result is cached on the ``ir`` object keyed by every argument
    that shapes it, so sweep + search rounds pack once.
    """
    cache = ir.__dict__.setdefault("_jax_packed", {})
    key = (int(min_samples), float(min_job_duration_s),
           None if hosts is None else tuple(sorted(set(hosts))),
           _platform_cache_key(platform_of), int(pad_floor))
    hit = cache.get(key)
    if hit is not None:
        return hit

    dt = float(ir.config.dt_s)
    kept = [s for s in ir.select(hosts)
            if s.ts_last - s.ts_first + dt >= min_job_duration_s]
    plat_cache: dict[int, PlatformSpec] = {}
    plats = [_resolve_platform(platform_of, plat_cache, s.platform_id)
             for s in kept]

    per_stream = []
    for s, plat in zip(kept, plats):
        off, low_flags = s.controller_runs()
        low_j = np.flatnonzero(low_flags)
        k = int(low_j.size)
        s0 = off[low_j]
        e0 = off[low_j + 1]
        trail = np.zeros(k, dtype=bool)
        if k and int(low_j[-1]) == low_flags.shape[0] - 1:
            trail[-1] = True
        planes = []
        for sm, mem in ((ClockLevel.MIN, ClockLevel.MAX),
                        (ClockLevel.MIN, ClockLevel.MIN)):
            delta = plat.exec_idle_w - plat.residency_floor_w(sm, mem)
            ce, ca = s.downscale_cums(float(delta), plat.deep_idle_w,
                                      min_samples)
            planes.extend((ce, ca))
        cap = s.cap_buckets(min_samples)
        cap_rows = [cap[_DEEP], cap[_EXEC], cap[_ACTIVE],
                    (cap["penalty"][0], cap["penalty"][2])]
        pk = s.parking_counterfactual(min_samples)
        base = s.baseline(min_samples)
        per_stream.append({
            "s0": s0, "e0": e0, "trail": trail,
            "busy": s.ts_first + dt * e0.astype(np.float64),
            "cum_res": s.cum_resident(),
            "planes": planes,
            "cap_rows": cap_rows,
            "pk_state": s.state.astype(np.int32),
            "pk_cf_state": pk["cf_state"].astype(np.int32),
            "pk_energy": pk["keep_sum"] + pk["idle_len"] * plat.deep_idle_w,
            "pk_len": s.length.astype(np.int64),
            "pk_wakes": pk["wakes"], "pk_idle": pk["idle_samples"],
            "base": base, "ts_first": float(s.ts_first),
            "sizes": (k, s.n_runs, s.n_rows,
                      max(r[0].shape[0] for r in cap_rows)),
        })

    n = len(kept)
    # bucket on the *scan* axis only (the low-run count): the downscale
    # kernel pays one sequential lax.scan step per padded low run, so
    # that axis sets both trace count and step count. The passive axes
    # (runs, rows, cap width) are merely gathered into — padding them to
    # the group max costs memory, not time — and folding them into the
    # key would explode 96 streams into dozens of kernel launches
    groups: dict[int, list[int]] = {}
    for i, d in enumerate(per_stream):
        groups.setdefault(_pow2(d["sizes"][0], pad_floor), []).append(i)

    buckets = []
    bucket_of = np.zeros(n, dtype=np.int64)
    pos_in_bucket = np.zeros(n, dtype=np.int64)
    for kp in sorted(groups):
        idx = np.array(groups[kp], dtype=np.int64)
        rp, npad, pp = (
            _pow2(max(per_stream[i]["sizes"][ax] for i in idx), pad_floor)
            for ax in (1, 2, 3))
        bk = (kp, rp, npad, pp)
        sb = idx.size
        arrays = {
            "lr_s0": np.zeros((sb, kp), np.int64),
            "lr_len": np.zeros((sb, kp), np.int64),
            "lr_busy": np.zeros((sb, kp), np.float64),
            "lr_valid": np.zeros((sb, kp), bool),
            "lr_trail": np.zeros((sb, kp), bool),
            "cum_res": np.zeros((sb, npad + 1), np.int64),
            "ds_cum": np.zeros((sb, 4, npad + 1), np.float64),
            "pk_state": np.full((sb, rp), -1, np.int32),
            "pk_energy": np.zeros((sb, rp), np.float64),
            "pk_len": np.zeros((sb, rp), np.int64),
            "cap_sorted": np.full((sb, 4, pp), -np.inf, np.float64),
            "cap_top": np.zeros((sb, 4, pp + 1), np.float64),
            "ts_first": np.zeros(sb, np.float64),
        }
        for r, i in enumerate(idx):
            d = per_stream[i]
            k, nr, nrow, _ = d["sizes"]
            arrays["lr_s0"][r, :k] = d["s0"]
            arrays["lr_len"][r, :k] = d["e0"] - d["s0"]
            arrays["lr_busy"][r, :k] = d["busy"]
            arrays["lr_valid"][r, :k] = True
            arrays["lr_trail"][r, :k] = d["trail"]
            arrays["cum_res"][r, :nrow + 1] = d["cum_res"]
            arrays["cum_res"][r, nrow + 1:] = d["cum_res"][-1]
            for j, plane in enumerate(d["planes"]):
                arrays["ds_cum"][r, j, :nrow + 1] = plane
                arrays["ds_cum"][r, j, nrow + 1:] = plane[-1]
            arrays["pk_state"][r, :nr] = d["pk_cf_state"]
            arrays["pk_energy"][r, :nr] = d["pk_energy"]
            arrays["pk_len"][r, :nr] = d["pk_len"]
            for j, (sp, top) in enumerate(d["cap_rows"]):
                p_real = sp.shape[0]
                arrays["cap_sorted"][r, j, pp - p_real:] = sp
                arrays["cap_top"][r, j, :p_real + 1] = top
                arrays["cap_top"][r, j, p_real + 1:] = top[-1]
            arrays["ts_first"][r] = d["ts_first"]
            bucket_of[i] = len(buckets)
            pos_in_bucket[i] = r
        buckets.append(PackedBucket(key=bk, idx=idx, arrays=arrays))

    if obs.enabled():
        obs.counter("repro_backend_pack_total",
                    help="pack_ir cache misses (full repacks)")
        obs.gauge("repro_backend_pack_buckets", float(len(buckets)),
                  help="padding buckets in the most recent pack")
        real = sum(d["sizes"][0] for d in per_stream)
        padded = sum(b.key[0] * b.idx.size for b in buckets)
        obs.gauge("repro_backend_pack_padding_waste_ratio",
                  1.0 - real / padded if padded else 0.0,
                  help="scan-axis cells lost to pow2 padding, most recent "
                       "pack")
        for b in buckets:
            obs.observe("repro_backend_pack_bucket_occupancy",
                        float(b.idx.size),
                        help="streams sharing one padding bucket")

    packed = PackedIR(
        streams=kept, platforms=plats, buckets=buckets,
        min_samples=int(min_samples), dt_s=dt,
        base_time=np.array([[d["base"].time_s[DeviceState(st)]
                             for st in _STATES] for d in per_stream]
                           ).reshape(n, 3),
        base_energy=np.array([[d["base"].energy_j[DeviceState(st)]
                               for st in _STATES] for d in per_stream]
                             ).reshape(n, 3),
        devs=np.array([s.key[2] for s in kept], dtype=np.int64),
        tdp=np.array([p.tdp_w for p in plats], dtype=np.float64),
        pk_wakes=np.array([d["pk_wakes"] for d in per_stream], np.int64),
        pk_idle=np.array([d["pk_idle"] for d in per_stream], np.int64),
        lr_n=np.array([d["sizes"][0] for d in per_stream], np.int64),
        n_runs=np.array([d["sizes"][1] for d in per_stream], np.int64),
        n_rows=np.array([d["sizes"][2] for d in per_stream], np.int64),
        cap_n=np.array([[r[0].shape[0] for r in d["cap_rows"]]
                        for d in per_stream], np.int64).reshape(n, 4),
        bucket_of=bucket_of, pos_in_bucket=pos_in_bucket,
    )
    cache[key] = packed
    return packed


# --------------------------------------------------------------------------- #
# jit / shard_map kernels
# --------------------------------------------------------------------------- #
def _downscale_kernel(lr_s0, lr_len, lr_busy, lr_valid, lr_trail, cum_res,
                      ds_cum, ts_first, dt, trig, y):
    """Whole-family Algorithm-1 replay over one bucket.

    The only truly sequential part of the replay is the cooldown chain —
    whether run k fires depends on the busy timestamp of the last fired
    run — so the ``lax.scan`` carries exactly that and nothing else. The
    fire test collapses to one float compare: with ``i_row = max(trig,
    searchsorted(ts[s0:e0], t_cd, "left"))`` and ``trig < len``, the run
    fires iff the cooldown expires before its last row, i.e. iff
    ``ts[e0-1] >= t_cd`` (timestamps are monotone). Everything priced off
    that decision — the trigger row, the prefix-table gathers, both
    clock-mode savings — is hoisted into vectorized ``[K, S, C]`` passes
    around the scan, where XLA:CPU runs an order of magnitude faster than
    inside a small-body scan step.

    The cooldown trigger index replicates the row path's
    ``searchsorted`` **exactly**: the crossing is float-predicted to
    within <<1 index, then resolved by a 4-probe window evaluating the
    same ``fl(ts_first + fl(dt*i))`` timestamps the host
    ``StreamIR.ts()`` reconstructs — bit-identical decisions, hence
    bit-identical event and throttle counts.

    The config axis is the family's **unique (trigger, cooldown) pairs**
    (decisions are clock-mode independent); savings come back for both
    clock modes and the host selects per config.
    """
    _mark_trace("downscale")
    s_dim = lr_s0.shape[0]
    k_dim = lr_s0.shape[1]
    c_dim = trig.shape[0]
    tsf = ts_first[:, None]
    y_row = y[None, :]

    # carry-independent gathers, one vectorized [S, K] pass each
    e0 = lr_s0 + lr_len
    res_end = jnp.take_along_axis(cum_res, e0, axis=1)
    end4 = jnp.take_along_axis(
        ds_cum, jnp.broadcast_to(e0[:, None, :], (s_dim, 4, k_dim)),
        axis=2)
    # last-row timestamp per run, same float expression as StreamIR.ts()
    ts_last = tsf + dt * (e0 - 1).astype(jnp.float64)
    can_fire = (lr_valid.T[:, :, None]
                & (lr_len.T[:, :, None] > trig[None, None, :]))

    def step(last_busy, xs):
        busy_k, ts_last_k, can_k = xs
        t_cd = last_busy + y_row
        fire = can_k & (ts_last_k[:, None] >= t_cd)
        return jnp.where(fire, busy_k[:, None], last_busy), (fire, t_cd)

    _, (fire, t_cd) = jax.lax.scan(
        step, jnp.full((s_dim, c_dim), -jnp.inf),
        (lr_busy.T, ts_last.T, can_fire), unroll=8)

    # vectorized trigger-row resolution over the whole [K, S, C] block:
    # float-predicted crossing, clipped in float space first so the -inf
    # no-cooldown sentinel never reaches the int cast
    s0k = lr_s0.T[:, :, None]
    lnk = lr_len.T[:, :, None]
    tsf3 = ts_first[None, :, None]
    # the float prediction is within ~1e-6 of the exact crossing, so a
    # 4-probe window [floor(rel)-1, floor(rel)+2] provably contains the
    # searchsorted result (ties shift it by at most one index)
    rel = (t_cd - tsf3) / dt - s0k.astype(jnp.float64)
    lo = jnp.clip(jnp.floor(rel) - 1.0, 0.0,
                  lnk.astype(jnp.float64)).astype(jnp.int64)
    cnt = jnp.zeros((k_dim, s_dim, c_dim), jnp.int64)
    for w in range(4):
        j = (s0k + lo + w).astype(jnp.float64)
        ts_j = tsf3 + dt * j
        cnt = cnt + ((lo + w < lnk) & (ts_j < t_cd)).astype(jnp.int64)
    i_row = jnp.maximum(trig[None, None, :], lo + cnt)
    gpos = s0k + jnp.where(fire, i_row, 0)

    # one 2-D gather per prefix plane, each feeding exactly one consumer
    # chain — a single fused 5-plane gather tempts XLA:CPU into
    # duplicating the (expensive) gather into every savings fusion
    idx = jnp.transpose(gpos, (1, 0, 2)).reshape(s_dim, k_dim * c_dim)
    firesc = jnp.transpose(fire, (1, 0, 2))

    n_down = jnp.sum(fire.astype(jnp.int64), axis=0)
    n_rest = jnp.sum((fire & ~lr_trail.T[:, :, None]).astype(jnp.int64),
                     axis=0)
    g_res = jnp.take_along_axis(cum_res, idx, axis=1).reshape(
        s_dim, k_dim, c_dim)
    thr = jnp.sum(jnp.where(
        firesc, res_end[:, :, None] - g_res, 0), axis=1)

    def saved(plane):
        g = jnp.take_along_axis(ds_cum[:, plane], idx, axis=1).reshape(
            s_dim, k_dim, c_dim)
        return jnp.sum(jnp.where(
            firesc, end4[:, plane][:, :, None] - g, 0.0), axis=1)

    return (n_down, n_rest, thr,
            saved(0), saved(1),   # clocks (MIN, MAX)
            saved(2), saved(3))   # clocks (MIN, MIN)


def _integrate_runs_kernel(state, energy, lengths, min_samples):
    """:meth:`BatchedStreamingIntegrator.update_runs` as one jit'd pass
    over ``[rows, runs]``: merge consecutive equal-state runs by
    ``segment_sum``, relabel short EXECUTION_IDLE merges ACTIVE, reduce
    per state. Times are exact integer sums (bit-identical to the
    streaming integrator); energies agree to summation order."""
    _mark_trace("integrate")
    s_dim, r_dim = state.shape
    prev = jnp.concatenate(
        [jnp.full((s_dim, 1), -2, state.dtype), state[:, :-1]], axis=1)
    seg = jnp.cumsum((state != prev).astype(jnp.int64), axis=1) - 1
    gid = (seg + (jnp.arange(s_dim) * r_dim)[:, None]).reshape(-1)
    seg_len = jax.ops.segment_sum(lengths.reshape(-1), gid,
                                  num_segments=s_dim * r_dim)
    merged = seg_len[gid].reshape(s_dim, r_dim)
    final = jnp.where((state == _EXEC) & (merged < min_samples),
                      _ACTIVE, state)
    times = []
    energies = []
    for st in _STATES:
        m = final == st
        times.append(jnp.sum(jnp.where(m, lengths, 0), axis=1))
        energies.append(jnp.sum(jnp.where(m, energy, 0.0), axis=1))
    return jnp.stack(times, axis=1), jnp.stack(energies, axis=1)


def _powercap_kernel(cap_sorted, cap_top, base_e, caps, cbrt_caps, dt):
    """Every cap fraction against the sorted-power prefix structures:
    ``k = #{p > cap}`` per (stream, bucket, config) via the run-replay
    cap scan, then clipped energy / throttle / cube-law penalty are O(1)
    gathers — the device port of :meth:`PowerCapBatch.apply_runs`."""
    _mark_trace("powercap")
    s_dim, n_b, p_dim = cap_sorted.shape
    c_dim = caps.shape[1]
    rows = cap_sorted.reshape(s_dim * n_b, p_dim)
    cap_rows = jnp.broadcast_to(
        caps[:, None, :], (s_dim, n_b, c_dim)).reshape(s_dim * n_b, c_dim)
    k = cap_bucket_counts(rows, cap_rows).astype(jnp.int64).reshape(
        s_dim, n_b, c_dim)
    top_at = jnp.take_along_axis(cap_top, k, axis=2)
    e_cf = base_e[:, :, None] - (top_at[:, :3, :]
                                 - k[:, :3, :] * caps[:, None, :]) * dt
    pen = dt * (top_at[:, 3, :] / cbrt_caps - k[:, 3, :])
    thr = k[:, 0, :] + k[:, 1, :] + k[:, 2, :]
    return e_cf, pen, thr


#: compiled-callable cache: (kernel name, mesh, axis) -> jitted fn.
#: Recreating jax.jit wrappers per call would retrace every call; this
#: keys compilation on the mesh identity so local and sharded variants
#: coexist.
_FN_CACHE: dict[tuple, object] = {}

_DS_STREAM_SPECS = (P(None, None),) * 5 + (P(None, None), P(None, None, None),
                                           P(None), P())
_CAP_STREAM_SPECS = (P(None, None, None), P(None, None, None), P(None, None))


def _get_fn(name: str, dist: DistContext | None):
    dist_on = dist is not None and dist.enabled
    key = (name, dist.mesh if dist_on else None,
           dist.batch_axes[0] if dist_on else None)
    fn = _FN_CACHE.get(key)
    if fn is not None:
        return fn
    if name == "downscale":
        kernel, stream_specs, n_cfg, n_out = (
            _downscale_kernel, _DS_STREAM_SPECS, 2, 7)
    elif name == "powercap":
        kernel, stream_specs, n_cfg, n_out = (
            _powercap_kernel, _CAP_STREAM_SPECS + (P(None, None),), 0, 0)
    else:
        kernel = _integrate_runs_kernel
        fn = _FN_CACHE[key] = jax.jit(kernel)
        return fn
    if dist_on:
        from jax.experimental.shard_map import shard_map
        ax = dist.batch_axes[0]
        if name == "downscale":
            in_specs = stream_specs + (P(ax),) * n_cfg
            out_specs = (P(None, ax),) * n_out
        else:
            in_specs = _CAP_STREAM_SPECS + (P(None, ax), P(None, ax), P())
            out_specs = (P(None, None, ax), P(None, ax), P(None, ax))
        kernel = shard_map(kernel, mesh=dist.mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)
    fn = _FN_CACHE[key] = jax.jit(kernel)
    return fn


def _config_pad(n: int, dist: DistContext | None, floor: int = 8) -> int:
    """Pad the config axis to a power of two (>= ``floor``) so search
    rounds with drifting candidate counts reuse compilations, rounded up
    to the mesh axis size (shard_map needs exact divisibility — same
    rule as :mod:`repro.distributed.sharding`)."""
    c = _pow2(n, floor)
    if dist is not None and dist.enabled:
        ax = int(dist.mesh.shape[dist.batch_axes[0]])
        c = ((c + ax - 1) // ax) * ax
    return c


def _pad_cols(a: np.ndarray, c_pad: int, fill) -> np.ndarray:
    out = np.full(a.shape[:-1] + (c_pad,), fill, dtype=a.dtype)
    out[..., :a.shape[-1]] = a
    return out


# --------------------------------------------------------------------------- #
# Public integrator port
# --------------------------------------------------------------------------- #
def jax_integrate_runs(states: np.ndarray, energy: np.ndarray,
                       lengths: np.ndarray, min_samples: int,
                       dt_s: float = 1.0) -> list[EnergyBreakdown]:
    """Drop-in port of :func:`repro.core.energy.integrate_runs` on JAX:
    per-state times bit-identical, energies <= 1e-9 relative."""
    energy = np.asarray(energy, dtype=np.float64)
    if energy.ndim == 1:
        energy = energy[None, :]
    c, r = energy.shape
    with jax.experimental.enable_x64():
        fn = _get_fn("integrate", None)
        t, e = fn(
            jnp.asarray(np.broadcast_to(
                np.asarray(states, np.int32)[None, :], (c, r))),
            jnp.asarray(energy),
            jnp.asarray(np.broadcast_to(
                np.asarray(lengths, np.int64)[None, :], (c, r))),
            jnp.asarray(int(min_samples), jnp.int64))
        t = np.asarray(t)
        e = np.asarray(e)
    return [
        EnergyBreakdown(
            time_s={DeviceState(st): float(t[i, j] * dt_s)
                    for j, st in enumerate(_STATES)},
            energy_j={DeviceState(st): float(e[i, j] * dt_s)
                      for j, st in enumerate(_STATES)})
        for i in range(c)
    ]


# --------------------------------------------------------------------------- #
# Family evaluators (fill [S, C_family] blocks)
# --------------------------------------------------------------------------- #
def _price_rows(policies, platforms) -> np.ndarray:
    """[S, C] per-event prices: ``event_penalty_s`` per distinct platform."""
    rows: dict[str, np.ndarray] = {}
    out = np.empty((len(platforms), len(policies)))
    for i, plat in enumerate(platforms):
        row = rows.get(plat.name)
        if row is None:
            row = rows[plat.name] = np.array(
                [p.event_penalty_s(plat) for p in policies])
        out[i] = row
    return out


def _parked_mask(pools, devs: np.ndarray) -> np.ndarray:
    """[S, C] bool — is each stream's device outside each pool config's
    active set (``device_id % n_devices not in active_set``)?"""
    out = np.empty((devs.shape[0], len(pools)), dtype=bool)
    for c, (nd, act) in enumerate(pools):
        out[:, c] = ~np.isin(devs % nd, list(act))
    return out


def _run_downscale_family(packed: PackedIR, batch, dist, dt):
    """Run the downscale kernel over every bucket; returns
    ``(n_down, n_rest, throttled, sav_exec, sav_act)`` as [S, C] host
    arrays (savings in W·samples, exactly the NumPy kernel's units).

    The kernel's config axis is the family's unique (trigger, cooldown)
    pairs — the decision sequence is clock-mode independent, so a dense
    x/y grid swept at both clock modes replays each pair once. The
    kernel prices both modes; this expands pairs back to configs and
    selects the mode's savings planes."""
    c_real = len(batch.policies)
    mode_lo = np.array(
        [p._min_clocks() == (ClockLevel.MIN, ClockLevel.MIN)
         for p in batch.policies], dtype=bool)
    pair_key = np.stack(
        [np.asarray(batch._trig, np.float64), np.asarray(batch._y)], axis=1)
    _, uniq_idx, pair_of_c = np.unique(
        pair_key, axis=0, return_index=True, return_inverse=True)
    pair_of_c = pair_of_c.reshape(-1)
    p_real = uniq_idx.shape[0]
    p_pad = _config_pad(p_real, dist)
    trig = jnp.asarray(_pad_cols(batch._trig[uniq_idx], p_pad,
                                 _NEVER_TRIGGERS))
    y = jnp.asarray(_pad_cols(batch._y[uniq_idx], p_pad, 0.0))
    s = packed.n_streams
    outs = [np.zeros((s, p_real), np.int64) for _ in range(3)] + \
           [np.zeros((s, p_real)) for _ in range(4)]
    fn = _get_fn("downscale", dist)
    for bucket in packed.buckets:
        a = bucket.device_arrays()
        res = fn(a["lr_s0"], a["lr_len"], a["lr_busy"], a["lr_valid"],
                 a["lr_trail"], a["cum_res"], a["ds_cum"], a["ts_first"],
                 dt, trig, y)
        for dst, arr in zip(outs, res):
            dst[bucket.idx] = np.asarray(arr)[:, :p_real]
    nd, nr, th, se_hi, sa_hi, se_lo, sa_lo = outs
    sel = mode_lo[None, :]
    return [nd[:, pair_of_c], nr[:, pair_of_c], th[:, pair_of_c],
            np.where(sel, se_lo[:, pair_of_c], se_hi[:, pair_of_c]),
            np.where(sel, sa_lo[:, pair_of_c], sa_hi[:, pair_of_c])]


def _park_tables(packed: PackedIR) -> tuple[np.ndarray, np.ndarray]:
    """Config-independent parked counterfactual per stream: the
    integrator port over the pre-priced parking run tables. Cached on
    the packed IR — every parking/composite family and round shares it."""
    if packed.park_time is None:
        s = packed.n_streams
        t_out = np.zeros((s, 3))
        e_out = np.zeros((s, 3))
        fn = _get_fn("integrate", None)
        ms = jnp.asarray(packed.min_samples, jnp.int64)
        for bucket in packed.buckets:
            a = bucket.device_arrays()
            t, e = fn(a["pk_state"], a["pk_energy"], a["pk_len"], ms)
            t_out[bucket.idx] = np.asarray(t) * packed.dt_s
            e_out[bucket.idx] = np.asarray(e) * packed.dt_s
        packed.park_time = t_out
        packed.park_energy = e_out
    return packed.park_time, packed.park_energy


def _run_powercap_family(packed: PackedIR, batch, dist, dt):
    """Cap kernel over every bucket: ``(energy_cf [S,3,C], penalty
    [S,C], throttled [S,C])``. Caps and their cube roots are host-built
    per stream platform (``frac * tdp_w``, same floats as NumPy)."""
    c_real = len(batch.policies)
    c_pad = _config_pad(c_real, dist)
    # pad with a huge finite cap (k = 0 lanes): +inf would make the
    # clipped-energy term 0 * inf = NaN
    fracs = _pad_cols(batch._fracs, c_pad, 1e300)
    caps = np.where(np.arange(c_pad) < c_real,
                    fracs[None, :] * packed.tdp[:, None], 1e300)
    cbrt_caps = np.cbrt(caps)
    s = packed.n_streams
    e_cf = np.zeros((s, 3, c_real))
    pen = np.zeros((s, c_real))
    thr = np.zeros((s, c_real), np.int64)
    fn = _get_fn("powercap", dist)
    caps_j = jnp.asarray(caps)
    cbrt_j = jnp.asarray(cbrt_caps)
    for bucket in packed.buckets:
        a = bucket.device_arrays()
        base_e = jnp.asarray(packed.base_energy[bucket.idx])
        e_b, p_b, t_b = fn(a["cap_sorted"], a["cap_top"], base_e,
                           caps_j[bucket.idx], cbrt_j[bucket.idx], dt)
        e_cf[bucket.idx] = np.asarray(e_b)[:, :, :c_real]
        pen[bucket.idx] = np.asarray(p_b)[:, :c_real]
        thr[bucket.idx] = np.asarray(t_b)[:, :c_real]
    return e_cf, pen, thr


# --------------------------------------------------------------------------- #
# The backend's replay entry point
# --------------------------------------------------------------------------- #
def replay_ir_outcomes(
    ir,
    policies: Sequence,
    platform_of: str | Mapping[int, str] | None = None,
    min_job_duration_s: float = 2 * 3600.0,
    min_interval_s: float | None = 5.0,
    classifier: ClassifierConfig = DEFAULT_CLASSIFIER,
    dt_s: float = 1.0,
    hosts: Iterable[str] | None = None,
    dist: DistContext | None = None,
    pad_floor: int = 8,
) -> tuple[list[PolicyOutcome], int, int]:
    """Replay a policy grid against a :class:`RunIR` on the JAX backend.

    The device-side counterpart of :func:`repro.whatif.replay.replay_ir`
    + :func:`repro.whatif.sweep._outcome` fused: family kernels produce
    ``[n_streams, n_configs]`` counts/savings on device, and the fleet
    assembly on the host replays the NumPy reduction *order* (vectorized
    axis-0 left folds over sorted streams), so time/count metrics are
    bit-identical and energies/penalties <= 1e-9 relative. Every
    policy must be IR-capable (:func:`repro.whatif.ir.ir_supported`) —
    the sweep kernel routes anything else through the row path.

    ``dist`` shards the config axis over a mesh from
    :func:`config_mesh`; results are identical for every mesh shape.
    Returns ``(outcomes in grid order, n_rows, n_runs)``.
    """
    if classifier != ir.config.classifier:
        raise ValueError(
            f"IR was built for classifier {ir.config.classifier}, replay "
            f"requested {classifier}; rebuild the IR or use compact=False")
    if dt_s != ir.config.dt_s:
        raise ValueError(f"IR dt_s {ir.config.dt_s} != replay dt_s {dt_s}")
    policies = list(policies)
    min_samples = (0 if min_interval_s is None
                   else int(np.ceil(min_interval_s / dt_s)))
    selected = ir.select(hosts)
    n_rows = sum(s.n_rows for s in selected)
    n_runs = sum(s.n_runs for s in selected)
    n_cfg = len(policies)
    if n_cfg == 0:
        return [], n_rows, n_runs

    with obs.span("backend.pack", streams=len(selected)):
        packed = pack_ir(ir, min_samples,
                         min_job_duration_s=min_job_duration_s,
                         hosts=hosts, platform_of=platform_of,
                         pad_floor=pad_floor)
    s = packed.n_streams
    dt = dt_s

    if obs.enabled():
        n_dev = (dist.mesh.size if dist is not None and dist.mesh is not None
                 else len(jax.devices()))
        obs.gauge("repro_backend_devices", float(n_dev),
                  help="devices the config axis runs over (mesh size when "
                       "sharded, visible devices otherwise)")

    # per-(stream, config) accumulators, initialised to the baseline
    cf_time = np.repeat(packed.base_time[:, :, None], n_cfg, axis=2)
    cf_energy = np.repeat(packed.base_energy[:, :, None], n_cfg, axis=2)
    pen = np.zeros((s, n_cfg))
    wakes = np.zeros((s, n_cfg), np.int64)
    downs = np.zeros((s, n_cfg), np.int64)
    thr = np.zeros((s, n_cfg), np.int64)

    with obs.span("backend.kernels", configs=n_cfg, streams=s), \
         jax.experimental.enable_x64():
        dt_j = jnp.asarray(dt, jnp.float64)
        for batch, idxs in make_batches(policies):
            ci = np.asarray(idxs, dtype=np.int64)
            if isinstance(batch, NoOpBatch):
                continue
            if isinstance(batch, DownscaleBatch):
                nd, nr, th, se, sa = _run_downscale_family(
                    packed, batch, dist, dt_j)
                cf_energy[:, 1, ci] = packed.base_energy[:, 1:2] - se * dt
                cf_energy[:, 2, ci] = packed.base_energy[:, 2:3] - sa * dt
                pen[:, ci] = nr * _price_rows(batch.policies,
                                              packed.platforms)
                wakes[:, ci] = nr
                downs[:, ci] = nd
                thr[:, ci] = th
            elif isinstance(batch, ParkingBatch):
                pt, pe = _park_tables(packed)
                mask = _parked_mask(batch._pools, packed.devs)
                m3 = mask[:, None, :]
                cf_time[:, :, ci] = np.where(m3, pt[:, :, None],
                                             packed.base_time[:, :, None])
                cf_energy[:, :, ci] = np.where(m3, pe[:, :, None],
                                               packed.base_energy[:, :, None])
                wk = np.where(mask, packed.pk_wakes[:, None], 0)
                wakes[:, ci] = wk
                thr[:, ci] = np.where(mask, packed.pk_idle[:, None], 0)
                pen[:, ci] = wk * np.array(
                    [p.resume_latency_s for p in batch.policies])[None, :]
            elif isinstance(batch, PowerCapBatch):
                e_cf, p_cap, th = _run_powercap_family(
                    packed, batch, dist, dt_j)
                cf_energy[:, :, ci] = e_cf
                pen[:, ci] = p_cap
                thr[:, ci] = th
            elif isinstance(batch, CompositeBatch):
                if not batch._ir_ok:
                    raise ValueError(
                        "run-level replay supports only parking+downscale "
                        "composites; route this batch through the row path")
                nd, nr, th_ds, se, sa = _run_downscale_family(
                    packed, batch._ds_batch, dist, dt_j)
                pt, pe = _park_tables(packed)
                mask = _parked_mask(batch._park_pools, packed.devs)
                m3 = mask[:, None, :]
                ds_e = np.repeat(packed.base_energy[:, :, None],
                                 len(idxs), axis=2)
                ds_e[:, 1, :] -= se * dt
                ds_e[:, 2, :] -= sa * dt
                cf_time[:, :, ci] = np.where(m3, pt[:, :, None],
                                             packed.base_time[:, :, None])
                cf_energy[:, :, ci] = np.where(m3, pe[:, :, None], ds_e)
                wk = np.where(mask, packed.pk_wakes[:, None], 0)
                wakes[:, ci] = wk + nr
                downs[:, ci] = nd
                thr[:, ci] = np.where(mask, packed.pk_idle[:, None], th_ds)
                price_park = np.array(
                    [p.parts[0].resume_latency_s for p in batch.policies])
                price_ds = _price_rows(
                    [p.parts[1] for p in batch.policies], packed.platforms)
                # matches price_events' per-channel left fold:
                # fl(fl(wakes*price0) + fl(restores*price1))
                pen[:, ci] = wk * price_park[None, :] + nr * price_ds
            else:
                raise ValueError(
                    f"jax backend supports only IR-capable policy families, "
                    f"got {type(batch).__name__}")

    # ---- fleet assembly: replicate the NumPy reduction order ---------- #
    # merge() is a per-state left fold over jobs in sorted-stream order.
    # ``np.sum`` over the outer axis of a C-order array reduces one
    # stream-row at a time — the same left fold, so times stay bitwise
    # identical to the explicit per-stream loop this replaces. Penalties
    # use the same axis-0 fold (all terms non-negative, so the naive sum
    # sits well inside the <= 1e-9 oracle tolerance fsum used to meet).
    with obs.span("backend.assembly", configs=n_cfg, streams=s):
        fleet_t = cf_time.sum(axis=0)
        fleet_e = cf_energy.sum(axis=0)
        fleet_bt = packed.base_time.sum(axis=0)
        fleet_be = packed.base_energy.sum(axis=0)

        def _total(per_state):
            # sum(dict.values()) == left fold over DeviceState order
            tot = np.zeros(per_state.shape[1:])
            for j in range(3):
                tot = tot + per_state[j]
            return tot

        base_tot = float(_total(fleet_be[:, None])[0]) if s else 0.0
        cf_tot = _total(fleet_e)
        penalty_s = pen.sum(axis=0)
        wake_tot = wakes.sum(axis=0)
        down_tot = downs.sum(axis=0)
        thr_tot = thr.sum(axis=0)

        jb_tot = _total(np.swapaxes(packed.base_energy, 0, 1))    # [S]
        jc_tot = _total(np.swapaxes(cf_energy, 0, 1))             # [S, C]
        with np.errstate(invalid="ignore", divide="ignore"):
            jb_col = jb_tot[:, None]
            saved_jobs = np.where(jb_col != 0.0,
                                  (jb_col - jc_tot) / jb_col, 0.0)
        # one transpose+tolist per CDF instead of a Python float() loop
        # per (config, stream) cell — same float64 values either way
        saved_rows = np.sort(saved_jobs, axis=0).T.tolist()       # [C][S]
        pen_rows = np.sort(pen, axis=0).T.tolist()                # [C][S]

        active_t = float(fleet_bt[2]) if s else 0.0
        base_exec_den = float(fleet_be[1] + fleet_be[2]) if s else 0.0
        base_exec_frac = (float(fleet_be[1]) / base_exec_den
                          if base_exec_den else 0.0)
        cf_exec_den = fleet_e[1] + fleet_e[2]

        outcomes = []
        for c, pol in enumerate(policies):
            cf_total = float(cf_tot[c])
            saved = base_tot - cf_total
            p_s = float(penalty_s[c])
            outcomes.append(PolicyOutcome(
                name=pol.name,
                params=pol.describe(),
                n_jobs=s,
                baseline_energy_j=base_tot,
                counterfactual_energy_j=cf_total,
                energy_saved_j=saved,
                saved_fraction=saved / base_tot if base_tot else 0.0,
                penalty_s=p_s,
                penalty_fraction=p_s / active_t if active_t else 0.0,
                wake_events=int(wake_tot[c]),
                downscale_events=int(down_tot[c]),
                throttled_time_s=float(int(thr_tot[c]) * dt),
                exec_idle_energy_fraction_baseline=base_exec_frac,
                exec_idle_energy_fraction_cf=(
                    float(fleet_e[1, c]) / float(cf_exec_den[c])
                    if s and cf_exec_den[c] else 0.0),
                per_job_saved_fraction=tuple(saved_rows[c]),
                per_job_penalty_s=tuple(pen_rows[c]),
            ))
    return outcomes, n_rows, n_runs

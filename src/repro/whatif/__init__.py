"""What-if policy engine: counterfactual mitigation sweeps over stored
fleet telemetry.

Replays any :class:`~repro.telemetry.storage.TelemetryStore` (cluster
simulator output, DES/serving traces) under execution-idle mitigation
policies — Algorithm-1 downscaling, k-of-n consolidation parking, power
capping, and sequential :class:`~repro.whatif.policies.CompositePolicy`
combinations of them — fully out-of-core, and reports the energy/perf
trade-off :class:`~repro.whatif.sweep.Frontier`. Policies are values in the
:mod:`repro.whatif.effects` algebra; grids ride the config-axis batched
replay; and :func:`~repro.whatif.search.search_frontier` turns the fixed
grid sweep into a budgeted closed-loop knob search around the Pareto knee.
Turns the repro from "measure execution-idle" into "choose a mitigation".
"""
from repro.whatif.effects import (  # noqa: F401
    BatchEffect,
    SegmentEffect,
    compose,
    effect_view,
    identity_effect,
    policy_event_channels,
    policy_event_prices,
    price_events,
)
from repro.whatif.policies import (  # noqa: F401
    BatchDownscaleCarry,
    CompositeBatch,
    CompositePolicy,
    DownscaleBatch,
    DownscaleCarry,
    DownscalePolicy,
    FallbackBatch,
    NoOpBatch,
    NoOpPolicy,
    ParkingBatch,
    ParkingPolicy,
    Policy,
    PolicyBatch,
    PowerCapBatch,
    PowerCapPolicy,
    RunBatchResult,
    batched_downscale_decisions,
    downscale_decisions,
    downscale_trigger_index,
    low_activity_series,
    make_batches,
)
from repro.whatif.ir import (  # noqa: F401
    IRBuilder,
    IRConfig,
    IRUnsupportedError,
    RunIR,
    StreamIR,
    build_ir,
    get_ir,
    ir_config_for,
    ir_supported,
    load_sidecar,
    save_sidecar,
)
from repro.whatif.replay import (  # noqa: F401
    BatchedPolicyReplayer,
    JobReplay,
    PolicyReplayer,
    ReplayResult,
    replay_chunk,
    replay_ir,
    replay_store,
)
from repro.whatif.sweep import (  # noqa: F401
    Frontier,
    PolicyOutcome,
    assemble_frontier,
    default_policy_grid,
    evaluate,
    pareto_flags,
    run_sweep,
    sweep_frame,
)
from repro.whatif.search import (  # noqa: F401
    CategoricalAxis,
    ContinuousAxis,
    PenaltyBudget,
    PolicyFamily,
    RoundRecord,
    SearchResult,
    achievable_saving,
    default_families,
    find_knee,
    search_frontier,
    seed_points,
)
from repro.whatif.report import (  # noqa: F401
    format_frontier,
    format_search_trace,
    frontier_from_dict,
    frontier_to_dict,
    load_frontier,
    save_frontier,
)

"""What-if policy engine: counterfactual mitigation sweeps over stored
fleet telemetry.

Replays any :class:`~repro.telemetry.storage.TelemetryStore` (cluster
simulator output, DES/serving traces) under a grid of execution-idle
mitigation policies — Algorithm-1 downscaling, k-of-n consolidation
parking, power capping — fully out-of-core, and reports the energy/perf
trade-off :class:`~repro.whatif.sweep.Frontier`. Turns the repro from
"measure execution-idle" into "choose a mitigation".
"""
from repro.whatif.policies import (  # noqa: F401
    BatchDownscaleCarry,
    BatchEffect,
    DownscaleBatch,
    DownscaleCarry,
    DownscalePolicy,
    FallbackBatch,
    NoOpBatch,
    NoOpPolicy,
    ParkingBatch,
    ParkingPolicy,
    Policy,
    PolicyBatch,
    PowerCapBatch,
    PowerCapPolicy,
    SegmentEffect,
    batched_downscale_decisions,
    downscale_decisions,
    low_activity_series,
    make_batches,
)
from repro.whatif.replay import (  # noqa: F401
    BatchedPolicyReplayer,
    JobReplay,
    PolicyReplayer,
    ReplayResult,
    replay_chunk,
    replay_store,
)
from repro.whatif.sweep import (  # noqa: F401
    Frontier,
    PolicyOutcome,
    default_policy_grid,
    run_sweep,
    sweep_frame,
)
from repro.whatif.report import (  # noqa: F401
    format_frontier,
    frontier_from_dict,
    frontier_to_dict,
    load_frontier,
    save_frontier,
)

"""Policy evaluation kernel and the fixed-grid sweep built on it.

:func:`evaluate` is the reusable kernel: replay any set of policy configs
over one :class:`TelemetryStore`, one :class:`PolicyOutcome` per config.
:func:`run_sweep` is its fixed-grid caller — it assembles a
:class:`Frontier` (energy saved vs performance penalty per config, the
Pareto-optimal subset flagged, per-job CDFs attached) from the default
200-config grid. :func:`repro.whatif.search.search_frontier` is the
*closed-loop* caller: the same kernel inside a budgeted refinement loop
around the Pareto knee.

Execution model: the store's shards are partitioned by host label (each
(job, host, device) stream lives entirely under one host label, so
partitions hold disjoint streams); each partition streams its shards once.
By default (``batched=True``) the whole grid rides one
:class:`~repro.whatif.replay.BatchedPolicyReplayer` per partition: the grid
is grouped into family batches and every stream segment is classified,
run-length-encoded and baseline-integrated ONCE for all configs, each
family evaluated as a ``(n_configs, n_samples)`` block — the sweep is
O(rows + configs), not O(rows x configs). ``batched=False`` keeps one
:class:`~repro.whatif.replay.PolicyReplayer` per config (sharing only
grouping + classification via :func:`repro.whatif.replay.replay_chunk`);
it is the reference oracle the batched path is verified bit-identical
against. Either way peak memory is one shard + per-stream carry state.
With ``workers > 1`` partitions run in a process pool and the replayers are
merged (disjoint-stream merge); every per-stream computation is identical
and the cross-stream reductions are exact (``math.fsum``) or order-fixed
(sorted stream keys), so ``workers=N`` is **bit-identical** to
``workers=1``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import TYPE_CHECKING, Iterable, Sequence

import numpy as np

import repro.obs as obs
from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.imbalance import PoolConfig, PoolPolicy
from repro.telemetry.pipeline import map_shard_partitions
from repro.whatif.policies import (DownscalePolicy, NoOpPolicy, ParkingPolicy,
                                   Policy, PowerCapPolicy)
from repro.whatif.replay import (BatchedPolicyReplayer, PolicyReplayer,
                                 ReplayResult, replay_chunk)

if TYPE_CHECKING:
    from repro.telemetry.storage import TelemetryStore


# --------------------------------------------------------------------------- #
# Default policy grid
# --------------------------------------------------------------------------- #
def default_policy_grid(dense: bool = True) -> list[Policy]:
    """Policy configs spanning the paper's mitigation space.

    ``dense=True`` (default): 200 configs — 1 no-op + 64 Algorithm-1
    downscale (X x Y x mode) + 21 consolidation (k-of-n x resume latency)
    + 114 power caps. The dense parking/cap axes follow the "Model Parking
    Tax" trade-off study; a grid this size is only affordable because the
    config-axis batched replay makes the sweep O(rows + configs).

    ``dense=False``: the legacy 48-config grid (1 + 24 + 6 + 17) that the
    committed ``BENCH_whatif_sweep.json`` baseline measures.
    """
    grid: list[Policy] = [NoOpPolicy()]
    xs = ((0.5, 1.0, 2.0, 3.0, 5.0, 8.0, 10.0, 15.0) if dense
          else (1.0, 2.0, 3.0, 5.0, 8.0, 10.0))
    ys = (1.0, 2.0, 5.0, 10.0) if dense else (2.0, 5.0)
    for x in xs:
        for y in ys:
            for mode in (DownscaleMode.SM_ONLY, DownscaleMode.SM_AND_MEM):
                grid.append(DownscalePolicy(config=ControllerConfig(
                    threshold_x_s=x, cooldown_y_s=y, mode=mode)))
    resumes = (2.0, 5.0, 10.0, 30.0, 60.0) if dense else (5.0, 30.0)
    for k in (1, 2, 3):
        for resume_s in resumes:
            grid.append(ParkingPolicy(
                pool=PoolConfig(n_devices=4, policy=PoolPolicy.CONSOLIDATED,
                                n_active=k),
                resume_latency_s=resume_s))
    if dense:
        for k in (2, 4, 6):
            for resume_s in (5.0, 30.0):
                grid.append(ParkingPolicy(
                    pool=PoolConfig(n_devices=8,
                                    policy=PoolPolicy.CONSOLIDATED,
                                    n_active=k),
                    resume_latency_s=resume_s))
    n_caps = 114 if dense else 17
    for frac in np.linspace(0.25, 0.95, n_caps):
        grid.append(PowerCapPolicy(cap_fraction=round(float(frac), 4)))
    return grid


# --------------------------------------------------------------------------- #
# Frontier report
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PolicyOutcome:
    """One grid point on the energy/perf trade-off frontier."""

    name: str
    params: dict
    n_jobs: int
    baseline_energy_j: float
    counterfactual_energy_j: float
    energy_saved_j: float
    saved_fraction: float
    penalty_s: float
    penalty_fraction: float
    wake_events: int
    downscale_events: int
    throttled_time_s: float
    exec_idle_energy_fraction_baseline: float
    exec_idle_energy_fraction_cf: float
    #: sorted per-job CDFs (x-axes of the Fig-7-style what-if plots)
    per_job_saved_fraction: tuple[float, ...]
    per_job_penalty_s: tuple[float, ...]
    pareto: bool = False


@dataclasses.dataclass(frozen=True)
class Frontier:
    """Sweep result: one outcome per policy config, Pareto subset flagged.

    Produced by the fixed-grid :func:`run_sweep` / :func:`sweep_frame` and
    by the closed-loop :func:`repro.whatif.search.search_frontier` (whose
    :class:`~repro.whatif.search.SearchResult.frontier` holds every config
    the search evaluated). :func:`repro.whatif.search.find_knee` locates a
    frontier's point of diminishing returns;
    :meth:`best_within_penalty` / :class:`repro.whatif.search.PenaltyBudget`
    answer the budget question directly.

    ``n_runs`` is the run-level IR's compact axis size when the sweep took
    the compact path (0 otherwise): ``n_rows / n_runs`` is the corpus's
    compaction ratio — a direct view of how idle-dominated (and therefore
    run-compressible) the fleet telemetry is.

    ``trace`` is the closed-loop search's eval-by-eval convergence record
    (empty for fixed-grid sweeps): one dict per evaluated config, in
    evaluation order — ``{"i", "round", "family", "saved_fraction",
    "penalty_s"}`` — deliberately containing only deterministic replay
    results (no wall-clock), so frontiers stay **bit-identical** whether
    observability is on or off. Render with
    :func:`repro.whatif.report.format_search_trace`.
    """

    outcomes: tuple[PolicyOutcome, ...]
    n_rows: int
    n_jobs: int
    n_runs: int = 0
    trace: tuple[dict, ...] = ()
    #: rows replayed / rows on disk — 1.0 unless shards were skipped under
    #: ``strict=False`` (see README "Robustness & dirty telemetry")
    coverage: float = 1.0

    @property
    def compaction_ratio(self) -> float:
        return self.n_rows / self.n_runs if self.n_runs else float("nan")

    def pareto_set(self) -> list[PolicyOutcome]:
        return [o for o in self.outcomes if o.pareto]

    def best_within_penalty(self, max_penalty_s: float) -> PolicyOutcome | None:
        """Highest-saving config whose modeled penalty fits the budget."""
        ok = [o for o in self.outcomes if o.penalty_s <= max_penalty_s]
        return max(ok, key=lambda o: o.energy_saved_j) if ok else None


def pareto_flags(saved: Sequence[float], penalty: Sequence[float]) -> list[bool]:
    """Non-dominated points for (maximize saved, minimize penalty)."""
    flags = []
    for i, (s_i, p_i) in enumerate(zip(saved, penalty)):
        dominated = any(
            (s_j >= s_i and p_j <= p_i) and (s_j > s_i or p_j < p_i)
            for j, (s_j, p_j) in enumerate(zip(saved, penalty)) if j != i)
        flags.append(not dominated)
    return flags


def assemble_frontier(outcomes: Sequence[PolicyOutcome],
                      n_rows: int = 0, n_runs: int = 0,
                      trace: Sequence[dict] = (),
                      coverage: float = 1.0) -> Frontier:
    """Build a :class:`Frontier` from already-evaluated outcomes, recomputing
    the Pareto flags over exactly this set (any flags carried in are
    discarded). The closed-loop search accumulates outcomes across
    refinement rounds and re-assembles after every round (passing its
    convergence ``trace``)."""
    flags = pareto_flags([o.energy_saved_j for o in outcomes],
                         [o.penalty_s for o in outcomes])
    flagged = tuple(dataclasses.replace(o, pareto=f)
                    for o, f in zip(outcomes, flags))
    n_jobs = max((o.n_jobs for o in flagged), default=0)
    return Frontier(outcomes=flagged, n_rows=n_rows, n_jobs=n_jobs,
                    n_runs=n_runs, trace=tuple(trace), coverage=coverage)


def _outcome(result: ReplayResult) -> PolicyOutcome:
    saved_cdf = tuple(sorted(float(j.saved_fraction) for j in result.jobs))
    penalty_cdf = tuple(sorted(float(j.penalty_s) for j in result.jobs))
    return PolicyOutcome(
        name=result.policy_name,
        params=result.policy_params,
        n_jobs=len(result.jobs),
        baseline_energy_j=result.baseline.total_energy_j,
        counterfactual_energy_j=result.counterfactual.total_energy_j,
        energy_saved_j=result.energy_saved_j,
        saved_fraction=result.saved_fraction,
        penalty_s=result.penalty_s,
        penalty_fraction=result.penalty_fraction,
        wake_events=result.wake_events,
        downscale_events=result.downscale_events,
        throttled_time_s=result.throttled_time_s,
        exec_idle_energy_fraction_baseline=result.baseline.exec_idle_energy_fraction,
        exec_idle_energy_fraction_cf=result.counterfactual.exec_idle_energy_fraction,
        per_job_saved_fraction=saved_cdf,
        per_job_penalty_s=penalty_cdf,
    )


def _assemble(results: list[ReplayResult], n_rows: int,
              n_runs: int = 0) -> Frontier:
    return assemble_frontier([_outcome(r) for r in results], n_rows, n_runs)


# --------------------------------------------------------------------------- #
# Evaluation kernel and its fixed-grid caller
# --------------------------------------------------------------------------- #
def _replay_partition(
    root: str,
    shard_files: list[str],
    policies: Sequence[Policy],
    mmap: bool,
    replayer_kwargs: dict,
    strict: bool = True,
    verify: bool = False,
) -> tuple[list[PolicyReplayer], list[dict]]:
    """Stream one shard subset through every policy's replayer (worker body;
    must stay module-level picklable). The reference oracle path."""
    from repro.telemetry.storage import TelemetryStore
    store = TelemetryStore(root)
    replayers = [PolicyReplayer(p, **replayer_kwargs) for p in policies]
    skips: list[dict] = []
    for name in shard_files:
        frame = store.read_shard_or_skip(name, skips, mmap=mmap,
                                         strict=strict, verify=verify)
        if frame is not None:
            replay_chunk(replayers, frame)
    return replayers, skips


def _replay_partition_batched(
    root: str,
    shard_files: list[str],
    policies: Sequence[Policy],
    mmap: bool,
    replayer_kwargs: dict,
    strict: bool = True,
    verify: bool = False,
) -> tuple[BatchedPolicyReplayer, list[dict]]:
    """Stream one shard subset through the config-axis batched replayer
    (worker body; must stay module-level picklable)."""
    from repro.telemetry.storage import TelemetryStore
    store = TelemetryStore(root)
    replayer = BatchedPolicyReplayer(policies, **replayer_kwargs)
    skips: list[dict] = []
    for name in shard_files:
        frame = store.read_shard_or_skip(name, skips, mmap=mmap,
                                         strict=strict, verify=verify)
        if frame is not None:
            replayer.update(frame)
    return replayer, skips


def _ir_skips(ir_obj, hosts: Iterable[str] | None) -> list[dict]:
    """The IR's recorded shard skips, filtered to the replayed host set."""
    if not ir_obj.skipped:
        return []
    host_set = set(hosts) if hosts is not None else None
    return [dict(s) for s in ir_obj.skipped
            if host_set is None or s.get("host") in host_set]


def _merge_skips(*skip_lists: Sequence[dict]) -> list[dict]:
    """Concatenate skip-record lists, deduplicating by shard file (the IR
    and a row-fallback recursion may both report the same bad shard)."""
    seen: set = set()
    out: list[dict] = []
    for lst in skip_lists:
        for s in lst:
            key = s.get("file")
            if key in seen:
                continue
            seen.add(key)
            out.append(s)
    return out


def _coverage_of(store: "TelemetryStore", hosts: Iterable[str] | None,
                 skips: Sequence[dict]) -> float:
    """Rows replayed / rows on disk for the host selection (1.0 when no
    shards were skipped or the store is empty)."""
    if not skips:
        return 1.0
    expected = store.rows_on_disk(hosts)
    if expected <= 0:
        return 1.0
    return max(0.0, 1.0 - sum(float(s.get("rows", 0)) for s in skips)
               / expected)


def _evaluate(
    configs: Sequence[Policy],
    store: "TelemetryStore",
    workers: int = 1,
    hosts: Iterable[str] | None = None,
    mmap: bool = False,
    batched: bool = True,
    replayer_kwargs: dict | None = None,
    compact: bool | None = None,
    ir=None,
    strict: bool = True,
    verify: bool = False,
    fault=None,
) -> tuple[list[ReplayResult], int, int, list[dict]]:
    """Kernel body shared by :func:`evaluate` / :func:`run_sweep`: one
    :class:`ReplayResult` per config in input order, plus the replayed
    job-attributed row count, (when the compact path ran) the IR's run
    count, and the shard skip records of a ``strict=False`` replay.

    ``compact=None`` resolves to ``batched`` — the row-exact reference
    paths (``batched=False`` / ``compact=False``) stay byte-for-byte what
    they were. With the compact path on, configs the IR supports replay
    against the run axis (:func:`repro.whatif.replay.replay_ir`); the rest
    — custom policies, mismatched thresholds, unsupported composites —
    stream the store through the row path, and an irregularly-sampled
    store falls back entirely (a ``compact -> row`` fallback in the
    degradation ladder).
    """
    configs = list(configs)
    replayer_kwargs = replayer_kwargs or {}
    if compact is None:
        compact = batched

    if compact:
        from repro.whatif import ir as ir_mod
        from repro.whatif.replay import replay_ir

        classifier = replayer_kwargs.get("classifier", None)
        dt_s = replayer_kwargs.get("dt_s", 1.0)
        if ir is not None:
            ir_obj = ir
        else:
            from repro.core.states import DEFAULT_CLASSIFIER
            cfg = ir_mod.ir_config_for(
                configs, classifier or DEFAULT_CLASSIFIER, dt_s)
            ir_obj = None
            if any(ir_mod.ir_supported(p, cfg) for p in configs):
                try:
                    ir_obj = ir_mod.get_ir(store, cfg, workers=workers,
                                           mmap=mmap, strict=strict,
                                           verify=verify, fault=fault)
                except ir_mod.IRUnsupportedError:
                    ir_obj = None       # e.g. irregular sampling: use rows
                    obs.fallback("compact", "row", "ir_unsupported")
        if ir_obj is not None:
            sup = [i for i, p in enumerate(configs)
                   if ir_mod.ir_supported(p, ir_obj.config)]
            if sup:
                ir_kwargs = {k: v for k, v in replayer_kwargs.items()
                             if k in ("platform_of", "min_job_duration_s",
                                      "min_interval_s", "classifier", "dt_s")}
                obs.counter("repro_replay_configs_total", float(len(sup)),
                            path="compact",
                            help="policy configs replayed, by execution path")
                sup_results = replay_ir(
                    ir_obj, [configs[i] for i in sup], hosts=hosts,
                    workers=workers, fault=fault, **ir_kwargs)
                skips = _ir_skips(ir_obj, hosts)
                results: list[ReplayResult | None] = [None] * len(configs)
                for i, res in zip(sup, sup_results):
                    results[i] = res
                rest = [i for i in range(len(configs)) if results[i] is None]
                if rest:
                    obs.counter("repro_replay_row_fallback_configs_total",
                                float(len(rest)),
                                help="configs the IR could not cover "
                                     "(row-path fallback)")
                    rest_results, _, _, rest_skips = _evaluate(
                        [configs[i] for i in rest], store, workers=workers,
                        hosts=hosts, mmap=mmap, batched=batched,
                        replayer_kwargs=replayer_kwargs, compact=False,
                        strict=strict, verify=verify, fault=fault)
                    for i, res in zip(rest, rest_results):
                        results[i] = res
                    skips = _merge_skips(skips, rest_skips)
                selected = ir_obj.select(hosts)
                n_rows = sum(s.n_rows for s in selected)
                n_runs = sum(s.n_runs for s in selected)
                return results, n_rows, n_runs, skips

    if batched:
        obs.counter("repro_replay_configs_total", float(len(configs)),
                    path="row_batched",
                    help="policy configs replayed, by execution path")
        replayer, skips = map_shard_partitions(
            store, hosts, workers, _replay_partition_batched,
            (configs, mmap, replayer_kwargs, strict, verify),
            merge=lambda a, b: a.merge(b), stage="sweep", fault=fault)
        n_rows = replayer.n_rows          # finalize() resets the counter
        return replayer.finalize(), n_rows, 0, skips

    def merge_lists(a: list[PolicyReplayer], b: list[PolicyReplayer]):
        for dst, src in zip(a, b):
            dst.merge(src)
        return a

    obs.counter("repro_replay_configs_total", float(len(configs)),
                path="row_serial",
                help="policy configs replayed, by execution path")
    replayers, skips = map_shard_partitions(
        store, hosts, workers, _replay_partition,
        (configs, mmap, replayer_kwargs, strict, verify),
        merge=merge_lists, stage="sweep", fault=fault)
    n_rows = replayers[0].n_rows if replayers else 0
    return [r.finalize() for r in replayers], n_rows, 0, skips


def resolve_backend(backend: str) -> str:
    """Resolve an ``evaluate``/``run_sweep`` ``backend`` argument.

    ``"numpy"`` (the default and the bit-exactness oracle), ``"jax"`` (the
    :mod:`repro.whatif.backend` accelerator path), or ``"auto"`` — jax when
    importable, numpy otherwise, so scripts stay portable to machines
    without the jax toolchain.
    """
    if backend == "auto":
        try:
            import repro.whatif.backend  # noqa: F401  (probe only)
        except Exception:
            return "numpy"
        return "jax"
    if backend not in ("numpy", "jax"):
        raise ValueError(
            f"unknown backend {backend!r}; use 'numpy', 'jax' or 'auto'")
    return backend


def _evaluate_outcomes(
    configs: Sequence[Policy],
    store: "TelemetryStore",
    workers: int = 1,
    hosts: Iterable[str] | None = None,
    mmap: bool = False,
    batched: bool = True,
    replayer_kwargs: dict | None = None,
    compact: bool | None = None,
    ir=None,
    backend: str = "numpy",
    dist=None,
    strict: bool = True,
    verify: bool = False,
    fault=None,
) -> tuple[list[PolicyOutcome], int, int, list[dict]]:
    """Observability wrapper around :func:`_evaluate_outcomes_impl`: every
    evaluate call runs under a ``whatif.evaluate`` span, with per-family
    config counts and a throughput gauge recorded when :mod:`repro.obs` is
    enabled. Pure pass-through otherwise — outcomes are bit-identical with
    obs on or off."""
    configs = list(configs)
    t0 = time.perf_counter()
    with obs.span("whatif.evaluate", configs=len(configs), backend=backend):
        out = _evaluate_outcomes_impl(
            configs, store, workers=workers, hosts=hosts, mmap=mmap,
            batched=batched, replayer_kwargs=replayer_kwargs,
            compact=compact, ir=ir, backend=backend, dist=dist,
            strict=strict, verify=verify, fault=fault)
    if obs.enabled():
        dt = max(time.perf_counter() - t0, 1e-12)
        obs.observe("repro_replay_seconds", dt,
                    help="wall time of evaluate calls")
        obs.gauge("repro_replay_configs_per_s", len(configs) / dt,
                  help="config throughput of the last evaluate")
        for fam, n in collections.Counter(p.name for p in configs).items():
            obs.counter("repro_replay_family_configs_total", float(n),
                        family=fam,
                        help="policy configs replayed, by policy family")
    return out


def _evaluate_outcomes_impl(
    configs: Sequence[Policy],
    store: "TelemetryStore",
    workers: int = 1,
    hosts: Iterable[str] | None = None,
    mmap: bool = False,
    batched: bool = True,
    replayer_kwargs: dict | None = None,
    compact: bool | None = None,
    ir=None,
    backend: str = "numpy",
    dist=None,
    strict: bool = True,
    verify: bool = False,
    fault=None,
) -> tuple[list[PolicyOutcome], int, int, list[dict]]:
    """:func:`_evaluate` lifted to outcomes, with backend dispatch.

    ``backend="jax"`` routes every IR-capable config through
    :func:`repro.whatif.backend.replay_ir_outcomes` — the jit'd
    ``(n_configs, n_runs)`` evaluators, config axis optionally sharded
    over ``dist`` (a :class:`repro.distributed.context.DistContext` from
    :func:`repro.whatif.backend.config_mesh`) — and the rest through the
    NumPy row path; stores without a usable IR fall back to NumPy
    entirely. The NumPy path remains the oracle: time/count metrics are
    bit-identical across backends, energies/penalties <= 1e-9 relative
    (tests/test_whatif_backend.py).

    Degradation ladder: a jax-backend failure (missing toolchain at call
    time, device loss, a kernel error) is not fatal — it is counted as a
    ``jax -> numpy`` fallback and the same configs replay through the
    NumPy compact kernel, which itself degrades ``compact -> row`` on an
    IR-unsupported store. The NumPy oracle contract makes every rung
    result-equivalent, so degradations change latency, never answers.
    """
    configs = list(configs)
    replayer_kwargs = replayer_kwargs or {}
    backend = resolve_backend(backend)
    if backend == "jax" and (compact is None or compact):
        from repro.whatif import ir as ir_mod

        classifier = replayer_kwargs.get("classifier", None)
        dt_s = replayer_kwargs.get("dt_s", 1.0)
        if ir is not None:
            ir_obj = ir
        else:
            from repro.core.states import DEFAULT_CLASSIFIER
            cfg = ir_mod.ir_config_for(
                configs, classifier or DEFAULT_CLASSIFIER, dt_s)
            ir_obj = None
            if any(ir_mod.ir_supported(p, cfg) for p in configs):
                try:
                    ir_obj = ir_mod.get_ir(store, cfg, workers=workers,
                                           mmap=mmap, strict=strict,
                                           verify=verify, fault=fault)
                except ir_mod.IRUnsupportedError:
                    ir_obj = None       # e.g. irregular sampling: use rows
                    obs.fallback("compact", "row", "ir_unsupported")
        if ir_obj is not None:
            sup = [i for i, p in enumerate(configs)
                   if ir_mod.ir_supported(p, ir_obj.config)]
            if sup:
                ir_kwargs = {k: v for k, v in replayer_kwargs.items()
                             if k in ("platform_of", "min_job_duration_s",
                                      "min_interval_s", "classifier", "dt_s")}
                try:
                    from repro.whatif import backend as jax_backend
                    sup_out, n_rows, n_runs = jax_backend.replay_ir_outcomes(
                        ir_obj, [configs[i] for i in sup], hosts=hosts,
                        dist=dist, **ir_kwargs)
                except Exception as e:
                    obs.fallback("jax", "numpy", type(e).__name__)
                    sup_out = None
                if sup_out is not None:
                    obs.counter("repro_replay_configs_total",
                                float(len(sup)), path="jax",
                                help="policy configs replayed, by execution "
                                     "path")
                    skips = _ir_skips(ir_obj, hosts)
                    outcomes: list[PolicyOutcome | None] = \
                        [None] * len(configs)
                    for i, out in zip(sup, sup_out):
                        outcomes[i] = out
                    rest = [i for i in range(len(configs))
                            if outcomes[i] is None]
                    if rest:
                        obs.counter(
                            "repro_replay_row_fallback_configs_total",
                            float(len(rest)),
                            help="configs the IR could not cover "
                                 "(row-path fallback)")
                        rest_results, _, _, rest_skips = _evaluate(
                            [configs[i] for i in rest], store,
                            workers=workers, hosts=hosts, mmap=mmap,
                            batched=batched,
                            replayer_kwargs=replayer_kwargs, compact=False,
                            strict=strict, verify=verify, fault=fault)
                        for i, res in zip(rest, rest_results):
                            outcomes[i] = _outcome(res)
                        skips = _merge_skips(skips, rest_skips)
                    return outcomes, n_rows, n_runs, skips
        # nothing for the accelerator to do (or it failed): NumPy kernel
    results, n_rows, n_runs, skips = _evaluate(
        configs, store, workers=workers, hosts=hosts, mmap=mmap,
        batched=batched, replayer_kwargs=replayer_kwargs, compact=compact,
        ir=ir, strict=strict, verify=verify, fault=fault)
    return [_outcome(r) for r in results], n_rows, n_runs, skips


def evaluate(
    configs: Sequence[Policy],
    store: "TelemetryStore",
    workers: int = 1,
    hosts: Iterable[str] | None = None,
    mmap: bool = False,
    batched: bool = True,
    compact: bool | None = None,
    ir=None,
    backend: str = "numpy",
    dist=None,
    strict: bool = True,
    verify: bool = False,
    fault=None,
    **replayer_kwargs,
) -> list[PolicyOutcome]:
    """Evaluate an arbitrary set of policy configs over a store.

    The reusable kernel under both the fixed-grid :func:`run_sweep` and the
    closed-loop :func:`repro.whatif.search.search_frontier`: replays
    ``configs`` (grouped into family batches, one pass per stream segment)
    and returns one :class:`PolicyOutcome` per config, **in input order**,
    with no Pareto flags — Pareto-ness is a property of a *set* of outcomes;
    flag a set with :func:`assemble_frontier`.

    Args:
        configs: policy configs to evaluate (any mix of families).
        store: shard store to replay (simulator output or DES/serving traces).
        workers: process-pool width. Partitions are host-label-disjoint, so
            results are bit-identical for every worker count. Scripts calling
            this with ``workers > 1`` at top level need the standard
            ``if __name__ == "__main__":`` guard (workers re-import main).
        hosts: optional host-label filter.
        mmap: pass ``mmap=True`` to shard reads (zero-copy for ``npy_dir``
            shards; see :meth:`TelemetryStore.iter_shards`).
        batched: evaluate the configs family-by-family along a config axis
            (:class:`BatchedPolicyReplayer`) — one classification / RLE /
            baseline integration per stream segment for the whole set.
            ``batched=False`` runs the per-policy reference path; both are
            bit-identical (tests/test_whatif_batched.py), the batched one is
            the fast default.
        compact: replay against the run-level IR (:mod:`repro.whatif.ir`)
            where the configs support it — the "compact once, replay many"
            fast path, O(runs) per config after a one-off O(rows) build
            that is cached in memory and as a store sidecar. ``None``
            (default) follows ``batched``; time/count metrics match the row
            paths bit-for-bit, energies/penalties to <= 1e-9 relative
            (tests/test_whatif_ir.py). Unsupported configs and
            irregularly-sampled stores fall back to the row path.
        ir: a prebuilt :class:`repro.whatif.ir.RunIR` to replay against
            (skips the cache lookup entirely; the closed-loop search passes
            one IR across all refinement rounds, and
            :func:`repro.telemetry.pipeline.analyze_store` accepts the same
            handle — one compaction serves the whole run-algebra consumer
            family: analyze / sweep / search).
        backend: ``"numpy"`` (default, the oracle), ``"jax"`` (jit'd
            run-level evaluators, :mod:`repro.whatif.backend`) or
            ``"auto"`` (jax when importable). The jax backend accelerates
            IR-capable configs on compact replays; everything else runs
            the NumPy path regardless.
        dist: optional :class:`repro.distributed.context.DistContext`
            sharding the jax backend's config axis over a device mesh
            (see :func:`repro.whatif.backend.config_mesh`); ignored by
            the NumPy backend. Results are mesh-shape-independent.
        strict: ``False`` skips unreadable shards instead of raising —
            results are bit-identical to replaying the clean shard subset
            (README "Robustness & dirty telemetry").
        verify: checksum every shard read against the manifest.
        fault: a :class:`repro.telemetry.pipeline.FaultTolerance` policy
            for the process-pool crash/hang supervisor.
        **replayer_kwargs: forwarded to the replayer
            (``min_job_duration_s``, ``platform_of``, ``classifier``, ...).
    """
    outcomes, _, _, _ = _evaluate_outcomes(
        configs, store, workers=workers, hosts=hosts, mmap=mmap,
        batched=batched, replayer_kwargs=replayer_kwargs, compact=compact,
        ir=ir, backend=backend, dist=dist, strict=strict, verify=verify,
        fault=fault)
    return outcomes


def run_sweep(
    store: "TelemetryStore",
    policies: Sequence[Policy] | None = None,
    workers: int = 1,
    hosts: Iterable[str] | None = None,
    mmap: bool = False,
    batched: bool = True,
    compact: bool | None = None,
    ir=None,
    backend: str = "numpy",
    dist=None,
    strict: bool = True,
    verify: bool = False,
    fault=None,
    **replayer_kwargs,
) -> Frontier:
    """Replay a fixed policy grid over a store and report the trade-off
    frontier — the fixed-grid caller of the :func:`evaluate` kernel.

    ``policies`` defaults to :func:`default_policy_grid` (200 configs). For
    a *budgeted* search of the same knob space instead of a dense dump, see
    :func:`repro.whatif.search.search_frontier`. All other arguments are
    :func:`evaluate`'s; ``run_sweep(compact=False)`` is the retained
    row-exact verification path for the default compact (run-IR) sweep,
    and ``backend="jax"`` runs IR-capable configs on the jit'd run-level
    evaluators (:mod:`repro.whatif.backend`). With ``strict=False`` the
    returned frontier's ``coverage`` reports the fraction of on-disk rows
    actually replayed (< 1.0 when shards were skipped).
    """
    hosts = list(hosts) if hosts is not None else None
    policies = list(default_policy_grid() if policies is None else policies)
    outcomes, n_rows, n_runs, skips = _evaluate_outcomes(
        policies, store, workers=workers, hosts=hosts, mmap=mmap,
        batched=batched, replayer_kwargs=replayer_kwargs, compact=compact,
        ir=ir, backend=backend, dist=dist, strict=strict, verify=verify,
        fault=fault)
    coverage = _coverage_of(store, hosts, skips)
    obs.gauge("repro_coverage_fraction", coverage, stage="sweep",
              help="rows analyzed / rows on disk for the last run")
    return assemble_frontier(outcomes, n_rows, n_runs, coverage=coverage)


def sweep_frame(frame, policies: Sequence[Policy] | None = None,
                batched: bool = True, **replayer_kwargs) -> Frontier:
    """In-memory convenience: sweep a single :class:`TelemetryFrame`
    (e.g. a DES :class:`PoolResult` telemetry) without a store."""
    policies = list(default_policy_grid() if policies is None else policies)
    if batched:
        replayer = BatchedPolicyReplayer(policies, **replayer_kwargs)
        replayer.update(frame)
        n_rows = replayer.n_rows          # finalize() resets the counter
        return _assemble(replayer.finalize(), n_rows)
    replayers = [PolicyReplayer(p, **replayer_kwargs) for p in policies]
    replay_chunk(replayers, frame)
    n_rows = replayers[0].n_rows if replayers else 0
    return _assemble([r.finalize() for r in replayers], n_rows)

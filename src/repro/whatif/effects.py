"""Shared effect algebra for counterfactual policy replay.

A policy's counterfactual for one time-ordered segment is an **effect**: a
power transform (the counterfactual board-power series, plus an optional
residency override) composed with a **time dilation** (seconds of modeled
lost progress, carried as sample-proportional partial sums plus integer
event counts priced at finalize). Effects form a monoid under
:func:`compose`:

* ``compose(a, b)`` is *b applied downstream of a* — ``b`` was computed on
  the segment view produced by ``a``, so the composed power series is
  ``b``'s, residency is the last override, throttled masks union, and the
  dilation terms add;
* :func:`identity_effect` (the recorded segment, no dilation) is a two-sided
  identity: ``compose(identity, e)`` and ``compose(e, identity_of(e))`` are
  bit-identical to ``e`` (``0.0 + x == x`` and ``0 | m == m`` exactly);
* composition is associative: power/residency take the last value, masks
  union, and the dilation sums are left-folded the same way by either
  bracketing (integer event counts are exactly associative; float partial
  sums are folded in a fixed left-to-right order by every caller).

:class:`SegmentEffect` is the scalar form (one policy config per segment),
:class:`BatchEffect` the config-axis form (one policy *family* per segment,
row-compressed). Both were previously private to ``whatif.policies``; they
live here so :class:`~repro.whatif.policies.CompositePolicy` and the
replayers share one definition.

Event pricing
-------------
Event-priced dilations (downscale restores, parking wakes) stay integer
counts until finalize so totals are chunking-invariant. A policy prices its
events through **channels**: a leaf policy has one channel priced at
``event_penalty_s``; a composite concatenates its parts' channels, so a
"park the rest + downscale the active" composite prices parking wakes at
the resume latency and downscale restores at the clock-switch cost — in one
replay. :func:`policy_event_prices` / :func:`policy_event_channels` adapt
any :class:`~repro.whatif.policies.Policy` (leaf policies need no changes),
and :func:`price_events` turns (prices, counts) into seconds with a fixed
left-fold so scalar and batched finalization perform identical float ops.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any

import numpy as np

if TYPE_CHECKING:
    from repro.telemetry.records import TelemetryFrame


@dataclasses.dataclass
class SegmentEffect:
    """One policy's counterfactual for one time-ordered segment."""

    #: counterfactual board power per sample (W)
    power_w: np.ndarray
    #: counterfactual residency, or None when unchanged from the recording
    resident: np.ndarray | None
    #: samples the policy affected (downscaled / parked / capped)
    throttled: np.ndarray
    #: penalty partial-sum for sample-proportional penalty models; partials
    #: are fsum'd at finalize so totals are chunking-invariant
    penalty_partial_s: float = 0.0
    #: events priced at finalize via ``Policy.event_penalty_s`` (restores,
    #: wake-ups); integer counts keep the pricing chunking-invariant
    wake_events: int = 0
    downscale_events: int = 0
    #: per-channel event counts for multi-channel pricing (composites), or
    #: None for the single-channel leaf form ``[wake_events]``
    events: np.ndarray | None = None

    def event_vector(self, n_channels: int = 1) -> np.ndarray:
        """Counts in channel space: ``events`` when present, else the leaf
        form (wake events in channel 0 of ``n_channels``)."""
        if self.events is not None:
            return self.events
        v = np.zeros(n_channels, dtype=np.int64)
        if n_channels:
            v[0] = self.wake_events
        return v


@dataclasses.dataclass
class BatchEffect:
    """One family batch's counterfactual for one segment, row-compressed.

    ``row_of[c]`` maps member config ``c`` to a row of ``power_rows`` /
    ``throttled_rows`` (and ``resident_rows`` when present); ``-1`` means the
    config leaves this stream untouched (counterfactual == recorded series,
    so the replayer aliases it to the shared baseline integration). Distinct
    configs may share a row — every parking config that parks a device
    produces the *same* counterfactual series — so integration cost scales
    with distinct rows, not grid size.
    """

    #: counterfactual board power rows (W), [R, n]
    power_rows: np.ndarray
    #: samples each row's policy affected, [R, n]
    throttled_rows: np.ndarray
    #: config -> row index, or -1 for identity (cf == recorded), [C]
    row_of: np.ndarray
    #: counterfactual residency rows, or None when unchanged for every row
    resident_rows: np.ndarray | None
    #: per-config penalty partial-sums (fsum'd at finalize), [C]
    penalty_partial_s: np.ndarray
    #: per-config event counts priced at finalize, [C]
    wake_events: np.ndarray
    downscale_events: np.ndarray
    #: per-config per-channel event counts ([C, K]) for multi-channel
    #: pricing (composites), or None for the single-channel leaf form
    events_rows: np.ndarray | None = None


def identity_effect(seg: "TelemetryFrame",
                    n_channels: int = 1) -> SegmentEffect:
    """The recorded segment unchanged — the monoid identity of
    :func:`compose` (zero dilation, no throttling, no events)."""
    n = len(seg)
    return SegmentEffect(
        power_w=np.asarray(seg["power"], dtype=np.float64),
        resident=None,
        throttled=np.zeros(n, dtype=bool),
        events=np.zeros(n_channels, dtype=np.int64),
    )


def compose(first: SegmentEffect, second: SegmentEffect) -> SegmentEffect:
    """``second`` applied downstream of ``first`` (on ``first``'s output).

    Power takes the downstream series, residency the last override,
    throttled masks union, and every dilation term adds. Both effects must
    live in the same event-channel space (lift leaf effects with
    :meth:`SegmentEffect.event_vector` / an offset first — see
    :meth:`CompositePolicy.apply <repro.whatif.policies.CompositePolicy>`).
    """
    if (first.events is None) != (second.events is None):
        raise ValueError("compose() requires both effects in the same "
                         "event-channel space; lift the leaf effect first")
    if first.events is not None and first.events.shape != second.events.shape:
        raise ValueError(
            f"compose() channel mismatch: {first.events.shape} vs "
            f"{second.events.shape}")
    return SegmentEffect(
        power_w=second.power_w,
        resident=(second.resident if second.resident is not None
                  else first.resident),
        throttled=first.throttled | second.throttled,
        penalty_partial_s=first.penalty_partial_s + second.penalty_partial_s,
        wake_events=first.wake_events + second.wake_events,
        downscale_events=first.downscale_events + second.downscale_events,
        events=(None if first.events is None
                else first.events + second.events),
    )


def effect_view(seg: "TelemetryFrame", effect: SegmentEffect):
    """The segment as the next policy in a composition sees it: power (and
    residency, when overridden) replaced by the effect's counterfactual,
    every signal column shared with the recording.

    The low-activity memo (``seg._low_cache``) is shared between base and
    view: the predicate reads only signal columns, which the view aliases,
    so downstream parts reuse (and extend) the same per-segment cache.
    """
    from repro.telemetry.records import TelemetryFrame

    cols = dict(seg.columns)
    cols["power"] = np.asarray(effect.power_w, dtype=np.float64)
    if effect.resident is not None:
        cols["program_resident"] = np.asarray(effect.resident)
    view = TelemetryFrame(cols)
    cache = getattr(seg, "_low_cache", None)
    if cache is None:
        cache = seg._low_cache = {}
    view._low_cache = cache
    return view


# --------------------------------------------------------------------------- #
# Event pricing (finalize-time, chunking-invariant)
# --------------------------------------------------------------------------- #
def policy_event_channels(policy: Any) -> int:
    """Number of event-pricing channels: ``policy.n_event_channels`` when the
    policy defines it (composites), else 1 (every leaf policy)."""
    return int(getattr(policy, "n_event_channels", 1))


def policy_event_prices(policy: Any, plat: Any) -> np.ndarray:
    """Per-channel event prices (seconds/event): ``policy.event_prices_s``
    when defined (composites), else the leaf adapter
    ``[policy.event_penalty_s(plat)]``."""
    fn = getattr(policy, "event_prices_s", None)
    if fn is not None:
        return np.asarray(fn(plat), dtype=np.float64)
    return np.array([policy.event_penalty_s(plat)], dtype=np.float64)


def price_events(prices: np.ndarray, counts: np.ndarray) -> float:
    """Seconds of event-priced dilation: ``sum_k counts[k] * prices[k]`` as a
    fixed left-fold, so the scalar and batched finalize paths perform the
    identical float operations (and a single channel reduces to the legacy
    ``wakes * price`` bit-exactly: ``0.0 + x == x``)."""
    if len(prices) != len(counts):
        raise ValueError(
            f"event pricing mismatch: {len(counts)} count channels vs "
            f"{len(prices)} price channels")
    total = 0.0
    for c, p in zip(counts, prices):
        total += float(c) * float(p)
    return total

"""Frontier serialization and human-readable reporting.

JSON schema is flat and stable: one object with sweep metadata plus a list
of per-config outcomes (params, energy saved, modeled penalty, Pareto flag,
per-job CDFs), so downstream dashboards can diff sweeps across fleet
snapshots.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from repro.core.energy import energy_kwh
from repro.whatif.sweep import Frontier, PolicyOutcome

SCHEMA_VERSION = 1


def frontier_to_dict(frontier: Frontier) -> dict:
    return {
        "schema_version": SCHEMA_VERSION,
        "n_rows": frontier.n_rows,
        "n_jobs": frontier.n_jobs,
        "n_runs": frontier.n_runs,
        "coverage": frontier.coverage,
        "trace": [dict(t) for t in frontier.trace],
        "outcomes": [dataclasses.asdict(o) for o in frontier.outcomes],
    }


def frontier_from_dict(payload: dict) -> Frontier:
    outcomes = []
    for o in payload["outcomes"]:
        o = dict(o)
        o["per_job_saved_fraction"] = tuple(o["per_job_saved_fraction"])
        o["per_job_penalty_s"] = tuple(o["per_job_penalty_s"])
        outcomes.append(PolicyOutcome(**o))
    return Frontier(outcomes=tuple(outcomes),
                    n_rows=payload["n_rows"], n_jobs=payload["n_jobs"],
                    n_runs=payload.get("n_runs", 0),
                    coverage=payload.get("coverage", 1.0),
                    trace=tuple(dict(t) for t in payload.get("trace", ())))


def save_frontier(frontier: Frontier, path: str | pathlib.Path,
                  compact: bool = True) -> pathlib.Path:
    """Write the frontier JSON. ``compact=True`` (default) uses minimal
    separators and no indentation — a dense-grid frontier is ~10k lines
    pretty-printed, one line compact, at identical fidelity (the loader
    accepts both) — pass ``compact=False`` for a human-diffable dump."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = frontier_to_dict(frontier)
    if compact:
        text = json.dumps(payload, separators=(",", ":"))
    else:
        text = json.dumps(payload, indent=1)
    path.write_text(text + "\n")
    return path


def load_frontier(path: str | pathlib.Path) -> Frontier:
    return frontier_from_dict(json.loads(pathlib.Path(path).read_text()))


def format_search_trace(frontier: Frontier) -> str:
    """Render the search convergence trace (one line per round).

    The trace is recorded unconditionally by
    :func:`repro.whatif.search.search_frontier` — it contains only
    deterministic replay results, no wall-clock — so this works on any
    searched frontier, observability on or off. Swept (non-searched)
    frontiers have an empty trace.
    """
    if not frontier.trace:
        return "search trace: empty (frontier was swept, not searched)"
    rounds: dict[int, list[dict]] = {}
    for t in frontier.trace:
        rounds.setdefault(int(t["round"]), []).append(t)
    lines = [f"search trace: {len(frontier.trace)} evals over "
             f"{len(rounds)} rounds",
             f"{'round':>5} {'evals':>6} {'cum':>5} {'best saved %':>12} "
             f"{'families':<32}"]
    best = 0.0
    cum = 0
    for r in sorted(rounds):
        evs = rounds[r]
        cum += len(evs)
        best = max(best, max(t["saved_fraction"] for t in evs))
        fams = sorted({t["family"] for t in evs})
        lines.append(f"{r:5d} {len(evs):6d} {cum:5d} {best:12.2%} "
                     f"{', '.join(fams):<32}")
    return "\n".join(lines)


def _label_params(name: str, p: dict) -> str:
    if p.get("policy") == "composite":
        return " + ".join(_label_params(q.get("policy", "?"), q)
                          for q in p["parts"])
    if name == "downscale":
        return (f"downscale X={p['threshold_x_s']:g} Y={p['cooldown_y_s']:g} "
                f"{p['mode']}")
    if name == "parking":
        return (f"parking {p['n_active']}-of-{p['n_devices']} "
                f"resume={p['resume_latency_s']:g}s")
    if name == "powercap":
        return f"powercap {p['cap_fraction']:.0%} TDP"
    return name


def _label(outcome: PolicyOutcome) -> str:
    return _label_params(outcome.name, outcome.params)


def format_frontier(frontier: Frontier, top: int | None = None) -> str:
    """Text table of the sweep, best energy saving first; ``*`` marks the
    Pareto set."""
    rows = sorted(frontier.outcomes, key=lambda o: -o.energy_saved_j)
    if top is not None:
        rows = rows[:top]
    compaction = ""
    if frontier.n_runs:
        # rows/runs: how run-compressible (idle-dominated) the corpus is —
        # the leverage behind the run-IR replay (paper: execution-idle
        # stretches are long and near-constant)
        compaction = (f" ({frontier.n_runs:,} runs, compaction "
                      f"{frontier.compaction_ratio:.1f}x)")
    lines = [
        f"what-if frontier: {len(frontier.outcomes)} configs, "
        f"{frontier.n_jobs} jobs, {frontier.n_rows:,} samples{compaction}",
        f"{'':2}{'policy':44} {'saved kWh':>10} {'saved %':>8} "
        f"{'penalty s':>10} {'wakes':>7}",
    ]
    for o in rows:
        mark = "* " if o.pareto else "  "
        lines.append(
            f"{mark}{_label(o):44} {energy_kwh(o.energy_saved_j):10.2f} "
            f"{o.saved_fraction:8.1%} {o.penalty_s:10.1f} {o.wake_events:7d}")
    return "\n".join(lines)

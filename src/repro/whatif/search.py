"""Closed-loop Pareto search: budgeted knob optimization over policy families.

A dense grid sweep (:func:`repro.whatif.sweep.run_sweep`) answers "what does
the whole mitigation space look like" with an O(grid) dump. An operator asks
a narrower question: *the best knob setting under a performance-penalty
budget* — and wants it without paying for 200 grid points.
:func:`search_frontier` answers it closed-loop: evaluate a coarse per-family
grid once (one batched replay over the store), find the Pareto **knee**,
then successively refine each family's continuous knobs around its
knee-adjacent Pareto members — midpoint subdivision per axis, one batched
:func:`repro.whatif.sweep.evaluate` pass per round — terminating on a
config-evaluation budget, knee convergence, or axis resolution.

The budget currency is **config evaluations**: each refinement round costs
one streaming pass over the store, so the search pays O(rounds x rows) in
shared per-row work and wins where per-*config* cost dominates — composite
or custom families (no row sharing), knob spaces finer than the fixed
grid's 200 points, or when only the knee neighbourhood matters. On a corpus
where batched per-row work dominates, the dense sweep is the faster dump
(see ``BENCH_whatif_search.json``: ``dense_sweep_s`` vs ``search_s``).

The refinement mirrors the data-driven deadline-aware frequency-scaling
approach of Ilager et al. (budgeted knob search instead of exhaustive
sweep); the parking/cap axes follow the "Model Parking Tax" trade-off study.
Everything is deterministic — candidate generation is order-fixed and the
batched evaluator is bit-identical for any worker count — so a search is
reproducible across runs and process-pool widths.

Typical use::

    result = search_frontier(store, budget=PenaltyBudget(
        max_penalty_fraction=0.01))     # <= 1% of recorded active time
    print(result.best.params, result.knee.params)
    print(format_frontier(result.frontier, top=10))

Observability: the search runs under a ``whatif.search`` span with one
``search.round`` child per refinement round, and records per-round evals,
knee movement, budget consumption and warm-seed hits as ``repro_search_*``
metrics when :mod:`repro.obs` is enabled. Independently of obs, every
search emits a deterministic eval-by-eval convergence trace in
``result.frontier.trace`` (see :class:`repro.whatif.sweep.Frontier`).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import math
from typing import TYPE_CHECKING, Callable, Iterable, Sequence

import repro.obs as obs
from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.imbalance import PoolConfig, PoolPolicy
from repro.whatif.policies import (CompositePolicy, DownscalePolicy,
                                   NoOpPolicy, ParkingPolicy, Policy,
                                   PowerCapPolicy)
from repro.whatif.sweep import (Frontier, PolicyOutcome, assemble_frontier,
                                _evaluate_outcomes, pareto_flags)

if TYPE_CHECKING:
    from repro.telemetry.storage import TelemetryStore


# --------------------------------------------------------------------------- #
# Budget
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PenaltyBudget:
    """Feasibility constraint on the modeled performance penalty.

    ``max_penalty_s`` bounds the fleet-total modeled stall seconds;
    ``max_penalty_fraction`` bounds the stall relative to the recorded
    active time (``PolicyOutcome.penalty_fraction``). Give either or both;
    a config is feasible when it satisfies every given bound.
    """

    max_penalty_s: float | None = None
    max_penalty_fraction: float | None = None

    def __post_init__(self) -> None:
        for field in ("max_penalty_s", "max_penalty_fraction"):
            v = getattr(self, field)
            if v is not None and v < 0:
                raise ValueError(f"PenaltyBudget {field} must be >= 0, got {v}")

    def feasible(self, outcome: PolicyOutcome) -> bool:
        if (self.max_penalty_s is not None
                and outcome.penalty_s > self.max_penalty_s):
            return False
        if (self.max_penalty_fraction is not None
                and outcome.penalty_fraction > self.max_penalty_fraction):
            return False
        return True


# --------------------------------------------------------------------------- #
# Family knob spaces
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ContinuousAxis:
    """A refinable knob. ``coarse`` seeds round 0; refinement inserts
    midpoints (geometric when ``log``) between a Pareto anchor's value and
    its nearest tried neighbours, while the gap exceeds ``resolution``
    (axis units when linear, log-units when ``log``)."""

    name: str
    lo: float
    hi: float
    coarse: tuple[float, ...]
    log: bool = False
    resolution: float = 0.05

    def __post_init__(self) -> None:
        if not self.lo < self.hi:
            raise ValueError(f"axis {self.name}: lo must be < hi")
        if self.log and self.lo <= 0:
            raise ValueError(f"axis {self.name}: log axis requires lo > 0")
        for v in self.coarse:
            if not self.lo <= v <= self.hi:
                raise ValueError(
                    f"axis {self.name}: coarse level {v} outside "
                    f"[{self.lo}, {self.hi}]")

    def gap(self, a: float, b: float) -> float:
        return math.log(b / a) if self.log else b - a

    def midpoint(self, a: float, b: float) -> float:
        return math.sqrt(a * b) if self.log else 0.5 * (a + b)


@dataclasses.dataclass(frozen=True)
class CategoricalAxis:
    """A discrete knob: every option is tried in round 0, never refined."""

    name: str
    options: tuple

    def __post_init__(self) -> None:
        if not self.options:
            raise ValueError(f"axis {self.name}: options must be non-empty")


@dataclasses.dataclass(frozen=True)
class PolicyFamily:
    """One searchable family: a knob space plus a policy factory.

    ``build`` maps a point (``{axis name: value}``) to a
    :class:`~repro.whatif.policies.Policy`; the search only ever identifies
    configs by the built policy's ``describe()``, so factories are free to
    derive several constructor arguments from one axis.

    ``from_params`` is ``build``'s partial inverse: map a
    :class:`~repro.whatif.sweep.PolicyOutcome`'s ``params`` dict back to an
    axis point (or None when the params belong to another family) — it is
    what lets :func:`search_frontier` warm-start from a previously saved
    frontier (``init_frontier=``), seeding round 0 at last snapshot's knee.
    """

    name: str
    axes: tuple[ContinuousAxis | CategoricalAxis, ...]
    build: Callable[[dict], Policy]
    from_params: Callable[[dict], dict | None] | None = None

    def coarse_points(self) -> list[dict]:
        levels = [(ax.name, ax.coarse if isinstance(ax, ContinuousAxis)
                   else ax.options) for ax in self.axes]
        return [dict(zip([n for n, _ in levels], combo))
                for combo in itertools.product(*[v for _, v in levels])]

    def clip_point(self, pt: dict) -> dict | None:
        """Validate a seed point against the axes: categorical values must
        be known options (a retired pool shape cannot be refined), and
        continuous values clip into the axis range so refinement stays
        well-defined."""
        out = {}
        for ax in self.axes:
            if ax.name not in pt:
                return None
            v = pt[ax.name]
            if isinstance(ax, CategoricalAxis):
                if v not in ax.options:
                    return None
                out[ax.name] = v
            else:
                out[ax.name] = min(max(float(v), ax.lo), ax.hi)
        return out


def _build_downscale(pt: dict) -> Policy:
    return DownscalePolicy(config=ControllerConfig(
        threshold_x_s=pt["threshold_x_s"], cooldown_y_s=pt["cooldown_y_s"],
        mode=pt["mode"]))


def _build_parking(pt: dict) -> Policy:
    n_devices, n_active = pt["pool"]
    return ParkingPolicy(
        pool=PoolConfig(n_devices=n_devices, policy=PoolPolicy.CONSOLIDATED,
                        n_active=n_active),
        resume_latency_s=pt["resume_latency_s"])


def _build_powercap(pt: dict) -> Policy:
    return PowerCapPolicy(cap_fraction=pt["cap_fraction"])


def _build_park_downscale(pt: dict) -> Policy:
    n_devices, n_active = pt["pool"]
    return CompositePolicy((
        ParkingPolicy(
            pool=PoolConfig(n_devices=n_devices,
                            policy=PoolPolicy.CONSOLIDATED,
                            n_active=n_active),
            resume_latency_s=pt["resume_latency_s"]),
        DownscalePolicy(config=ControllerConfig(
            threshold_x_s=pt["threshold_x_s"])),
    ))


def _downscale_from_params(p: dict) -> dict | None:
    if p.get("policy") != "downscale":
        return None
    return {"threshold_x_s": p["threshold_x_s"],
            "cooldown_y_s": p["cooldown_y_s"],
            "mode": DownscaleMode(p["mode"])}


def _parking_from_params(p: dict) -> dict | None:
    if p.get("policy") != "parking":
        return None
    return {"pool": (p["n_devices"], p["n_active"]),
            "resume_latency_s": p["resume_latency_s"]}


def _powercap_from_params(p: dict) -> dict | None:
    if p.get("policy") != "powercap":
        return None
    return {"cap_fraction": p["cap_fraction"]}


def _park_downscale_from_params(p: dict) -> dict | None:
    if p.get("policy") != "composite" or len(p.get("parts", ())) != 2:
        return None
    park, down = p["parts"]
    if park.get("policy") != "parking" or down.get("policy") != "downscale":
        return None
    return {"pool": (park["n_devices"], park["n_active"]),
            "resume_latency_s": park["resume_latency_s"],
            "threshold_x_s": down["threshold_x_s"]}


def default_families(composites: bool = True) -> list[PolicyFamily]:
    """The searchable mirror of :func:`~repro.whatif.sweep
    .default_policy_grid`: same families, same knob ranges, but coarse seeds
    instead of dense levels — the refinement loop supplies the density, and
    only where the Pareto knee needs it.

    ``composites=True`` adds the operator's composite ("Model Parking Tax"
    meets Algorithm 1): park the pool's inactive devices, downscale the
    active rest — a point the fixed grid cannot express at all.
    """
    families = [
        PolicyFamily(
            name="downscale",
            axes=(
                ContinuousAxis("threshold_x_s", 0.5, 15.0,
                               coarse=(0.5, 3.0, 15.0), log=True),
                ContinuousAxis("cooldown_y_s", 1.0, 10.0,
                               coarse=(1.0, 10.0), log=True),
                CategoricalAxis("mode", (DownscaleMode.SM_ONLY,
                                         DownscaleMode.SM_AND_MEM)),
            ),
            build=_build_downscale, from_params=_downscale_from_params),
        PolicyFamily(
            name="parking",
            axes=(
                CategoricalAxis("pool", ((4, 1), (4, 2), (4, 3),
                                         (8, 2), (8, 4), (8, 6))),
                ContinuousAxis("resume_latency_s", 2.0, 60.0,
                               coarse=(2.0, 60.0), log=True),
            ),
            build=_build_parking, from_params=_parking_from_params),
        PolicyFamily(
            name="powercap",
            axes=(
                ContinuousAxis("cap_fraction", 0.25, 0.95,
                               coarse=(0.25, 0.6, 0.95), resolution=0.005),
            ),
            build=_build_powercap, from_params=_powercap_from_params),
    ]
    if composites:
        families.append(PolicyFamily(
            name="park+downscale",
            axes=(
                CategoricalAxis("pool", ((4, 1), (4, 2), (8, 4))),
                ContinuousAxis("resume_latency_s", 2.0, 60.0,
                               coarse=(10.0,), log=True),
                ContinuousAxis("threshold_x_s", 0.5, 15.0,
                               coarse=(1.0, 8.0), log=True),
            ),
            build=_build_park_downscale,
            from_params=_park_downscale_from_params))
    return families


# --------------------------------------------------------------------------- #
# Knee detection
# --------------------------------------------------------------------------- #
def _normalizer(outcomes: Sequence[PolicyOutcome]):
    s = [o.energy_saved_j for o in outcomes]
    p = [o.penalty_s for o in outcomes]
    s_lo, s_span = min(s), max(s) - min(s)
    p_lo, p_span = min(p), max(p) - min(p)

    def norm(o: PolicyOutcome) -> tuple[float, float]:
        return ((o.energy_saved_j - s_lo) / s_span if s_span else 0.0,
                (o.penalty_s - p_lo) / p_span if p_span else 0.0)
    return norm


def find_knee(outcomes: Sequence[PolicyOutcome]) -> PolicyOutcome:
    """The Pareto front's point of diminishing returns.

    Pareto-filter the outcomes, normalize saved energy and penalty to the
    front's extents, and take the member with the maximum perpendicular
    distance above the chord joining the front's endpoints (the classic
    elbow/kneedle construction). Degenerate fronts (fewer than three
    members, or a flat chord) fall back to the member maximizing
    ``saved_norm - penalty_norm``. Deterministic: ties keep the
    lowest-penalty member.
    """
    if not outcomes:
        raise ValueError("find_knee requires at least one outcome")
    flags = pareto_flags([o.energy_saved_j for o in outcomes],
                         [o.penalty_s for o in outcomes])
    front = [o for o, f in zip(outcomes, flags) if f]
    front.sort(key=lambda o: (o.penalty_s, -o.energy_saved_j))
    norm = _normalizer(front)
    if len(front) >= 3:
        (s0, p0), (s1, p1) = norm(front[0]), norm(front[-1])
        ds, dp = s1 - s0, p1 - p0
        chord = math.hypot(ds, dp)
        if chord > 0:
            best_i, best_d = 0, -math.inf
            for i, o in enumerate(front):
                s, p = norm(o)
                d = (dp * (s - s0) - ds * (p - p0)) / chord
                if d > best_d + 1e-12:
                    best_i, best_d = i, d
            return front[best_i]
    best_i, best_u = 0, -math.inf
    for i, o in enumerate(front):
        s, p = norm(o)
        if s - p > best_u + 1e-12:
            best_i, best_u = i, s - p
    return front[best_i]


def achievable_saving(outcomes: Iterable[PolicyOutcome],
                      max_penalty_s: float) -> float:
    """Best ``saved_fraction`` among outcomes with ``penalty_s`` within
    ``max_penalty_s`` — the scalar used to compare two frontiers at a common
    operating point (e.g. a search frontier vs a dense sweep, at the dense
    knee's penalty)."""
    ok = [o.saved_fraction for o in outcomes if o.penalty_s <= max_penalty_s]
    return max(ok, default=0.0)


# --------------------------------------------------------------------------- #
# Search driver
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class RoundRecord:
    """One refinement round's accounting."""

    n_new: int
    n_evals_total: int
    knee_saved_fraction: float
    knee_penalty_s: float
    knee_params: dict


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Outcome of a :func:`search_frontier` run."""

    #: every evaluated config (evaluation order), Pareto subset flagged
    frontier: Frontier
    #: the front's point of diminishing returns (:func:`find_knee`)
    knee: PolicyOutcome
    #: highest-saving config within the budget; the knee when no budget was
    #: given; None when no evaluated config is feasible
    best: PolicyOutcome | None
    n_evals: int
    n_rounds: int
    #: True when the loop stopped because the knee stopped moving or every
    #: axis reached resolution — False when it ran out of eval budget/rounds
    converged: bool
    history: tuple[RoundRecord, ...]


def _key(policy: Policy) -> str:
    return json.dumps(policy.describe(), sort_keys=True, default=str)


def _neighbor_mids(axis: ContinuousAxis, value: float,
                   tried: Sequence[float]) -> list[float]:
    """Midpoints between ``value`` and its nearest tried neighbours on each
    side, respecting the axis resolution."""
    mids = []
    below = [v for v in tried if v < value]
    above = [v for v in tried if v > value]
    if below:
        left = max(below)
        if axis.gap(left, value) > 2 * axis.resolution:
            mids.append(axis.midpoint(left, value))
    if above:
        right = min(above)
        if axis.gap(value, right) > 2 * axis.resolution:
            mids.append(axis.midpoint(value, right))
    return mids


def seed_points(families: Sequence[PolicyFamily], frontier: "Frontier | str",
                per_family: int = 3) -> dict[str, list[dict]]:
    """Warm-start seeds: map a previous frontier's Pareto members back into
    each family's knob space (via :attr:`PolicyFamily.from_params`),
    dropping members whose categorical knobs are no longer searchable and
    clipping continuous knobs into the current axis ranges. Members are
    taken knee-outward — the previous knee seeds first — capped at
    ``per_family`` so round 0 stays close to the coarse-grid size:
    week-over-week re-searches start *at* last snapshot's knee instead of
    re-discovering it through refinement rounds."""
    if not hasattr(frontier, "outcomes"):
        from repro.whatif.report import load_frontier
        frontier = load_frontier(frontier)
    members = frontier.pareto_set() or list(frontier.outcomes)
    if len(members) > 1:
        knee = find_knee(members)
        norm = _normalizer(members)
        ks, kp = norm(knee)

        def knee_dist(o: PolicyOutcome) -> float:
            s, p = norm(o)
            return math.hypot(s - ks, p - kp)
        members = sorted(members, key=knee_dist)
    seeds: dict[str, list[dict]] = {}
    for fam in families:
        if fam.from_params is None:
            continue
        pts: list[dict] = []
        for o in members:
            pt = fam.from_params(o.params)
            if pt is None:
                continue
            pt = fam.clip_point(pt)
            if pt is not None and pt not in pts:
                pts.append(pt)
            if len(pts) >= per_family:
                break
        if pts:
            seeds[fam.name] = pts
    return seeds


def search_frontier(
    store: "TelemetryStore",
    budget: PenaltyBudget | None = None,
    families: Sequence[PolicyFamily] | None = None,
    max_evals: int = 100,
    max_rounds: int = 8,
    knee_tol: float = 0.01,
    knee_patience: int = 2,
    anchors_per_family: int = 2,
    include_noop: bool = True,
    workers: int = 1,
    hosts: Iterable[str] | None = None,
    mmap: bool = False,
    batched: bool = True,
    compact: bool | None = None,
    ir=None,
    backend: str = "numpy",
    dist=None,
    init_frontier=None,
    strict: bool = True,
    verify: bool = False,
    fault=None,
    **replayer_kwargs,
) -> SearchResult:
    """Budgeted closed-loop knob search over a telemetry store.

    Round 0 evaluates every family's coarse grid in one batched replay
    (:func:`repro.whatif.sweep.evaluate` is the inner loop). Each later
    round (a) Pareto-filters everything evaluated so far and finds the knee
    (:func:`find_knee`), (b) picks per-family anchors — the family's Pareto
    members nearest the knee, plus its best budget-feasible member when a
    ``budget`` is given — and (c) proposes midpoint subdivisions of each
    continuous axis around every anchor. The loop stops when the
    config-evaluation budget ``max_evals`` is spent, the knee moves less
    than ``knee_tol`` (relative, both coordinates) for ``knee_patience``
    consecutive rounds, no axis can be subdivided above its resolution, or
    ``max_rounds`` is reached.

    With the compact path on (``compact=None`` follows ``batched``), the
    run-level IR is acquired **once** — memory cache, store sidecar, or one
    O(rows) build — and every refinement round replays against it, so
    rounds cost O(runs x new configs) instead of re-streaming and
    re-classifying the store (:mod:`repro.whatif.ir`). Pass ``ir=`` to
    reuse one across searches. ``backend="jax"`` additionally runs every
    IR-capable round on the jit'd run-level evaluators
    (:mod:`repro.whatif.backend`), config axis optionally sharded over
    ``dist`` (:func:`repro.whatif.backend.config_mesh`); candidate counts
    are padded to powers of two there, so refinement rounds of drifting
    size reuse compilations.

    ``init_frontier`` (a :class:`~repro.whatif.sweep.Frontier` or a saved
    frontier JSON path) warm-starts the search: the previous frontier's
    Pareto members seed round 0 alongside the coarse grids
    (:func:`seed_points`), so a week-over-week re-search reaches its knee
    in fewer evaluations (tracked in ``BENCH_whatif_search.json``).

    Determinism: candidates are generated in family/axis order from sorted
    tried-value sets and evaluated through the batched replayer, so the
    result is bit-identical for any ``workers`` (tests/test_whatif_search.py).

    Returns a :class:`SearchResult`; its ``frontier`` holds every evaluated
    config with the Pareto subset flagged, ``best`` answers the operator's
    budget question directly.

    ``strict`` / ``verify`` / ``fault`` are :func:`repro.whatif.sweep
    .evaluate`'s dirty-telemetry knobs: ``strict=False`` skips unreadable
    shards (the returned frontier's ``coverage`` reports the replayed
    fraction), ``fault`` tunes the pool crash/hang supervisor.
    """
    if max_evals < 1:
        raise ValueError(f"max_evals must be >= 1, got {max_evals}")
    families = (default_families() if families is None else list(families))
    names = [f.name for f in families]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate family names: {names}")
    if compact is None:
        compact = batched
    hosts = list(hosts) if hosts is not None else None
    with obs.span("whatif.search", backend=backend, max_evals=max_evals):
        return _search_loop(
            store, budget, families, max_evals, max_rounds, knee_tol,
            knee_patience, anchors_per_family, include_noop, workers, hosts,
            mmap, batched, compact, ir, backend, dist, init_frontier,
            replayer_kwargs, strict=strict, verify=verify, fault=fault)


def _search_loop(
    store: "TelemetryStore",
    budget: PenaltyBudget | None,
    families: Sequence[PolicyFamily],
    max_evals: int,
    max_rounds: int,
    knee_tol: float,
    knee_patience: int,
    anchors_per_family: int,
    include_noop: bool,
    workers: int,
    hosts: Iterable[str] | None,
    mmap: bool,
    batched: bool,
    compact: bool,
    ir,
    backend: str,
    dist,
    init_frontier,
    replayer_kwargs: dict,
    strict: bool = True,
    verify: bool = False,
    fault=None,
) -> SearchResult:
    """The :func:`search_frontier` loop body (arguments already resolved).

    Split out so the public entry point can hold the ``whatif.search``
    observability span without re-indenting the whole driver."""
    # evaluation state, keyed by the built policy's canonical describe()
    outcomes: dict[str, PolicyOutcome] = {}
    point_of: dict[str, tuple[str, dict]] = {}     # key -> (family, point)
    order: list[str] = []                          # evaluation order
    tried: dict[tuple[str, str], set[float]] = {}  # (family, axis) -> values
    n_rows = 0
    n_runs = 0
    round_no = 0
    last_skips: list[dict] = []
    # deterministic convergence record (one entry per eval, all rounds) —
    # replay results only, no wall-clock, so frontiers stay bit-identical
    # with obs on or off
    trace: list[dict] = []

    def build_candidates(fam: PolicyFamily, points: list[dict]):
        cands = []
        for pt in points:
            pol = fam.build(pt)
            key = _key(pol)
            if key in outcomes or any(key == k for k, _ in cands):
                continue
            cands.append((key, (fam.name, pt, pol)))
        return cands

    def evaluate_round(cands) -> int:
        nonlocal n_rows, n_runs, last_skips
        if not cands:
            return 0
        pols = [pol for _, (_, _, pol) in cands]
        with obs.span("search.round", round=round_no, new=len(cands)):
            outs, rows, runs, skips = _evaluate_outcomes(
                pols, store, workers=workers, hosts=hosts, mmap=mmap,
                batched=batched, replayer_kwargs=replayer_kwargs,
                compact=compact, ir=ir, backend=backend, dist=dist,
                strict=strict, verify=verify, fault=fault)
        n_rows = rows
        n_runs = max(n_runs, runs)
        if skips:
            last_skips = skips
        for (key, (fam_name, pt, _)), out in zip(cands, outs):
            outcomes[key] = out
            point_of[key] = (fam_name, pt)
            order.append(key)
            trace.append({"i": len(order) - 1, "round": round_no,
                          "family": fam_name,
                          "saved_fraction": out.saved_fraction,
                          "penalty_s": out.penalty_s})
            for ax_name, v in pt.items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    tried.setdefault((fam_name, ax_name), set()).add(float(v))
        obs.counter("repro_search_evals_total", float(len(cands)),
                    help="policy configs evaluated by the closed-loop search")
        obs.counter("repro_search_rounds_total",
                    help="search evaluation rounds (round 0 included)")
        obs.gauge("repro_search_budget_remaining",
                  float(max_evals - len(order)),
                  help="eval budget left after the last search round")
        return len(cands)

    # ---------------- round 0: coarse grids (+ warm-start seeds) -------- #
    round0: list[tuple[str, tuple]] = []
    if include_noop:
        noop = NoOpPolicy()
        round0.append((_key(noop), ("noop", {}, noop)))
    for fam in families:
        round0.extend(build_candidates(fam, fam.coarse_points()))
    if len(round0) > max_evals:
        raise ValueError(
            f"max_evals={max_evals} cannot cover the coarse grids "
            f"({len(round0)} configs); raise the budget or thin the "
            f"families' coarse levels")
    if init_frontier is not None:
        # warm-start seeds ride along only as far as the eval budget
        # allows — the coarse grids keep priority, so a budget that was
        # valid cold can never become invalid warm
        seeds = seed_points(families, init_frontier)
        round0_keys = {k for k, _ in round0}
        seed_cands = [
            c for fam in families
            for c in build_candidates(fam, seeds.get(fam.name, []))
            if c[0] not in round0_keys]
        seed_cands = seed_cands[:max_evals - len(round0)]
        if seed_cands:
            obs.counter("repro_search_warm_seed_hits_total",
                        float(len(seed_cands)),
                        help="warm-start seeds admitted into round 0")
        round0.extend(seed_cands)

    # acquire the shared IR handle ONCE (memory cache / sidecar /
    # incremental extend / one O(rows) build) and thread it through every
    # refinement round: rounds then skip get_ir's freshness re-validation
    # entirely, and a store that grows mid-search cannot shear the search
    # across two IR generations. Configs the handle's config cannot cover
    # fall back per-config to the row path inside the evaluator, exactly
    # as before.
    if compact and ir is None:
        from repro.core.states import DEFAULT_CLASSIFIER
        from repro.whatif import ir as ir_mod
        pols0 = [pol for _, (_, _, pol) in round0]
        cfg = ir_mod.ir_config_for(
            pols0, replayer_kwargs.get("classifier") or DEFAULT_CLASSIFIER,
            replayer_kwargs.get("dt_s", 1.0))
        if any(ir_mod.ir_supported(p, cfg) for p in pols0):
            try:
                ir = ir_mod.get_ir(store, cfg, workers=workers, mmap=mmap,
                                   strict=strict, verify=verify, fault=fault)
            except ir_mod.IRUnsupportedError:
                ir = None          # e.g. irregular sampling: use rows
                obs.fallback("compact", "row", "ir_unsupported")

    evaluate_round(round0)

    history: list[RoundRecord] = []
    knee = find_knee(list(outcomes.values()))
    history.append(RoundRecord(
        n_new=len(order), n_evals_total=len(order),
        knee_saved_fraction=knee.saved_fraction, knee_penalty_s=knee.penalty_s,
        knee_params=knee.params))

    def record_knee(k: PolicyOutcome) -> None:
        obs.gauge("repro_search_knee_saved_fraction", k.saved_fraction,
                  help="saved fraction at the current Pareto knee")
        obs.gauge("repro_search_knee_penalty_s", k.penalty_s,
                  help="penalty seconds at the current Pareto knee")

    record_knee(knee)

    # ---------------- refinement rounds ---------------- #
    def close(a: float, b: float) -> bool:
        return abs(a - b) <= knee_tol * max(abs(a), abs(b), 1e-12)

    converged = False
    stable = 0
    by_fam: dict[str, list[str]] = {}
    while len(history) - 1 < max_rounds:
        round_no = len(history)
        all_outcomes = [outcomes[k] for k in order]
        flags = pareto_flags([o.energy_saved_j for o in all_outcomes],
                             [o.penalty_s for o in all_outcomes])
        pareto_keys = {k for k, f in zip(order, flags) if f}
        norm = _normalizer(all_outcomes)
        ks, kp = norm(knee)

        def knee_dist(key: str) -> float:
            s, p = norm(outcomes[key])
            return math.hypot(s - ks, p - kp)

        by_fam.clear()
        for k in order:
            by_fam.setdefault(point_of[k][0], []).append(k)

        candidates: list[tuple[str, tuple]] = []
        for fam in families:
            keys = by_fam.get(fam.name, [])
            if not keys:
                continue
            anchors = sorted((k for k in keys if k in pareto_keys),
                             key=knee_dist)[:anchors_per_family]
            if not anchors:
                # no Pareto member: refine the family's most competitive
                # point so a coarse miss can still recover
                anchors = sorted(keys, key=knee_dist)[:1]
            if budget is not None:
                feas = [k for k in keys if budget.feasible(outcomes[k])]
                if feas:
                    best_f = max(feas,
                                 key=lambda k: outcomes[k].energy_saved_j)
                    if best_f not in anchors:
                        anchors.append(best_f)
            points = []
            for akey in anchors:
                _, apt = point_of[akey]
                for ax in fam.axes:
                    if not isinstance(ax, ContinuousAxis):
                        continue
                    vals = sorted(tried.get((fam.name, ax.name), ()))
                    for mid in _neighbor_mids(ax, float(apt[ax.name]), vals):
                        points.append({**apt, ax.name: mid})
            candidates.extend(build_candidates(fam, points))

        room = max_evals - len(order)
        if not candidates:
            converged = True
            break
        if room <= 0:
            break
        new = evaluate_round(candidates[:room])
        prev = knee
        knee = find_knee(list(outcomes.values()))
        record_knee(knee)
        history.append(RoundRecord(
            n_new=new, n_evals_total=len(order),
            knee_saved_fraction=knee.saved_fraction,
            knee_penalty_s=knee.penalty_s, knee_params=knee.params))
        if (close(prev.saved_fraction, knee.saved_fraction)
                and close(prev.penalty_s, knee.penalty_s)):
            stable += 1
            if stable >= knee_patience:
                converged = True
                break
        else:
            stable = 0
            obs.counter("repro_search_knee_moves_total",
                        help="refinement rounds that moved the knee beyond "
                             "knee_tol")
        if new < len(candidates):      # budget truncated the round
            break

    from repro.whatif.sweep import _coverage_of
    coverage = _coverage_of(store, hosts, last_skips)
    obs.gauge("repro_coverage_fraction", coverage, stage="search",
              help="rows analyzed / rows on disk for the last run")
    frontier = assemble_frontier([outcomes[k] for k in order], n_rows, n_runs,
                                 trace=trace, coverage=coverage)
    final_outcomes = list(frontier.outcomes)
    knee = find_knee(final_outcomes)
    if budget is None:
        best: PolicyOutcome | None = knee
    else:
        feasible = [o for o in final_outcomes if budget.feasible(o)]
        best = (max(feasible, key=lambda o: o.energy_saved_j)
                if feasible else None)
    return SearchResult(
        frontier=frontier,
        knee=knee,
        best=best,
        n_evals=len(order),
        n_rounds=len(history),
        converged=converged,
        history=tuple(history),
    )

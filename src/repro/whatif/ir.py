"""Run-level telemetry IR: compact the row axis once, replay against runs.

The paper's central observation — in-execution telemetry is dominated by
long, near-constant low-activity stretches — makes per-second fleet
telemetry extremely *run-compressible*. This module exploits that for the
what-if stack: per (job, host, device) stream, the row series is collapsed
once, under a given classifier + low-activity threshold pair, into maximal
runs of constant ``(device_state, low_activity)`` with per-run sample
counts and power sums (plus the raw power samples for the few aggregates
that are nonlinear per sample — power-cap clipping, downscale floors).
Policy grids then replay against the ``(n_configs, n_runs)`` axis instead
of ``(n_configs, n_rows)``: downscale decisions, parking counterfactuals
and cap thresholds are run-structured, so per-config cost drops from
O(rows) to O(runs) ("compact once, replay many").

Contracts mirrored from the row-exact reference path
(:class:`repro.whatif.replay.BatchedPolicyReplayer`):

* **time/count metrics are bit-identical** — per-state durations are
  integer sample sums, decision sequences reduce to the same trigger
  indices, event counts and throttled-sample counts are exact integers;
* **energies/penalties agree to <= 1e-9 relative** — per-run power sums
  are exact partial sums of the same samples, but the float summation
  *order* differs from the sample-level integrator
  (tests/test_whatif_ir.py property-tests the equivalence).

The IR is cached in memory across sweep/search rounds and persisted as a
sidecar file next to the store's ``npz``/``npy_dir`` shards, keyed by the
:meth:`IRConfig.config_hash` in the manifest (``manifest["run_ir"]``), so
repeat sweeps skip stream grouping, classification and run-length encoding
entirely. Sidecars are invalidated when the classifier config changes (a
different hash misses); a store that merely *grew* is caught up
incrementally instead of rebuilt: :meth:`IRBuilder.extend` re-opens each
appended-to stream at its trailing run (the same cross-chunk carry the
from-scratch build uses, so the result is bit-identical), re-derives the
memoized per-stream aggregates only for the affected suffix, and carries
untouched streams over as the same objects, memo caches intact. The
sidecar manifest entry records a per-stream shard **watermark**
(``n_shards`` covered manifest prefix + per-host row counts), so growth
invalidates appended-to streams' tails, not the world — see
:func:`save_sidecar` and the storage-module docstring for the format.

Requirements: streams must be regularly sampled (``ts == ts[0] +
dt_s*arange(n)`` exactly, per stream) — the run table stores offsets, not
timestamps. Irregular streams raise :class:`IRUnsupportedError` and the
callers (:func:`repro.whatif.sweep.evaluate`) fall back to the row path.

The IR is also the input format of the JAX replay backend
(:mod:`repro.whatif.backend`): :func:`repro.whatif.backend.pack_ir`
bridges these ragged per-stream run tables into padded power-of-two
device buckets, and the jit'd family kernels replay ``(n_configs,
n_runs)`` blocks under the same bit-exactness contract, with the config
axis optionally sharded over a mesh
(:func:`repro.whatif.backend.config_mesh`).

Memory: unlike the row paths (peak ~ one shard), a resident IR holds the
store's *power column* (~8 bytes/row, 1/25th of the full schema) plus the
run tables and lazy per-stream aggregates — the price of O(runs)
replays. The in-process cache is a small LRU (``_IR_CACHE_MAX``); for a
corpus whose power column alone exceeds RAM, sweep with
``compact=False`` to stay fully out-of-core.

Observability: build time, compaction ratio and every cache-ladder
outcome (memory/sidecar hit, invalidation, negative-cache hit) are
recorded under the ``repro_ir_*`` metrics when :mod:`repro.obs` is
enabled — see the README "Observability" section for the full table.

Robustness (README "Robustness & dirty telemetry"): sidecar writes commit
through :func:`repro.telemetry.storage.atomic_replace` (kill-mid-write
leaves the previous sidecar intact); a corrupt or unparseable sidecar is
deleted and rebuilt from the shards (``sidecar -> rebuild`` fallback),
never raised to the caller; IRs built with ``strict=False`` record the
shards they skipped (:attr:`RunIR.skipped`) and are refused by strict
cache hits, so a degraded IR can never silently serve a strict caller.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import time
import zipfile
import zlib
from typing import TYPE_CHECKING, Iterable, Mapping

import numpy as np

import repro.obs as obs
from repro.core.controller import ControllerConfig
from repro.core.energy import EnergyBreakdown, integrate_runs
from repro.core.states import (ClassifierConfig, DEFAULT_CLASSIFIER,
                               DeviceState, classify_series)
from repro.whatif.policies import (CompositePolicy, DownscalePolicy,
                                   NoOpPolicy, ParkingPolicy, Policy,
                                   PowerCapPolicy, low_activity_series)

if TYPE_CHECKING:
    from repro.telemetry.records import TelemetryFrame
    from repro.telemetry.storage import TelemetryStore

#: manifest key holding {config_hash: {"file", "source_rows", "config"}}
MANIFEST_KEY = "run_ir"

_DEEP = int(DeviceState.DEEP_IDLE)
_EXEC = int(DeviceState.EXECUTION_IDLE)
_ACTIVE = int(DeviceState.ACTIVE)


class IRUnsupportedError(ValueError):
    """The store/grid cannot be compacted; callers fall back to rows."""


@dataclasses.dataclass(frozen=True)
class IRConfig:
    """Everything the run decomposition depends on.

    ``classifier`` fixes the §2.2 device states; ``activity_threshold`` /
    ``comm_threshold_gbs`` fix the Algorithm-1 low-activity predicate the
    policies share (:func:`repro.whatif.policies.low_activity_series`);
    ``dt_s`` fixes the sample spacing the run lengths are denominated in.
    Policies whose knobs disagree with these are simply *unsupported* by an
    IR built from this config (:func:`ir_supported`) — they replay through
    the row path instead.
    """

    classifier: ClassifierConfig = DEFAULT_CLASSIFIER
    activity_threshold: float = 0.05
    comm_threshold_gbs: float = 1.0
    dt_s: float = 1.0

    def low_config(self) -> ControllerConfig:
        return ControllerConfig(activity_threshold=self.activity_threshold,
                                comm_threshold_gbs=self.comm_threshold_gbs)

    def to_dict(self) -> dict:
        return {
            "classifier": dataclasses.asdict(self.classifier),
            "activity_threshold": self.activity_threshold,
            "comm_threshold_gbs": self.comm_threshold_gbs,
            "dt_s": self.dt_s,
        }

    @staticmethod
    def from_dict(d: Mapping) -> "IRConfig":
        cls_d = dict(d["classifier"])
        cls_d["compute_memory_signals"] = tuple(cls_d["compute_memory_signals"])
        cls_d["communication_signals"] = tuple(cls_d["communication_signals"])
        return IRConfig(
            classifier=ClassifierConfig(**cls_d),
            activity_threshold=d["activity_threshold"],
            comm_threshold_gbs=d["comm_threshold_gbs"],
            dt_s=d["dt_s"],
        )

    def config_hash(self) -> str:
        payload = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


# --------------------------------------------------------------------------- #
# Per-stream IR + lazily derived replay aggregates
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class StreamIR:
    """One stream's run table plus its power samples.

    The run arrays are the *compact* axis every policy config iterates;
    ``power`` keeps the raw samples so nonlinear per-sample aggregates
    (cap clipping, downscale floors) stay exact — computed **once** per
    stream (lazily, memoized in ``_cache``) and shared by every config and
    every sweep/search round. ``_cache`` is dropped on pickling, so
    process-pool workers rebuild their own aggregates.
    """

    key: tuple[int, int, int]        # (job_id, hostname, device_id)
    host_label: str                  # manifest host label (partition unit)
    platform_id: int
    ts_first: float
    dt_s: float
    state: np.ndarray                # [R] int8  DeviceState per run
    low: np.ndarray                  # [R] bool  Algorithm-1 low-activity flag
    length: np.ndarray               # [R] int64 samples per run
    power_sum: np.ndarray            # [R] f8    sum of board power over run
    power: np.ndarray                # [N] f8    raw per-sample board power

    def __post_init__(self) -> None:
        self._cache: dict = {}

    def __getstate__(self):
        d = dict(self.__dict__)
        d["_cache"] = {}
        return d

    # ------------------------------------------------------------------ #
    @property
    def n_rows(self) -> int:
        return int(self.power.shape[0])

    @property
    def n_runs(self) -> int:
        return int(self.state.shape[0])

    @property
    def ts_last(self) -> float:
        return float(self.ts_first + self.dt_s * (self.n_rows - 1))

    def _memo(self, key, fn):
        hit = self._cache.get(key)
        if hit is None:
            hit = self._cache[key] = fn()
        return hit

    def run_offsets(self) -> np.ndarray:
        """[R+1] sample offset of each run (cumulative lengths)."""
        return self._memo("off", lambda: np.concatenate(
            [[0], np.cumsum(self.length)]).astype(np.int64))

    def ts(self) -> np.ndarray:
        """Reconstructed per-sample timestamps (regularity is validated at
        build time, so this equals the recorded column bit-for-bit)."""
        return self._memo("ts", lambda: self.ts_first
                          + self.dt_s * np.arange(self.n_rows))

    def resident_runs(self) -> np.ndarray:
        """[R] bool — a program is resident (state is not DEEP_IDLE)."""
        return self._memo("res", lambda: self.state != _DEEP)

    def cum_resident(self) -> np.ndarray:
        """[N+1] prefix counts of resident samples (exact throttle counts)."""
        def build():
            res = np.repeat(self.resident_runs(), self.length)
            return np.concatenate([[0], np.cumsum(res)]).astype(np.int64)
        return self._memo("cumres", build)

    def expand(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-sample ``(states, low)`` — the inverse of the run-length
        encoding (round-trip tested in tests/test_whatif_ir.py)."""
        return (np.repeat(self.state, self.length),
                np.repeat(self.low, self.length))

    # ------------------------------------------------------------------ #
    def final_state(self, min_samples: int) -> np.ndarray:
        """[R] the state each run's samples are *accounted* under: maximal
        same-state runs (merging across the low flag) shorter than the §2.2
        sustain threshold relabel EXECUTION_IDLE -> ACTIVE, exactly as the
        streaming integrator does."""
        def build():
            change = np.flatnonzero(np.diff(self.state)) + 1
            starts = np.concatenate([[0], change])
            m_state = self.state[starts].astype(np.int64)
            m_len = np.add.reduceat(self.length, starts)
            m_final = np.where((m_state == _EXEC) & (m_len < min_samples),
                               _ACTIVE, m_state)
            reps = np.diff(np.concatenate([starts, [self.n_runs]]))
            return np.repeat(m_final, reps).astype(np.int8)
        return self._memo(("final", min_samples), build)

    def sample_final_state(self, min_samples: int) -> np.ndarray:
        return self._memo(("sfinal", min_samples), lambda: np.repeat(
            self.final_state(min_samples), self.length))

    def baseline(self, min_samples: int) -> EnergyBreakdown:
        """Recorded-series breakdown from run aggregates: per-state times
        bit-identical to the sample integrator, energies within summation
        order."""
        return self._memo(("base", min_samples), lambda: integrate_runs(
            self.state, self.power_sum[None, :], self.length,
            min_samples, self.dt_s)[0])

    def controller_runs(self) -> tuple[np.ndarray, np.ndarray]:
        """Maximal runs of the low-activity flag (the Algorithm-1 axis):
        ``(offsets [K+1] sample indices, low [K])``. Adjacent IR runs with
        equal ``low`` but different state merge here — the controller sees
        only the flag."""
        def build():
            change = np.flatnonzero(np.diff(self.low)) + 1
            starts = np.concatenate([[0], change]).astype(np.int64)
            off = self.run_offsets()[np.concatenate(
                [starts, [self.n_runs]])]
            return off, self.low[starts]
        return self._memo("crs", build)

    def downscale_cums(self, delta: float, deep_idle_w: float,
                       min_samples: int) -> tuple[np.ndarray, np.ndarray]:
        """Per-sample prefix sums of the downscale saving
        ``power - max(power - delta, deep_idle_w)`` on resident samples,
        split by the accounting state bucket: ``(cum_exec [N+1],
        cum_active [N+1])``. One O(N) pass per (platform delta, sustain
        threshold), shared by every config and round."""
        def build():
            p = self.power
            sav = p - np.maximum(p - delta, deep_idle_w)
            sav = np.where(np.repeat(self.resident_runs(), self.length),
                           sav, 0.0)
            fs = self.sample_final_state(min_samples)
            cum_exec = np.concatenate(
                [[0.0], np.cumsum(np.where(fs == _EXEC, sav, 0.0))])
            cum_act = np.concatenate(
                [[0.0], np.cumsum(np.where(fs == _ACTIVE, sav, 0.0))])
            return cum_exec, cum_act
        return self._memo(("dscum", float(delta), float(deep_idle_w),
                           min_samples), build)

    def cap_buckets(self, min_samples: int) -> dict:
        """Sorted-power aggregates for power capping, one O(N log N) build
        shared by every cap fraction:

        * per accounting state ``s``: ``(sorted_p ascending, top_sum)``
          where ``top_sum[k]`` is the sum of the k largest samples — so a
          cap's clipped energy is ``bucket_sum - (top_sum[k] - k*cap_w)``
          with ``k = #{p > cap_w}`` found by one vectorized searchsorted;
        * ``"penalty"``: the resident & not-low samples (the cube-law
          slowdown base), with ``top_cbrt[k]`` the sum of the k largest
          samples' cube roots.
        """
        def build():
            fs = self.sample_final_state(min_samples)
            out = {}
            for s in (_DEEP, _EXEC, _ACTIVE):
                sp = np.sort(self.power[fs == s])
                top = np.concatenate([[0.0], np.cumsum(sp[::-1])])
                out[s] = (sp, top)
            pen_mask = np.repeat(self.resident_runs() & ~self.low,
                                 self.length)
            sp = np.sort(self.power[pen_mask])
            top = np.concatenate([[0.0], np.cumsum(sp[::-1])])
            top_cbrt = np.concatenate([[0.0], np.cumsum(np.cbrt(sp[::-1]))])
            out["penalty"] = (sp, top, top_cbrt)
            return out
        return self._memo(("caps", min_samples), build)

    def parking_counterfactual(self, min_samples: int) -> dict:
        """The one counterfactual every parked config shares: idle samples
        (resident & low) drop to deep-idle residency. Returns per-run cf
        states / energies plus exact wake and idle-sample counts. The
        deep-idle *power value* is platform-dependent, so energies are
        returned as ``(power_sum part, idle-sample count)`` for the caller
        to price: ``energy = keep_sum + idle_len * deep_idle_w`` per run.
        """
        def build():
            idle = self.resident_runs() & self.low
            active = self.resident_runs() & ~self.low
            cf_state = np.where(idle, _DEEP, self.state).astype(np.int8)
            keep_sum = np.where(idle, 0.0, self.power_sum)
            idle_len = np.where(idle, self.length, 0).astype(np.int64)
            wakes = int(np.sum(idle[:-1] & active[1:]))
            return {"cf_state": cf_state, "keep_sum": keep_sum,
                    "idle_len": idle_len, "wakes": wakes,
                    "idle_samples": int(np.sum(idle_len))}
        return self._memo(("park", min_samples), build)


# --------------------------------------------------------------------------- #
# Fleet-level IR
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class RunIR:
    """The whole store's run-level IR: one :class:`StreamIR` per
    job-attributed stream, plus the build config and the store row count it
    was built from (staleness check).

    ``source_shards`` is the covered prefix length of the store's
    append-only ``manifest["shards"]`` list — the watermark
    :meth:`IRBuilder.extend` validates before appending only the new
    shards. ``unattributed`` keeps one ``(host_label, power_sum)`` pair per
    ingested chunk for the ``job_id < 0`` samples, so the fleet-analysis
    consumer can price unattributed energy (``math.fsum`` over the pairs is
    exact, hence identical to the row path's per-shard partials)."""

    config: IRConfig
    streams: dict[tuple[int, int, int], StreamIR]
    source_rows: int
    skipped: tuple = ()      # shard skip records from a strict=False build
    source_shards: int = 0   # covered prefix of manifest["shards"]
    unattributed: tuple = () # (host_label, power sum) per ingested chunk

    @property
    def n_rows(self) -> int:
        return sum(s.n_rows for s in self.streams.values())

    @property
    def n_runs(self) -> int:
        return sum(s.n_runs for s in self.streams.values())

    @property
    def compaction_ratio(self) -> float:
        runs = self.n_runs
        return self.n_rows / runs if runs else float("nan")

    def select(self, hosts: Iterable[str] | None = None) -> list[StreamIR]:
        """Streams in sorted-key order, optionally host-label filtered."""
        host_set = set(hosts) if hosts is not None else None
        return [self.streams[k] for k in sorted(self.streams)
                if host_set is None
                or self.streams[k].host_label in host_set]


# --------------------------------------------------------------------------- #
# Builder (streaming, mergeable — same partition contract as the replayers)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _StreamAccum:
    host_label: str
    platform_id: int
    ts_first: float
    n_seen: int = 0
    run_state: list = dataclasses.field(default_factory=list)
    run_low: list = dataclasses.field(default_factory=list)
    run_len: list = dataclasses.field(default_factory=list)
    run_sum: list = dataclasses.field(default_factory=list)
    power_pieces: list = dataclasses.field(default_factory=list)
    # trailing, possibly-unfinished run
    t_state: int = -1
    t_low: bool = False
    t_len: int = 0
    t_sum: float = 0.0
    # closed run arrays inherited from an extended IR (state/low/len/sum) —
    # prepended verbatim at finalize, never re-encoded
    prefix: tuple | None = None


def _seed_accum(s: StreamIR) -> _StreamAccum:
    """Re-open a finalized stream for appending: the closed-run prefix is
    carried verbatim and the trailing run becomes the accumulator's open
    run — exactly the state a from-scratch build would hold after ingesting
    this stream's shards, so continuing the build is bit-identical."""
    if s.n_runs == 0:
        return _StreamAccum(host_label=s.host_label,
                            platform_id=s.platform_id, ts_first=s.ts_first)
    t = s.n_runs - 1
    return _StreamAccum(
        host_label=s.host_label,
        platform_id=s.platform_id,
        ts_first=s.ts_first,
        n_seen=s.n_rows,
        power_pieces=[s.power],
        t_state=int(s.state[t]),
        t_low=bool(s.low[t]),
        t_len=int(s.length[t]),
        t_sum=float(s.power_sum[t]),
        prefix=(s.state[:t], s.low[:t], s.length[:t], s.power_sum[:t]),
    )


class IRBuilder:
    """Build a :class:`RunIR` from time-ordered telemetry chunks.

    Same streaming contract as the replayers (chunks may mix streams; per
    stream they arrive in time order), one classification + low-activity
    pass + run-length encoding per chunk — this is the *only* O(rows) work
    the compact path ever does, paid once per (store, IRConfig).
    ``merge`` absorbs a builder that saw a disjoint stream set (the
    process-pool reduction).
    """

    def __init__(self, config: IRConfig):
        self.config = config
        self._low_cfg = config.low_config()
        self._acc: dict[tuple[int, int, int], _StreamAccum] = {}
        self._unattr: list[tuple[str, float]] = []
        self._seed: dict[tuple[int, int, int], StreamIR] = {}

    def update(self, chunk: "TelemetryFrame", host_label: str = "") -> None:
        if len(chunk) == 0:
            return
        obs.counter("repro_ir_build_rows_total", float(len(chunk)),
                    help="telemetry rows run-length encoded by IRBuilder")
        neg = chunk["job_id"] < 0
        if np.any(neg):
            # same per-chunk partial the row path records; math.fsum over
            # the pieces is exact, so consumers match it bit-for-bit
            self._unattr.append(
                (host_label, float(np.sum(chunk["power"][neg]))))
        for key, seg in chunk.group_streams():
            if key[0] < 0:
                continue
            self._update_segment(key, seg, host_label)

    def _update_segment(self, key, seg, host_label: str) -> None:
        n = len(seg)
        ts = np.asarray(seg["timestamp"], dtype=np.float64)
        acc = self._acc.get(key)
        if acc is None:
            seed = self._seed.pop(key, None)
            if seed is not None:
                acc = self._acc[key] = _seed_accum(seed)
            else:
                acc = self._acc[key] = _StreamAccum(
                    host_label=host_label,
                    platform_id=int(seg["platform"][0]),
                    ts_first=float(ts[0]))
        expected = acc.ts_first + self.config.dt_s * np.arange(
            acc.n_seen, acc.n_seen + n)
        if not np.array_equal(ts, expected):
            raise IRUnsupportedError(
                f"stream {key} is not regularly sampled at dt={self.config.dt_s}"
                f" (run-level IR stores offsets, not timestamps); replay this "
                f"store with compact=False")
        states = classify_series(
            seg["program_resident"].astype(bool),
            seg.activity_pct(),
            seg.comm_gbs(),
            self.config.classifier,
        )
        low = low_activity_series(seg, self._low_cfg)
        power = np.asarray(seg["power"], dtype=np.float64)
        acc.power_pieces.append(power)
        acc.n_seen += n

        code = states.astype(np.int16) * 2 + low
        change = np.flatnonzero(np.diff(code)) + 1
        starts = np.concatenate([[0], change]).astype(np.int64)
        ends = np.concatenate([change, [n]]).astype(np.int64)
        sums = np.add.reduceat(power, starts)
        first = 0
        if acc.t_len and acc.t_state == int(states[0]) \
                and acc.t_low == bool(low[0]):
            acc.t_len += int(ends[0] - starts[0])
            acc.t_sum += float(sums[0])
            first = 1
        for i in range(first, starts.shape[0]):
            if acc.t_len:
                acc.run_state.append(acc.t_state)
                acc.run_low.append(acc.t_low)
                acc.run_len.append(acc.t_len)
                acc.run_sum.append(acc.t_sum)
            acc.t_state = int(states[starts[i]])
            acc.t_low = bool(low[starts[i]])
            acc.t_len = int(ends[i] - starts[i])
            acc.t_sum = float(sums[i])

    def merge(self, other: "IRBuilder") -> "IRBuilder":
        overlap = self._acc.keys() & other._acc.keys()
        if overlap:
            raise ValueError(f"cannot merge IR builders with overlapping "
                             f"streams: {sorted(overlap)[:3]}...")
        if other.config != self.config:
            raise ValueError("cannot merge IR builders with different configs")
        self._acc.update(other._acc)
        self._unattr.extend(other._unattr)
        return self

    def finalize(self, source_rows: int = 0, source_shards: int = 0) -> RunIR:
        streams: dict[tuple[int, int, int], StreamIR] = {}
        for key in sorted(self._acc):
            acc = self._acc[key]
            if acc.t_len:
                acc.run_state.append(acc.t_state)
                acc.run_low.append(acc.t_low)
                acc.run_len.append(acc.t_len)
                acc.run_sum.append(acc.t_sum)
                acc.t_len = 0
            state = np.array(acc.run_state, dtype=np.int8)
            low = np.array(acc.run_low, dtype=bool)
            length = np.array(acc.run_len, dtype=np.int64)
            power_sum = np.array(acc.run_sum, dtype=np.float64)
            if acc.prefix is not None:
                p_state, p_low, p_len, p_sum = acc.prefix
                state = np.concatenate([p_state, state])
                low = np.concatenate([p_low, low])
                length = np.concatenate([p_len, length])
                power_sum = np.concatenate([p_sum, power_sum])
            streams[key] = StreamIR(
                key=key,
                host_label=acc.host_label,
                platform_id=acc.platform_id,
                ts_first=acc.ts_first,
                dt_s=self.config.dt_s,
                state=state,
                low=low,
                length=length,
                power_sum=power_sum,
                power=(np.concatenate(acc.power_pieces)
                       if acc.power_pieces else np.empty(0)),
            )
        self._acc.clear()
        unattr = tuple(self._unattr)
        self._unattr = []
        return RunIR(config=self.config, streams=streams,
                     source_rows=source_rows, source_shards=source_shards,
                     unattributed=unattr)

    def extend(self, ir: RunIR, chunks: Iterable[tuple],
               source_rows: int | None = None,
               source_shards: int | None = None) -> RunIR:
        """Append ``chunks`` to an existing IR, rebuilding only the tails.

        ``chunks`` is an iterable of ``(frame, host_label)`` pairs — one per
        appended shard, in append (manifest) order. Each appended-to stream
        is re-opened at its trailing run via :func:`_seed_accum` (the same
        cross-chunk carry the from-scratch build uses), so the result is
        **bit-identical** to ``build_ir`` over the full shard sequence —
        run tables, power columns and every seeded memo agree bit-for-bit
        (property-tested in tests/test_ir_append.py). Cost is O(new rows +
        affected suffixes), not O(store).

        Untouched streams are carried over as the *same*
        :class:`StreamIR` objects, lazy memo caches intact; touched streams
        get their expensive memos (prefix sums, cap buckets,
        accounting-state labels) seeded from the old stream's cache via
        :func:`_extend_stream_memos`, recomputing only from the start of
        the last maximal state run (the only region the §2.2 sustain rule
        can relabel). ``ir`` itself is never mutated.

        ``source_rows``/``source_shards`` default to ``ir``'s values plus
        what ``chunks`` contributed; :func:`_try_extend` passes the
        manifest-derived totals instead so skipped shards still count
        toward staleness, mirroring ``build_ir``'s semantics.
        """
        if self._acc:
            raise ValueError("extend requires a fresh IRBuilder")
        if ir.config != self.config:
            raise ValueError(
                "cannot extend an IR built with a different config")
        t0 = time.perf_counter()
        self._seed = dict(ir.streams)
        self._unattr = list(ir.unattributed)
        n_chunks = 0
        new_rows = 0
        try:
            for frame, host_label in chunks:
                n_chunks += 1
                new_rows += len(frame)
                self.update(frame, host_label=host_label)
        finally:
            self._seed = {}
        out = self.finalize(
            source_rows=(ir.source_rows + new_rows if source_rows is None
                         else source_rows),
            source_shards=(ir.source_shards + n_chunks
                           if source_shards is None else source_shards))
        recomputed = 0
        streams = dict(out.streams)
        for key, new_s in out.streams.items():
            old_s = ir.streams.get(key)
            if old_s is not None:
                recomputed += _extend_stream_memos(old_s, new_s)
            else:
                recomputed += new_s.n_rows
        for key, old_s in ir.streams.items():
            streams.setdefault(key, old_s)
        out.streams = {k: streams[k] for k in sorted(streams)}
        out.skipped = tuple(ir.skipped)
        total = out.n_rows
        obs.counter("repro_ir_appends_total",
                    help="incremental IR catches-up via IRBuilder.extend")
        obs.counter("repro_ir_append_rows_total", float(new_rows),
                    help="telemetry rows appended through IRBuilder.extend")
        obs.gauge("repro_ir_suffix_rebuild_fraction",
                  recomputed / total if total else 0.0,
                  help="rows whose derived aggregates the last extend "
                       "recomputed, as a fraction of the IR's rows")
        if obs.enabled():
            obs.observe("repro_ir_extend_seconds", time.perf_counter() - t0,
                        help="wall time of IRBuilder.extend")
        return out


def _final_state_suffix(state: np.ndarray, length: np.ndarray,
                        min_samples: int) -> np.ndarray:
    """:meth:`StreamIR.final_state` restricted to a run-slice that starts
    on a maximal-state-run boundary — the relabel seen by those runs in a
    full build (reduceat grouping is identical on either side of a state
    change)."""
    change = np.flatnonzero(np.diff(state)) + 1
    starts = np.concatenate([[0], change])
    m_state = state[starts].astype(np.int64)
    m_len = np.add.reduceat(length, starts)
    m_final = np.where((m_state == _EXEC) & (m_len < min_samples),
                       _ACTIVE, m_state)
    reps = np.diff(np.concatenate([starts, [state.shape[0]]]))
    return np.repeat(m_final, reps).astype(np.int8)


def _multiset_delete(sp: np.ndarray, rem: np.ndarray) -> np.ndarray:
    """Remove the sorted multiset ``rem`` from the sorted array ``sp``
    (every ``rem`` value must be present): the k-th duplicate of a value in
    ``rem`` deletes the k-th duplicate in ``sp`` — occurrence-rank indexing,
    so ties never collapse onto one index."""
    if rem.size == 0:
        return sp
    idx = (np.searchsorted(sp, rem, side="left")
           + (np.arange(rem.size) - np.searchsorted(rem, rem, side="left")))
    return np.delete(sp, idx)


def _sorted_insert(sp: np.ndarray, add: np.ndarray) -> np.ndarray:
    """Merge the sorted array ``add`` into the sorted array ``sp``. The
    result is element-wise identical to re-sorting the union: equal floats
    share a bit pattern, so duplicate placement cannot be observed."""
    if add.size == 0:
        return sp
    return np.insert(sp, np.searchsorted(sp, add), add)


def _extend_stream_memos(old: StreamIR, new: StreamIR) -> int:
    """Seed ``new``'s lazy memo cache from ``old``'s after an append.

    Only labels and prefix aggregates of samples at or after ``B`` — the
    sample offset of the old stream's **last maximal constant-state run**
    — can change when rows append (§2.2 sustain relabels apply per maximal
    run, and only the last one can keep growing), so every seeded memo
    keeps its ``[:B]`` prefix and recomputes the suffix:

    * ``cumres`` — integer prefix counts: left-fold extended (exact);
    * ``("final"/"sfinal", m)`` — relabel recomputed from the maximal-run
      boundary ``q`` only;
    * ``("dscum", delta, deep_w, m)`` — float prefix sums extended by
      continuing the sequential cumsum *fold* from the old value at ``B``
      (``np.cumsum`` accumulates left-to-right, so this is bit-identical
      to a fresh full-series cumsum — never add the base to a sub-cumsum,
      association differs);
    * ``("caps", m)`` — sorted buckets patched by multiset delete/insert
      of the suffix samples (the O(N log N) sort is avoided; the cheap
      top-k cumsums are recomputed over the merged bucket).

    Cheap O(runs) memos (offsets, controller runs, baselines, parking)
    recompute lazily on demand. Returns the number of rows whose derived
    aggregates were recomputed (``new.n_rows - B``), the numerator of
    ``repro_ir_suffix_rebuild_fraction``.
    """
    old_off = old.run_offsets()
    t = old.n_runs - 1
    if t < 0:
        return new.n_rows
    change = np.flatnonzero(np.diff(old.state))
    q = int(change[-1] + 1) if change.size else 0
    B = int(old_off[q])
    off_t = int(old_off[t])
    old_n = old.n_rows
    cache = old._cache
    newc = new._cache

    if "cumres" in cache:
        old_cum = cache["cumres"]
        suf = np.repeat(new.resident_runs()[t:], new.length[t:])
        newc["cumres"] = np.concatenate(
            [old_cum[:off_t + 1],
             old_cum[off_t] + np.cumsum(suf)]).astype(np.int64)

    ms = {k[1] for k in cache if isinstance(k, tuple)
          and k[0] in ("final", "sfinal", "caps")}
    ms |= {k[3] for k in cache if isinstance(k, tuple) and k[0] == "dscum"}
    for m in sorted(ms):
        old_final = cache.get(("final", m))
        if old_final is None:
            continue                     # parameterized family never built
        suffix_final = _final_state_suffix(new.state[q:], new.length[q:], m)
        new_final = np.concatenate([old_final[:q], suffix_final])
        newc[("final", m)] = new_final
        old_sf = cache.get(("sfinal", m))
        if old_sf is None:
            continue
        new_sf = np.concatenate(
            [old_sf[:B], np.repeat(suffix_final, new.length[q:])])
        newc[("sfinal", m)] = new_sf

        if ("caps", m) in cache:
            old_caps = cache[("caps", m)]
            out: dict = {}
            ofs_b = old_sf[B:]
            nfs_b = new_sf[B:]
            for s in (_DEEP, _EXEC, _ACTIVE):
                kept = _multiset_delete(old_caps[s][0],
                                        np.sort(old.power[B:][ofs_b == s]))
                sp = _sorted_insert(kept,
                                    np.sort(new.power[B:][nfs_b == s]))
                top = np.concatenate([[0.0], np.cumsum(sp[::-1])])
                out[s] = (sp, top)
            # the penalty bucket has no min_samples dependence — old
            # samples never change membership, so it is insert-only
            pen_suf = np.repeat(new.resident_runs()[t:] & ~new.low[t:],
                                new.length[t:])
            sp = _sorted_insert(
                old_caps["penalty"][0],
                np.sort(new.power[old_n:][pen_suf[old_n - off_t:]]))
            top = np.concatenate([[0.0], np.cumsum(sp[::-1])])
            top_cbrt = np.concatenate([[0.0], np.cumsum(np.cbrt(sp[::-1]))])
            out["penalty"] = (sp, top, top_cbrt)
            newc[("caps", m)] = out

    for k in [k for k in cache if isinstance(k, tuple) and k[0] == "dscum"]:
        _, delta, deep_w, m = k
        new_sf = newc.get(("sfinal", m))
        if new_sf is None:
            continue
        old_ce, old_ca = cache[k]
        p = new.power[B:]
        sav = p - np.maximum(p - delta, deep_w)
        sav = np.where(np.repeat(new.resident_runs()[q:], new.length[q:]),
                       sav, 0.0)
        fs = new_sf[B:]
        newc[k] = tuple(
            np.concatenate([old_cum[:B + 1], np.cumsum(np.concatenate(
                [old_cum[B:B + 1], np.where(fs == want, sav, 0.0)]))[1:]])
            for old_cum, want in ((old_ce, _EXEC), (old_ca, _ACTIVE)))
    return new.n_rows - B


def _build_partition(root: str, shard_files: list[str], config: IRConfig,
                     mmap: bool, strict: bool = True,
                     verify: bool = False) -> tuple[IRBuilder, list[dict]]:
    """Process-pool worker body (module-level picklable)."""
    from repro.telemetry.storage import TelemetryStore
    store = TelemetryStore(root)
    host_of = {s["file"]: s["host"] for s in store.manifest["shards"]}
    builder = IRBuilder(config)
    skips: list[dict] = []
    for name in shard_files:
        frame = store.read_shard_or_skip(name, skips, mmap=mmap,
                                         strict=strict, verify=verify)
        if frame is not None:
            builder.update(frame, host_label=host_of.get(name, ""))
    return builder, skips


def build_ir(store: "TelemetryStore", config: IRConfig | None = None,
             workers: int = 1, mmap: bool = False, strict: bool = True,
             verify: bool = False, fault=None) -> RunIR:
    """One O(rows) pass over the store: group, classify, low-flag, RLE.

    ``workers > 1`` partitions by host label exactly like the sweep; the
    result is identical for any worker count (per-stream decomposition is
    independent, streams are reassembled in sorted order).

    ``strict=False`` skips unreadable shards (recorded in
    :attr:`RunIR.skipped`) instead of raising — note a skipped mid-stream
    shard usually makes its streams irregular, so the build then raises
    :class:`IRUnsupportedError` and callers replay through the row path,
    exactly as they would on the clean shard subset.
    """
    from repro.telemetry.pipeline import map_shard_partitions
    config = config or IRConfig()
    t0 = time.perf_counter()
    with obs.span("ir.build", workers=workers):
        builder, skips = map_shard_partitions(
            store, None, workers, _build_partition,
            (config, mmap, strict, verify),
            merge=lambda a, b: a.merge(b), stage="ir_build", fault=fault)
        ir = builder.finalize(source_rows=store.total_rows,
                              source_shards=len(store.manifest["shards"]))
        ir.skipped = tuple(skips)
    if obs.enabled():
        obs.counter("repro_ir_builds_total", help="fresh IR builds")
        obs.observe("repro_ir_build_seconds", time.perf_counter() - t0,
                    help="wall time of build_ir")
        obs.gauge("repro_ir_runs", float(ir.n_runs),
                  help="runs in the last-built IR")
        obs.gauge("repro_ir_rows", float(ir.n_rows),
                  help="source rows of the last-built IR")
        if ir.n_runs:
            obs.gauge("repro_ir_compaction_ratio", ir.compaction_ratio,
                      help="rows per run in the last-built IR")
    return ir


# --------------------------------------------------------------------------- #
# Policy support
# --------------------------------------------------------------------------- #
def _low_pair(policy: Policy) -> tuple[float, float] | None:
    if isinstance(policy, (DownscalePolicy, ParkingPolicy, PowerCapPolicy)):
        return (policy.config.activity_threshold,
                policy.config.comm_threshold_gbs)
    if isinstance(policy, CompositePolicy):
        pairs = {_low_pair(p) for p in policy.parts}
        pairs.discard(None)
        if len(pairs) == 1:
            return next(iter(pairs))
    return None


def ir_supported(policy: Policy, config: IRConfig) -> bool:
    """Can ``policy`` replay against an IR built with ``config``?

    Leaf families must share the IR's low-activity thresholds (the run
    decomposition bakes the flag in); composites must be the known
    parking-then-downscale shape (each part's effect stays run-structured
    because they touch disjoint residency); anything else — custom policies,
    other composite orders — replays through the row path.
    """
    pair = (config.activity_threshold, config.comm_threshold_gbs)
    if isinstance(policy, NoOpPolicy):
        return True
    if isinstance(policy, (DownscalePolicy, ParkingPolicy, PowerCapPolicy)):
        return _low_pair(policy) == pair
    if isinstance(policy, CompositePolicy):
        return (len(policy.parts) == 2
                and isinstance(policy.parts[0], ParkingPolicy)
                and isinstance(policy.parts[1], DownscalePolicy)
                and _low_pair(policy) == pair)
    return False


def ir_config_for(policies: Iterable[Policy],
                  classifier: ClassifierConfig = DEFAULT_CLASSIFIER,
                  dt_s: float = 1.0) -> IRConfig:
    """The :class:`IRConfig` covering the most grid configs: the modal
    low-threshold pair among the policies (ties broken deterministically
    by pair value); configs on other pairs fall back to the row path."""
    counts: dict[tuple[float, float], int] = {}
    for p in policies:
        pair = _low_pair(p)
        if pair is not None:
            counts[pair] = counts.get(pair, 0) + 1
    if not counts:
        pair = (ControllerConfig.activity_threshold,
                ControllerConfig.comm_threshold_gbs)
    else:
        pair = max(sorted(counts), key=lambda k: counts[k])
    return IRConfig(classifier=classifier, activity_threshold=pair[0],
                    comm_threshold_gbs=pair[1], dt_s=dt_s)


# --------------------------------------------------------------------------- #
# Sidecar persistence (next to the store's shards, keyed in the manifest)
# --------------------------------------------------------------------------- #
def sidecar_name(config: IRConfig) -> str:
    return f"run_ir_{config.config_hash()}.npz"


def save_sidecar(ir: RunIR, store: "TelemetryStore") -> pathlib.Path:
    """Persist the IR next to the shards and key it in the manifest.

    Format: one compressed ``.npz`` holding the stream table (keys, host
    labels, platforms, first timestamps, run/sample counts), the
    concatenated run arrays (state/low/length/power_sum) and the
    concatenated power samples; ``meta`` embeds the :class:`IRConfig`, the
    source row count and the **shard watermark** (``source_shards``: the
    covered prefix of the append-only manifest shard list, plus the
    per-chunk unattributed-power pairs). ``manifest["run_ir"][hash]``
    points at the file and mirrors the watermark (``n_shards`` +
    per-host covered row counts) — a changed classifier config hashes to a
    different sidecar; an appended store no longer invalidates wholesale
    but is caught up by :meth:`IRBuilder.extend` over the uncovered shard
    suffix (:func:`get_ir`'s ``memory_extend``/``sidecar_extend`` rungs),
    provided the covered prefix still sums to ``source_rows`` (a rewritten
    or quarantined prefix shard forces a full rebuild).
    """
    streams = [ir.streams[k] for k in sorted(ir.streams)]
    meta = json.dumps({"config": ir.config.to_dict(),
                       "source_rows": ir.source_rows,
                       "source_shards": ir.source_shards,
                       "unattributed": [[h, v] for h, v in ir.unattributed],
                       "skipped": list(ir.skipped)})
    arrays = {
        "meta": np.array(meta),
        "job": np.array([s.key[0] for s in streams], dtype=np.int64),
        "host": np.array([s.key[1] for s in streams], dtype=np.int64),
        "dev": np.array([s.key[2] for s in streams], dtype=np.int64),
        "host_label": np.array([s.host_label for s in streams]),
        "platform": np.array([s.platform_id for s in streams], dtype=np.int64),
        "ts_first": np.array([s.ts_first for s in streams]),
        "n_runs": np.array([s.n_runs for s in streams], dtype=np.int64),
        "n_rows": np.array([s.n_rows for s in streams], dtype=np.int64),
        "state": (np.concatenate([s.state for s in streams])
                  if streams else np.empty(0, np.int8)),
        "low": (np.concatenate([s.low for s in streams])
                if streams else np.empty(0, bool)),
        "length": (np.concatenate([s.length for s in streams])
                   if streams else np.empty(0, np.int64)),
        "power_sum": (np.concatenate([s.power_sum for s in streams])
                      if streams else np.empty(0)),
        "power": (np.concatenate([s.power for s in streams])
                  if streams else np.empty(0)),
    }
    name = sidecar_name(ir.config)
    path = store.root / name
    # commit through storage.atomic_replace: a process killed mid-write
    # leaves the previous sidecar (or none) fully intact, never a torn file
    from repro.telemetry import storage as storage_mod
    storage_mod._write_atomic_npz(path, arrays)
    marks: dict[str, int] = {}
    for s in store.manifest["shards"][:ir.source_shards]:
        marks[s["host"]] = marks.get(s["host"], 0) + int(s["rows"])
    entry = {"file": name, "source_rows": ir.source_rows,
             "n_shards": ir.source_shards, "watermarks": marks,
             "config": ir.config.to_dict()}
    # atomic single-key merge: a concurrent appender's shard entries must
    # survive this derived-data write (see TelemetryStore.merge_manifest_key)
    store.merge_manifest_key(MANIFEST_KEY, ir.config.config_hash(), entry)
    return path


#: everything a torn/bit-flipped sidecar or poisoned manifest subtree can
#: raise through np.load/json/entry access — all mapped to "rebuild"
_SIDECAR_ERRORS = (zipfile.BadZipFile, zlib.error, ValueError, KeyError,
                   TypeError, OSError, EOFError)


def load_sidecar(store: "TelemetryStore", config: IRConfig,
                 allow_stale: bool = False) -> RunIR | None:
    """Load a sidecar if a *fresh* one exists: the manifest must key this
    config's hash and the persisted ``source_rows`` must still equal the
    store's row count (an appended store silently invalidates).
    ``allow_stale=True`` skips the freshness check — :func:`get_ir` uses it
    to load a stale-but-watermarked sidecar as the base of an incremental
    :meth:`IRBuilder.extend` instead of rebuilding from scratch.

    Tolerant by construction: a poisoned manifest subtree, a missing file,
    or a corrupt/truncated archive (``BadZipFile``, CRC errors, bad JSON
    meta) is counted as a ``sidecar -> rebuild`` fallback, the bad file is
    deleted, and ``None`` is returned so the caller rebuilds from shards —
    derived data is never allowed to take down the pipeline."""
    raw = store.manifest.get(MANIFEST_KEY)
    entry = raw.get(config.config_hash()) if isinstance(raw, dict) else None
    if not isinstance(entry, dict):
        return None
    try:
        if not allow_stale and int(entry["source_rows"]) != store.total_rows:
            obs.counter("repro_ir_cache_invalidations_total", level="sidecar",
                        help="cached IRs rejected as stale")
            return None
        path = store.root / str(entry["file"])
    except _SIDECAR_ERRORS:
        obs.fallback("sidecar", "rebuild", "bad_manifest_entry")
        return None
    if not path.exists():
        return None
    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            src_rows = int(meta["source_rows"])
            src_shards = int(meta.get("source_shards", 0))
            unattr = tuple((str(h), float(v))
                           for h, v in meta.get("unattributed", ()))
            skipped = tuple(meta.get("skipped", ()))
            loaded_cfg = IRConfig.from_dict(meta["config"])
            if loaded_cfg != config:
                obs.counter("repro_ir_cache_invalidations_total",
                            level="sidecar",
                            help="cached IRs rejected as stale")
                return None
            run_off = np.concatenate(
                [[0], np.cumsum(z["n_runs"])]).astype(np.int64)
            row_off = np.concatenate(
                [[0], np.cumsum(z["n_rows"])]).astype(np.int64)
            streams: dict[tuple[int, int, int], StreamIR] = {}
            for i in range(z["job"].shape[0]):
                r0, r1 = run_off[i], run_off[i + 1]
                p0, p1 = row_off[i], row_off[i + 1]
                key = (int(z["job"][i]), int(z["host"][i]), int(z["dev"][i]))
                streams[key] = StreamIR(
                    key=key,
                    host_label=str(z["host_label"][i]),
                    platform_id=int(z["platform"][i]),
                    ts_first=float(z["ts_first"][i]),
                    dt_s=config.dt_s,
                    state=z["state"][r0:r1].astype(np.int8),
                    low=z["low"][r0:r1].astype(bool),
                    length=z["length"][r0:r1].astype(np.int64),
                    power_sum=np.array(z["power_sum"][r0:r1]),
                    power=np.array(z["power"][p0:p1]),
                )
    except _SIDECAR_ERRORS as e:
        obs.fallback("sidecar", "rebuild", type(e).__name__)
        try:
            path.unlink(missing_ok=True)
        except OSError:
            pass
        return None
    return RunIR(config=config, streams=streams,
                 source_rows=src_rows, skipped=skipped,
                 source_shards=src_shards, unattributed=unattr)


def _try_extend(store: "TelemetryStore", ir: RunIR, mmap: bool,
                strict: bool, verify: bool) -> RunIR | None:
    """Catch a stale IR up to the store by appending only the new shards.

    Valid only while the covered manifest prefix is untouched: the first
    ``ir.source_shards`` entries must still sum to ``ir.source_rows`` (a
    rewritten, quarantined or reordered prefix shard breaks the watermark).
    Returns ``None`` when extension is impossible — irregular appended
    streams included — so the caller falls through to a full rebuild,
    which then *defines* the semantics. Suffix-shard read errors propagate
    under ``strict=True`` exactly as a rebuild's would; under
    ``strict=False`` they become skip records on the returned IR.
    """
    shards = store.manifest["shards"]
    k = ir.source_shards
    if not 0 < k <= len(shards):
        return None
    if sum(int(s["rows"]) for s in shards[:k]) != ir.source_rows:
        return None
    skips: list[dict] = []
    chunks = []
    for s in shards[k:]:
        frame = store.read_shard_or_skip(s["file"], skips, mmap=mmap,
                                         strict=strict, verify=verify)
        if frame is not None:
            chunks.append((frame, s.get("host", "")))
    try:
        out = IRBuilder(ir.config).extend(
            ir, chunks, source_rows=store.total_rows,
            source_shards=len(shards))
    except IRUnsupportedError:
        return None
    out.skipped = tuple(ir.skipped) + tuple(skips)
    return out


#: in-process cache: (resolved store root, config hash) -> RunIR. An IR
#: pins the store's power column (~8 bytes/row) plus the run tables in
#: memory, so the cache is a small LRU rather than unbounded.
_IR_CACHE: dict[tuple[str, str], RunIR] = {}
_IR_CACHE_MAX = 4
#: negative cache: builds that raised IRUnsupportedError, keyed with the
#: row count they failed at — a search over an irregular store fails the
#: build once, not once per refinement round
_IR_UNSUPPORTED: dict[tuple[str, str], tuple[int, str]] = {}


def get_ir(store: "TelemetryStore", config: IRConfig | None = None,
           workers: int = 1, mmap: bool = False,
           persist: bool = True, strict: bool = True,
           verify: bool = False, fault=None) -> RunIR:
    """The IR acquisition ladder: in-memory cache, then incremental
    *extension* of a stale cached IR (:func:`_try_extend`: only the
    appended shards are read, only the appended-to streams' tails rebuilt
    — untouched streams keep their object identity and memo caches), then
    a fresh sidecar, then extension of a stale-but-watermarked sidecar,
    then a fresh build. Extended and built IRs are persisted back as
    sidecars unless ``persist=False`` or the store root is not writable.
    A store whose build failed (:class:`IRUnsupportedError`, e.g.
    irregular sampling) re-raises from a negative cache until the store
    changes, so callers that fall back to the row path don't pay a doomed
    O(rows) build per call.

    Cache hits additionally require that a cached IR built with skipped
    shards (``strict=False`` on a dirty store) is never served to a
    ``strict=True`` caller — degraded derived data must not silently
    masquerade as complete."""
    config = config or IRConfig()
    cache_key = (str(pathlib.Path(store.root).resolve()),
                 config.config_hash())
    failed = _IR_UNSUPPORTED.get(cache_key)
    if failed is not None and failed[0] == store.total_rows:
        obs.counter("repro_ir_negative_cache_hits_total",
                    help="IR builds skipped via the unsupported-store cache")
        raise IRUnsupportedError(failed[1])

    def _finish(ir: RunIR, save: bool) -> RunIR:
        if save and persist:
            try:
                save_sidecar(ir, store)
            except OSError:
                pass                    # read-only store: memory cache only
        _IR_CACHE.pop(cache_key, None)
        _IR_CACHE[cache_key] = ir       # (re-)insert at LRU head
        while len(_IR_CACHE) > _IR_CACHE_MAX:  # dicts keep insert order
            _IR_CACHE.pop(next(iter(_IR_CACHE)))
        return ir

    ir = _IR_CACHE.get(cache_key)
    if ir is not None and not (ir.skipped and strict):
        if ir.source_rows == store.total_rows:
            obs.counter("repro_ir_cache_hits_total", level="memory",
                        help="IR acquisitions served from a cache level")
            return _finish(ir, save=False)
        ext = _try_extend(store, ir, mmap, strict, verify)
        if ext is not None and not (ext.skipped and strict):
            obs.counter("repro_ir_cache_hits_total", level="memory_extend",
                        help="IR acquisitions served from a cache level")
            return _finish(ext, save=True)
    if ir is not None:
        obs.counter("repro_ir_cache_invalidations_total", level="memory",
                    help="cached IRs rejected as stale")
    ir = load_sidecar(store, config)
    if ir is not None and ir.skipped and strict:
        obs.counter("repro_ir_cache_invalidations_total", level="sidecar",
                    help="cached IRs rejected as stale")
        ir = None
    if ir is not None:
        obs.counter("repro_ir_cache_hits_total", level="sidecar",
                    help="IR acquisitions served from a cache level")
        return _finish(ir, save=False)
    stale = load_sidecar(store, config, allow_stale=True)
    if stale is not None and stale.source_rows != store.total_rows \
            and not (stale.skipped and strict):
        ext = _try_extend(store, stale, mmap, strict, verify)
        if ext is not None and not (ext.skipped and strict):
            obs.counter("repro_ir_cache_hits_total", level="sidecar_extend",
                        help="IR acquisitions served from a cache level")
            return _finish(ext, save=True)
    obs.counter("repro_ir_cache_misses_total",
                help="IR acquisitions that required a fresh build")
    try:
        ir = build_ir(store, config, workers=workers, mmap=mmap,
                      strict=strict, verify=verify, fault=fault)
    except IRUnsupportedError as e:
        _IR_UNSUPPORTED[cache_key] = (store.total_rows, str(e))
        raise
    return _finish(ir, save=True)

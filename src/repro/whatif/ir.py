"""Run-level telemetry IR: compact the row axis once, replay against runs.

The paper's central observation — in-execution telemetry is dominated by
long, near-constant low-activity stretches — makes per-second fleet
telemetry extremely *run-compressible*. This module exploits that for the
what-if stack: per (job, host, device) stream, the row series is collapsed
once, under a given classifier + low-activity threshold pair, into maximal
runs of constant ``(device_state, low_activity)`` with per-run sample
counts and power sums (plus the raw power samples for the few aggregates
that are nonlinear per sample — power-cap clipping, downscale floors).
Policy grids then replay against the ``(n_configs, n_runs)`` axis instead
of ``(n_configs, n_rows)``: downscale decisions, parking counterfactuals
and cap thresholds are run-structured, so per-config cost drops from
O(rows) to O(runs) ("compact once, replay many").

Contracts mirrored from the row-exact reference path
(:class:`repro.whatif.replay.BatchedPolicyReplayer`):

* **time/count metrics are bit-identical** — per-state durations are
  integer sample sums, decision sequences reduce to the same trigger
  indices, event counts and throttled-sample counts are exact integers;
* **energies/penalties agree to <= 1e-9 relative** — per-run power sums
  are exact partial sums of the same samples, but the float summation
  *order* differs from the sample-level integrator
  (tests/test_whatif_ir.py property-tests the equivalence).

The IR is cached in memory across sweep/search rounds and persisted as a
sidecar file next to the store's ``npz``/``npy_dir`` shards, keyed by the
:meth:`IRConfig.config_hash` in the manifest (``manifest["run_ir"]``), so
repeat sweeps skip stream grouping, classification and run-length encoding
entirely. Sidecars are invalidated when the classifier config changes (a
different hash misses) or the store grows (``source_rows`` mismatch).

Requirements: streams must be regularly sampled (``ts == ts[0] +
dt_s*arange(n)`` exactly, per stream) — the run table stores offsets, not
timestamps. Irregular streams raise :class:`IRUnsupportedError` and the
callers (:func:`repro.whatif.sweep.evaluate`) fall back to the row path.

The IR is also the input format of the JAX replay backend
(:mod:`repro.whatif.backend`): :func:`repro.whatif.backend.pack_ir`
bridges these ragged per-stream run tables into padded power-of-two
device buckets, and the jit'd family kernels replay ``(n_configs,
n_runs)`` blocks under the same bit-exactness contract, with the config
axis optionally sharded over a mesh
(:func:`repro.whatif.backend.config_mesh`).

Memory: unlike the row paths (peak ~ one shard), a resident IR holds the
store's *power column* (~8 bytes/row, 1/25th of the full schema) plus the
run tables and lazy per-stream aggregates — the price of O(runs)
replays. The in-process cache is a small LRU (``_IR_CACHE_MAX``); for a
corpus whose power column alone exceeds RAM, sweep with
``compact=False`` to stay fully out-of-core.

Observability: build time, compaction ratio and every cache-ladder
outcome (memory/sidecar hit, invalidation, negative-cache hit) are
recorded under the ``repro_ir_*`` metrics when :mod:`repro.obs` is
enabled — see the README "Observability" section for the full table.

Robustness (README "Robustness & dirty telemetry"): sidecar writes commit
through :func:`repro.telemetry.storage.atomic_replace` (kill-mid-write
leaves the previous sidecar intact); a corrupt or unparseable sidecar is
deleted and rebuilt from the shards (``sidecar -> rebuild`` fallback),
never raised to the caller; IRs built with ``strict=False`` record the
shards they skipped (:attr:`RunIR.skipped`) and are refused by strict
cache hits, so a degraded IR can never silently serve a strict caller.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import time
import zipfile
import zlib
from typing import TYPE_CHECKING, Iterable, Mapping

import numpy as np

import repro.obs as obs
from repro.core.controller import ControllerConfig
from repro.core.energy import EnergyBreakdown, integrate_runs
from repro.core.states import (ClassifierConfig, DEFAULT_CLASSIFIER,
                               DeviceState, classify_series)
from repro.whatif.policies import (CompositePolicy, DownscalePolicy,
                                   NoOpPolicy, ParkingPolicy, Policy,
                                   PowerCapPolicy, low_activity_series)

if TYPE_CHECKING:
    from repro.telemetry.records import TelemetryFrame
    from repro.telemetry.storage import TelemetryStore

#: manifest key holding {config_hash: {"file", "source_rows", "config"}}
MANIFEST_KEY = "run_ir"

_DEEP = int(DeviceState.DEEP_IDLE)
_EXEC = int(DeviceState.EXECUTION_IDLE)
_ACTIVE = int(DeviceState.ACTIVE)


class IRUnsupportedError(ValueError):
    """The store/grid cannot be compacted; callers fall back to rows."""


@dataclasses.dataclass(frozen=True)
class IRConfig:
    """Everything the run decomposition depends on.

    ``classifier`` fixes the §2.2 device states; ``activity_threshold`` /
    ``comm_threshold_gbs`` fix the Algorithm-1 low-activity predicate the
    policies share (:func:`repro.whatif.policies.low_activity_series`);
    ``dt_s`` fixes the sample spacing the run lengths are denominated in.
    Policies whose knobs disagree with these are simply *unsupported* by an
    IR built from this config (:func:`ir_supported`) — they replay through
    the row path instead.
    """

    classifier: ClassifierConfig = DEFAULT_CLASSIFIER
    activity_threshold: float = 0.05
    comm_threshold_gbs: float = 1.0
    dt_s: float = 1.0

    def low_config(self) -> ControllerConfig:
        return ControllerConfig(activity_threshold=self.activity_threshold,
                                comm_threshold_gbs=self.comm_threshold_gbs)

    def to_dict(self) -> dict:
        return {
            "classifier": dataclasses.asdict(self.classifier),
            "activity_threshold": self.activity_threshold,
            "comm_threshold_gbs": self.comm_threshold_gbs,
            "dt_s": self.dt_s,
        }

    @staticmethod
    def from_dict(d: Mapping) -> "IRConfig":
        cls_d = dict(d["classifier"])
        cls_d["compute_memory_signals"] = tuple(cls_d["compute_memory_signals"])
        cls_d["communication_signals"] = tuple(cls_d["communication_signals"])
        return IRConfig(
            classifier=ClassifierConfig(**cls_d),
            activity_threshold=d["activity_threshold"],
            comm_threshold_gbs=d["comm_threshold_gbs"],
            dt_s=d["dt_s"],
        )

    def config_hash(self) -> str:
        payload = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


# --------------------------------------------------------------------------- #
# Per-stream IR + lazily derived replay aggregates
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class StreamIR:
    """One stream's run table plus its power samples.

    The run arrays are the *compact* axis every policy config iterates;
    ``power`` keeps the raw samples so nonlinear per-sample aggregates
    (cap clipping, downscale floors) stay exact — computed **once** per
    stream (lazily, memoized in ``_cache``) and shared by every config and
    every sweep/search round. ``_cache`` is dropped on pickling, so
    process-pool workers rebuild their own aggregates.
    """

    key: tuple[int, int, int]        # (job_id, hostname, device_id)
    host_label: str                  # manifest host label (partition unit)
    platform_id: int
    ts_first: float
    dt_s: float
    state: np.ndarray                # [R] int8  DeviceState per run
    low: np.ndarray                  # [R] bool  Algorithm-1 low-activity flag
    length: np.ndarray               # [R] int64 samples per run
    power_sum: np.ndarray            # [R] f8    sum of board power over run
    power: np.ndarray                # [N] f8    raw per-sample board power

    def __post_init__(self) -> None:
        self._cache: dict = {}

    def __getstate__(self):
        d = dict(self.__dict__)
        d["_cache"] = {}
        return d

    # ------------------------------------------------------------------ #
    @property
    def n_rows(self) -> int:
        return int(self.power.shape[0])

    @property
    def n_runs(self) -> int:
        return int(self.state.shape[0])

    @property
    def ts_last(self) -> float:
        return float(self.ts_first + self.dt_s * (self.n_rows - 1))

    def _memo(self, key, fn):
        hit = self._cache.get(key)
        if hit is None:
            hit = self._cache[key] = fn()
        return hit

    def run_offsets(self) -> np.ndarray:
        """[R+1] sample offset of each run (cumulative lengths)."""
        return self._memo("off", lambda: np.concatenate(
            [[0], np.cumsum(self.length)]).astype(np.int64))

    def ts(self) -> np.ndarray:
        """Reconstructed per-sample timestamps (regularity is validated at
        build time, so this equals the recorded column bit-for-bit)."""
        return self._memo("ts", lambda: self.ts_first
                          + self.dt_s * np.arange(self.n_rows))

    def resident_runs(self) -> np.ndarray:
        """[R] bool — a program is resident (state is not DEEP_IDLE)."""
        return self._memo("res", lambda: self.state != _DEEP)

    def cum_resident(self) -> np.ndarray:
        """[N+1] prefix counts of resident samples (exact throttle counts)."""
        def build():
            res = np.repeat(self.resident_runs(), self.length)
            return np.concatenate([[0], np.cumsum(res)]).astype(np.int64)
        return self._memo("cumres", build)

    def expand(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-sample ``(states, low)`` — the inverse of the run-length
        encoding (round-trip tested in tests/test_whatif_ir.py)."""
        return (np.repeat(self.state, self.length),
                np.repeat(self.low, self.length))

    # ------------------------------------------------------------------ #
    def final_state(self, min_samples: int) -> np.ndarray:
        """[R] the state each run's samples are *accounted* under: maximal
        same-state runs (merging across the low flag) shorter than the §2.2
        sustain threshold relabel EXECUTION_IDLE -> ACTIVE, exactly as the
        streaming integrator does."""
        def build():
            change = np.flatnonzero(np.diff(self.state)) + 1
            starts = np.concatenate([[0], change])
            m_state = self.state[starts].astype(np.int64)
            m_len = np.add.reduceat(self.length, starts)
            m_final = np.where((m_state == _EXEC) & (m_len < min_samples),
                               _ACTIVE, m_state)
            reps = np.diff(np.concatenate([starts, [self.n_runs]]))
            return np.repeat(m_final, reps).astype(np.int8)
        return self._memo(("final", min_samples), build)

    def sample_final_state(self, min_samples: int) -> np.ndarray:
        return self._memo(("sfinal", min_samples), lambda: np.repeat(
            self.final_state(min_samples), self.length))

    def baseline(self, min_samples: int) -> EnergyBreakdown:
        """Recorded-series breakdown from run aggregates: per-state times
        bit-identical to the sample integrator, energies within summation
        order."""
        return self._memo(("base", min_samples), lambda: integrate_runs(
            self.state, self.power_sum[None, :], self.length,
            min_samples, self.dt_s)[0])

    def controller_runs(self) -> tuple[np.ndarray, np.ndarray]:
        """Maximal runs of the low-activity flag (the Algorithm-1 axis):
        ``(offsets [K+1] sample indices, low [K])``. Adjacent IR runs with
        equal ``low`` but different state merge here — the controller sees
        only the flag."""
        def build():
            change = np.flatnonzero(np.diff(self.low)) + 1
            starts = np.concatenate([[0], change]).astype(np.int64)
            off = self.run_offsets()[np.concatenate(
                [starts, [self.n_runs]])]
            return off, self.low[starts]
        return self._memo("crs", build)

    def downscale_cums(self, delta: float, deep_idle_w: float,
                       min_samples: int) -> tuple[np.ndarray, np.ndarray]:
        """Per-sample prefix sums of the downscale saving
        ``power - max(power - delta, deep_idle_w)`` on resident samples,
        split by the accounting state bucket: ``(cum_exec [N+1],
        cum_active [N+1])``. One O(N) pass per (platform delta, sustain
        threshold), shared by every config and round."""
        def build():
            p = self.power
            sav = p - np.maximum(p - delta, deep_idle_w)
            sav = np.where(np.repeat(self.resident_runs(), self.length),
                           sav, 0.0)
            fs = self.sample_final_state(min_samples)
            cum_exec = np.concatenate(
                [[0.0], np.cumsum(np.where(fs == _EXEC, sav, 0.0))])
            cum_act = np.concatenate(
                [[0.0], np.cumsum(np.where(fs == _ACTIVE, sav, 0.0))])
            return cum_exec, cum_act
        return self._memo(("dscum", float(delta), float(deep_idle_w),
                           min_samples), build)

    def cap_buckets(self, min_samples: int) -> dict:
        """Sorted-power aggregates for power capping, one O(N log N) build
        shared by every cap fraction:

        * per accounting state ``s``: ``(sorted_p ascending, top_sum)``
          where ``top_sum[k]`` is the sum of the k largest samples — so a
          cap's clipped energy is ``bucket_sum - (top_sum[k] - k*cap_w)``
          with ``k = #{p > cap_w}`` found by one vectorized searchsorted;
        * ``"penalty"``: the resident & not-low samples (the cube-law
          slowdown base), with ``top_cbrt[k]`` the sum of the k largest
          samples' cube roots.
        """
        def build():
            fs = self.sample_final_state(min_samples)
            out = {}
            for s in (_DEEP, _EXEC, _ACTIVE):
                sp = np.sort(self.power[fs == s])
                top = np.concatenate([[0.0], np.cumsum(sp[::-1])])
                out[s] = (sp, top)
            pen_mask = np.repeat(self.resident_runs() & ~self.low,
                                 self.length)
            sp = np.sort(self.power[pen_mask])
            top = np.concatenate([[0.0], np.cumsum(sp[::-1])])
            top_cbrt = np.concatenate([[0.0], np.cumsum(np.cbrt(sp[::-1]))])
            out["penalty"] = (sp, top, top_cbrt)
            return out
        return self._memo(("caps", min_samples), build)

    def parking_counterfactual(self, min_samples: int) -> dict:
        """The one counterfactual every parked config shares: idle samples
        (resident & low) drop to deep-idle residency. Returns per-run cf
        states / energies plus exact wake and idle-sample counts. The
        deep-idle *power value* is platform-dependent, so energies are
        returned as ``(power_sum part, idle-sample count)`` for the caller
        to price: ``energy = keep_sum + idle_len * deep_idle_w`` per run.
        """
        def build():
            idle = self.resident_runs() & self.low
            active = self.resident_runs() & ~self.low
            cf_state = np.where(idle, _DEEP, self.state).astype(np.int8)
            keep_sum = np.where(idle, 0.0, self.power_sum)
            idle_len = np.where(idle, self.length, 0).astype(np.int64)
            wakes = int(np.sum(idle[:-1] & active[1:]))
            return {"cf_state": cf_state, "keep_sum": keep_sum,
                    "idle_len": idle_len, "wakes": wakes,
                    "idle_samples": int(np.sum(idle_len))}
        return self._memo(("park", min_samples), build)


# --------------------------------------------------------------------------- #
# Fleet-level IR
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class RunIR:
    """The whole store's run-level IR: one :class:`StreamIR` per
    job-attributed stream, plus the build config and the store row count it
    was built from (staleness check)."""

    config: IRConfig
    streams: dict[tuple[int, int, int], StreamIR]
    source_rows: int
    skipped: tuple = ()      # shard skip records from a strict=False build

    @property
    def n_rows(self) -> int:
        return sum(s.n_rows for s in self.streams.values())

    @property
    def n_runs(self) -> int:
        return sum(s.n_runs for s in self.streams.values())

    @property
    def compaction_ratio(self) -> float:
        runs = self.n_runs
        return self.n_rows / runs if runs else float("nan")

    def select(self, hosts: Iterable[str] | None = None) -> list[StreamIR]:
        """Streams in sorted-key order, optionally host-label filtered."""
        host_set = set(hosts) if hosts is not None else None
        return [self.streams[k] for k in sorted(self.streams)
                if host_set is None
                or self.streams[k].host_label in host_set]


# --------------------------------------------------------------------------- #
# Builder (streaming, mergeable — same partition contract as the replayers)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _StreamAccum:
    host_label: str
    platform_id: int
    ts_first: float
    n_seen: int = 0
    run_state: list = dataclasses.field(default_factory=list)
    run_low: list = dataclasses.field(default_factory=list)
    run_len: list = dataclasses.field(default_factory=list)
    run_sum: list = dataclasses.field(default_factory=list)
    power_pieces: list = dataclasses.field(default_factory=list)
    # trailing, possibly-unfinished run
    t_state: int = -1
    t_low: bool = False
    t_len: int = 0
    t_sum: float = 0.0


class IRBuilder:
    """Build a :class:`RunIR` from time-ordered telemetry chunks.

    Same streaming contract as the replayers (chunks may mix streams; per
    stream they arrive in time order), one classification + low-activity
    pass + run-length encoding per chunk — this is the *only* O(rows) work
    the compact path ever does, paid once per (store, IRConfig).
    ``merge`` absorbs a builder that saw a disjoint stream set (the
    process-pool reduction).
    """

    def __init__(self, config: IRConfig):
        self.config = config
        self._low_cfg = config.low_config()
        self._acc: dict[tuple[int, int, int], _StreamAccum] = {}

    def update(self, chunk: "TelemetryFrame", host_label: str = "") -> None:
        if len(chunk) == 0:
            return
        obs.counter("repro_ir_build_rows_total", float(len(chunk)),
                    help="telemetry rows run-length encoded by IRBuilder")
        for key, seg in chunk.group_streams():
            if key[0] < 0:
                continue
            self._update_segment(key, seg, host_label)

    def _update_segment(self, key, seg, host_label: str) -> None:
        n = len(seg)
        ts = np.asarray(seg["timestamp"], dtype=np.float64)
        acc = self._acc.get(key)
        if acc is None:
            acc = self._acc[key] = _StreamAccum(
                host_label=host_label,
                platform_id=int(seg["platform"][0]),
                ts_first=float(ts[0]))
        expected = acc.ts_first + self.config.dt_s * np.arange(
            acc.n_seen, acc.n_seen + n)
        if not np.array_equal(ts, expected):
            raise IRUnsupportedError(
                f"stream {key} is not regularly sampled at dt={self.config.dt_s}"
                f" (run-level IR stores offsets, not timestamps); replay this "
                f"store with compact=False")
        states = classify_series(
            seg["program_resident"].astype(bool),
            seg.activity_pct(),
            seg.comm_gbs(),
            self.config.classifier,
        )
        low = low_activity_series(seg, self._low_cfg)
        power = np.asarray(seg["power"], dtype=np.float64)
        acc.power_pieces.append(power)
        acc.n_seen += n

        code = states.astype(np.int16) * 2 + low
        change = np.flatnonzero(np.diff(code)) + 1
        starts = np.concatenate([[0], change]).astype(np.int64)
        ends = np.concatenate([change, [n]]).astype(np.int64)
        sums = np.add.reduceat(power, starts)
        first = 0
        if acc.t_len and acc.t_state == int(states[0]) \
                and acc.t_low == bool(low[0]):
            acc.t_len += int(ends[0] - starts[0])
            acc.t_sum += float(sums[0])
            first = 1
        for i in range(first, starts.shape[0]):
            if acc.t_len:
                acc.run_state.append(acc.t_state)
                acc.run_low.append(acc.t_low)
                acc.run_len.append(acc.t_len)
                acc.run_sum.append(acc.t_sum)
            acc.t_state = int(states[starts[i]])
            acc.t_low = bool(low[starts[i]])
            acc.t_len = int(ends[i] - starts[i])
            acc.t_sum = float(sums[i])

    def merge(self, other: "IRBuilder") -> "IRBuilder":
        overlap = self._acc.keys() & other._acc.keys()
        if overlap:
            raise ValueError(f"cannot merge IR builders with overlapping "
                             f"streams: {sorted(overlap)[:3]}...")
        if other.config != self.config:
            raise ValueError("cannot merge IR builders with different configs")
        self._acc.update(other._acc)
        return self

    def finalize(self, source_rows: int = 0) -> RunIR:
        streams: dict[tuple[int, int, int], StreamIR] = {}
        for key in sorted(self._acc):
            acc = self._acc[key]
            if acc.t_len:
                acc.run_state.append(acc.t_state)
                acc.run_low.append(acc.t_low)
                acc.run_len.append(acc.t_len)
                acc.run_sum.append(acc.t_sum)
                acc.t_len = 0
            streams[key] = StreamIR(
                key=key,
                host_label=acc.host_label,
                platform_id=acc.platform_id,
                ts_first=acc.ts_first,
                dt_s=self.config.dt_s,
                state=np.array(acc.run_state, dtype=np.int8),
                low=np.array(acc.run_low, dtype=bool),
                length=np.array(acc.run_len, dtype=np.int64),
                power_sum=np.array(acc.run_sum, dtype=np.float64),
                power=(np.concatenate(acc.power_pieces)
                       if acc.power_pieces else np.empty(0)),
            )
        self._acc.clear()
        return RunIR(config=self.config, streams=streams,
                     source_rows=source_rows)


def _build_partition(root: str, shard_files: list[str], config: IRConfig,
                     mmap: bool, strict: bool = True,
                     verify: bool = False) -> tuple[IRBuilder, list[dict]]:
    """Process-pool worker body (module-level picklable)."""
    from repro.telemetry.storage import TelemetryStore
    store = TelemetryStore(root)
    host_of = {s["file"]: s["host"] for s in store.manifest["shards"]}
    builder = IRBuilder(config)
    skips: list[dict] = []
    for name in shard_files:
        frame = store.read_shard_or_skip(name, skips, mmap=mmap,
                                         strict=strict, verify=verify)
        if frame is not None:
            builder.update(frame, host_label=host_of.get(name, ""))
    return builder, skips


def build_ir(store: "TelemetryStore", config: IRConfig | None = None,
             workers: int = 1, mmap: bool = False, strict: bool = True,
             verify: bool = False, fault=None) -> RunIR:
    """One O(rows) pass over the store: group, classify, low-flag, RLE.

    ``workers > 1`` partitions by host label exactly like the sweep; the
    result is identical for any worker count (per-stream decomposition is
    independent, streams are reassembled in sorted order).

    ``strict=False`` skips unreadable shards (recorded in
    :attr:`RunIR.skipped`) instead of raising — note a skipped mid-stream
    shard usually makes its streams irregular, so the build then raises
    :class:`IRUnsupportedError` and callers replay through the row path,
    exactly as they would on the clean shard subset.
    """
    from repro.telemetry.pipeline import map_shard_partitions
    config = config or IRConfig()
    t0 = time.perf_counter()
    with obs.span("ir.build", workers=workers):
        builder, skips = map_shard_partitions(
            store, None, workers, _build_partition,
            (config, mmap, strict, verify),
            merge=lambda a, b: a.merge(b), stage="ir_build", fault=fault)
        ir = builder.finalize(source_rows=store.total_rows)
        ir.skipped = tuple(skips)
    if obs.enabled():
        obs.counter("repro_ir_builds_total", help="fresh IR builds")
        obs.observe("repro_ir_build_seconds", time.perf_counter() - t0,
                    help="wall time of build_ir")
        obs.gauge("repro_ir_runs", float(ir.n_runs),
                  help="runs in the last-built IR")
        obs.gauge("repro_ir_rows", float(ir.n_rows),
                  help="source rows of the last-built IR")
        if ir.n_runs:
            obs.gauge("repro_ir_compaction_ratio", ir.compaction_ratio,
                      help="rows per run in the last-built IR")
    return ir


# --------------------------------------------------------------------------- #
# Policy support
# --------------------------------------------------------------------------- #
def _low_pair(policy: Policy) -> tuple[float, float] | None:
    if isinstance(policy, (DownscalePolicy, ParkingPolicy, PowerCapPolicy)):
        return (policy.config.activity_threshold,
                policy.config.comm_threshold_gbs)
    if isinstance(policy, CompositePolicy):
        pairs = {_low_pair(p) for p in policy.parts}
        pairs.discard(None)
        if len(pairs) == 1:
            return next(iter(pairs))
    return None


def ir_supported(policy: Policy, config: IRConfig) -> bool:
    """Can ``policy`` replay against an IR built with ``config``?

    Leaf families must share the IR's low-activity thresholds (the run
    decomposition bakes the flag in); composites must be the known
    parking-then-downscale shape (each part's effect stays run-structured
    because they touch disjoint residency); anything else — custom policies,
    other composite orders — replays through the row path.
    """
    pair = (config.activity_threshold, config.comm_threshold_gbs)
    if isinstance(policy, NoOpPolicy):
        return True
    if isinstance(policy, (DownscalePolicy, ParkingPolicy, PowerCapPolicy)):
        return _low_pair(policy) == pair
    if isinstance(policy, CompositePolicy):
        return (len(policy.parts) == 2
                and isinstance(policy.parts[0], ParkingPolicy)
                and isinstance(policy.parts[1], DownscalePolicy)
                and _low_pair(policy) == pair)
    return False


def ir_config_for(policies: Iterable[Policy],
                  classifier: ClassifierConfig = DEFAULT_CLASSIFIER,
                  dt_s: float = 1.0) -> IRConfig:
    """The :class:`IRConfig` covering the most grid configs: the modal
    low-threshold pair among the policies (ties broken deterministically
    by pair value); configs on other pairs fall back to the row path."""
    counts: dict[tuple[float, float], int] = {}
    for p in policies:
        pair = _low_pair(p)
        if pair is not None:
            counts[pair] = counts.get(pair, 0) + 1
    if not counts:
        pair = (ControllerConfig.activity_threshold,
                ControllerConfig.comm_threshold_gbs)
    else:
        pair = max(sorted(counts), key=lambda k: counts[k])
    return IRConfig(classifier=classifier, activity_threshold=pair[0],
                    comm_threshold_gbs=pair[1], dt_s=dt_s)


# --------------------------------------------------------------------------- #
# Sidecar persistence (next to the store's shards, keyed in the manifest)
# --------------------------------------------------------------------------- #
def sidecar_name(config: IRConfig) -> str:
    return f"run_ir_{config.config_hash()}.npz"


def save_sidecar(ir: RunIR, store: "TelemetryStore") -> pathlib.Path:
    """Persist the IR next to the shards and key it in the manifest.

    Format: one compressed ``.npz`` holding the stream table (keys, host
    labels, platforms, first timestamps, run/sample counts), the
    concatenated run arrays (state/low/length/power_sum) and the
    concatenated power samples; ``meta`` embeds the :class:`IRConfig` and
    the source row count. ``manifest["run_ir"][hash]`` points at the file —
    a changed classifier config hashes to a different sidecar, an appended
    store invalidates via ``source_rows``.
    """
    streams = [ir.streams[k] for k in sorted(ir.streams)]
    meta = json.dumps({"config": ir.config.to_dict(),
                       "source_rows": ir.source_rows,
                       "skipped": list(ir.skipped)})
    arrays = {
        "meta": np.array(meta),
        "job": np.array([s.key[0] for s in streams], dtype=np.int64),
        "host": np.array([s.key[1] for s in streams], dtype=np.int64),
        "dev": np.array([s.key[2] for s in streams], dtype=np.int64),
        "host_label": np.array([s.host_label for s in streams]),
        "platform": np.array([s.platform_id for s in streams], dtype=np.int64),
        "ts_first": np.array([s.ts_first for s in streams]),
        "n_runs": np.array([s.n_runs for s in streams], dtype=np.int64),
        "n_rows": np.array([s.n_rows for s in streams], dtype=np.int64),
        "state": (np.concatenate([s.state for s in streams])
                  if streams else np.empty(0, np.int8)),
        "low": (np.concatenate([s.low for s in streams])
                if streams else np.empty(0, bool)),
        "length": (np.concatenate([s.length for s in streams])
                   if streams else np.empty(0, np.int64)),
        "power_sum": (np.concatenate([s.power_sum for s in streams])
                      if streams else np.empty(0)),
        "power": (np.concatenate([s.power for s in streams])
                  if streams else np.empty(0)),
    }
    name = sidecar_name(ir.config)
    path = store.root / name
    # commit through storage.atomic_replace: a process killed mid-write
    # leaves the previous sidecar (or none) fully intact, never a torn file
    from repro.telemetry import storage as storage_mod
    storage_mod._write_atomic_npz(path, arrays)
    entry = {"file": name, "source_rows": ir.source_rows,
             "config": ir.config.to_dict()}
    # atomic single-key merge: a concurrent appender's shard entries must
    # survive this derived-data write (see TelemetryStore.merge_manifest_key)
    store.merge_manifest_key(MANIFEST_KEY, ir.config.config_hash(), entry)
    return path


#: everything a torn/bit-flipped sidecar or poisoned manifest subtree can
#: raise through np.load/json/entry access — all mapped to "rebuild"
_SIDECAR_ERRORS = (zipfile.BadZipFile, zlib.error, ValueError, KeyError,
                   TypeError, OSError, EOFError)


def load_sidecar(store: "TelemetryStore",
                 config: IRConfig) -> RunIR | None:
    """Load a sidecar if a *fresh* one exists: the manifest must key this
    config's hash and the persisted ``source_rows`` must still equal the
    store's row count (an appended store silently invalidates).

    Tolerant by construction: a poisoned manifest subtree, a missing file,
    or a corrupt/truncated archive (``BadZipFile``, CRC errors, bad JSON
    meta) is counted as a ``sidecar -> rebuild`` fallback, the bad file is
    deleted, and ``None`` is returned so the caller rebuilds from shards —
    derived data is never allowed to take down the pipeline."""
    raw = store.manifest.get(MANIFEST_KEY)
    entry = raw.get(config.config_hash()) if isinstance(raw, dict) else None
    if not isinstance(entry, dict):
        return None
    try:
        if int(entry["source_rows"]) != store.total_rows:
            obs.counter("repro_ir_cache_invalidations_total", level="sidecar",
                        help="cached IRs rejected as stale")
            return None
        path = store.root / str(entry["file"])
    except _SIDECAR_ERRORS:
        obs.fallback("sidecar", "rebuild", "bad_manifest_entry")
        return None
    if not path.exists():
        return None
    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            src_rows = int(meta["source_rows"])
            skipped = tuple(meta.get("skipped", ()))
            loaded_cfg = IRConfig.from_dict(meta["config"])
            if loaded_cfg != config:
                obs.counter("repro_ir_cache_invalidations_total",
                            level="sidecar",
                            help="cached IRs rejected as stale")
                return None
            run_off = np.concatenate(
                [[0], np.cumsum(z["n_runs"])]).astype(np.int64)
            row_off = np.concatenate(
                [[0], np.cumsum(z["n_rows"])]).astype(np.int64)
            streams: dict[tuple[int, int, int], StreamIR] = {}
            for i in range(z["job"].shape[0]):
                r0, r1 = run_off[i], run_off[i + 1]
                p0, p1 = row_off[i], row_off[i + 1]
                key = (int(z["job"][i]), int(z["host"][i]), int(z["dev"][i]))
                streams[key] = StreamIR(
                    key=key,
                    host_label=str(z["host_label"][i]),
                    platform_id=int(z["platform"][i]),
                    ts_first=float(z["ts_first"][i]),
                    dt_s=config.dt_s,
                    state=z["state"][r0:r1].astype(np.int8),
                    low=z["low"][r0:r1].astype(bool),
                    length=z["length"][r0:r1].astype(np.int64),
                    power_sum=np.array(z["power_sum"][r0:r1]),
                    power=np.array(z["power"][p0:p1]),
                )
    except _SIDECAR_ERRORS as e:
        obs.fallback("sidecar", "rebuild", type(e).__name__)
        try:
            path.unlink(missing_ok=True)
        except OSError:
            pass
        return None
    return RunIR(config=config, streams=streams,
                 source_rows=src_rows, skipped=skipped)


#: in-process cache: (resolved store root, config hash) -> RunIR. An IR
#: pins the store's power column (~8 bytes/row) plus the run tables in
#: memory, so the cache is a small LRU rather than unbounded.
_IR_CACHE: dict[tuple[str, str], RunIR] = {}
_IR_CACHE_MAX = 4
#: negative cache: builds that raised IRUnsupportedError, keyed with the
#: row count they failed at — a search over an irregular store fails the
#: build once, not once per refinement round
_IR_UNSUPPORTED: dict[tuple[str, str], tuple[int, str]] = {}


def get_ir(store: "TelemetryStore", config: IRConfig | None = None,
           workers: int = 1, mmap: bool = False,
           persist: bool = True, strict: bool = True,
           verify: bool = False, fault=None) -> RunIR:
    """The IR acquisition ladder: in-memory cache, then sidecar, then a
    fresh build (persisted back as a sidecar unless ``persist=False`` or
    the store root is not writable). Every level validates freshness
    against ``store.total_rows``; a store whose build failed
    (:class:`IRUnsupportedError`, e.g. irregular sampling) re-raises from
    a negative cache until the store changes, so callers that fall back to
    the row path don't pay a doomed O(rows) build per call.

    Cache hits additionally require that a cached IR built with skipped
    shards (``strict=False`` on a dirty store) is never served to a
    ``strict=True`` caller — degraded derived data must not silently
    masquerade as complete."""
    config = config or IRConfig()
    cache_key = (str(pathlib.Path(store.root).resolve()),
                 config.config_hash())
    failed = _IR_UNSUPPORTED.get(cache_key)
    if failed is not None and failed[0] == store.total_rows:
        obs.counter("repro_ir_negative_cache_hits_total",
                    help="IR builds skipped via the unsupported-store cache")
        raise IRUnsupportedError(failed[1])
    ir = _IR_CACHE.get(cache_key)
    if ir is not None:
        if ir.source_rows == store.total_rows and not (ir.skipped and strict):
            obs.counter("repro_ir_cache_hits_total", level="memory",
                        help="IR acquisitions served from a cache level")
            _IR_CACHE.pop(cache_key)
            _IR_CACHE[cache_key] = ir       # refresh LRU recency
            return ir
        obs.counter("repro_ir_cache_invalidations_total", level="memory",
                    help="cached IRs rejected as stale")
    ir = load_sidecar(store, config)
    if ir is not None and ir.skipped and strict:
        obs.counter("repro_ir_cache_invalidations_total", level="sidecar",
                    help="cached IRs rejected as stale")
        ir = None
    if ir is not None:
        obs.counter("repro_ir_cache_hits_total", level="sidecar",
                    help="IR acquisitions served from a cache level")
    else:
        obs.counter("repro_ir_cache_misses_total",
                    help="IR acquisitions that required a fresh build")
        try:
            ir = build_ir(store, config, workers=workers, mmap=mmap,
                          strict=strict, verify=verify, fault=fault)
        except IRUnsupportedError as e:
            _IR_UNSUPPORTED[cache_key] = (store.total_rows, str(e))
            raise
        if persist:
            try:
                save_sidecar(ir, store)
            except OSError:
                pass                    # read-only store: memory cache only
    _IR_CACHE.pop(cache_key, None)
    _IR_CACHE[cache_key] = ir
    while len(_IR_CACHE) > _IR_CACHE_MAX:      # LRU: dicts keep insert order
        _IR_CACHE.pop(next(iter(_IR_CACHE)))
    return ir

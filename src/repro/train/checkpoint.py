"""Step-atomic checkpointing with elastic restore (mesh-independent).

Layout:
    <dir>/step_000123/
        arrays.npz          # flattened leaf -> array (host-gathered)
        manifest.json       # treedef paths, shapes, dtypes, step, mesh info
    <dir>/LATEST            # atomic pointer file (written last)

Restore targets any mesh: arrays are loaded on host and ``jax.device_put``
with the *new* mesh's NamedShardings (elastic re-shard). Failure recovery =
read LATEST, load, continue; a crashed half-written step directory is ignored
because LATEST moves only after a complete write.
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import pathlib
import shutil

import jax
import numpy as np

from repro.distributed.context import DistContext


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys, leaves = [], []
    for path, leaf in flat:
        keys.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return keys, leaves, treedef


def save(directory: str | pathlib.Path, step: int, params, opt_state,
         extra: dict | None = None) -> pathlib.Path:
    root = pathlib.Path(directory)
    step_dir = root / f"step_{step:08d}"
    tmp_dir = root / f".tmp_step_{step:08d}"
    if tmp_dir.exists():
        shutil.rmtree(tmp_dir)
    tmp_dir.mkdir(parents=True)

    state = {"params": params, "opt_state": opt_state}
    keys, leaves, _ = _flatten_with_paths(state)
    arrays = {}
    for i, x in enumerate(leaves):
        a = np.asarray(jax.device_get(x))
        # npz cannot round-trip ml_dtypes (bf16 etc.); store as f32 and let
        # restore cast back to the model dtype recorded in `dtypes`.
        if a.dtype.kind not in "fiub?":
            a = a.astype(np.float32)
        elif a.dtype == np.float16 or str(a.dtype) == "bfloat16":
            a = a.astype(np.float32)
        arrays[f"a{i}"] = a
    np.savez(tmp_dir / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "keys": keys,
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "extra": extra or {},
    }
    (tmp_dir / "manifest.json").write_text(json.dumps(manifest))
    if step_dir.exists():
        shutil.rmtree(step_dir)
    tmp_dir.rename(step_dir)
    (root / "LATEST").write_text(step_dir.name)       # atomic pointer last
    return step_dir


def latest_step(directory: str | pathlib.Path) -> int | None:
    root = pathlib.Path(directory)
    pointer = root / "LATEST"
    if not pointer.exists():
        return None
    name = pointer.read_text().strip()
    if not (root / name / "manifest.json").exists():
        return None
    return int(name.split("_")[1])


def restore(directory: str | pathlib.Path, like_params, like_opt_state,
            dist: DistContext | None = None, param_shardings=None,
            opt_shardings=None, step: int | None = None):
    """Load the checkpoint onto (possibly different) mesh/shardings.

    ``like_*`` give the target tree structure; ``*_shardings`` (optional
    NamedSharding trees) trigger elastic re-shard via device_put.
    Returns (params, opt_state, step).
    """
    root = pathlib.Path(directory)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    step_dir = root / f"step_{step:08d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())

    like = {"params": like_params, "opt_state": like_opt_state}
    keys, like_leaves, treedef = _flatten_with_paths(like)
    if keys != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(keys)
        raise ValueError(f"checkpoint/model structure mismatch: {sorted(missing)[:5]}...")

    with np.load(step_dir / "arrays.npz") as z:
        arrays = [z[f"a{i}"] for i in range(len(keys))]

    shardings = None
    if param_shardings is not None and opt_shardings is not None:
        sh = {"params": param_shardings, "opt_state": opt_shardings}
        _, sh_leaves, _ = _flatten_with_paths(sh)
        shardings = sh_leaves

    out_leaves = []
    for i, (arr, like_leaf) in enumerate(zip(arrays, like_leaves)):
        target_dtype = jnp.dtype(like_leaf.dtype)
        if arr.dtype != target_dtype:
            arr = jnp.asarray(arr).astype(target_dtype)
        if shardings is not None:
            out_leaves.append(jax.device_put(arr, shardings[i]))
        else:
            out_leaves.append(jax.device_put(arr))
    state = jax.tree_util.tree_unflatten(treedef, out_leaves)
    return state["params"], state["opt_state"], step

"""Optimizers (pure-functional, no optax dependency).

* **AdamW** — f32 master weights + f32 first/second moments (12 B/param of
  state on top of the bf16 compute params).
* **Adafactor** — factored second moment (row/col statistics), no first
  moment, f32 master weights (~4 B/param of state). Used for deepseek-v3-671b
  and llama-3.2-vision-90b, whose Adam state cannot fit 256 x 16 GiB chips
  (see DESIGN.md §6 / EXPERIMENTS.md §Dry-run).

API:
    opt = adamw(lr=...) | adafactor(lr=...)
    state = opt.init(params)
    new_params, new_state, stats = opt.step(params, grads, state)
    specs = opt.state_specs(param_spec_tree, abstract_params)
State trees mirror the param tree, so param PartitionSpecs apply leaf-wise
(factored stats drop one dim and inherit the compatible prefix spec).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class Optimizer(NamedTuple):
    init: Callable
    step: Callable
    state_specs: Callable  # (param_specs, abstract_params) -> state spec tree


def _global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads), norm


def _zip_apply(fn, *trees):
    """Apply fn leaf-wise across trees whose structures match tree[0];
    fn returns a tuple; returns a tuple of trees."""
    flat0, treedef = jax.tree.flatten(trees[0])
    flats = [flat0] + [treedef.flatten_up_to(t) for t in trees[1:]]
    outs = [fn(*leaves) for leaves in zip(*flats)]
    n_out = len(outs[0])
    return tuple(jax.tree.unflatten(treedef, [o[i] for o in outs])
                 for i in range(n_out))


# --------------------------------------------------------------------------- #
# AdamW
# --------------------------------------------------------------------------- #
def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        return {
            "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def step(params, grads, state):
        grads, gnorm = _clip_by_global_norm(grads, grad_clip)
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c

        def upd(master, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            new_master = master - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                                        + weight_decay * master)
            return new_master, m, v

        master, m, v = _zip_apply(upd, state["master"], grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda ms, p: ms.astype(p.dtype), master, params)
        return new_params, {"master": master, "m": m, "v": v, "count": count}, \
            {"grad_norm": gnorm}

    def state_specs(param_specs, abstract_params):
        return {"master": param_specs, "m": param_specs, "v": param_specs,
                "count": P()}

    return Optimizer(init=init, step=step, state_specs=state_specs)


# --------------------------------------------------------------------------- #
# Adafactor (factored second moment, beta1 = 0)
# --------------------------------------------------------------------------- #
def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              weight_decay: float = 0.0, grad_clip: float = 1.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    def _factored(shape) -> bool:
        return (len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor
                and shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def stats(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {
            "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
            "stats": jax.tree.map(stats, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def step(params, grads, state):
        grads, gnorm = _clip_by_global_norm(grads, grad_clip)
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        beta2 = 1.0 - c ** (-decay)

        def upd(master, g, st):
            g2 = jnp.square(g) + eps
            if _factored(g.shape):
                vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                v = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                new_st = {"v": v}
            update = g * jax.lax.rsqrt(v + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(update)) + eps)
            update = update / jnp.maximum(1.0, rms)
            return master - lr * (update + weight_decay * master), new_st

        is_stats = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        flat_m, treedef = jax.tree.flatten(state["master"])
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(
            jax.tree.map(lambda s: s, state["stats"], is_leaf=is_stats))
        new_m, new_s = [], []
        for ms, g, st in zip(flat_m, flat_g, flat_s):
            nm, ns = upd(ms, g, st)
            new_m.append(nm)
            new_s.append(ns)
        master = jax.tree.unflatten(treedef, new_m)
        stats = jax.tree.unflatten(treedef, new_s)
        new_params = jax.tree.map(lambda ms, p: ms.astype(p.dtype), master, params)
        return new_params, {"master": master, "stats": stats, "count": count}, \
            {"grad_norm": gnorm}

    def state_specs(param_specs, abstract_params):
        def stats_spec(spec, leaf):
            axes = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
            if _factored(leaf.shape):
                return {"vr": P(*axes[:-1]), "vc": P(*(axes[:-2] + (axes[-1],)))}
            return {"v": P(*axes)}

        flat_spec, treedef = jax.tree.flatten(
            param_specs, is_leaf=lambda x: isinstance(x, P))
        flat_params = treedef.flatten_up_to(abstract_params)
        stats = jax.tree.unflatten(
            treedef, [stats_spec(s, p) for s, p in zip(flat_spec, flat_params)])
        return {"master": param_specs, "stats": stats, "count": P()}

    return Optimizer(init=init, step=step, state_specs=state_specs)


def for_arch(arch_name: str, lr: float = 3e-4) -> Optimizer:
    """Giant archs get Adafactor (memory); everything else AdamW."""
    if arch_name.startswith(("deepseek-v3", "llama-3.2-vision")):
        return adafactor(lr=lr)
    return adamw(lr=lr)

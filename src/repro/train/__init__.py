"""Training substrate: optimizers, checkpointing, data pipeline, trainer."""

"""Training loop with first-class execution-idle telemetry + fault tolerance.

The trainer is where the paper's technique integrates with training:
every step reports busy/idle phases to a :class:`RuntimeSampler`; an optional
:class:`ExecutionIdleController` (Algorithm 1) watches those samples and
downscales the (simulated) device clocks during sustained input-pipeline or
checkpoint stalls — turning the paper's serving-centric controller into a
training-side guard against PCIe/NIC-preceded execution-idle (§4.5).

Fault tolerance:
* step-atomic checkpoints every ``checkpoint_every`` steps (train.checkpoint),
* automatic resume from LATEST,
* straggler mitigation — per-step deadline (k x running median); steps
  breaching it are logged and (simulated) the slow replica's contribution is
  skipped for that step (gradient from remaining replicas; in this
  single-process harness the skip is recorded, not physically partitioned),
* optional int8+EF gradient compression across the pod axis.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.controller import ExecutionIdleController
from repro.core.power_model import SimulatedDevice, get_platform
from repro.distributed import sharding as shd
from repro.distributed.compression import make_compressed_allreduce
from repro.distributed.context import DistContext, LOCAL
from repro.models import api
from repro.telemetry.sampler import RuntimeSampler
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.data import SyntheticDataset


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    straggler_deadline_factor: float = 3.0
    grad_compression: str | None = None     # None | "int8"
    lr: float = 3e-4
    telemetry: bool = True
    #: utilization the power model sees during a step (roofline-informed)
    step_compute_util: float = 0.85
    step_hbm_util: float = 0.55


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_loss: float
    losses: list[float]
    straggler_events: int
    resumed_from: int | None
    telemetry_rows: int
    wall_s: float


def make_train_step(cfg: ModelConfig, optimizer, dist: DistContext = LOCAL):
    """Returns a jit'd (params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            api.loss_fn, has_aux=True)(params, batch, cfg, dist)
        params, opt_state, stats = optimizer.step(params, grads, opt_state)
        metrics = dict(metrics, **stats)
        return params, opt_state, metrics

    if not dist.enabled:
        return jax.jit(step_fn)

    from repro.models import common as cm
    cm.set_shard_hook(shd.make_shard_hook(cfg, dist))
    abstract = api.abstract_params(cfg, ep_size=dist.ep_size)
    p_specs = shd.param_specs(abstract, dist)
    o_specs = optimizer.state_specs(p_specs, abstract)
    b_specs = shd.batch_specs(cfg, dist)
    return jax.jit(
        step_fn,
        in_shardings=(shd.named(dist, p_specs), shd.named(dist, o_specs),
                      shd.named(dist, b_specs)),
        out_shardings=(shd.named(dist, p_specs), shd.named(dist, o_specs), None),
        donate_argnums=(0, 1),
    )


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainerConfig,
                 dist: DistContext = LOCAL, global_batch: int = 8,
                 seq_len: int = 128, platform: str = "tpu_v5e",
                 controller: bool = False, seed: int = 0):
        self.cfg = cfg
        self.tc = tc
        self.dist = dist
        self.optimizer = opt_mod.for_arch(cfg.name, lr=tc.lr)
        self.dataset = SyntheticDataset(cfg, global_batch, seq_len, seed=seed)
        self.step_fn = make_train_step(cfg, self.optimizer, dist)
        self.device = SimulatedDevice(get_platform(platform))
        self.sampler = RuntimeSampler(self.device, job_id=1)
        self.controller = (ExecutionIdleController(self.device)
                           if controller else None)
        key = jax.random.PRNGKey(seed)
        self.params = api.init_params(key, cfg, ep_size=dist.ep_size)
        self.opt_state = self.optimizer.init(self.params)

    # ------------------------------------------------------------------ #
    def _telemetry_tick(self, busy_s: float, idle_s: float) -> None:
        if not self.tc.telemetry:
            return
        s = self.sampler
        if busy_s > 0:
            s.busy(busy_s, compute_util=self.tc.step_compute_util,
                   hbm_util=self.tc.step_hbm_util)
        if idle_s > 0:
            s.idle(idle_s, pcie_gbs=0.2, cpu_util=0.4)  # input-pipeline wait
        if self.controller is not None:
            frame = s.frame()
            if len(frame):
                row = frame.row(len(frame) - 1)
                self.controller.step(s.now, {
                    "sm": float(row["sm"]) / 100.0,
                    "dram": float(row["dram"]) / 100.0,
                    "pcie_rx": float(row["pcie_rx"]),
                })

    def run(self) -> TrainReport:
        tc = self.tc
        resumed_from = None
        start_step = 0
        if tc.checkpoint_dir and ckpt.latest_step(tc.checkpoint_dir) is not None:
            self.params, self.opt_state, start_step = ckpt.restore(
                tc.checkpoint_dir, self.params, self.opt_state)
            resumed_from = start_step

        self.sampler.load_program()
        losses: list[float] = []
        step_times: list[float] = []
        stragglers = 0
        t0 = time.monotonic()

        for step in range(start_step, tc.steps):
            fetch_t0 = time.monotonic()
            batch = self.dataset.device_batch_at(step)
            fetch_s = time.monotonic() - fetch_t0

            step_t0 = time.monotonic()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            step_s = time.monotonic() - step_t0
            losses.append(loss)
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")

            # straggler mitigation: deadline = k x running median
            step_times.append(step_s)
            if len(step_times) >= 5:
                median = float(np.median(step_times[-20:]))
                if step_s > tc.straggler_deadline_factor * median:
                    stragglers += 1

            self._telemetry_tick(busy_s=step_s, idle_s=fetch_s)

            if tc.checkpoint_dir and (step + 1) % tc.checkpoint_every == 0:
                ck_t0 = time.monotonic()
                ckpt.save(tc.checkpoint_dir, step + 1, self.params, self.opt_state)
                self._telemetry_tick(busy_s=0.0,
                                     idle_s=time.monotonic() - ck_t0)

        self.sampler.unload_program()
        return TrainReport(
            steps_run=tc.steps - start_step,
            final_loss=losses[-1] if losses else float("nan"),
            losses=losses,
            straggler_events=stragglers,
            resumed_from=resumed_from,
            telemetry_rows=len(self.sampler.frame()),
            wall_s=time.monotonic() - t0,
        )

"""Deterministic synthetic data pipeline (token stream + modality stubs).

Produces the same global batch for a given (seed, step) on any topology —
restart/elastic-safe — with host-side generation (cheap threefry via numpy)
and device_put onto the batch shardings. Injects configurable host-side
latency to emulate input-pipeline stalls (the paper's PCIe/NIC-preceded
execution-idle states come largely from exactly this path, §4.5).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticDataset:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    #: emulated host-side fetch latency per batch (s); 0 disables
    fetch_latency_s: float = 0.0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        if self.fetch_latency_s > 0:
            time.sleep(self.fetch_latency_s)
        tokens = rng.integers(0, self.cfg.vocab_size,
                              (self.global_batch, self.seq_len + 1),
                              dtype=np.int32)
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if self.cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (self.global_batch, self.cfg.n_frames, self.cfg.d_model),
                dtype=np.float32)
        if self.cfg.family == "vlm":
            out["vision"] = rng.standard_normal(
                (self.global_batch, self.cfg.n_vision_tokens, self.cfg.d_model),
                dtype=np.float32)
        return out

    def device_batch_at(self, step: int, shardings=None):
        host = self.batch_at(step)
        if shardings is None:
            return jax.tree.map(jax.device_put, host)
        return {k: jax.device_put(v, shardings[k]) for k, v in host.items()}

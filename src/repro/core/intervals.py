"""Sustained-interval extraction over classified state series (paper §2.2, §4.4).

The paper counts an execution-idle interval only when the low-activity
condition holds *continuously* for at least ``min_duration_s`` (5 s baseline;
1 s permissive / 10 s conservative in Table 2). Intervals shorter than the
threshold are re-labelled as part of the surrounding execution (ACTIVE) for
accounting purposes, mirroring the paper's conservative quantification.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.states import DeviceState


@dataclasses.dataclass(frozen=True)
class Interval:
    """A maximal run of one state. ``start``/``end`` are sample indices,
    end-exclusive; with 1 Hz sampling they equal seconds."""

    state: DeviceState
    start: int
    end: int

    @property
    def duration(self) -> int:
        return self.end - self.start

    def __post_init__(self) -> None:
        if self.end <= self.start:
            raise ValueError(f"empty interval [{self.start}, {self.end})")


def runs(states: np.ndarray) -> Iterator[Interval]:
    """Yield maximal constant runs of a state series."""
    states = np.asarray(states)
    if states.size == 0:
        return
    change = np.flatnonzero(np.diff(states)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [states.size]])
    for s, e in zip(starts, ends):
        yield Interval(DeviceState(int(states[s])), int(s), int(e))


@dataclasses.dataclass
class RunCarry:
    """Trailing run of a chunked state stream, not yet known to be maximal.

    Carried across chunk boundaries so that a run spanning two (or more)
    chunks is seen as ONE maximal run, exactly as the monolithic
    :func:`runs` would see it on the concatenated series. ``start`` is a
    global sample index; ``state`` is -1 when no run is pending.
    """

    state: int = -1
    start: int = 0
    length: int = 0


def runs_streaming(
    states: np.ndarray,
    carry: RunCarry,
    offset: int,
) -> tuple[list[tuple[int, int, int]], RunCarry]:
    """Boundary-aware run decomposition of one chunk.

    Args:
        states: int array [T] — this chunk's classified states.
        carry: pending trailing run from the previous chunks.
        offset: global sample index of this chunk's first sample
            (must equal ``carry.start + carry.length`` when a run is pending).

    Returns:
        ``(completed, carry_out)`` where ``completed`` is a list of
        ``(state, global_start, global_end)`` maximal runs finished within
        this chunk, in time order, and ``carry_out`` is the new trailing run.
        Feeding chunks of any size yields the exact same sequence of completed
        runs (after a final carry flush) as :func:`runs` on the full series.
    """
    states = np.asarray(states)
    n = states.shape[0]
    if n == 0:
        return [], carry
    change = np.flatnonzero(np.diff(states)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [n]])

    completed: list[tuple[int, int, int]] = []
    first = 0
    if carry.length:
        if carry.state == int(states[0]):
            if starts.size == 1:        # whole chunk continues the carry
                return [], RunCarry(carry.state, carry.start, carry.length + n)
            completed.append((carry.state, carry.start, offset + int(ends[0])))
            first = 1
        else:                           # carry ended exactly at the boundary
            completed.append((carry.state, carry.start, carry.start + carry.length))
    for i in range(first, starts.size - 1):
        completed.append((int(states[starts[i]]),
                          offset + int(starts[i]), offset + int(ends[i])))
    last = starts.size - 1
    carry_out = RunCarry(int(states[starts[last]]), offset + int(starts[last]),
                         int(ends[last] - starts[last]))
    return completed, carry_out


def extract_intervals(
    states: np.ndarray,
    state: DeviceState = DeviceState.EXECUTION_IDLE,
    min_duration_s: float = 5.0,
    dt_s: float = 1.0,
) -> list[Interval]:
    """All maximal runs of ``state`` lasting at least ``min_duration_s``."""
    min_samples = int(np.ceil(min_duration_s / dt_s))
    return [r for r in runs(states) if r.state == state and r.duration >= min_samples]


def apply_min_duration(
    states: np.ndarray,
    min_duration_s: float = 5.0,
    dt_s: float = 1.0,
    short_relabel: DeviceState = DeviceState.ACTIVE,
) -> np.ndarray:
    """Return a copy of ``states`` where EXECUTION_IDLE runs shorter than the
    sustain threshold are relabelled (conservative accounting, §2.2).

    Deep-idle runs are never relabelled — they are not transient DVFS events.
    """
    out = np.asarray(states).copy()
    min_samples = int(np.ceil(min_duration_s / dt_s))
    for r in runs(out):
        if r.state == DeviceState.EXECUTION_IDLE and r.duration < min_samples:
            out[r.start : r.end] = int(short_relabel)
    return out


def duration_percentiles(
    intervals: list[Interval], percentiles=(50, 90, 99), dt_s: float = 1.0
) -> dict[float, float]:
    """Duration percentiles in seconds over a set of intervals (Fig 8)."""
    if not intervals:
        return {float(p): float("nan") for p in percentiles}
    durations = np.array([iv.duration * dt_s for iv in intervals], dtype=np.float64)
    return {float(p): float(np.percentile(durations, p)) for p in percentiles}


def interval_count(states: np.ndarray, min_duration_s: float = 5.0, dt_s: float = 1.0) -> int:
    return len(extract_intervals(states, DeviceState.EXECUTION_IDLE, min_duration_s, dt_s))

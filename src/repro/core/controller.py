"""Algorithm 1 — Execution-Idle-Aware Frequency Control (paper §5.3).

Faithful transcription of the paper's controller:

    Require: threshold X, cooldown Y, clocks f_max, f_min
    c <- 0, t_cooldown <- 0, downscaled <- false
    for each eps-second control interval at time t:
        read sm, tensor, fp16, dram, pcie, nvlink, ...
        a_comp <- max(sm, tensor, fp16, ...)
        a_mem  <- dram
        a_comm <- max(pcie, nvlink)
        if a_comp < 0.05 and a_mem < 0.05 and a_comm < 1 GB/s:
            c <- c + 1
        else:
            c <- 0
            if downscaled:
                set GPU clock to f_max; downscaled <- false
                t_cooldown <- t + Y
        if c > X and t >= t_cooldown and not downscaled:
            set GPU clock to f_min; downscaled <- true

Paper defaults: X = 3 s trigger, Y = 5 s cooldown, eps = 1 s.
Two downscale modes per §5.3: compute clock only, or compute + memory clocks.

For counterfactual what-if sweeps over *recorded* telemetry, use the
vectorized re-derivation :class:`repro.whatif.policies.DownscalePolicy`
(:func:`repro.whatif.policies.downscale_decisions`): same decision sequence,
verified sample-exact against this controller, but O(runs) instead of a
Python call per second.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Mapping

from repro.core.power_model import ClockActuator, ClockLevel
from repro.core.states import COMMUNICATION_SIGNALS, COMPUTE_SIGNALS


class DownscaleMode(enum.Enum):
    SM_ONLY = "sm_only"
    SM_AND_MEM = "sm_and_mem"


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    threshold_x_s: float = 3.0       # consecutive low-activity seconds before downscale
    cooldown_y_s: float = 5.0        # hold f_max after resume to avoid oscillation
    interval_eps_s: float = 1.0      # control interval
    activity_threshold: float = 0.05  # fraction (5%)
    comm_threshold_gbs: float = 1.0
    mode: DownscaleMode = DownscaleMode.SM_ONLY


@dataclasses.dataclass
class ControllerStats:
    downscale_events: int = 0
    restore_events: int = 0
    downscaled_time_s: float = 0.0
    control_steps: int = 0


class ExecutionIdleController:
    """Stateful per-device controller driving a :class:`ClockActuator`."""

    def __init__(self, actuator: ClockActuator, config: ControllerConfig | None = None):
        self.actuator = actuator
        self.config = config or ControllerConfig()
        self._c = 0.0              # consecutive low-activity time (s)
        self._t_cooldown = 0.0
        self._downscaled = False
        self.stats = ControllerStats()

    # ------------------------------------------------------------------ #
    @property
    def downscaled(self) -> bool:
        return self._downscaled

    def _low_activity(self, sample: Mapping[str, float]) -> bool:
        cfg = self.config
        a_comp = max((float(sample.get(k, 0.0) or 0.0)
                      for k in COMPUTE_SIGNALS), default=0.0)
        a_mem = float(sample.get("dram", 0.0) or 0.0)
        a_comm = max((float(sample.get(k, 0.0) or 0.0)
                      for k in COMMUNICATION_SIGNALS), default=0.0)
        # activity signals here are fractions in [0,1] to match Algorithm 1's
        # "< 0.05"; telemetry records store percent, callers divide by 100.
        return (
            a_comp < cfg.activity_threshold
            and a_mem < cfg.activity_threshold
            and a_comm < cfg.comm_threshold_gbs
        )

    def _min_clocks(self) -> tuple[ClockLevel, ClockLevel]:
        if self.config.mode == DownscaleMode.SM_AND_MEM:
            return ClockLevel.MIN, ClockLevel.MIN
        return ClockLevel.MIN, ClockLevel.MAX

    # ------------------------------------------------------------------ #
    def step(self, t_s: float, sample: Mapping[str, float]) -> bool:
        """One eps-second control interval. Returns True iff downscaled after
        this step. ``sample`` holds activity fractions + comm GB/s."""
        cfg = self.config
        self.stats.control_steps += 1

        if self._low_activity(sample):
            self._c += cfg.interval_eps_s
        else:
            self._c = 0.0
            if self._downscaled:
                self.actuator.set_clocks(t_s, ClockLevel.MAX, ClockLevel.MAX)
                self._downscaled = False
                self.stats.restore_events += 1
                self._t_cooldown = t_s + cfg.cooldown_y_s

        if self._c > cfg.threshold_x_s and t_s >= self._t_cooldown and not self._downscaled:
            sm, mem = self._min_clocks()
            self.actuator.set_clocks(t_s, sm, mem)
            self._downscaled = True
            self.stats.downscale_events += 1

        if self._downscaled:
            self.stats.downscaled_time_s += cfg.interval_eps_s
        return self._downscaled

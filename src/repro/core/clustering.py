"""Density-based clustering (HDBSCAN-lite) for pre-idle window grouping (§4.5).

The paper uses HDBSCAN over pre-idle telemetry windows. hdbscan/sklearn are
not installable offline, so this is a NumPy implementation of the core of the
algorithm:

1. core distances (k-th nearest neighbour),
2. mutual-reachability distances  mreach(a,b) = max(core_a, core_b, d(a,b)),
3. minimum spanning tree over the mutual-reachability graph (Prim, O(n^2)),
4. single-linkage hierarchy from sorted MST edges,
5. flat extraction: cut edges above an adaptive scale, discard components
   smaller than ``min_cluster_size`` as noise (label -1).

O(n^2) memory/time is fine at our scale (10^3–10^4 windows).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClusterResult:
    labels: np.ndarray          # [n] int, -1 = noise
    n_clusters: int
    cut_scale: float
    core_distances: np.ndarray  # [n]


def _pairwise_dist(x: np.ndarray) -> np.ndarray:
    sq = np.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    np.maximum(d2, 0.0, out=d2)
    return np.sqrt(d2)


def _mst_prim(w: np.ndarray) -> list[tuple[float, int, int]]:
    """Prim's MST over a dense weight matrix; returns (weight, u, v) edges."""
    n = w.shape[0]
    in_tree = np.zeros(n, dtype=bool)
    best = np.full(n, np.inf)
    parent = np.full(n, -1, dtype=np.int64)
    best[0] = 0.0
    edges: list[tuple[float, int, int]] = []
    for _ in range(n):
        u = int(np.argmin(np.where(in_tree, np.inf, best)))
        in_tree[u] = True
        if parent[u] >= 0:
            edges.append((float(w[u, parent[u]]), int(parent[u]), u))
        better = (~in_tree) & (w[u] < best)
        best[better] = w[u][better]
        parent[better] = u
    return edges


class _UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n)
        self.size = np.ones(n, dtype=np.int64)

    def find(self, a: int) -> int:
        root = a
        while self.parent[root] != root:
            root = int(self.parent[root])
        while self.parent[a] != root:
            self.parent[a], a = root, int(self.parent[a])
        return root

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return
        if self.size[ra] < self.size[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        self.size[ra] += self.size[rb]


def density_cluster(
    features: np.ndarray,
    min_cluster_size: int = 10,
    min_samples: int = 5,
    cut_quantile: float = 0.85,
    standardize: bool = True,
) -> ClusterResult:
    """Cluster rows of ``features``; small/low-density points become noise."""
    x = np.asarray(features, dtype=np.float64)
    if x.ndim != 2:
        raise ValueError("features must be [n, d]")
    n = x.shape[0]
    if n == 0:
        return ClusterResult(np.empty(0, dtype=np.int64), 0, 0.0, np.empty(0))
    if standardize:
        mu = x.mean(axis=0)
        sd = x.std(axis=0)
        sd[sd == 0] = 1.0
        x = (x - mu) / sd
    if n == 1:
        return ClusterResult(np.zeros(1, dtype=np.int64), 1, 0.0, np.zeros(1))

    d = _pairwise_dist(x)
    k = min(min_samples, n - 1)
    core = np.partition(d, k, axis=1)[:, k]
    mreach = np.maximum(np.maximum(core[:, None], core[None, :]), d)
    np.fill_diagonal(mreach, 0.0)

    edges = sorted(_mst_prim(mreach))
    weights = np.array([e[0] for e in edges])
    cut = float(np.quantile(weights, cut_quantile)) if weights.size else 0.0

    uf = _UnionFind(n)
    for wgt, u, v in edges:
        if wgt <= cut:
            uf.union(u, v)

    roots = np.array([uf.find(i) for i in range(n)])
    labels = np.full(n, -1, dtype=np.int64)
    next_label = 0
    for root in np.unique(roots):
        members = np.flatnonzero(roots == root)
        if members.size >= min_cluster_size:
            labels[members] = next_label
            next_label += 1
    return ClusterResult(labels=labels, n_clusters=next_label, cut_scale=cut,
                         core_distances=core)

"""Energy accounting over telemetry series (paper §2.2, §4).

Power is integrated per-sample (1 Hz board power, as NVML would report).
The paper's headline metrics are *in-execution fractions*: the denominator is
execution-idle + active time/energy only; deep-idle (unallocated or program
absent) is excluded (§4, "In-execution fractions").
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.intervals import apply_min_duration
from repro.core.states import DeviceState, in_execution_mask


JOULES_PER_KWH = 3.6e6
US_CENTS_PER_KWH = 13.6          # paper footnote 3
CO2E_LBS_PER_KWH = (0.82, 0.89)  # paper footnote 3
LBS_PER_METRIC_TON = 2204.62


@dataclasses.dataclass(frozen=True)
class EnergyBreakdown:
    """Time (s) and energy (J) per state, plus in-execution fractions."""

    time_s: dict[DeviceState, float]
    energy_j: dict[DeviceState, float]

    @property
    def total_time_s(self) -> float:
        return float(sum(self.time_s.values()))

    @property
    def total_energy_j(self) -> float:
        return float(sum(self.energy_j.values()))

    # ------------------------------------------------------------------ #
    # Whole-window fractions (Fig 3b uses these, denominator = everything)
    # ------------------------------------------------------------------ #
    def time_fraction(self, state: DeviceState) -> float:
        t = self.total_time_s
        return self.time_s[state] / t if t else 0.0

    def energy_fraction(self, state: DeviceState) -> float:
        e = self.total_energy_j
        return self.energy_j[state] / e if e else 0.0

    # ------------------------------------------------------------------ #
    # In-execution fractions (§4 headline metrics; deep-idle excluded)
    # ------------------------------------------------------------------ #
    @property
    def in_execution_time_s(self) -> float:
        return self.time_s[DeviceState.EXECUTION_IDLE] + self.time_s[DeviceState.ACTIVE]

    @property
    def in_execution_energy_j(self) -> float:
        return self.energy_j[DeviceState.EXECUTION_IDLE] + self.energy_j[DeviceState.ACTIVE]

    @property
    def exec_idle_time_fraction(self) -> float:
        t = self.in_execution_time_s
        return self.time_s[DeviceState.EXECUTION_IDLE] / t if t else 0.0

    @property
    def exec_idle_energy_fraction(self) -> float:
        e = self.in_execution_energy_j
        return self.energy_j[DeviceState.EXECUTION_IDLE] / e if e else 0.0


def integrate(
    states: np.ndarray,
    power_w: np.ndarray,
    dt_s: float = 1.0,
    min_duration_s: float | None = 5.0,
) -> EnergyBreakdown:
    """Integrate power over a classified series.

    Args:
        states: int array [T] of DeviceState values.
        power_w: float array [T] of board power in watts.
        dt_s: sample spacing.
        min_duration_s: if given, EXECUTION_IDLE runs shorter than this are
            conservatively relabelled ACTIVE before accounting (§2.2).
    """
    states = np.asarray(states)
    power_w = np.asarray(power_w, dtype=np.float64)
    if states.shape != power_w.shape:
        raise ValueError(f"states {states.shape} vs power {power_w.shape}")
    if min_duration_s is not None:
        states = apply_min_duration(states, min_duration_s, dt_s)

    time_s: dict[DeviceState, float] = {}
    energy_j: dict[DeviceState, float] = {}
    for s in DeviceState:
        mask = states == int(s)
        time_s[s] = float(np.sum(mask) * dt_s)
        energy_j[s] = float(np.sum(power_w[mask]) * dt_s)
    return EnergyBreakdown(time_s=time_s, energy_j=energy_j)


def merge(breakdowns: list[EnergyBreakdown]) -> EnergyBreakdown:
    """Aggregate per-device/per-job breakdowns into a fleet breakdown."""
    time_s = {s: 0.0 for s in DeviceState}
    energy_j = {s: 0.0 for s in DeviceState}
    for b in breakdowns:
        for s in DeviceState:
            time_s[s] += b.time_s[s]
            energy_j[s] += b.energy_j[s]
    return EnergyBreakdown(time_s=time_s, energy_j=energy_j)


def energy_kwh(energy_j: float) -> float:
    return energy_j / JOULES_PER_KWH


def cost_usd(energy_j: float, cents_per_kwh: float = US_CENTS_PER_KWH) -> float:
    return energy_kwh(energy_j) * cents_per_kwh / 100.0


def co2e_metric_tons(energy_j: float) -> tuple[float, float]:
    """(low, high) CO2e estimate per paper footnote 3."""
    kwh = energy_kwh(energy_j)
    lo, hi = CO2E_LBS_PER_KWH
    return kwh * lo / LBS_PER_METRIC_TON, kwh * hi / LBS_PER_METRIC_TON


def tdp_upper_bound_j(tdp_w: float, window_s: float, n_devices: int = 1) -> float:
    """Energy had the fleet run at TDP continuously (Fig 3a comparison)."""
    return tdp_w * window_s * n_devices


def fraction_of_tdp(total_energy_j: float, tdp_w: float, window_s: float, n_devices: int) -> float:
    return total_energy_j / tdp_upper_bound_j(tdp_w, window_s, n_devices)

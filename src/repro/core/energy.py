"""Energy accounting over telemetry series (paper §2.2, §4).

Power is integrated per-sample (1 Hz board power, as NVML would report).
The paper's headline metrics are *in-execution fractions*: the denominator is
execution-idle + active time/energy only; deep-idle (unallocated or program
absent) is excluded (§4, "In-execution fractions").
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.intervals import Interval, RunCarry, runs_streaming
from repro.core.states import DeviceState


JOULES_PER_KWH = 3.6e6
US_CENTS_PER_KWH = 13.6          # paper footnote 3
CO2E_LBS_PER_KWH = (0.82, 0.89)  # paper footnote 3
LBS_PER_METRIC_TON = 2204.62


@dataclasses.dataclass(frozen=True)
class EnergyBreakdown:
    """Time (s) and energy (J) per state, plus in-execution fractions."""

    time_s: dict[DeviceState, float]
    energy_j: dict[DeviceState, float]

    @property
    def total_time_s(self) -> float:
        return float(sum(self.time_s.values()))

    @property
    def total_energy_j(self) -> float:
        return float(sum(self.energy_j.values()))

    # ------------------------------------------------------------------ #
    # Whole-window fractions (Fig 3b uses these, denominator = everything)
    # ------------------------------------------------------------------ #
    def time_fraction(self, state: DeviceState) -> float:
        t = self.total_time_s
        return self.time_s[state] / t if t else 0.0

    def energy_fraction(self, state: DeviceState) -> float:
        e = self.total_energy_j
        return self.energy_j[state] / e if e else 0.0

    # ------------------------------------------------------------------ #
    # In-execution fractions (§4 headline metrics; deep-idle excluded)
    # ------------------------------------------------------------------ #
    @property
    def in_execution_time_s(self) -> float:
        return self.time_s[DeviceState.EXECUTION_IDLE] + self.time_s[DeviceState.ACTIVE]

    @property
    def in_execution_energy_j(self) -> float:
        return self.energy_j[DeviceState.EXECUTION_IDLE] + self.energy_j[DeviceState.ACTIVE]

    @property
    def exec_idle_time_fraction(self) -> float:
        t = self.in_execution_time_s
        return self.time_s[DeviceState.EXECUTION_IDLE] / t if t else 0.0

    @property
    def exec_idle_energy_fraction(self) -> float:
        e = self.in_execution_energy_j
        return self.energy_j[DeviceState.EXECUTION_IDLE] / e if e else 0.0


class BatchedStreamingIntegrator:
    """Boundary-aware energy integration over one stream, with a leading
    **config axis**: one shared classified-state series, ``n_configs``
    counterfactual power series integrated in a single pass.

    Feed time-ordered chunks via :meth:`update` with ``states [T]`` and
    ``power_w [n_configs, T]``; :meth:`finalize` returns one
    :class:`EnergyBreakdown` per config plus the shared sustained
    EXECUTION_IDLE :class:`Interval` list. Because every config sees the
    same state series, the run decomposition (the expensive, Python-level
    part) happens once; each run's energy is one ``np.sum(..., axis=-1)``
    over the config axis. Results are *bit-identical*, per config, to
    ``n_configs`` independent :class:`StreamingIntegrator` instances — and
    to every chunking of the same series — because:

    * run decomposition is chunking-invariant (:func:`runs_streaming` carries
      the trailing run across boundaries), so the §2.2 sustain rule sees the
      same maximal runs regardless of where chunks split;
    * each run's energy is ``np.sum`` over the run's full power samples —
      pending samples of an unfinished run are retained until the run closes,
      so the summation tree only depends on the run itself, and NumPy's
      pairwise reduction over the (contiguous) last axis applies the same
      summation tree per row as the 1-D sum of that row;
    * per-state totals accumulate run energies in time order, which is the
      same sequence of (elementwise) additions under any chunking.

    Retained pending samples are bounded by the longest constant-state run.
    As a safety valve, runs longer than ``max_pending_samples`` collapse their
    prefix into a partial sum (only such pathological runs can then differ
    from the monolithic result, in the last ulp).
    """

    def __init__(self, n_configs: int = 1, min_duration_s: float | None = 5.0,
                 dt_s: float = 1.0, max_pending_samples: int = 1 << 22):
        self.n_configs = n_configs
        self.dt_s = dt_s
        self.min_samples = (0 if min_duration_s is None
                            else int(np.ceil(min_duration_s / dt_s)))
        self.max_pending_samples = max_pending_samples
        self._carry = RunCarry()
        self._pending: list[np.ndarray] = []   # [C, k] power of the pending run
        self._pending_n = 0
        self._collapsed = np.zeros(n_configs)  # prefix sum of an over-long run
        self._run_energy: np.ndarray | None = None  # update_runs trailing run
        self._time: dict[DeviceState, int] = {s: 0 for s in DeviceState}
        self._energy: dict[DeviceState, np.ndarray] = {
            s: np.zeros(n_configs) for s in DeviceState}
        self._intervals: list[Interval] = []
        self.n_samples = 0

    def _close_run(self, state: int, start: int, end: int,
                   energy: np.ndarray) -> None:
        n = end - start
        final = DeviceState(state)
        if state == int(DeviceState.EXECUTION_IDLE):
            if n < self.min_samples:
                final = DeviceState.ACTIVE      # conservative relabel (§2.2)
            else:
                self._intervals.append(
                    Interval(DeviceState.EXECUTION_IDLE, start, end))
        self._time[final] += n
        self._energy[final] += energy

    def _pending_energy(self, extra: np.ndarray | None) -> np.ndarray:
        pieces = self._pending + (
            [extra] if extra is not None and extra.shape[-1] else [])
        if not pieces:
            arr_sum = 0.0
        elif len(pieces) == 1:
            arr_sum = np.sum(pieces[0], axis=-1)
        else:
            arr_sum = np.sum(np.concatenate(pieces, axis=-1), axis=-1)
        e = self._collapsed + arr_sum
        self._pending = []
        self._pending_n = 0
        self._collapsed = np.zeros(self.n_configs)
        return e

    def update(self, states: np.ndarray, power_w: np.ndarray) -> None:
        if self._run_energy is not None:
            raise ValueError("update cannot follow update_runs() on one "
                             "integrator: trailing-run state differs")
        states = np.asarray(states)
        power_w = np.asarray(power_w, dtype=np.float64)
        if power_w.ndim == 1:
            power_w = power_w[None, :]
        if power_w.shape != (self.n_configs, states.shape[0]):
            raise ValueError(
                f"power {power_w.shape} vs expected "
                f"({self.n_configs}, {states.shape[0]})")
        if states.size == 0:
            return
        offset = self.n_samples
        completed, carry = runs_streaming(states, self._carry, offset)
        for state, start, end in completed:
            if start < offset:          # run includes carried-in samples
                energy = self._pending_energy(
                    power_w[:, :max(end - offset, 0)])
            else:
                # .sum() is np.sum minus the dispatch wrapper — same ufunc
                # reduction bit for bit, and this is the hot loop (one call
                # per maximal run per stream)
                energy = power_w[:, start - offset:end - offset].sum(axis=-1)
            self._close_run(state, start, end, energy)
        self._carry = carry
        if carry.length:
            # copy (not view) so chunk buffers can be released
            piece = np.array(power_w[:, max(carry.start - offset, 0):])
            if piece.shape[-1]:
                self._pending.append(piece)
                self._pending_n += piece.shape[-1]
            # valve on retained ELEMENTS (samples x configs): a [C, k]
            # pending block costs C times the scalar design's memory, so a
            # wide config axis must trip the collapse proportionally earlier
            if self._pending_n * self.n_configs > self.max_pending_samples:
                self._collapsed += np.sum(
                    np.concatenate(self._pending, axis=-1), axis=-1)
                self._pending = []
                self._pending_n = 0
        self.n_samples += states.size

    def update_runs(self, states: np.ndarray, energy: np.ndarray,
                    lengths: np.ndarray) -> None:
        """Run-weighted update: fold pre-aggregated runs instead of samples.

        The run-level IR fast path (:mod:`repro.whatif.ir`) feeds this with
        ``states [R]`` (one state per run, consecutive duplicates allowed —
        e.g. runs split on an orthogonal flag), ``energy [n_configs, R]``
        (each run's power *sum* in W·samples, one row per config) and
        ``lengths [R]`` (samples per run). Consecutive equal-state runs are
        merged — including a trailing run carried across calls — so the
        §2.2 sustain rule sees the same maximal runs :meth:`update` would
        see on the expanded per-sample series: per-state *times* and the
        sustained-interval list are **bit-identical** to the sample path
        (integer sample counts), per-state *energies* agree up to float
        summation order (the per-run sums arrive pre-reduced).

        Do not mix with :meth:`update` on one instance: the two paths carry
        different trailing-run state.
        """
        if self._pending or (self._carry.length and self._run_energy is None):
            raise ValueError("update_runs cannot follow update() on one "
                             "integrator: trailing-run state differs")
        states = np.asarray(states)
        lengths = np.asarray(lengths, dtype=np.int64)
        energy = np.asarray(energy, dtype=np.float64)
        if energy.ndim == 1:
            energy = energy[None, :]
        if energy.shape != (self.n_configs, states.shape[0]):
            raise ValueError(f"energy {energy.shape} vs expected "
                             f"({self.n_configs}, {states.shape[0]})")
        if states.shape[0] != lengths.shape[0]:
            raise ValueError(
                f"states {states.shape} vs lengths {lengths.shape}")
        if states.size == 0:
            return
        change = np.flatnonzero(np.diff(states)) + 1
        starts = np.concatenate([[0], change])
        m_state = states[starts]
        m_len = np.add.reduceat(lengths, starts)
        m_energy = np.add.reduceat(energy, starts, axis=1)
        offsets = np.concatenate([[0], np.cumsum(m_len)])
        gpos = self.n_samples           # global index of this call's sample 0
        n_m = m_state.shape[0]
        i0 = 0
        if self._run_energy is not None and self._carry.state == int(m_state[0]):
            # trailing run continues: extend it in place
            self._carry.length += int(m_len[0])
            self._run_energy = self._run_energy + m_energy[:, 0]
            i0 = 1
        if i0 < n_m:
            self._flush_run_carry()     # old carry ended at a state change
            last = n_m - 1
            if i0 < last:
                # bulk-close every new maximal run except the trailing one:
                # per-state time/energy accumulate by masked sums (times are
                # exact integer sums; energy grouping differs from the
                # sample path only in float association)
                cs = m_state[i0:last].astype(np.int64)
                cl = m_len[i0:last]
                ce = m_energy[:, i0:last]
                cstart = gpos + offsets[i0:last]
                exec_i = int(DeviceState.EXECUTION_IDLE)
                final = np.where((cs == exec_i) & (cl < self.min_samples),
                                 int(DeviceState.ACTIVE), cs)
                for s in DeviceState:
                    mask = final == int(s)
                    if mask.any():
                        self._time[s] += int(cl[mask].sum())
                        self._energy[s] = (self._energy[s]
                                           + ce[:, mask].sum(axis=1))
                for i in np.flatnonzero((cs == exec_i)
                                        & (cl >= self.min_samples)):
                    self._intervals.append(Interval(
                        DeviceState.EXECUTION_IDLE, int(cstart[i]),
                        int(cstart[i] + cl[i])))
            self._carry = RunCarry(int(m_state[last]),
                                   gpos + int(offsets[last]),
                                   int(m_len[last]))
            self._run_energy = m_energy[:, last].copy()
        self.n_samples += int(offsets[-1])

    def _flush_run_carry(self) -> None:
        if self._run_energy is None:
            return
        self._close_run(self._carry.state, self._carry.start,
                        self._carry.start + self._carry.length,
                        self._run_energy)
        self._carry = RunCarry()
        self._run_energy = None

    def finalize_batch(self) -> tuple[list[EnergyBreakdown], list[Interval]]:
        """Flush carried state; one :class:`EnergyBreakdown` per config."""
        self._flush_run_carry()
        if self._carry.length:
            energy = self._pending_energy(None)
            self._close_run(self._carry.state, self._carry.start,
                            self._carry.start + self._carry.length, energy)
            self._carry = RunCarry()
        breakdowns = [
            EnergyBreakdown(
                time_s={s: float(self._time[s] * self.dt_s)
                        for s in DeviceState},
                energy_j={s: float(self._energy[s][c] * self.dt_s)
                          for s in DeviceState},
            )
            for c in range(self.n_configs)
        ]
        return breakdowns, self._intervals


class StreamingIntegrator(BatchedStreamingIntegrator):
    """Boundary-aware ``integrate`` + ``extract_intervals`` over one stream.

    The single-config view of :class:`BatchedStreamingIntegrator` (which see
    for the bit-identity contract): feed time-ordered chunks of a single
    (job, host, device) stream via :meth:`update` with 1-D ``power_w``;
    :meth:`finalize` returns the :class:`EnergyBreakdown` and the sustained
    EXECUTION_IDLE :class:`Interval` list. Results are *bit-identical* for
    every chunking of the same series, including the monolithic single-chunk
    case (:func:`integrate` is this class applied once).
    """

    def __init__(self, min_duration_s: float | None = 5.0, dt_s: float = 1.0,
                 max_pending_samples: int = 1 << 22):
        super().__init__(n_configs=1, min_duration_s=min_duration_s,
                         dt_s=dt_s, max_pending_samples=max_pending_samples)

    def update(self, states: np.ndarray, power_w: np.ndarray) -> None:
        states = np.asarray(states)
        power_w = np.asarray(power_w, dtype=np.float64)
        if states.shape != power_w.shape:
            raise ValueError(f"states {states.shape} vs power {power_w.shape}")
        super().update(states, power_w)

    def finalize(self) -> tuple[EnergyBreakdown, list[Interval]]:
        breakdowns, intervals = self.finalize_batch()
        return breakdowns[0], intervals


def integrate(
    states: np.ndarray,
    power_w: np.ndarray,
    dt_s: float = 1.0,
    min_duration_s: float | None = 5.0,
) -> EnergyBreakdown:
    """Integrate power over a classified series.

    Single-chunk application of :class:`StreamingIntegrator`, so monolithic
    and chunked analyses share one accounting implementation (and agree
    bit-for-bit).

    Args:
        states: int array [T] of DeviceState values.
        power_w: float array [T] of board power in watts.
        dt_s: sample spacing.
        min_duration_s: if given, EXECUTION_IDLE runs shorter than this are
            conservatively relabelled ACTIVE before accounting (§2.2).
    """
    si = StreamingIntegrator(min_duration_s=min_duration_s, dt_s=dt_s)
    si.update(states, power_w)
    breakdown, _ = si.finalize()
    return breakdown


def integrate_runs_with_intervals(
    states: np.ndarray,
    energy: np.ndarray,
    lengths: np.ndarray,
    min_samples: int,
    dt_s: float = 1.0,
) -> tuple[list[EnergyBreakdown], list[Interval]]:
    """Integrate pre-aggregated runs, keeping the sustained-interval list.

    Single-call application of
    :meth:`BatchedStreamingIntegrator.update_runs` — the run-level IR's
    accounting primitive (``states [R]``, ``energy [C, R]`` per-run power
    sums in W·samples, ``lengths [R]``). Per-state times, interval bounds
    and counts are bit-identical to sample-level integration of the
    expanded series; energies agree up to float summation order. The
    interval sample indices are stream-local (sample 0 = the first run's
    first sample), exactly like a single-stream :func:`integrate` pass.
    """
    energy = np.asarray(energy, dtype=np.float64)
    if energy.ndim == 1:
        energy = energy[None, :]
    bi = BatchedStreamingIntegrator(n_configs=energy.shape[0],
                                    min_duration_s=None, dt_s=dt_s)
    bi.min_samples = int(min_samples)
    bi.update_runs(states, energy, lengths)
    return bi.finalize_batch()


def integrate_runs(
    states: np.ndarray,
    energy: np.ndarray,
    lengths: np.ndarray,
    min_samples: int,
    dt_s: float = 1.0,
) -> list[EnergyBreakdown]:
    """Breakdown-only view of :func:`integrate_runs_with_intervals`."""
    breakdowns, _ = integrate_runs_with_intervals(
        states, energy, lengths, min_samples, dt_s)
    return breakdowns


def merge(breakdowns: list[EnergyBreakdown]) -> EnergyBreakdown:
    """Aggregate per-device/per-job breakdowns into a fleet breakdown."""
    time_s = {s: 0.0 for s in DeviceState}
    energy_j = {s: 0.0 for s in DeviceState}
    for b in breakdowns:
        for s in DeviceState:
            time_s[s] += b.time_s[s]
            energy_j[s] += b.energy_j[s]
    return EnergyBreakdown(time_s=time_s, energy_j=energy_j)


def energy_kwh(energy_j: float) -> float:
    return energy_j / JOULES_PER_KWH


def cost_usd(energy_j: float, cents_per_kwh: float = US_CENTS_PER_KWH) -> float:
    return energy_kwh(energy_j) * cents_per_kwh / 100.0


def co2e_metric_tons(energy_j: float) -> tuple[float, float]:
    """(low, high) CO2e estimate per paper footnote 3."""
    kwh = energy_kwh(energy_j)
    lo, hi = CO2E_LBS_PER_KWH
    return kwh * lo / LBS_PER_METRIC_TON, kwh * hi / LBS_PER_METRIC_TON


def tdp_upper_bound_j(tdp_w: float, window_s: float, n_devices: int = 1) -> float:
    """Energy had the fleet run at TDP continuously (Fig 3a comparison)."""
    return tdp_w * window_s * n_devices


def fraction_of_tdp(total_energy_j: float, tdp_w: float, window_s: float, n_devices: int) -> float:
    return total_energy_j / tdp_upper_bound_j(tdp_w, window_s, n_devices)

"""Energy accounting over telemetry series (paper §2.2, §4).

Power is integrated per-sample (1 Hz board power, as NVML would report).
The paper's headline metrics are *in-execution fractions*: the denominator is
execution-idle + active time/energy only; deep-idle (unallocated or program
absent) is excluded (§4, "In-execution fractions").
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.intervals import Interval, RunCarry, runs_streaming
from repro.core.states import DeviceState


JOULES_PER_KWH = 3.6e6
US_CENTS_PER_KWH = 13.6          # paper footnote 3
CO2E_LBS_PER_KWH = (0.82, 0.89)  # paper footnote 3
LBS_PER_METRIC_TON = 2204.62


@dataclasses.dataclass(frozen=True)
class EnergyBreakdown:
    """Time (s) and energy (J) per state, plus in-execution fractions."""

    time_s: dict[DeviceState, float]
    energy_j: dict[DeviceState, float]

    @property
    def total_time_s(self) -> float:
        return float(sum(self.time_s.values()))

    @property
    def total_energy_j(self) -> float:
        return float(sum(self.energy_j.values()))

    # ------------------------------------------------------------------ #
    # Whole-window fractions (Fig 3b uses these, denominator = everything)
    # ------------------------------------------------------------------ #
    def time_fraction(self, state: DeviceState) -> float:
        t = self.total_time_s
        return self.time_s[state] / t if t else 0.0

    def energy_fraction(self, state: DeviceState) -> float:
        e = self.total_energy_j
        return self.energy_j[state] / e if e else 0.0

    # ------------------------------------------------------------------ #
    # In-execution fractions (§4 headline metrics; deep-idle excluded)
    # ------------------------------------------------------------------ #
    @property
    def in_execution_time_s(self) -> float:
        return self.time_s[DeviceState.EXECUTION_IDLE] + self.time_s[DeviceState.ACTIVE]

    @property
    def in_execution_energy_j(self) -> float:
        return self.energy_j[DeviceState.EXECUTION_IDLE] + self.energy_j[DeviceState.ACTIVE]

    @property
    def exec_idle_time_fraction(self) -> float:
        t = self.in_execution_time_s
        return self.time_s[DeviceState.EXECUTION_IDLE] / t if t else 0.0

    @property
    def exec_idle_energy_fraction(self) -> float:
        e = self.in_execution_energy_j
        return self.energy_j[DeviceState.EXECUTION_IDLE] / e if e else 0.0


class StreamingIntegrator:
    """Boundary-aware ``integrate`` + ``extract_intervals`` over one stream.

    Feed time-ordered chunks of a single (job, host, device) stream via
    :meth:`update`; :meth:`finalize` returns the :class:`EnergyBreakdown` and
    the sustained EXECUTION_IDLE :class:`Interval` list. Results are
    *bit-identical* for every chunking of the same series, including the
    monolithic single-chunk case (:func:`integrate` is this class applied
    once), because:

    * run decomposition is chunking-invariant (:func:`runs_streaming` carries
      the trailing run across boundaries), so the §2.2 sustain rule sees the
      same maximal runs regardless of where chunks split;
    * each run's energy is ``np.sum`` over the run's full power samples —
      pending samples of an unfinished run are retained until the run closes,
      so the summation tree only depends on the run itself;
    * per-state totals accumulate run energies in time order, which is the
      same sequence of additions under any chunking.

    Retained pending samples are bounded by the longest constant-state run.
    As a safety valve, runs longer than ``max_pending_samples`` collapse their
    prefix into a partial sum (only such pathological runs can then differ
    from the monolithic result, in the last ulp).
    """

    def __init__(self, min_duration_s: float | None = 5.0, dt_s: float = 1.0,
                 max_pending_samples: int = 1 << 22):
        self.dt_s = dt_s
        self.min_samples = (0 if min_duration_s is None
                            else int(np.ceil(min_duration_s / dt_s)))
        self.max_pending_samples = max_pending_samples
        self._carry = RunCarry()
        self._pending: list[np.ndarray] = []   # power of the pending run
        self._pending_n = 0
        self._collapsed = 0.0                  # prefix sum of an over-long run
        self._time: dict[DeviceState, int] = {s: 0 for s in DeviceState}
        self._energy: dict[DeviceState, float] = {s: 0.0 for s in DeviceState}
        self._intervals: list[Interval] = []
        self.n_samples = 0

    def _close_run(self, state: int, start: int, end: int, energy: float) -> None:
        n = end - start
        final = DeviceState(state)
        if state == int(DeviceState.EXECUTION_IDLE):
            if n < self.min_samples:
                final = DeviceState.ACTIVE      # conservative relabel (§2.2)
            else:
                self._intervals.append(
                    Interval(DeviceState.EXECUTION_IDLE, start, end))
        self._time[final] += n
        self._energy[final] += energy

    def _pending_energy(self, extra: np.ndarray | None) -> float:
        pieces = self._pending + ([extra] if extra is not None and extra.size else [])
        if not pieces:
            arr_sum = 0.0
        elif len(pieces) == 1:
            arr_sum = float(np.sum(pieces[0]))
        else:
            arr_sum = float(np.sum(np.concatenate(pieces)))
        e = self._collapsed + arr_sum
        self._pending = []
        self._pending_n = 0
        self._collapsed = 0.0
        return e

    def update(self, states: np.ndarray, power_w: np.ndarray) -> None:
        states = np.asarray(states)
        power_w = np.asarray(power_w, dtype=np.float64)
        if states.shape != power_w.shape:
            raise ValueError(f"states {states.shape} vs power {power_w.shape}")
        if states.size == 0:
            return
        offset = self.n_samples
        completed, carry = runs_streaming(states, self._carry, offset)
        for state, start, end in completed:
            if start < offset:          # run includes carried-in samples
                energy = self._pending_energy(power_w[:max(end - offset, 0)])
            else:
                energy = float(np.sum(power_w[start - offset:end - offset]))
            self._close_run(state, start, end, energy)
        self._carry = carry
        if carry.length:
            # copy (not view) so chunk buffers can be released
            piece = np.array(power_w[max(carry.start - offset, 0):])
            if piece.size:
                self._pending.append(piece)
                self._pending_n += piece.size
            if self._pending_n > self.max_pending_samples:
                self._collapsed += float(np.sum(np.concatenate(self._pending)))
                self._pending = []
                self._pending_n = 0
        self.n_samples += states.size

    def finalize(self) -> tuple[EnergyBreakdown, list[Interval]]:
        if self._carry.length:
            energy = self._pending_energy(None)
            self._close_run(self._carry.state, self._carry.start,
                            self._carry.start + self._carry.length, energy)
            self._carry = RunCarry()
        time_s = {s: float(self._time[s] * self.dt_s) for s in DeviceState}
        energy_j = {s: float(self._energy[s] * self.dt_s) for s in DeviceState}
        return EnergyBreakdown(time_s=time_s, energy_j=energy_j), self._intervals


def integrate(
    states: np.ndarray,
    power_w: np.ndarray,
    dt_s: float = 1.0,
    min_duration_s: float | None = 5.0,
) -> EnergyBreakdown:
    """Integrate power over a classified series.

    Single-chunk application of :class:`StreamingIntegrator`, so monolithic
    and chunked analyses share one accounting implementation (and agree
    bit-for-bit).

    Args:
        states: int array [T] of DeviceState values.
        power_w: float array [T] of board power in watts.
        dt_s: sample spacing.
        min_duration_s: if given, EXECUTION_IDLE runs shorter than this are
            conservatively relabelled ACTIVE before accounting (§2.2).
    """
    si = StreamingIntegrator(min_duration_s=min_duration_s, dt_s=dt_s)
    si.update(states, power_w)
    breakdown, _ = si.finalize()
    return breakdown


def merge(breakdowns: list[EnergyBreakdown]) -> EnergyBreakdown:
    """Aggregate per-device/per-job breakdowns into a fleet breakdown."""
    time_s = {s: 0.0 for s in DeviceState}
    energy_j = {s: 0.0 for s in DeviceState}
    for b in breakdowns:
        for s in DeviceState:
            time_s[s] += b.time_s[s]
            energy_j[s] += b.energy_j[s]
    return EnergyBreakdown(time_s=time_s, energy_j=energy_j)


def energy_kwh(energy_j: float) -> float:
    return energy_j / JOULES_PER_KWH


def cost_usd(energy_j: float, cents_per_kwh: float = US_CENTS_PER_KWH) -> float:
    return energy_kwh(energy_j) * cents_per_kwh / 100.0


def co2e_metric_tons(energy_j: float) -> tuple[float, float]:
    """(low, high) CO2e estimate per paper footnote 3."""
    kwh = energy_kwh(energy_j)
    lo, hi = CO2E_LBS_PER_KWH
    return kwh * lo / LBS_PER_METRIC_TON, kwh * hi / LBS_PER_METRIC_TON


def tdp_upper_bound_j(tdp_w: float, window_s: float, n_devices: int = 1) -> float:
    """Energy had the fleet run at TDP continuously (Fig 3a comparison)."""
    return tdp_w * window_s * n_devices


def fraction_of_tdp(total_energy_j: float, tdp_w: float, window_s: float, n_devices: int) -> float:
    return total_energy_j / tdp_upper_bound_j(tdp_w, window_s, n_devices)

"""Core: the paper's contribution — execution-idle as a first-class state.

Taxonomy + classifier (states), sustained-interval extraction (intervals),
energy accounting (energy), platform power/DVFS models (power_model),
Algorithm-1 controller (controller), load-imbalance pool scheduling
(imbalance), density clustering (clustering), pre-idle cause attribution
(attribution).
"""
from repro.core.states import (  # noqa: F401
    DeviceState,
    ClassifierConfig,
    DEFAULT_CLASSIFIER,
    classify_sample,
    classify_series,
    state_time_fractions,
    in_execution_mask,
)
from repro.core.intervals import (  # noqa: F401
    Interval,
    extract_intervals,
    apply_min_duration,
    duration_percentiles,
)
from repro.core.energy import EnergyBreakdown, integrate, merge  # noqa: F401
from repro.core.power_model import (  # noqa: F401
    ClockLevel,
    PlatformSpec,
    PLATFORMS,
    get_platform,
    SimulatedDevice,
    TPU_V5E,
)
from repro.core.controller import (  # noqa: F401
    ControllerConfig,
    DownscaleMode,
    ExecutionIdleController,
)
from repro.core.imbalance import (  # noqa: F401
    PoolPolicy,
    PoolConfig,
    ImbalanceScheduler,
)
from repro.core.attribution import (  # noqa: F401
    extract_pre_idle_windows,
    attribute_causes,
    AttributionResult,
)

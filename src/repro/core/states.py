"""GPU/TPU operating-state taxonomy and the execution-idle classifier (paper §2.2).

Three states, mutually exclusive and collectively exhaustive:

* ``DEEP_IDLE``       — no program resident; device at baseline power.
* ``EXECUTION_IDLE``  — a program is resident, yet every available compute- and
                        memory-activity signal is below ``activity_threshold``
                        (default 5%) AND every available communication signal is
                        below ``comm_threshold_gbs`` (default 1 GB/s),
                        simultaneously.
* ``ACTIVE``          — a program is resident and at least one signal exceeds
                        its threshold.

Signals that are unavailable on a given platform are *omitted from the rule*
rather than treated as violated (paper §2.2).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Mapping, Sequence

import numpy as np


class DeviceState(enum.IntEnum):
    """Operating state of one accelerator during one telemetry sample."""

    DEEP_IDLE = 0
    EXECUTION_IDLE = 1
    ACTIVE = 2


#: Signals treated as "compute or memory activity", in percent [0, 100].
COMPUTE_MEMORY_SIGNALS: tuple[str, ...] = (
    "sm",        # streaming-multiprocessor / scalar-core activity
    "tensor",    # tensor-core / MXU activity
    "fp16",
    "fp32",
    "fp64",
    "dram",      # memory-subsystem activity
)

#: Algorithm 1's split of the activity signals: ``a_comp`` is the max over
#: the compute counters, ``a_mem`` is dram. Derived from
#: COMPUTE_MEMORY_SIGNALS so the classifier, the step controller
#: (core.controller) and its vectorized re-derivation (repro.whatif)
#: can never drift apart when the Table-1 schema grows.
COMPUTE_SIGNALS: tuple[str, ...] = tuple(
    s for s in COMPUTE_MEMORY_SIGNALS if s != "dram")

#: Signals treated as "communication", in GB/s.
COMMUNICATION_SIGNALS: tuple[str, ...] = (
    "pcie_tx",
    "pcie_rx",
    "nvlink_tx",
    "nvlink_rx",
    "ici_tx",    # TPU inter-chip interconnect (framework-native analogue)
    "ici_rx",
)


@dataclasses.dataclass(frozen=True)
class ClassifierConfig:
    """Thresholds of the §2.2 execution-idle rule."""

    activity_threshold_pct: float = 5.0
    comm_threshold_gbs: float = 1.0
    compute_memory_signals: tuple[str, ...] = COMPUTE_MEMORY_SIGNALS
    communication_signals: tuple[str, ...] = COMMUNICATION_SIGNALS

    def validate(self) -> None:
        if not (0.0 <= self.activity_threshold_pct <= 100.0):
            raise ValueError("activity_threshold_pct must be in [0, 100]")
        if self.comm_threshold_gbs < 0:
            raise ValueError("comm_threshold_gbs must be >= 0")


DEFAULT_CLASSIFIER = ClassifierConfig()


def _available(sample: Mapping[str, object], key: str) -> bool:
    value = sample.get(key)
    if value is None:
        return False
    if isinstance(value, float) and np.isnan(value):
        return False
    return True


def classify_sample(
    sample: Mapping[str, object],
    config: ClassifierConfig = DEFAULT_CLASSIFIER,
) -> DeviceState:
    """Classify one telemetry sample (a mapping of signal name -> value).

    The sample must carry ``program_resident`` (bool). Missing activity /
    communication signals are omitted from the rule per the paper.
    """
    config.validate()
    if not sample.get("program_resident", False):
        return DeviceState.DEEP_IDLE

    for key in config.compute_memory_signals:
        if _available(sample, key) and float(sample[key]) >= config.activity_threshold_pct:
            return DeviceState.ACTIVE
    for key in config.communication_signals:
        if _available(sample, key) and float(sample[key]) >= config.comm_threshold_gbs:
            return DeviceState.ACTIVE
    return DeviceState.EXECUTION_IDLE


def classify_series(
    program_resident: np.ndarray,
    activity_pct: Mapping[str, np.ndarray] | None = None,
    comm_gbs: Mapping[str, np.ndarray] | None = None,
    config: ClassifierConfig = DEFAULT_CLASSIFIER,
) -> np.ndarray:
    """Vectorized classifier over aligned 1 Hz series.

    Args:
        program_resident: bool array [T] — a job's program is loaded.
        activity_pct: dict of signal name -> float array [T] in percent.
            NaN entries mean "signal unavailable at that sample".
        comm_gbs: dict of signal name -> float array [T] in GB/s.

    Returns:
        int array [T] of :class:`DeviceState` values.
    """
    config.validate()
    resident = np.asarray(program_resident, dtype=bool)
    n = resident.shape[0]
    active = np.zeros(n, dtype=bool)

    def _accumulate(signals: Mapping[str, np.ndarray] | None, names: Sequence[str], thr: float) -> None:
        nonlocal active
        if not signals:
            return
        for name in names:
            series = signals.get(name)
            if series is None:
                continue
            arr = np.asarray(series, dtype=np.float64)
            if arr.shape[0] != n:
                raise ValueError(f"signal {name!r} length {arr.shape[0]} != {n}")
            with np.errstate(invalid="ignore"):
                active |= np.nan_to_num(arr, nan=-np.inf) >= thr

    _accumulate(activity_pct, config.compute_memory_signals, config.activity_threshold_pct)
    _accumulate(comm_gbs, config.communication_signals, config.comm_threshold_gbs)

    out = np.full(n, int(DeviceState.DEEP_IDLE), dtype=np.int8)
    out[resident & active] = int(DeviceState.ACTIVE)
    out[resident & ~active] = int(DeviceState.EXECUTION_IDLE)
    return out


def state_time_fractions(states: np.ndarray, dt_s: float = 1.0) -> dict[DeviceState, float]:
    """Fraction of total sampled time spent in each state."""
    states = np.asarray(states)
    total = states.size * dt_s
    if total == 0:
        return {s: 0.0 for s in DeviceState}
    return {s: float(np.sum(states == int(s)) * dt_s / total) for s in DeviceState}


def in_execution_mask(states: np.ndarray) -> np.ndarray:
    """Samples counted in the paper's *in-execution* denominator (§4):
    execution-idle + active; deep-idle excluded."""
    states = np.asarray(states)
    return (states == int(DeviceState.EXECUTION_IDLE)) | (states == int(DeviceState.ACTIVE))

"""Pre-idle window extraction and cause attribution (paper §4.5).

For each execution-idle interval, extract up to ``window_s`` seconds of the
immediately preceding telemetry, truncated so the window contains only the
nearest preceding ACTIVE segment. Fingerprint each window, group fingerprints
with density clustering, and label clusters by their dominant signals:

    pcie_heavy        elevated PCIe + CPU          (host-device transfer)
    nic_heavy         elevated NIC + CPU           (network/storage I/O)
    nvlink_heavy      elevated NVLink/ICI          (device-device comm)
    compute_to_idle   elevated SM/DRAM then drop   (bursty compute phases)
    other             none of the above dominates
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core.clustering import density_cluster
from repro.core.intervals import Interval, extract_intervals
from repro.core.states import DeviceState

#: fingerprint feature order
FEATURES: tuple[str, ...] = ("sm", "dram", "pcie", "nic", "nvlink", "cpu")

CATEGORIES: tuple[str, ...] = (
    "pcie_heavy", "compute_to_idle", "nic_heavy", "nvlink_heavy", "other",
)


@dataclasses.dataclass(frozen=True)
class PreIdleWindow:
    interval: Interval
    fingerprint: np.ndarray  # [len(FEATURES)] mean signal over the window
    window_len_s: int


def extract_pre_idle_windows(
    states: np.ndarray,
    signals: Mapping[str, np.ndarray],
    window_s: int = 10,
    min_duration_s: float = 5.0,
    dt_s: float = 1.0,
) -> list[PreIdleWindow]:
    """Windows preceding each sustained execution-idle interval.

    ``signals`` maps FEATURES names to [T] series; missing keys become 0.
    The window is truncated at the start of the nearest preceding ACTIVE run
    (and never crosses deep-idle or another execution-idle interval).
    """
    states = np.asarray(states)
    t = states.shape[0]
    series = {k: np.asarray(signals.get(k, np.zeros(t)), dtype=np.float64) for k in FEATURES}

    windows: list[PreIdleWindow] = []
    for interval in extract_intervals(states, DeviceState.EXECUTION_IDLE, min_duration_s, dt_s):
        end = interval.start
        start = max(0, end - window_s)
        # truncate to the contiguous preceding ACTIVE segment
        while start < end and states[start] != int(DeviceState.ACTIVE):
            start += 1
        for i in range(end - 1, start - 1, -1):
            if states[i] != int(DeviceState.ACTIVE):
                start = i + 1
                break
        if end - start <= 0:
            continue
        fp = np.array([series[k][start:end].mean() for k in FEATURES])
        windows.append(PreIdleWindow(interval=interval, fingerprint=fp,
                                     window_len_s=end - start))
    return windows


def _label_centroid(centroid: np.ndarray,
                    comm_gbs_threshold: float = 0.7,
                    activity_pct_threshold: float = 20.0) -> str:
    sm, dram, pcie, nic, nvlink, cpu = centroid
    comm = {"pcie_heavy": pcie, "nic_heavy": nic, "nvlink_heavy": nvlink}
    best = max(comm, key=comm.get)  # type: ignore[arg-type]
    if comm[best] >= comm_gbs_threshold:
        return best
    if max(sm, dram) >= activity_pct_threshold:
        return "compute_to_idle"
    return "other"


@dataclasses.dataclass(frozen=True)
class AttributionResult:
    category_shares: dict[str, float]   # fraction of windows per category
    labels: list[str]                   # per-window category
    n_clusters: int


def attribute_causes(
    windows: Sequence[PreIdleWindow],
    min_cluster_size: int = 10,
    min_samples: int = 5,
) -> AttributionResult:
    """Cluster fingerprints and assign category labels (Fig 9)."""
    if not windows:
        return AttributionResult({c: 0.0 for c in CATEGORIES}, [], 0)
    x = np.stack([w.fingerprint for w in windows])
    result = density_cluster(x, min_cluster_size=min_cluster_size, min_samples=min_samples)

    labels: list[str] = [""] * len(windows)
    for cluster_id in range(result.n_clusters):
        members = np.flatnonzero(result.labels == cluster_id)
        centroid = x[members].mean(axis=0)
        cat = _label_centroid(centroid)
        for m in members:
            labels[m] = cat
    # noise points: label individually by their own fingerprint
    for i in np.flatnonzero(result.labels == -1):
        labels[i] = _label_centroid(x[i])

    shares = {c: labels.count(c) / len(labels) for c in CATEGORIES}
    return AttributionResult(category_shares=shares, labels=labels,
                             n_clusters=result.n_clusters)

"""Deliberate load-imbalance scheduling for serving pools (paper §5.1).

Instead of spreading requests across all n devices (leaving each lightly
loaded and repeatedly exposed to execution-idle), concentrate work onto k
active devices so the remaining n-k sit in *deep idle* (or downscaled
residency). Energy falls because fewer devices pay the execution-idle floor;
latency rises because the active devices queue more work — the paper's
cautionary trade-off (energy → 56%, p95 +80%/+93% for k = 4/2 of 8).

The live scheduler below routes requests; to evaluate k-of-n consolidation
*counterfactually* on recorded fleet telemetry (parked idle at deep-idle
power, a model-reload tax per wake), sweep
:class:`repro.whatif.policies.ParkingPolicy`, which reuses
:meth:`PoolConfig.active_set` for the k-of-n membership.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence


class PoolPolicy(enum.Enum):
    BALANCED = "balanced"            # join-shortest-queue over all devices
    CONSOLIDATED = "consolidated"    # join-shortest-queue over k active devices


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    n_devices: int
    policy: PoolPolicy = PoolPolicy.BALANCED
    #: number of devices that receive work under CONSOLIDATED
    n_active: int | None = None
    #: park inactive devices: if True they hold no program (deep idle);
    #: if False they stay resident-but-downscaled (paper's "lightly loaded
    #: and downscaled" variant)
    park_inactive: bool = True
    #: under CONSOLIDATED with park_inactive=False, route every k-th request
    #: to the parked pool ("lightly loaded"); 0 disables
    spill_every: int = 0

    def active_set(self) -> tuple[int, ...]:
        if self.policy == PoolPolicy.BALANCED:
            return tuple(range(self.n_devices))
        k = self.n_active if self.n_active is not None else self.n_devices
        if not (1 <= k <= self.n_devices):
            raise ValueError(f"n_active={k} out of range for pool of {self.n_devices}")
        return tuple(range(k))


class ImbalanceScheduler:
    """Stateless-policy, stateful-load request router.

    ``outstanding`` tracks queued + running work per device (in arbitrary
    work units, e.g. predicted decode tokens); routing is join-shortest-
    outstanding-work within the allowed active set.
    """

    def __init__(self, config: PoolConfig):
        self.config = config
        self._active = config.active_set()
        self.outstanding = [0.0] * config.n_devices
        self.routed = [0] * config.n_devices
        self._count = 0

    def route(self, work_units: float = 1.0) -> int:
        """Pick a device for a new request and account its work."""
        self._count += 1
        pool = self._active
        inactive = self.inactive_devices()
        if (self.config.spill_every and inactive
                and not self.config.park_inactive
                and self._count % self.config.spill_every == 0):
            pool = inactive                       # light traffic to parked set
        device = min(pool, key=lambda d: self.outstanding[d])
        self.outstanding[device] += work_units
        self.routed[device] += 1
        return device

    def complete(self, device: int, work_units: float = 1.0) -> None:
        self.outstanding[device] = max(0.0, self.outstanding[device] - work_units)

    def is_active(self, device: int) -> bool:
        return device in self._active

    def inactive_devices(self) -> tuple[int, ...]:
        return tuple(d for d in range(self.config.n_devices) if d not in self._active)


def downscale_pool_configs(n_devices: int = 8) -> list[PoolConfig]:
    """The three §5.1 experiment cases on an 8-device pool."""
    return [
        PoolConfig(n_devices=n_devices, policy=PoolPolicy.BALANCED),
        PoolConfig(n_devices=n_devices, policy=PoolPolicy.CONSOLIDATED, n_active=4,
                   park_inactive=False),
        PoolConfig(n_devices=n_devices, policy=PoolPolicy.CONSOLIDATED, n_active=2,
                   park_inactive=False),
    ]

"""Platform power models and a simulated DVFS actuator.

Two roles:

1. **Replication** — per-platform power tables calibrated from the paper
   (Table 4 power limits; Fig 2/4 deep-idle vs execution-idle gaps; §5.3
   downscaled powers on L40S; §4.4 kWh anchors on B200/L40S).
2. **TPU adaptation** — a TPU-v5e-class platform for the framework's own
   runtime. TPUs expose no user DVFS API, so the actuator here is a *model*
   (with the 1–500 ms frequency-switch latency of Velicka et al. [52]); the
   controller (Algorithm 1) is written against the ``ClockActuator`` protocol
   so a real actuator can be substituted on hardware that has one.

Power decomposition (per platform, program resident):

    P(util, f_sm, f_mem) = P_residency(f_sm, f_mem) + util_term(util, f_sm)

``P_residency`` is the loaded-but-inactive floor — the execution-idle power —
and is what frequency downscaling attacks. ``util_term`` scales with visible
activity and compute-clock, saturating at (tdp − residency_floor).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Protocol

import numpy as np


class ClockLevel(enum.IntEnum):
    MIN = 0
    MAX = 1


@dataclasses.dataclass(frozen=True)
class PlatformSpec:
    """One accelerator platform's power/perf envelope."""

    name: str
    tdp_w: float
    deep_idle_w: float
    #: residency floor at (f_max, f_max) — the paper's execution-idle power
    exec_idle_w: float
    #: residency floor with compute clock at min, memory clock at max (§5.3)
    exec_idle_sm_min_w: float
    #: residency floor with both clocks at min (§5.3: reaches deep-idle power)
    exec_idle_all_min_w: float
    #: compute clock range, MHz (for reporting; power interpolates on level)
    sm_clk_mhz: tuple[float, float] = (210.0, 2520.0)
    mem_clk_mhz: tuple[float, float] = (405.0, 9001.0)
    #: perf multiplier at f_min for compute-bound work (throughput ratio;
    #: ~210/2520 MHz with some latency hiding)
    perf_at_min_compute: float = 0.15
    #: perf multiplier at f_min-memory for memory-bound work (~405/9001 MHz
    #: effective bandwidth ratio; LLM decode is memory-bound, so this is the
    #: §5.3 SM+mem latency cliff)
    perf_at_min_memory: float = 0.09
    #: roofline terms (TPU platform only; None for GPUs we never dry-run on)
    peak_bf16_tflops: float | None = None
    hbm_gbps: float | None = None
    ici_gbps_per_link: float | None = None
    hbm_capacity_gib: float | None = None

    def residency_floor_w(self, sm: ClockLevel, mem: ClockLevel) -> float:
        if sm == ClockLevel.MAX and mem == ClockLevel.MAX:
            return self.exec_idle_w
        if sm == ClockLevel.MIN and mem == ClockLevel.MAX:
            return self.exec_idle_sm_min_w
        if sm == ClockLevel.MIN and mem == ClockLevel.MIN:
            return self.exec_idle_all_min_w
        # mem-only downscale: between the sm-only and all-min floors
        return 0.5 * (self.exec_idle_w + self.exec_idle_all_min_w)

    def power_w(
        self,
        util: float,
        sm: ClockLevel = ClockLevel.MAX,
        mem: ClockLevel = ClockLevel.MAX,
        resident: bool = True,
    ) -> float:
        """Board power for a given utilization in [0, 1] and clock levels."""
        if not resident:
            return self.deep_idle_w
        floor = self.residency_floor_w(sm, mem)
        headroom = max(self.tdp_w - self.exec_idle_w, 0.0)
        # active power scales with util; at reduced compute clock both the
        # achievable util-term and its ceiling shrink (cubic-ish f–V scaling
        # approximated with the measured perf_at_min_compute ratio).
        clock_scale = 1.0 if sm == ClockLevel.MAX else self.perf_at_min_compute
        util = float(np.clip(util, 0.0, 1.0))
        # sub-linear power-vs-util (activity counters saturate before power):
        return floor + headroom * clock_scale * util ** 0.9

    def perf_scale(
        self,
        sm: ClockLevel,
        mem: ClockLevel,
        compute_bound_fraction: float = 0.7,
    ) -> float:
        """Throughput multiplier under the given clocks, for a workload that
        is ``compute_bound_fraction`` compute-bound and the rest memory-bound.
        """
        c = 1.0 if sm == ClockLevel.MAX else self.perf_at_min_compute
        m = 1.0 if mem == ClockLevel.MAX else self.perf_at_min_memory
        return 1.0 / (compute_bound_fraction / c + (1.0 - compute_bound_fraction) / m)


# --------------------------------------------------------------------------- #
# Platform registry.
#
# GPU rows: TDP from paper Table 4. L40S floors from §5.3 (105→61→35 W) and
# Fig 2 (deep idle ≈35 W). B200 execution-idle anchored by the paper's 44 s =
# 0.00267 kWh example (≈218 W). Other platforms scaled by TDP class with the
# consistent qualitative gap of Fig 4 (exec-idle ≫ deep-idle on every model).
# --------------------------------------------------------------------------- #
PLATFORMS: dict[str, PlatformSpec] = {}


def _register(spec: PlatformSpec) -> PlatformSpec:
    PLATFORMS[spec.name] = spec
    return spec


L40S = _register(PlatformSpec(
    name="l40s", tdp_w=400.0, deep_idle_w=35.0,
    exec_idle_w=105.0, exec_idle_sm_min_w=61.0, exec_idle_all_min_w=35.0,
))
A6000 = _register(PlatformSpec(
    name="a6000", tdp_w=300.0, deep_idle_w=22.0,
    exec_idle_w=78.0, exec_idle_sm_min_w=48.0, exec_idle_all_min_w=24.0,
))
RTX6000ADA = _register(PlatformSpec(
    name="rtx6000ada", tdp_w=300.0, deep_idle_w=25.0,
    exec_idle_w=82.0, exec_idle_sm_min_w=50.0, exec_idle_all_min_w=27.0,
))
L40 = _register(PlatformSpec(
    name="l40", tdp_w=300.0, deep_idle_w=30.0,
    exec_idle_w=90.0, exec_idle_sm_min_w=55.0, exec_idle_all_min_w=31.0,
))
A100 = _register(PlatformSpec(
    name="a100", tdp_w=400.0, deep_idle_w=52.0,
    exec_idle_w=120.0, exec_idle_sm_min_w=75.0, exec_idle_all_min_w=55.0,
))
H100 = _register(PlatformSpec(
    name="h100", tdp_w=700.0, deep_idle_w=70.0,
    exec_idle_w=165.0, exec_idle_sm_min_w=100.0, exec_idle_all_min_w=74.0,
))
B200 = _register(PlatformSpec(
    name="b200", tdp_w=1000.0, deep_idle_w=130.0,
    exec_idle_w=218.0, exec_idle_sm_min_w=160.0, exec_idle_all_min_w=135.0,
))

#: TPU-v5e-class platform for the framework's own runtime and roofline math.
#: Peak 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (assignment spec).
#: Power envelope modeled (no public per-state figures): residency floor
#: chosen to preserve the paper's qualitative exec-idle ≫ deep-idle gap.
TPU_V5E = _register(PlatformSpec(
    name="tpu_v5e", tdp_w=250.0, deep_idle_w=55.0,
    exec_idle_w=140.0, exec_idle_sm_min_w=90.0, exec_idle_all_min_w=60.0,
    sm_clk_mhz=(400.0, 1700.0), mem_clk_mhz=(600.0, 3200.0),
    peak_bf16_tflops=197.0, hbm_gbps=819.0, ici_gbps_per_link=50.0,
    hbm_capacity_gib=16.0,
))


def get_platform(name: str) -> PlatformSpec:
    try:
        return PLATFORMS[name]
    except KeyError:
        raise KeyError(f"unknown platform {name!r}; known: {sorted(PLATFORMS)}") from None


# --------------------------------------------------------------------------- #
# Actuator protocol + simulated DVFS device.
# --------------------------------------------------------------------------- #
class ClockActuator(Protocol):
    """What Algorithm 1 needs from the platform: set/restore clocks."""

    def set_clocks(self, t_s: float, sm: ClockLevel, mem: ClockLevel) -> None: ...
    def clocks(self) -> tuple[ClockLevel, ClockLevel]: ...


@dataclasses.dataclass
class SimulatedDevice:
    """A DVFS-capable device simulation with frequency-switch latency.

    Velicka et al. [52] measure 1–500 ms per switch; during the switch the
    device stalls (no useful progress), which is how downscaling converts
    into the latency penalty the paper reports.
    """

    platform: PlatformSpec
    switch_latency_s: float = 0.2
    _sm: ClockLevel = ClockLevel.MAX
    _mem: ClockLevel = ClockLevel.MAX
    _switch_done_t: float = 0.0
    switch_count: int = 0

    def set_clocks(self, t_s: float, sm: ClockLevel, mem: ClockLevel) -> None:
        if (sm, mem) == (self._sm, self._mem):
            return
        self._sm, self._mem = sm, mem
        self._switch_done_t = t_s + self.switch_latency_s
        self.switch_count += 1

    def clocks(self) -> tuple[ClockLevel, ClockLevel]:
        return self._sm, self._mem

    def switching(self, t_s: float) -> bool:
        return t_s < self._switch_done_t

    def power_w(self, t_s: float, util: float, resident: bool = True) -> float:
        return self.platform.power_w(util, self._sm, self._mem, resident)

    def perf_scale(self, t_s: float, compute_bound_fraction: float = 0.7) -> float:
        if self.switching(t_s):
            return 0.0  # stalled mid-switch
        return self.platform.perf_scale(self._sm, self._mem, compute_bound_fraction)

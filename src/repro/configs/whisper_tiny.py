"""whisper-tiny [arXiv:2212.04356; unverified] — enc-dec, conv frontend (stub).

4L (enc+dec each), d_model=384, 6H (kv=6), d_ff=1536, vocab=51865.
The audio frontend (2x conv1d, stride 2 -> 1500 frames at 30 s) is a STUB:
``input_specs()`` supplies precomputed frame embeddings (B, n_frames, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,            # decoder layers
    n_enc_layers=4,        # encoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    n_frames=1500,
    rope_theta=0.0,        # whisper uses learned/sinusoidal positions, not RoPE
)

"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B; hf] — QKV bias.

24L, d_model=1024, 16H (kv=16), d_ff=2816, vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
)

"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

100L total, d_model=8192, 64H (kv=8), d_ff=28672, vocab=128256.
Cross-attention image layers: one cross block after every 4 self layers
(20 cross + 80 self = 100). Vision frontend is a STUB: ``input_specs()``
supplies precomputed patch embeddings (B, n_vision_tokens, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,          # total = self + cross
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_every=5,         # every 5th layer is a cross-attn block
    n_vision_tokens=1601,  # 1 tile x (40x40 patches + 1 cls)
    rope_theta=500000.0,
    tie_embeddings=False,
)

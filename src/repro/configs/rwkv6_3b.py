"""rwkv6-3b (Finch) [arXiv:2404.05892; hf] — attention-free, data-dependent decay.

32L, d_model=2560, d_ff=8960, vocab=65536, head_size=64 (40 wkv heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # d_model // rwkv_head_size
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_size=64,
    rwkv_decay_lora=64,
    rwkv_mix_lora=32,
    tie_embeddings=False,  # rwkv uses separate head
)

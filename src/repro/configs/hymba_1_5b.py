"""hymba-1.5b [arXiv:2411.13676; hf] — parallel attention + mamba heads.

32L, d_model=1600, 25H (GQA kv=5), d_ff=5504, vocab=32001, ssm_state=16.
Sliding-window attention (1k) everywhere except 3 global full-attention
layers {0, 15, 31}, per the Hymba paper.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    d_inner=3200,          # 2x d_model mamba expansion
    conv_kernel=4,
    window=1024,
    global_layers=(0, 15, 31),
)

"""deepseek-v3-671b [arXiv:2412.19437; hf] — MLA, 1 shared + 256 routed top-8, MTP.

61L, d_model=7168, 128H, routed-expert d_ff=2048, vocab=129280.
First 3 layers dense (d_ff=18432 per the HF config). MLA dims: q_lora=1536,
kv_lora=512, qk_nope=128, qk_rope=64, v_head=128. One MTP module.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="mla_moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,            # dense-layer FFN width (first_k_dense layers)
    vocab_size=129280,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    d_expert=2048,
    first_k_dense=3,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mtp_depth=1,
    rope_theta=10000.0,
)

"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full published config;
``get_smoke_config(arch_id)`` returns a reduced same-family config for CPU
smoke tests (small layers/width/experts/vocab), per the assignment.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeSpec,
    LM_SHAPES,
    SUBQUADRATIC_ARCHS,
    cell_is_applicable,
)

from repro.configs import (  # noqa: F401
    whisper_tiny,
    deepseek_v3_671b,
    granite_moe_3b_a800m,
    rwkv6_3b,
    hymba_1_5b,
    gemma_2b,
    granite_3_8b,
    qwen1_5_0_5b,
    qwen1_5_4b,
    llama_3_2_vision_90b,
    llama_13b,
)

ARCHS: dict[str, ModelConfig] = {
    "whisper-tiny": whisper_tiny.CONFIG,
    "deepseek-v3-671b": deepseek_v3_671b.CONFIG,
    "granite-moe-3b-a800m": granite_moe_3b_a800m.CONFIG,
    "rwkv6-3b": rwkv6_3b.CONFIG,
    "hymba-1.5b": hymba_1_5b.CONFIG,
    "gemma-2b": gemma_2b.CONFIG,
    "granite-3-8b": granite_3_8b.CONFIG,
    "qwen1.5-0.5b": qwen1_5_0_5b.CONFIG,
    "qwen1.5-4b": qwen1_5_4b.CONFIG,
    "llama-3.2-vision-90b": llama_3_2_vision_90b.CONFIG,
    # the paper's own serving model (trace replay, §2.3)
    "llama-13b": llama_13b.CONFIG,
}

ASSIGNED_ARCHS: tuple[str, ...] = tuple(a for a in ARCHS if a != "llama-13b")


def get_config(arch: str) -> ModelConfig:
    try:
        cfg = ARCHS[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}") from None
    cfg.validate()
    return cfg


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: tiny width/depth/vocab/experts."""
    cfg = get_config(arch)
    n_kv = min(cfg.n_kv_heads, 2)
    n_heads = max(2, 4 // max(1, 4 // max(cfg.q_per_kv, 1)))
    n_heads = n_kv * min(cfg.q_per_kv, 2)
    d_model = 64
    updates: dict[str, object] = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 2),
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads if cfg.head_dim is None else 32,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.is_moe:
        updates.update(n_experts=4, top_k=2, d_expert=32,
                       n_shared_experts=min(cfg.n_shared_experts, 1),
                       first_k_dense=min(cfg.first_k_dense, 1))
    if cfg.is_mla:
        updates.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                       qk_rope_dim=8, v_head_dim=16, mtp_depth=min(cfg.mtp_depth, 1))
    if cfg.family == "rwkv":
        updates.update(rwkv_head_size=16, rwkv_decay_lora=8, rwkv_mix_lora=8)
    if cfg.family == "hybrid":
        updates.update(ssm_state=8, d_inner=128, window=16, global_layers=(0,))
    if cfg.family == "encdec":
        updates.update(n_enc_layers=2, n_frames=16)
    if cfg.family == "vlm":
        updates.update(cross_every=2, n_vision_tokens=8,
                       n_layers=4)  # needs a multiple of cross_every
    return dataclasses.replace(cfg, **updates)

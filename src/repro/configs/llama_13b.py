"""llama-13b [arXiv:2302.13971] — the paper's trace-replay serving model (S2.3).

40L, d_model=5120, 40H MHA, d_ff=13824, vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-13b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=13824,
    vocab_size=32000,
    tie_embeddings=False,
)

"""Model + run configuration dataclasses shared by the whole framework."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "mla_moe", "rwkv", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture. Families reuse fields; family-specific fields are
    ignored elsewhere. All attention is causal unless ``family == encdec``
    (encoder side bidirectional)."""

    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # default d_model // n_heads
    qkv_bias: bool = False               # qwen1.5
    act: Literal["silu", "gelu"] = "silu"  # gemma uses gelu (GeGLU)
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # --- MoE ---------------------------------------------------------- #
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0                    # per-expert FFN width
    first_k_dense: int = 0               # deepseek: first k layers dense
    router_aux_coef: float = 0.001

    # --- MLA (deepseek) ------------------------------------------------ #
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0                   # multi-token-prediction modules

    # --- RWKV ----------------------------------------------------------- #
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # --- hybrid (hymba) -------------------------------------------------- #
    ssm_state: int = 0
    d_inner: int = 0                     # mamba inner width
    conv_kernel: int = 4
    window: int = 0                      # sliding-window size (0 = full attn)
    global_layers: tuple[int, ...] = ()  # layer indices with full attention

    # --- encoder-decoder (whisper) ---------------------------------------- #
    n_enc_layers: int = 0
    n_frames: int = 0                    # stubbed audio-frontend output length

    # --- vlm (llama-3.2-vision) -------------------------------------------- #
    cross_every: int = 0                 # a cross-attn block after every k self layers
    n_vision_tokens: int = 0             # stubbed patch-embedding length

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def validate(self) -> None:
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        if self.is_moe and not (0 < self.top_k <= self.n_experts):
            raise ValueError("bad top_k")
        if self.family == "vlm" and self.cross_every <= 0:
            raise ValueError("vlm needs cross_every")
        if self.family == "encdec" and self.n_enc_layers <= 0:
            raise ValueError("encdec needs n_enc_layers")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


LM_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

#: archs allowed to run long_500k (sub-quadratic decoding state)
SUBQUADRATIC_ARCHS = frozenset({"rwkv6-3b", "hymba-1.5b"})


def cell_is_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in SUBQUADRATIC_ARCHS
    return True

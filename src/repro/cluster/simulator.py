"""Academic-cluster telemetry simulator (the §2.1 deployment, regenerated).

Assembles per-device 1 Hz telemetry streams: unallocated gaps, then jobs
(class-sampled via cluster.jobgen), on a fleet whose platform mix follows the
paper's Table 4. The output TelemetryFrame feeds the SAME analysis pipeline
(telemetry.pipeline / core.*) a real deployment would use — the simulator
exists because the raw 162 GB dataset cannot ship; the pipeline is the
deliverable (DESIGN.md §7, note 4).

Vectorized phase-block assembly: each phase contributes constant blocks +
noise, so a day x 40 devices generates in seconds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cluster import jobgen
from repro.core.power_model import PLATFORMS, PlatformSpec
from repro.telemetry.records import FIELDS, TelemetryFrame, _DTYPES
from repro.telemetry.storage import TelemetryStore

#: fleet platform mix (paper Table 4, profiled subset, normalized)
FLEET_MIX: tuple[tuple[str, float], ...] = (
    ("l40s", 0.54), ("a6000", 0.26), ("rtx6000ada", 0.07),
    ("l40", 0.0), ("a100", 0.085), ("h100", 0.032), ("b200", 0.013),
)

PLATFORM_IDS = {name: i for i, (name, _) in enumerate(FLEET_MIX)}


@dataclasses.dataclass
class ClusterSample:
    frame: TelemetryFrame
    job_classes: dict[int, str]       # job_id -> workload class
    job_platforms: dict[int, str]


def _noise(rng, n, scale):
    return rng.normal(0.0, scale, n)


def _noise_block(rng, n, scales):
    """One ``rng.normal`` draw covering several equal-length noise fields.

    ``Generator.normal`` with an array scale consumes the underlying
    bitstream element by element, exactly like the equivalent sequence of
    per-field ``normal(0, scale, n)`` calls — so collapsing a phase's
    per-field draws into one block keeps every seeded output bit-identical
    (tests/test_telemetry.py) while paying the generator dispatch once per
    phase instead of once per field.
    """
    flat = rng.normal(0.0, np.repeat(scales, n))
    return [flat[i * n:(i + 1) * n] for i in range(len(scales))]


def _phase_signals(rng, phase: jobgen.Phase, plat: PlatformSpec, n: int):
    """Column dict for one phase of n seconds."""
    cols = {f: np.zeros(n) for f in
            ("sm", "tensor", "dram", "pcie_rx", "pcie_tx", "nic_rx", "nic_tx",
             "cpu_util", "power")}
    resident = np.ones(n, np.int8)
    nvlink = np.full(n, np.nan) if plat.name not in jobgen.NVLINK_PLATFORMS \
        else np.zeros(n)
    if phase.kind == "deep":
        resident[:] = 0
        power_n, cpu_n = _noise_block(rng, n, (1.0, 2.0))
        cols["power"] = plat.deep_idle_w + power_n
        cols["cpu_util"] = np.clip(5 + cpu_n, 0, 100)
    elif phase.kind == "idle":
        cols["sm"] = np.clip(rng.uniform(0, 2.5, n), 0, 4.9)
        cols["dram"] = np.clip(rng.uniform(0, 2.0, n), 0, 4.9)
        power_n, cpu_n = _noise_block(rng, n, (3.0, 4.0))
        cols["power"] = plat.exec_idle_w + power_n
        cols["cpu_util"] = np.clip(8 + cpu_n, 0, 100)
    else:  # active
        util = phase.util
        sm_n, tensor_n, dram_n, power_n, cpu_n = _noise_block(
            rng, n, (6.0, 6.0, 8.0, 8.0, 8.0))
        cols["sm"] = np.clip(100 * util + sm_n, 6, 100)
        cols["tensor"] = np.clip(85 * util + tensor_n, 0, 100)
        cols["dram"] = np.clip(70 * util + dram_n, 5.5, 100)
        cols["power"] = np.clip(
            plat.power_w(util) + power_n, plat.exec_idle_w, plat.tdp_w)
        cols["cpu_util"] = np.clip(30 + cpu_n, 0, 100)
        # brief (1-4 s) stalls that the 5 s sustain rule excludes but the
        # permissive 1 s setting counts (Table 2's 19.2% -> 23.8% delta)
        # non-overlapping so adjacent dips can never merge into a >=5 s run
        n_dips = int(rng.poisson(max(n - 45, 0) / 28.0)) if n > 45 else 0
        n_slots = max((n - 24) // 10, 1)
        slots = rng.choice(n_slots, size=min(n_dips, n_slots), replace=False)
        for slot in slots:
            start = 8 + int(slot) * 10
            dlen = int(rng.integers(1, 4))
            sl_d = slice(start, start + dlen)
            cols["sm"][sl_d] = rng.uniform(0, 2.0, dlen)
            cols["tensor"][sl_d] = rng.uniform(0, 2.0, dlen)
            cols["dram"][sl_d] = rng.uniform(0, 1.5, dlen)
            cols["power"][sl_d] = plat.exec_idle_w + _noise(rng, dlen, 3.0)
        # cause signature on the trailing window (3-8 s) — §4.5 fingerprints
        tail = min(n, int(rng.integers(4, 10)))
        sl = slice(n - tail, n)
        if phase.cause == "pcie":
            cols["pcie_rx"][sl] = rng.uniform(3.0, 10.0, tail)
            cols["cpu_util"][sl] = np.clip(65 + _noise(rng, tail, 6), 0, 100)
        elif phase.cause == "nic":
            cols["nic_rx"][sl] = rng.uniform(2.5, 7.0, tail)
            cols["cpu_util"][sl] = np.clip(55 + _noise(rng, tail, 6), 0, 100)
        elif phase.cause == "nvlink" and plat.name in jobgen.NVLINK_PLATFORMS:
            nvlink[sl] = rng.uniform(6.0, 25.0, tail)
        # "compute" cause: high sm/dram straight into idle — already the case
    return cols, resident, nvlink


def _materialize(col_lists: dict[str, list[np.ndarray]]) -> dict[str, np.ndarray]:
    """Concatenate per-field piece lists into schema-typed columns; fields a
    platform never emits (e.g. ici_*) become all-NaN / zero columns."""
    n_total = sum(a.shape[0] for a in col_lists["timestamp"])
    columns = {}
    for f in FIELDS:
        if col_lists[f]:
            arr = np.concatenate(col_lists[f])
        else:
            fill = np.nan if _DTYPES[f].startswith("f") else 0
            arr = np.full(n_total, fill)
        columns[f] = arr.astype(_DTYPES[f])
    return columns


def generate_cluster(
    n_devices: int = 24,
    horizon_s: int = 6 * 3600,
    seed: int = 0,
    min_job_s: int = 1800,
    store: TelemetryStore | None = None,
    shard_s: int = 6 * 3600,
) -> ClusterSample:
    """Simulate the §2.1 deployment.

    With ``store=None`` (default) the whole fleet frame is materialized in
    memory, as before. Passing a :class:`TelemetryStore` switches to chunked
    emission: each device's stream is flushed to the store every ``shard_s``
    samples, so peak memory is one shard (+ one phase block) — day-scale x
    hundreds-of-devices fleets generate without building the fleet frame.
    Shards are appended in (device, time) order, i.e. already in the
    per-stream time order ``analyze_store`` requires, and the emitted rows
    are identical to the monolithic frame for the same seed.
    """
    rng = np.random.default_rng(seed)
    names = [n for n, _ in FLEET_MIX]
    probs = np.array([p for _, p in FLEET_MIX])
    probs = probs / probs.sum()

    all_cols: dict[str, list[np.ndarray]] = {f: [] for f in FIELDS}
    job_classes: dict[int, str] = {}
    job_platforms: dict[int, str] = {}
    job_id = 0

    for dev in range(n_devices):
        plat = PLATFORMS[str(rng.choice(names, p=probs))]
        t = 0
        dev_cols: dict[str, list[np.ndarray]] = {f: [] for f in FIELDS}
        buffered = 0

        def flush(force: bool = False):
            """Chunked emission: spill the device buffer into <=shard_s-row
            shards; a sub-shard remainder stays buffered unless forced."""
            nonlocal buffered
            if store is None or buffered == 0 or (buffered < shard_s and not force):
                return
            cols = _materialize(dev_cols)
            start = 0
            while buffered - start >= shard_s or (force and start < buffered):
                end = min(start + shard_s, buffered)
                store.write_shard(
                    TelemetryFrame({k: v[start:end] for k, v in cols.items()}),
                    host=f"h{dev // 4}",
                    day=int(cols["timestamp"][start]) // 86400,
                    flush_manifest=False)
                start = end
            for f in FIELDS:
                dev_cols[f][:] = [cols[f][start:]] if start < buffered else []
            buffered -= start

        def emit(cols, resident, nvlink, n, jid):
            nonlocal buffered
            buffered += n
            ts = np.arange(t, t + n, dtype=np.float64)
            dev_cols["timestamp"].append(ts)
            dev_cols["hostname"].append(np.full(n, dev // 4, np.int32))
            dev_cols["device_id"].append(np.full(n, dev, np.int32))
            dev_cols["platform"].append(
                np.full(n, PLATFORM_IDS.get(plat.name, 0), np.int32))
            dev_cols["job_id"].append(np.full(n, jid, np.int64))
            dev_cols["program_resident"].append(resident)
            for f in ("sm", "tensor", "dram", "pcie_rx", "pcie_tx",
                      "nic_rx", "nic_tx", "cpu_util", "power"):
                dev_cols[f].append(cols[f])
            dev_cols["nvlink_tx"].append(nvlink)
            dev_cols["nvlink_rx"].append(nvlink.copy())
            for f in ("fp16", "fp32", "fp64", "ici_tx", "ici_rx"):
                dev_cols[f].append(np.full(n, np.nan))
            dev_cols["host_mem_util"].append(np.full(n, 35.0))
            dev_cols["sm_clk"].append(np.full(n, plat.sm_clk_mhz[1]))
            dev_cols["mem_clk"].append(np.full(n, plat.mem_clk_mhz[1]))

        while t < horizon_s:
            # unallocated gap (deep idle, no job)
            gap = int(rng.lognormal(np.log(600), 0.8))
            gap = min(gap, horizon_s - t)
            if gap > 0:
                n = gap
                cols = {f: np.zeros(n) for f in
                        ("sm", "tensor", "dram", "pcie_rx", "pcie_tx",
                         "nic_rx", "nic_tx", "cpu_util", "power")}
                cols["power"] = plat.deep_idle_w + rng.normal(0, 1, n)
                nv = (np.full(n, np.nan)
                      if plat.name not in jobgen.NVLINK_PLATFORMS else np.zeros(n))
                emit(cols, np.zeros(n, np.int8), nv, n, -1)
                t += n
                flush()
            if t >= horizon_s:
                break

            klass = jobgen.sample_class(rng)
            phases, duration = jobgen.job_phases(rng, klass, plat)
            jid = job_id
            job_id += 1
            job_classes[jid] = klass.name
            job_platforms[jid] = plat.name
            for ph in phases:
                if t >= horizon_s:
                    break
                n = min(ph.duration_s, horizon_s - t)
                if n <= 0:
                    continue
                cols, resident, nvlink = _phase_signals(rng, ph, plat, n)
                emit(cols, resident, nvlink, n, jid)
                t += n
                flush()

        if store is not None:
            flush(force=True)
        else:
            for f in FIELDS:
                if dev_cols[f]:
                    all_cols[f].append(np.concatenate(dev_cols[f]))

    if store is not None:
        store.save_manifest()
    frame = (TelemetryFrame({f: np.empty(0, dtype=_DTYPES[f]) for f in FIELDS})
             if store is not None else TelemetryFrame(_materialize(all_cols)))
    return ClusterSample(frame=frame,
                         job_classes=job_classes,
                         job_platforms=job_platforms)

"""Workload-class job generators for the academic-cluster simulator (§2-4).

Each job is a sequence of phases (deep-idle setup, active bursts,
execution-idle intervals) whose statistics are calibrated to the paper:

* exec-idle interval durations: median 9 s / p90 44 s / p99 836 s (Fig 8)
  via a 4-component lognormal mixture,
* per-job exec-idle fractions per class (Fig 5 / Fig 7): serving ~61% of
  in-execution time, training ~13%, batch inference ~12%, others ~5%, with
  right-skewed per-job spread,
* pre-idle causes: PCIe 48% / compute-to-idle 33% / NIC 17% / NVLink 2%
  (Fig 9) — the tail of each active burst carries the cause's signal
  signature (NVLink causes only on NVLink platforms: A100/H100/B200),
* deep-idle setup ~24% of job-attributed time (Fig 3b).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.power_model import PlatformSpec

# ---------------------------------------------------------------------------
# exec-idle interval duration mixture (calibrated against Fig 8)
# ---------------------------------------------------------------------------
INTERVAL_MIX = (
    # (weight, ln-median, sigma)
    (0.63, np.log(7.6), 0.40),
    (0.28, np.log(18.0), 0.55),
    (0.075, np.log(95.0), 0.80),
    (0.015, np.log(1250.0), 0.60),
)
MIN_INTERVAL_S, MAX_INTERVAL_S = 5.0, 3600.0


def sample_interval(rng: np.random.Generator) -> float:
    w = np.array([m[0] for m in INTERVAL_MIX])
    i = rng.choice(len(INTERVAL_MIX), p=w / w.sum())
    _, mu, sigma = INTERVAL_MIX[i]
    return float(np.clip(rng.lognormal(mu, sigma), MIN_INTERVAL_S, MAX_INTERVAL_S))


# ---------------------------------------------------------------------------
# pre-idle causes (Fig 9)
# ---------------------------------------------------------------------------
CAUSES = ("pcie", "compute", "nic", "nvlink")
#: global target shares (paper Fig 9): pcie .48 / compute .33 / nic .17 /
#: nvlink .02. NVLink onsets exist only on NVLink platforms (~13% of the
#: fleet), so the per-platform rates below are chosen to hit the global mix.
CAUSE_P_NVLINK = (0.42, 0.28, 0.15, 0.15)
CAUSE_P_PLAIN = (0.49, 0.335, 0.175, 0.0)
NVLINK_PLATFORMS = frozenset({"a100", "h100", "b200"})


def sample_cause(rng: np.random.Generator, platform: str) -> str:
    p = np.array(CAUSE_P_NVLINK if platform in NVLINK_PLATFORMS
                 else CAUSE_P_PLAIN)
    return str(rng.choice(CAUSES, p=p / p.sum()))


# ---------------------------------------------------------------------------
# workload classes
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkloadClass:
    name: str
    #: probability a job belongs to this class (by count; serving = 14.6%, §4.2)
    count_share: float
    #: per-job exec-idle fraction sampler params: mixture of two Betas
    beta_lo: tuple[float, float]
    beta_hi: tuple[float, float]
    hi_weight: float
    #: job duration lognormal (s)
    dur_median_s: float
    dur_sigma: float
    #: active-phase utilization range
    util_range: tuple[float, float]


CLASSES: dict[str, WorkloadClass] = {
    "serving": WorkloadClass(
        name="serving", count_share=0.146,
        beta_lo=(2.2, 1.7), beta_hi=(5.0, 1.8), hi_weight=0.30,
        dur_median_s=2.8 * 3600, dur_sigma=0.30, util_range=(0.08, 0.35)),
    "training": WorkloadClass(
        name="training", count_share=0.40,
        beta_lo=(0.5, 15.0), beta_hi=(2.2, 2.2), hi_weight=0.17,
        dur_median_s=2.6 * 3600, dur_sigma=0.5, util_range=(0.22, 0.62)),
    "batch_inference": WorkloadClass(
        name="batch_inference", count_share=0.25,
        beta_lo=(0.5, 15.0), beta_hi=(2.2, 2.2), hi_weight=0.15,
        dur_median_s=2.4 * 3600, dur_sigma=0.45, util_range=(0.2, 0.58)),
    "other": WorkloadClass(
        name="other", count_share=0.204,
        beta_lo=(0.6, 20.0), beta_hi=(1.5, 2.0), hi_weight=0.02,
        dur_median_s=2.2 * 3600, dur_sigma=0.5, util_range=(0.2, 0.62)),
}


def sample_class(rng: np.random.Generator) -> WorkloadClass:
    names = list(CLASSES)
    p = np.array([CLASSES[n].count_share for n in names])
    return CLASSES[str(rng.choice(names, p=p / p.sum()))]


def sample_job_idle_fraction(rng: np.random.Generator, klass: WorkloadClass) -> float:
    if rng.random() < klass.hi_weight:
        a, b = klass.beta_hi
    else:
        a, b = klass.beta_lo
    return float(np.clip(rng.beta(a, b), 0.003, 0.97))


# ---------------------------------------------------------------------------
# phase stream
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Phase:
    kind: str          # "deep" | "active" | "idle"
    duration_s: int
    util: float = 0.0
    cause: str = ""    # cause signature carried by the END of an active phase


def job_phases(rng: np.random.Generator, klass: WorkloadClass,
               platform: PlatformSpec) -> tuple[list[Phase], float]:
    """Generate one job's phase list. Returns (phases, duration_s)."""
    duration = float(np.clip(rng.lognormal(np.log(klass.dur_median_s),
                                           klass.dur_sigma), 1800, 36 * 3600))
    f_idle = sample_job_idle_fraction(rng, klass)
    setup_frac = float(np.clip(rng.uniform(0.08, 0.34), 0, 0.5))

    phases: list[Phase] = [Phase("deep", max(30, int(duration * setup_frac)))]
    remaining = duration * (1 - setup_frac)

    # alternate active/idle with E[active] set by the target fraction
    mean_idle = 26.0   # mean of the interval mixture (s)
    mean_active = mean_idle * (1 - f_idle) / max(f_idle, 1e-3)
    while remaining > 5:
        active_s = float(np.clip(rng.lognormal(
            np.log(max(mean_active, 3.0)), 0.6), 3, remaining))
        cause = sample_cause(rng, platform.name)
        util = float(rng.uniform(*klass.util_range))
        phases.append(Phase("active", int(active_s), util, cause))
        remaining -= active_s
        if remaining <= 5:
            break
        idle_s = float(min(sample_interval(rng), remaining))
        phases.append(Phase("idle", int(idle_s)))
        remaining -= idle_s
    return phases, duration

"""Academic-cluster telemetry simulator (paper §2.1 deployment, regenerated)."""
from repro.cluster.simulator import generate_cluster, ClusterSample  # noqa: F401

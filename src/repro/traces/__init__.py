"""Industry serving-trace models + replay (paper §2.3)."""
from repro.traces.models import TRACES, TraceSpec, generate_trace, get_trace  # noqa: F401

"""Industry serving-trace models (Azure Code/Chat, BurstGPT Chat, Qwen
Reason/Chat) — synthesized from published statistics (§2.3 adaptation note 3).

Raw trace files are not shippable offline; each generator reproduces the
structure the paper reports: per-GPU inter-request medians of ~4-8 s with
heavier tails for BurstGPT Chat / Qwen Reason (Fig 6), and token-length mixes
that land the replay's busy fractions at the paper's exec-idle numbers
(Fig 5 right) under the calibrated Llama-13B/L40S perf model.

Inter-arrival: lognormal (optionally burst-mixture); prompt/output lengths:
lognormal with trace-specific means.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.serving.latency import Request


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    name: str
    #: lognormal inter-arrival: median (s) and sigma
    gap_median_s: float
    gap_sigma: float
    #: probability of a burst arrival (short-gap mixture component)
    burst_p: float
    burst_gap_median_s: float
    #: token-length lognormals
    prompt_mean: float
    prompt_sigma: float
    output_mean: float
    output_sigma: float
    #: device utilization while serving (power-model input; reasoning-style
    #: long-decode traces batch better -> higher util)
    busy_util: float
    #: paper-reported replay exec-idle fractions (validation targets)
    paper_time_fraction: float
    paper_energy_fraction: float


TRACES: dict[str, TraceSpec] = {
    "azure_code": TraceSpec(
        name="azure_code", gap_median_s=7.45, gap_sigma=0.8,
        burst_p=0.40, burst_gap_median_s=1.0,
        prompt_mean=1500, prompt_sigma=0.6, output_mean=25, output_sigma=0.5,
        busy_util=0.25, paper_time_fraction=0.76, paper_energy_fraction=0.65),
    "azure_chat": TraceSpec(
        name="azure_chat", gap_median_s=5.49, gap_sigma=0.7,
        burst_p=0.0, burst_gap_median_s=0.5,
        prompt_mean=1024, prompt_sigma=0.7, output_mean=210, output_sigma=0.5,
        busy_util=0.25, paper_time_fraction=0.29, paper_energy_fraction=0.17),
    "burstgpt_chat": TraceSpec(
        name="burstgpt_chat", gap_median_s=11.69, gap_sigma=1.2,
        burst_p=0.35, burst_gap_median_s=1.2,
        prompt_mean=900, prompt_sigma=0.7, output_mean=150, output_sigma=0.6,
        busy_util=0.45, paper_time_fraction=0.72, paper_energy_fraction=0.52),
    "qwen_reason": TraceSpec(
        name="qwen_reason", gap_median_s=10.40, gap_sigma=1.1,
        burst_p=0.0, burst_gap_median_s=0.5,
        prompt_mean=950, prompt_sigma=0.6, output_mean=640, output_sigma=0.5,
        busy_util=0.35, paper_time_fraction=0.18, paper_energy_fraction=0.08),
    "qwen_chat": TraceSpec(
        name="qwen_chat", gap_median_s=5.74, gap_sigma=0.68,
        burst_p=0.0, burst_gap_median_s=0.5,
        prompt_mean=800, prompt_sigma=0.6, output_mean=280, output_sigma=0.5,
        busy_util=0.3, paper_time_fraction=0.14, paper_energy_fraction=0.07),
}


def generate_trace(spec: TraceSpec, duration_s: float, n_devices: int = 1,
                   seed: int = 0) -> list[Request]:
    """Per-device renewal streams concatenated (device pre-assignment models
    the paper's fixed per-GPU replay; pool experiments re-route via the
    scheduler instead and ignore the pre-assignment)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([zlib.crc32(spec.name.encode()), seed]))
    requests: list[Request] = []
    rid = 0
    for dev in range(n_devices):
        t = float(rng.exponential(spec.gap_median_s))
        while t < duration_s:
            prompt = max(8, int(rng.lognormal(np.log(spec.prompt_mean), spec.prompt_sigma)))
            output = max(1, int(rng.lognormal(np.log(spec.output_mean), spec.output_sigma)))
            requests.append(Request(req_id=rid, arrival_s=t,
                                    prompt_tokens=prompt, output_tokens=output,
                                    device=dev))
            rid += 1
            if rng.random() < spec.burst_p:
                gap = rng.lognormal(np.log(spec.burst_gap_median_s), 0.5)
            else:
                gap = rng.lognormal(np.log(spec.gap_median_s), spec.gap_sigma)
            t += float(gap)
    requests.sort(key=lambda r: r.arrival_s)
    return requests


def get_trace(name: str) -> TraceSpec:
    try:
        return TRACES[name]
    except KeyError:
        raise KeyError(f"unknown trace {name!r}; known: {sorted(TRACES)}") from None

"""Pallas TPU kernel for the run-level replay's cap-bucket scan.

The PowerCap run evaluator reduces every cap fraction to ``k = #{p >
cap}`` against a stream's *sorted* per-state power buckets
(:meth:`repro.whatif.ir.StreamIR.cap_buckets`): clipped energy, throttle
count and the cube-law penalty are then O(1) gathers into prefix sums.
This module provides that scan for the JAX backend
(:mod:`repro.whatif.backend`):

* :func:`cap_bucket_scan` — the Pallas kernel: one sorted row per grid
  step, a fixed-trip vectorized binary search over the config axis in
  VMEM (no per-config HBM traffic);
* :func:`cap_bucket_scan_reference` — the pure-jnp oracle (vmapped
  ``searchsorted``), which is also the faster choice under XLA:CPU;
* :func:`cap_bucket_counts` — the dispatcher the backend calls: the
  compiled Pallas kernel on TPU, the jnp reference elsewhere (the
  ``_default_interpret()`` pattern from :mod:`repro.kernels.ops`).

Rows may be *front-padded* with ``-inf`` to a common bucket width: since
``-inf <= cap`` always, padding inflates the searchsorted insertion point
and ``n - insertion`` still counts exactly the real samples above the cap.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def default_interpret() -> bool:
    """Run Pallas kernels in interpret mode? True everywhere but TPU, with
    a ``REPRO_PALLAS_INTERPRET=0/1`` env override for CI and debugging."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "")
    return jax.default_backend() != "tpu"


def _cap_scan_kernel(sp_ref, caps_ref, k_ref, *, n: int, iters: int):
    sp = sp_ref[...][0]                       # [Np] ascending
    caps = caps_ref[...]                      # [1, C]
    lo = jnp.zeros(caps.shape, dtype=jnp.int32)
    hi = jnp.full(caps.shape, n, dtype=jnp.int32)
    # bisect_right with a static trip count: lo converges to the insertion
    # point (#{p <= cap}) in <= log2(n)+1 halvings; exhausted lanes keep
    # lo == hi and stop moving
    for _ in range(iters):
        cont = lo < hi
        mid = jnp.minimum((lo + hi) // 2, n - 1)
        v = jnp.take(sp, mid[0], axis=0)[None, :]
        go_right = cont & (v <= caps)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(cont & ~go_right, mid, hi)
    k_ref[...] = n - lo


def cap_bucket_scan(sorted_p, caps, interpret: bool = False):
    """``k[r, c] = #{sorted_p[r, :] > caps[r, c]}`` via Pallas.

    ``sorted_p``: [rows, Np] ascending (``-inf`` front-padding allowed);
    ``caps``: [rows, C]. Returns int32 [rows, C], exactly
    ``Np - searchsorted(sorted_p[r], caps[r], side="right")``.
    """
    rows, n = sorted_p.shape
    c = caps.shape[1]
    kernel = functools.partial(_cap_scan_kernel, n=n,
                               iters=max(n.bit_length(), 1))
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, c), jnp.int32),
        interpret=interpret,
    )(sorted_p, caps)


def cap_bucket_scan_reference(sorted_p, caps):
    """Pure-jnp oracle: vmapped ``searchsorted(side="right")`` per row."""
    ub = jax.vmap(lambda sp, cv: jnp.searchsorted(sp, cv, side="right"))(
        sorted_p, caps)
    return (sorted_p.shape[1] - ub).astype(jnp.int32)


def cap_bucket_counts(sorted_p, caps, use_pallas: bool | None = None):
    """Backend dispatcher: compiled Pallas kernel on TPU, jnp elsewhere
    (interpret-mode Pallas is far slower than XLA:CPU searchsorted)."""
    if use_pallas is None:
        use_pallas = not default_interpret()
    if use_pallas:
        return cap_bucket_scan(sorted_p, caps)
    return cap_bucket_scan_reference(sorted_p, caps)

"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True everywhere but TPU (where the compiled
kernels are the target); ``REPRO_PALLAS_INTERPRET=0/1`` overrides the
detection (see :func:`repro.kernels.run_replay.default_interpret`), so
CPU-only CI can force interpret mode regardless of what
``jax.default_backend()`` reports. The wrappers also adapt between the
model-code layout (B, S, H, d) and the kernels' head-major layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import run_replay as _rr
from repro.kernels import rwkv6_scan as _wkv
from repro.kernels import ssm_scan as _ssm
from repro.kernels import rmsnorm as _rms

#: canonical interpret-mode detection, shared with the run_replay kernel
_default_interpret = _rr.default_interpret


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B,S,H,d); k/v: (B,S,KV,d) — model layout. Returns (B,S,H,d)."""
    interpret = _default_interpret() if interpret is None else interpret
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    out = _fa.flash_attention(qh, kh, vh, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, cache_len, block_k: int = 512,
                     interpret: bool | None = None):
    """q: (B,1,H,d); caches: (B,S,KV,d) — model layout. Returns (B,1,H,d)."""
    interpret = _default_interpret() if interpret is None else interpret
    qh = q[:, 0]                                   # (B,H,d)
    kh = jnp.swapaxes(k_cache, 1, 2)               # (B,KV,S,d)
    vh = jnp.swapaxes(v_cache, 1, 2)
    out = _da.decode_attention(qh, kh, vh, cache_len, block_k=block_k,
                               interpret=interpret)
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, chunk: int = 32, interpret: bool | None = None):
    """r/k/v/w: (B,S,H,K) model layout; u: (H,K). Returns ((B,S,H,K), state)."""
    interpret = _default_interpret() if interpret is None else interpret
    args = [jnp.swapaxes(t, 1, 2) for t in (r, k, v, w)]
    y, state = _wkv.wkv6(*args, u, chunk=chunk, interpret=interpret)
    return jnp.swapaxes(y, 1, 2), state


@functools.partial(jax.jit, static_argnames=("chunk", "block_i", "interpret"))
def ssm_scan(u, dt, a, b, c, chunk: int = 32, block_i: int = 256,
             interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssm.ssm_scan(u, dt, a, b, c, chunk=chunk, block_i=block_i,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, weight, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rms.rmsnorm(x, weight, eps=eps, block_rows=block_rows,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def cap_bucket_scan(sorted_p, caps, use_pallas: bool | None = None):
    """``#{sorted_p[r] > caps[r, c]}`` per row — the run-replay cap scan.
    ``use_pallas=None`` resolves to the compiled kernel on TPU and the jnp
    reference elsewhere (:func:`repro.kernels.run_replay.cap_bucket_counts`)."""
    return _rr.cap_bucket_counts(sorted_p, caps, use_pallas=use_pallas)

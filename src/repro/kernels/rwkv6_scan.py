"""Pallas TPU chunked WKV-6 recurrence (RWKV-6 "Finch").

TPU-native adaptation of the RWKV CUDA kernel: instead of one thread per
channel, the sequence is processed in chunks with the (K, V) state matrix
resident in VMEM scratch across the (sequential) chunk grid dimension.
Within a chunk the recurrence is evaluated in closed form with *log-space
decay differences* (exponents always <= 0, so no overflow for any decay):

    y_t = r_t . (D_t * S_0)                      (carry-in state, D_t = exp(cum_{t-1}))
        + sum_{i<t} (r_t . exp(cum_{t-1}-cum_i) k_i) v_i     (intra-chunk)
        + (r_t . (u * k_t)) v_t                  (bonus)
    S' = exp(cum_{C-1}) * S_0 + sum_i exp(cum_{C-1}-cum_i) k_i v_i^T

The intra-chunk pair term materializes a (C, C, K) decay tensor in VMEM
(C=32/64, K=64 -> <= 1 MiB), trading FLOPs for exactness — the standard
matmul-form decomposition divides by cumulative decays and overflows f32.

Grid: (B, H, n_chunks), chunk dimension innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_out_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, 0].astype(jnp.float32)      # (C, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)      # (C, V)
    w = w_ref[0, 0].astype(jnp.float32)      # (C, K) decay in (0, 1)
    u = u_ref[0, 0].astype(jnp.float32)      # (1, K) bonus

    logw = jnp.log(jnp.maximum(w, 1e-30))
    cum = jnp.cumsum(logw, axis=0)           # cum_t = sum_{s<=t} logw_s

    # carry-in contribution: D_t = exp(cum_{t-1}), D_0 = 1
    cum_prev = jnp.concatenate([jnp.zeros((1, k.shape[1]), jnp.float32),
                                cum[:-1]], axis=0)
    s0 = state_ref[...]                      # (K, V)
    y = jax.lax.dot_general(r * jnp.exp(cum_prev), s0,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # intra-chunk strictly-causal pair term with per-channel decays
    # decay[t, i, k] = exp(cum_prev[t, k] - cum[i, k]) for i < t (<= 0 exponent)
    diff = cum_prev[:, None, :] - cum[None, :, :]          # (C, C, K)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = (i_idx < t_idx)[:, :, None]
    pair = jnp.where(causal, jnp.exp(diff), 0.0)
    a = jnp.einsum("tk,ik,tik->ti", r, k, pair)            # (C, C)

    # bonus diagonal (current token)
    bonus = jnp.sum(r * (u * k), axis=1)                   # (C,)
    a = a + jnp.diag(bonus)
    y = y + jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S' = exp(cum_last) * S0 + sum_i exp(cum_last - cum_i) k_i v_i
    cum_last = cum[-1]                                     # (K,)
    k_scaled = k * jnp.exp(cum_last[None, :] - cum)        # (C, K)
    new_state = jnp.exp(cum_last)[:, None] * s0 + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_ref[...] = new_state

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = new_state


def wkv6(r, k, v, w, u, *, chunk: int = 32, interpret: bool = False):
    """r/k/v/w: (B, H, S, K) — w is the per-step decay in (0,1); u: (H, K).

    Returns (y (B, H, S, K_v), final_state (B, H, K, K_v)). K_v == K here.
    """
    b, h, s, kd = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    u4 = jnp.broadcast_to(u.reshape(1, h, 1, kd), (1, h, 1, kd))

    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=nc)
    y, final_state = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, kd), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk, kd), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk, kd), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk, kd), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, 1, kd), lambda ib, ih, ic: (0, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, kd), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, kd, kd), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, kd), r.dtype),
            jax.ShapeDtypeStruct((b, h, kd, kd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kd, kd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u4)
    return y, final_state

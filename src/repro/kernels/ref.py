"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

These are deliberately simple O(S^2) / sequential implementations; kernel
tests sweep shapes/dtypes and assert allclose against them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def mha_reference(q, k, v, causal: bool = True, window: int = 0):
    """q: (B,H,Sq,d); k/v: (B,KV,Sk,d) -> (B,H,Sq,d) f32."""
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    k = jnp.repeat(k, h // kvh, axis=1)
    v = jnp.repeat(v, h // kvh, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


def decode_attention_reference(q, k_cache, v_cache, cache_len):
    """q: (B,H,d); caches: (B,KV,S,d) -> (B,H,d) f32."""
    b, h, d = q.shape
    kvh, s = k_cache.shape[1], k_cache.shape[2]
    k = jnp.repeat(k_cache, h // kvh, axis=1)
    v = jnp.repeat(v_cache, h // kvh, axis=1)
    scores = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    scores = jnp.where(jnp.arange(s)[None, None, :] < cache_len, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32))


def wkv6_reference(r, k, v, w, u, state0=None):
    """Sequential WKV-6. r/k/v/w: (B,H,S,K); u: (H,K)."""
    b, h, s, kd = r.shape
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    state = (jnp.zeros((b, h, kd, kd), jnp.float32) if state0 is None
             else state0.astype(jnp.float32))

    def step(state, t):
        kv = k[:, :, t, :, None] * v[:, :, t, None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r[:, :, t],
                       state + u[None, :, :, None] * kv)
        state = w[:, :, t, :, None] * state + kv
        return state, y

    state, ys = jax.lax.scan(step, state, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 2), state


def ssm_scan_reference(u, dt, a, b, c, h0=None):
    """Sequential selective scan. u/dt: (B,S,I); a: (I,N); b/c: (B,S,N)."""
    bsz, s, di = u.shape
    n = a.shape[-1]
    u, dt, b, c = (t.astype(jnp.float32) for t in (u, dt, b, c))
    h = jnp.zeros((bsz, di, n), jnp.float32) if h0 is None else h0

    def step(h, t):
        da = jnp.exp(dt[:, t, :, None] * a)
        h = da * h + dt[:, t, :, None] * b[:, t, None, :] * u[:, t, :, None]
        y = jnp.einsum("bin,bn->bi", h, c[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1), h


def rmsnorm_reference(x, weight, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(x.dtype)

"""Pallas TPU decode attention: one query token vs a long KV cache.

Flash-decoding-style schedule: grid (batch, kv_heads, kv_blocks) with the kv
dimension innermost; all q heads in one KV group are processed together as an
MXU-friendly (q_per_kv, d) tile, with online-softmax stats in VMEM scratch.
``cache_len`` arrives via scalar prefetch (SMEM) and masks invalid cache
slots, so one compiled kernel serves every fill level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                   scale: float, block_k: int, n_kv_blocks: int):
    ik = pl.program_id(2)
    cache_len = len_ref[0]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip blocks entirely past the valid cache
    @pl.when(ik * block_k < cache_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                # (qpk, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < cache_len, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, block_k: int = 512,
                     interpret: bool = False):
    """q: (B, H, d) one token; caches: (B, KV, S, d); cache_len scalar int32.

    Returns (B, H, d). Layout is head-major like flash_attention.
    """
    b, h, d = q.shape
    _, kv, s, _ = k_cache.shape
    assert h % kv == 0
    qpk = h // kv
    block_k = min(block_k, s)
    assert s % block_k == 0
    nk = s // block_k
    scale = 1.0 / np.sqrt(d)

    q4 = q.reshape(b, kv, qpk, d)
    cache_len = jnp.asarray(cache_len, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, kv, nk),
            in_specs=[
                # index maps receive the scalar-prefetch ref as a trailing arg
                pl.BlockSpec((1, 1, qpk, d), lambda ib, ih, ik, _len: (ib, ih, 0, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda ib, ih, ik, _len: (ib, ih, ik, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda ib, ih, ik, _len: (ib, ih, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, qpk, d), lambda ib, ih, ik, _len: (ib, ih, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((qpk, d), jnp.float32),
                pltpu.VMEM((qpk,), jnp.float32),
                pltpu.VMEM((qpk,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, qpk, d), q.dtype),
        interpret=interpret,
    )(cache_len, q4, k_cache, v_cache)
    return out.reshape(b, h, d)

"""Pallas TPU Mamba selective-scan (chunked, state in VMEM scratch).

Grid (B, inner-blocks, chunks) with the chunk dimension sequential; the
(I_blk, N) SSM state lives in VMEM scratch across chunks. Within a chunk the
recurrence uses the same log-space cumulative-decay closed form as the WKV
kernel — safe because a < 0 makes every exponent non-positive:

    h_t = exp(cumA_t) h_0 + sum_{j<=t} exp(cumA_t - cumA_j) dt_j B_j u_j
    y_t = C_t . h_t  (+ D u_t applied by the caller)

The pair term materializes (C, C) per (i, n) slice via an einsum over a
(C, C, I_blk) tile; N=16 keeps it small. Channels are tiled by ``block_i``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                h_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    u = u_ref[0].astype(jnp.float32)         # (C, I)
    dt = dt_ref[0].astype(jnp.float32)       # (C, I)
    a = a_ref[0].astype(jnp.float32)         # (I, N)  (a < 0)
    bb = b_ref[0].astype(jnp.float32)        # (C, N)
    cc = c_ref[0].astype(jnp.float32)        # (C, N)

    # dA_t[i, n] = dt[t, i] * a[i, n];  cum[t] = sum_{s<=t} dA_s  (<= 0)
    da = dt[:, :, None] * a[None, :, :]                       # (C, I, N)
    cum = jnp.cumsum(da, axis=0)
    dbu = dt[:, :, None] * u[:, :, None] * bb[:, None, :]     # (C, I, N)

    h0 = h_ref[...]                                           # (I, N)
    # carry-in term: exp(cum_t) * h0
    h_carry = jnp.exp(cum) * h0[None]                         # (C, I, N)
    # pair term: sum_{j<=t} exp(cum_t - cum_j) dbu_j  (exponent <= 0)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = (j_idx <= t_idx)[:, :, None, None]
    diff = cum[:, None] - cum[None, :]                        # (C, C, I, N)
    pair = jnp.where(causal, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    h_pair = jnp.einsum("tjin,jin->tin", pair, dbu)
    h = h_carry + h_pair                                      # (C, I, N)

    y = jnp.einsum("tin,tn->ti", h, cc)                       # (C, I)
    y_ref[0] = y.astype(y_ref.dtype)
    h_ref[...] = h[-1]

    @pl.when(ic == n_chunks - 1)
    def _emit():
        state_out_ref[0] = h[-1]


def ssm_scan(u, dt, a, b, c, *, chunk: int = 32, block_i: int = 256,
             interpret: bool = False):
    """u/dt: (B, S, I); a: (I, N); b/c: (B, S, N). Returns (y, h_final).

    y: (B, S, I) (without the D-skip term); h_final: (B, I, N).
    """
    bsz, s, di = u.shape
    n = a.shape[-1]
    chunk = min(chunk, s)
    block_i = min(block_i, di)
    assert s % chunk == 0 and di % block_i == 0
    nc, ni = s // chunk, di // block_i

    # layouts: time-major per (batch, i-block)
    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=nc)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(bsz, ni, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_i), lambda ib, ii, ic: (ib, ic, ii)),
            pl.BlockSpec((1, chunk, block_i), lambda ib, ii, ic: (ib, ic, ii)),
            pl.BlockSpec((1, block_i, n), lambda ib, ii, ic: (0, ii, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, ii, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, ii, ic: (ib, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_i), lambda ib, ii, ic: (ib, ic, ii)),
            pl.BlockSpec((1, block_i, n), lambda ib, ii, ic: (ib, ii, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), u.dtype),
            jax.ShapeDtypeStruct((bsz, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_i, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, a.reshape(1, di, n), b, c)
    return y, h_final

"""Pallas TPU kernels for the serving/training hot paths.

flash_attention, decode_attention, rwkv6_scan, ssm_scan, rmsnorm — each with
a jit'd wrapper in ops.py and a pure-jnp oracle in ref.py. Validated in
interpret mode on CPU; compiled kernels target TPU (see DESIGN.md §2).
"""

"""Pallas TPU fused RMSNorm (+ scale) row kernel.

Rows are tiled (block_rows, d) into VMEM; variance is accumulated in f32 and
the normalized/scaled output is written back in the input dtype — one HBM
read + one write per element (XLA's unfused chain reads x twice).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (normed * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, weight, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., D); weight: (D,). Fused RMSNorm over the last axis."""
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    rows = x2.shape[0]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n_blocks = x2.shape[0] // block_rows

    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, weight)
    if pad:
        out = out[:rows]
    return out.reshape(shape)

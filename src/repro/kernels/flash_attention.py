"""Pallas TPU flash attention (forward) with causal/sliding-window masks + GQA.

TPU-native adaptation: the canonical online-softmax flash schedule tiled for
VMEM/MXU — grid (batch, q_heads, q_blocks, kv_blocks) with the kv dimension
innermost (sequential on TPU), f32 accumulator/row-stats in VMEM scratch that
persist across kv steps, and MXU-aligned (multiple-of-128) block shapes.
GQA is expressed in the k/v BlockSpec index maps (q-head h reads kv-head
h // q_per_kv) so no repeated KV materialization happens in HBM.

Validated in interpret mode against ``ref.mha_reference`` (this container is
CPU-only; TPU is the target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # skip fully-masked blocks (strictly above the causal diagonal /
    # strictly outside the window)
    run = True
    if causal:
        run = ik * block_k <= iq * block_q + block_q - 1
    if window > 0:
        run = jnp.logical_and(run, (ik + 1) * block_k - 1 > iq * block_q - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, H, Sq, d); k/v: (B, KV, Sk, d). Returns (B, H, Sq, d).

    Head-major layout (batch, heads, seq, head_dim) so BlockSpecs tile the
    (seq, head_dim) plane per (batch, head) grid cell.
    """
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    assert h % kv == 0
    q_per_kv = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, _qpk=q_per_kv: (ib, ih // _qpk, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, _qpk=q_per_kv: (ib, ih // _qpk, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Serving substrate: JAX engine, pool DES, latency stats, perf models."""

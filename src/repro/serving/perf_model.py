"""Serving performance model: request service time on a (platform, model).

Roofline-derived defaults with a calibration hook:

* decode is memory-bound: tokens/s ~ HBM_bw / bytes(model + KV slice),
  scaled by a batching-efficiency factor (continuous batching amortizes the
  weight stream over concurrent sequences),
* prefill is compute-bound: tokens/s ~ peak_flops * mfu / (2 * N_active).

The §5.3 / §5.1 replication benches calibrate `decode_tps`/`prefill_tps` to
the paper's L40S + Llama-13B + vLLM operating point (busy-power and busy-
fraction anchors), documented in benchmarks/calibration.py.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class PerfModel:
    prefill_tps: float          # prompt tokens / s (effective, batched)
    decode_tps: float           # output tokens / s (effective, batched)
    #: device utilization (for the power model) while serving work runs
    busy_util: float = 0.25

    def service_time_s(self, prompt_tokens: int, output_tokens: int) -> float:
        return prompt_tokens / self.prefill_tps + output_tokens / self.decode_tps


def from_roofline(cfg: ModelConfig, peak_tflops: float, hbm_gbps: float,
                  n_params: int | None = None, batch_eff: float = 8.0,
                  prefill_mfu: float = 0.45) -> PerfModel:
    """Derive effective rates from hardware + model size."""
    if n_params is None:
        # rough dense estimate
        n_params = cfg.n_layers * (4 * cfg.d_model * cfg.n_heads *
                                   cfg.resolved_head_dim +
                                   3 * cfg.d_model * cfg.d_ff) \
            + cfg.vocab_size * cfg.d_model
    bytes_per_token_stream = 2 * n_params            # bf16 weight read
    decode_tps = batch_eff * hbm_gbps * 1e9 / bytes_per_token_stream
    prefill_tps = prefill_mfu * peak_tflops * 1e12 / (2 * n_params)
    return PerfModel(prefill_tps=prefill_tps, decode_tps=decode_tps)


#: The paper's replay operating point: Llama-13B on one L40S under vLLM.
#: Calibrated so the Azure-Code replay reproduces the paper's busy fraction
#: (~24%) and average power (123.9 W) — see benchmarks/bench_fig11_12.
LLAMA13B_L40S = PerfModel(prefill_tps=3200.0, decode_tps=55.0, busy_util=0.25)

"""Live JAX serving engine: continuous batching + execution-idle telemetry.

Runs a real model (any zoo family) with fixed decode slots: prefill admits a
request (padded to a bucket), its KV cache is spliced into a free slot, and
one jit'd ``decode_step`` advances every active slot per tick — inactive
slots are masked. The engine drives the same RuntimeSampler/Algorithm-1
controller stack as the DES, so the paper's technique is first-class in the
real serving path, not only in simulation.

Scale note: on this CPU container the engine runs smoke-size models; on TPU
the same code runs the full configs under the launch/serve.py shardings.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.controller import ControllerConfig, ExecutionIdleController
from repro.core.power_model import SimulatedDevice, get_platform
from repro.models import api
from repro.serving.latency import LatencyStats, Request
from repro.telemetry.sampler import RuntimeSampler


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 4
    max_seq_len: int = 256
    prefill_bucket: int = 32
    eos_token: int = 1
    max_new_tokens: int = 32
    controller: bool = False
    platform: str = "tpu_v5e"


@dataclasses.dataclass
class SlotState:
    active: bool = False
    request: Request | None = None
    generated: int = 0
    last_token: int = 0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, ec: EngineConfig):
        cfg.validate()
        self.cfg = cfg
        self.params = params
        self.ec = ec
        self.slots = [SlotState() for _ in range(ec.n_slots)]
        self.cache = api.init_cache(cfg, ec.n_slots, ec.max_seq_len)
        self.device = SimulatedDevice(get_platform(ec.platform))
        self.sampler = RuntimeSampler(self.device, job_id=1)
        self.controller = (ExecutionIdleController(self.device)
                           if ec.controller else None)
        self.completed: list[Request] = []

        self._decode = jax.jit(
            lambda p, c, t: api.decode_step(p, c, t, cfg))
        self._prefill = jax.jit(
            lambda p, t: api.prefill(p, t, cfg))

    # ------------------------------------------------------------------ #
    def _controller_signals(self) -> dict[str, float] | None:
        """Full scaled signal row for Algorithm 1 (§5.3), or None before the
        first telemetry row flushes.

        Activity percentages become fractions in [0, 1]; communication stays
        GB/s. NaN (signal unavailable on this platform) is dropped so the
        controller omits it rather than treating it as violated — previously
        only sm/dram were forwarded, so the rule could downscale during
        active communication (ici/pcie traffic with idle compute).
        """
        row = self.sampler.last_row()
        if row is None:
            return None
        signals: dict[str, float] = {}
        for k in ("sm", "tensor", "fp16", "fp32", "fp64", "dram"):
            v = float(row[k])
            if not np.isnan(v):
                signals[k] = v / 100.0
        for k in ("pcie_tx", "pcie_rx", "nvlink_tx", "nvlink_rx",
                  "ici_tx", "ici_rx"):
            v = float(row[k])
            if not np.isnan(v):
                signals[k] = v
        return signals

    def _free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if not s.active:
                return i
        return None

    def _splice_cache(self, slot: int, new_cache) -> None:
        """Copy a single-sequence prefill cache into slot ``slot``.

        Batch dims differ per family; we match by shape: any leaf whose
        dim equals the slot count at the engine's batch axis is updated.
        """
        def splice(dst, src):
            if not hasattr(dst, "shape") or dst.ndim == 0:
                return dst
            # find the batch axis: the unique axis where dst == n_slots and src == 1
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.ec.n_slots and src.shape[ax] == 1:
                    pad = [(0, 0)] * src.ndim
                    seq_ax = None
                    for ax2 in range(dst.ndim):
                        if ax2 != ax and src.shape[ax2] != dst.shape[ax2]:
                            seq_ax = ax2
                            pad[ax2] = (0, dst.shape[ax2] - src.shape[ax2])
                    src_p = jnp.pad(src, pad) if seq_ax is not None else src
                    start = [0] * dst.ndim
                    start[ax] = slot
                    return jax.lax.dynamic_update_slice(dst, src_p.astype(dst.dtype),
                                                        tuple(start))
            return dst

        self.cache = jax.tree.map(splice, self.cache, new_cache)

    def submit(self, request: Request, prompt_tokens: np.ndarray) -> bool:
        """Prefill + admit into a slot. Returns False if no slot free."""
        slot = self._free_slot()
        if slot is None:
            return False
        bucket = min(self.ec.prefill_bucket, self.ec.max_seq_len)
        toks = np.zeros((1, bucket), np.int32)
        n = min(len(prompt_tokens), bucket)
        toks[0, -n:] = prompt_tokens[-n:]
        with self.sampler.phase("prefill", compute_util=0.9, hbm_util=0.4):
            new_cache, logits = self._prefill(self.params, jnp.asarray(toks))
        self._splice_cache(slot, new_cache)
        s = self.slots[slot]
        s.active = True
        s.request = request
        s.generated = 0
        s.last_token = int(jnp.argmax(logits[0, -1]))
        request.start_s = self.sampler.now
        return True

    def decode_tick(self) -> int:
        """One batched decode step over all slots. Returns #active slots."""
        active = [i for i, s in enumerate(self.slots) if s.active]
        if not active:
            self.sampler.idle(1.0)
            if self.controller is not None:
                sig = self._controller_signals()
                self.controller.step(self.sampler.now,
                                     sig if sig is not None
                                     else {"sm": 0.0, "dram": 0.0})
            return 0
        tokens = np.array([[s.last_token] for s in self.slots], np.int32)
        with self.sampler.phase("decode", compute_util=0.5, hbm_util=0.9):
            self.cache, logits = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens))
        next_tokens = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for i in active:
            s = self.slots[i]
            s.last_token = int(next_tokens[i])
            s.generated += 1
            done = (s.generated >= min(s.request.output_tokens,
                                       self.ec.max_new_tokens)
                    or s.last_token == self.ec.eos_token)
            if done:
                s.request.finish_s = self.sampler.now
                self.completed.append(s.request)
                s.active = False
                s.request = None
        if self.controller is not None:
            sig = self._controller_signals()
            # sig is None before the first row flushes (sub-second warm
            # decode ticks): skip — fabricated zeros would read as low
            # activity and downscale clocks mid-decode
            if sig is not None:
                self.controller.step(self.sampler.now, sig)
        return len(active)

    # ------------------------------------------------------------------ #
    def run(self, requests: list[Request], prompts: dict[int, np.ndarray],
            max_ticks: int = 10_000, store=None, host: str = "host0",
            drain_every_s: float = 60.0) -> LatencyStats:
        """Replay: submit on arrival (engine time), decode until drained.

        With ``store`` (a :class:`~repro.telemetry.storage.TelemetryStore`)
        the sampler drains its buffered 1 Hz rows into a shard every
        ``drain_every_s`` of engine time (plus once at the end), so long
        replays keep peak telemetry memory bounded by one drain window
        instead of materializing the full run — read it back with the
        streaming ``analyze_store`` / ``run_sweep`` paths.
        """
        self.sampler.load_program()
        pending = sorted(requests, key=lambda r: r.arrival_s)
        idx = 0
        next_drain = self.sampler.now + drain_every_s
        for _ in range(max_ticks):
            while idx < len(pending) and pending[idx].arrival_s <= self.sampler.now:
                if self.submit(pending[idx], prompts[pending[idx].req_id]):
                    idx += 1
                else:
                    break
            n_active = self.decode_tick()
            if store is not None and self.sampler.now >= next_drain:
                self.sampler.drain_to(store, host=host, flush_manifest=False)
                next_drain = self.sampler.now + drain_every_s
            if idx >= len(pending) and n_active == 0:
                break
        self.sampler.unload_program()
        if store is not None:
            self.sampler.drain_to(store, host=host, flush_manifest=False)
            store.save_manifest()
        return LatencyStats.of(self.completed)

"""Request/latency bookkeeping for serving experiments."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    req_id: int
    arrival_s: float
    prompt_tokens: int
    output_tokens: int
    device: int = -1
    start_s: float = -1.0
    finish_s: float = -1.0

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def queueing_s(self) -> float:
        return self.start_s - self.arrival_s


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    n: int
    mean_s: float
    p50_s: float
    p95_s: float
    p99_s: float

    @staticmethod
    def of(requests: list[Request]) -> "LatencyStats":
        done = [r for r in requests if r.finish_s >= 0]
        if not done:
            return LatencyStats(0, float("nan"), float("nan"), float("nan"),
                                float("nan"))
        lat = np.array([r.latency_s for r in done])
        return LatencyStats(
            n=len(done),
            mean_s=float(lat.mean()),
            p50_s=float(np.percentile(lat, 50)),
            p95_s=float(np.percentile(lat, 95)),
            p99_s=float(np.percentile(lat, 99)),
        )


def inter_arrival_cdf(requests: list[Request]) -> np.ndarray:
    """Sorted per-device inter-arrival gaps (Fig 6)."""
    gaps: list[float] = []
    by_device: dict[int, list[float]] = {}
    for r in requests:
        by_device.setdefault(r.device, []).append(r.arrival_s)
    for arr in by_device.values():
        arr.sort()
        gaps.extend(np.diff(arr))
    return np.sort(np.asarray(gaps))

"""Discrete-event serving-pool simulator (tick-based, 1 Hz telemetry out).

Runs the *same* scheduler (core.imbalance) and controller (core.controller,
Algorithm 1) code as the live JAX engine, against a request trace and a
perf/power model — this is how the §5.1 and §5.3 experiments and the trace
replays (§2.3) execute at pool scale on a CPU-only box.

Model per device: work-conserving FIFO processor. Busy/idle structure (and
therefore energy) is exact for any work-conserving discipline (vLLM's
continuous batching included); individual latencies are FIFO-approximate.
The fine tick (default 0.1 s) resolves sub-second latencies; telemetry is
emitted at 1 Hz like the paper's pipeline.

Controller interplay: while downscaled, service progresses at
``platform.perf_scale(f_min)``; a clock switch stalls the device for the
measured 1-500 ms switch latency [52] — both produce the latency penalties
of Figs 10/12.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.controller import ControllerConfig, ExecutionIdleController
from repro.core.imbalance import ImbalanceScheduler, PoolConfig, PoolPolicy
from repro.core.power_model import ClockLevel, PlatformSpec, SimulatedDevice
from repro.serving.latency import LatencyStats, Request
from repro.serving.perf_model import PerfModel
from repro.telemetry.records import TelemetryFrame


@dataclasses.dataclass
class DeviceSim:
    device: SimulatedDevice
    resident: bool = True
    queue: list = dataclasses.field(default_factory=list)   # FIFO of requests
    current: Request | None = None
    remaining_work_s: float = 0.0
    busy_acc: float = 0.0       # busy seconds within current telemetry second
    util_acc: float = 0.0
    #: the previous completed 1 Hz sample — the controller reads DCGM-style
    #: windowed counters, i.e. it reacts one full second late
    prev_sample: dict = dataclasses.field(
        default_factory=lambda: {"sm": 0.0, "dram": 0.0, "pcie_rx": 0.0})


@dataclasses.dataclass
class PoolResult:
    requests: list[Request]
    latency: LatencyStats
    telemetry: TelemetryFrame
    energy_j: float
    avg_power_w: float
    busy_fraction: float        # fraction of device-seconds with any work
    exec_idle_time_fraction: float   # resident & no work (replay accounting)
    exec_idle_energy_fraction: float
    avg_sm_util: float


def simulate_pool(
    trace: list[Request],
    platform: PlatformSpec,
    perf: PerfModel,
    pool: PoolConfig,
    duration_s: float,
    controller_cfg: ControllerConfig | None = None,
    tick_s: float = 0.1,
    downscale_inactive: bool = False,
    store=None,
    host: str = "host0",
    drain_every_s: float = 3600.0,
) -> PoolResult:
    """Replay ``trace`` on a device pool. Requests must be sorted by arrival.

    With ``store`` (a :class:`~repro.telemetry.storage.TelemetryStore`) the
    accumulated 1 Hz rows spill into a shard every ``drain_every_s`` of
    simulated time (plus once at the end), so day-scale replays never
    materialize the full telemetry frame; ``PoolResult.telemetry`` is then
    empty — stream the store through ``analyze_store`` / ``run_sweep``
    instead. Each spill covers a contiguous time window over all devices, so
    shards arrive in the per-stream time order the streaming readers require.
    """
    n = pool.n_devices
    devices = [DeviceSim(device=SimulatedDevice(platform, switch_latency_s=0.4))
               for _ in range(n)]
    scheduler = ImbalanceScheduler(pool)
    controllers: dict[int, ExecutionIdleController] = {}
    if controller_cfg:
        for d_idx, d in enumerate(devices):
            if scheduler.is_active(d_idx):
                controllers[d_idx] = ExecutionIdleController(d.device, controller_cfg)

    # inactive devices under consolidation: parked deep-idle, or downscaled
    # with their own Algorithm-1 controller so spilled "light" traffic wakes
    # them (the paper's "lightly loaded and downscaled" pool, §5.1)
    from repro.core.controller import DownscaleMode
    for d_idx in scheduler.inactive_devices():
        if pool.park_inactive:
            devices[d_idx].resident = False
        else:
            devices[d_idx].device.set_clocks(0.0, ClockLevel.MIN, ClockLevel.MIN)
            parked_cfg = ControllerConfig(mode=DownscaleMode.SM_AND_MEM)
            ctl = ExecutionIdleController(devices[d_idx].device, parked_cfg)
            ctl._downscaled = True          # starts parked
            controllers[d_idx] = ctl

    # pre-compute service work (seconds at full clock)
    for r in trace:
        r.device = -1

    trace = sorted(trace, key=lambda r: r.arrival_s)
    next_arrival = 0
    t = 0.0
    ticks_per_second = max(1, round(1.0 / tick_s))
    rows: list[dict] = []
    busy_device_seconds = 0.0
    total_device_seconds = 0.0
    energy_j = 0.0
    exec_idle_s = 0.0
    exec_idle_j = 0.0
    active_j = 0.0
    active_s = 0.0
    sm_sum = 0.0

    n_ticks = int(round(duration_s / tick_s))
    for tick in range(n_ticks):
        t = tick * tick_s
        # arrivals
        while next_arrival < len(trace) and trace[next_arrival].arrival_s <= t:
            r = trace[next_arrival]
            d_idx = scheduler.route(perf.service_time_s(r.prompt_tokens,
                                                        r.output_tokens))
            r.device = d_idx
            devices[d_idx].queue.append(r)
            next_arrival += 1

        # progress work
        for d_idx, dev in enumerate(devices):
            if dev.current is None and dev.queue:
                dev.current = dev.queue.pop(0)
                dev.current.start_s = t
                dev.remaining_work_s = perf.service_time_s(
                    dev.current.prompt_tokens, dev.current.output_tokens)
            busy = 0.0
            if dev.current is not None:
                rate = dev.device.perf_scale(t, compute_bound_fraction=0.3)
                progress = rate * tick_s
                dev.remaining_work_s -= progress
                busy = tick_s
                if dev.remaining_work_s <= 0:
                    dev.current.finish_s = t + tick_s
                    scheduler.complete(d_idx, 0.0)
                    dev.current = None
            dev.busy_acc += busy
            dev.util_acc += (perf.busy_util if busy > 0 else 0.0) * tick_s

        # 1 Hz boundary: telemetry + controller
        if (tick + 1) % ticks_per_second == 0:
            sec = int(t) + 1
            for d_idx, dev in enumerate(devices):
                util = dev.util_acc  # time-weighted within the second
                sm_frac = dev.busy_acc * perf.busy_util
                power = dev.device.power_w(t, util, resident=dev.resident)
                energy_j += power
                total_device_seconds += 1.0
                if dev.busy_acc > 0:
                    busy_device_seconds += 1.0
                sm_sum += sm_frac
                is_exec_idle = dev.resident and dev.busy_acc == 0.0
                if is_exec_idle:
                    exec_idle_s += 1.0
                    exec_idle_j += power
                elif dev.resident:
                    active_s += 1.0
                    active_j += power
                rows.append({
                    "timestamp": float(sec),
                    "device_id": d_idx,
                    "job_id": 1,
                    "program_resident": int(dev.resident),
                    "sm": 100.0 * sm_frac,
                    "tensor": 100.0 * sm_frac,
                    "dram": 100.0 * min(1.0, dev.busy_acc * 0.9),
                    "power": power,
                    "pcie_rx": 0.0, "pcie_tx": 0.0,
                    "nic_rx": 0.0, "nic_tx": 0.0,
                    "cpu_util": 20.0 if dev.busy_acc > 0 else 2.0,
                    "host_mem_util": 30.0,
                    "sm_clk": dev.device.platform.sm_clk_mhz[int(dev.device.clocks()[0])],
                    "mem_clk": dev.device.platform.mem_clk_mhz[int(dev.device.clocks()[1])],
                })
                if d_idx in controllers and dev.resident:
                    controllers[d_idx].step(t, dev.prev_sample)
                dev.prev_sample = {"sm": sm_frac,
                                   "dram": min(1.0, dev.busy_acc * 0.9),
                                   "pcie_rx": 0.0}
                dev.busy_acc = 0.0
                dev.util_acc = 0.0
            if store is not None and sec % max(int(drain_every_s), 1) == 0:
                store.append(TelemetryFrame.from_rows(rows), host=host,
                             flush_manifest=False)
                rows.clear()

    if store is not None:
        store.append(TelemetryFrame.from_rows(rows), host=host,
                     flush_manifest=False)
        store.save_manifest()
        rows.clear()
    frame = TelemetryFrame.from_rows(rows)
    in_exec_s = exec_idle_s + active_s
    in_exec_j = exec_idle_j + active_j
    return PoolResult(
        requests=trace,
        latency=LatencyStats.of(trace),
        telemetry=frame,
        energy_j=energy_j,
        avg_power_w=energy_j / max(total_device_seconds, 1.0),
        busy_fraction=busy_device_seconds / max(total_device_seconds, 1.0),
        exec_idle_time_fraction=exec_idle_s / max(in_exec_s, 1.0),
        exec_idle_energy_fraction=exec_idle_j / max(in_exec_j, 1e-9),
        avg_sm_util=sm_sum / max(total_device_seconds, 1.0),
    )

"""Deterministic fault injection for the robustness layer.

Three families of faults, all reproducible (no randomness, no wall-clock):

* **File corruptors** — :func:`truncate_file`, :func:`bitflip_file`,
  :func:`poison_json`, :func:`corrupt_npy_dir` damage shard/manifest bytes
  in place, the way a full disk, a torn copy or silent media corruption
  would.
* **Worker faults** — a *fault plan* written to disk and advertised via the
  ``REPRO_FAULT_PLAN`` environment variable; the pipeline captures the path
  in the parent and ships it to pool children as a task argument
  (forkserver children keep the fork server's original environment, so the
  env var alone would go stale). :func:`check` is the pool submission hook
  (:func:`repro.telemetry.pipeline._partition_body`); a stage listed in the
  plan's ``crash`` list makes the *first* worker to claim the marker file
  die with ``os._exit`` (an un-catchable hard crash, exactly what an
  OOM-kill looks like to the pool), ``hang`` sleeps instead. Markers are
  claimed with ``O_CREAT | O_EXCL``, so each fault fires exactly once per
  plan — retried attempts succeed, which is what lets tests assert the
  supervisor's retry path deterministically. The installer's own process
  never faults (``installer_pid`` guard), so degraded in-process execution
  is safe.
* **Kill-mid-write** — :func:`dying_renames` patches
  :func:`repro.telemetry.storage.atomic_replace` (the single commit point
  of every manifest/shard/sidecar write) to raise, simulating a process
  killed after the temp file is written but before the rename commits.

Everything here is stdlib-only and import-free on the hot path: pipelines
only import this module when ``REPRO_FAULT_PLAN`` is set.
"""
from __future__ import annotations

import contextlib
import json
import os
import pathlib
import time

#: environment variable holding the fault-plan path; also hardcoded in
#: repro.telemetry.pipeline._partition_body so the pipeline never imports
#: this module unless a plan is active
ENV_PLAN = "REPRO_FAULT_PLAN"
#: exit status of an injected crash (distinguishable from a real segfault)
CRASH_EXIT_CODE = 13


# --------------------------------------------------------------------------- #
# File corruptors
# --------------------------------------------------------------------------- #
def truncate_file(path: str | pathlib.Path, keep_fraction: float = 0.5) -> None:
    """Cut a file to ``keep_fraction`` of its bytes (min 1) — a torn write
    or full-disk copy. Deterministic for a given input."""
    path = pathlib.Path(path)
    data = path.read_bytes()
    path.write_bytes(data[:max(1, int(len(data) * keep_fraction))])


def bitflip_file(path: str | pathlib.Path, offset: int | None = None,
                 bit: int = 0) -> None:
    """Flip one bit in place (default: the middle byte) — silent media
    corruption. Against an ``npz`` the zip CRC catches it at read; against
    a raw ``npy`` only a recorded sha256 can (``read_shard(verify=True)``)."""
    path = pathlib.Path(path)
    data = bytearray(path.read_bytes())
    if not data:
        return
    i = len(data) // 2 if offset is None else offset
    data[i] ^= 1 << bit
    path.write_bytes(bytes(data))


def poison_json(path: str | pathlib.Path) -> None:
    """Overwrite a JSON file with a truncated, unparseable payload."""
    pathlib.Path(path).write_text('{"shards": [{"file": "tele')


def corrupt_npy_dir(path: str | pathlib.Path,
                    column: str = "power.npy") -> None:
    """Truncate one column file of an ``npy_dir`` shard."""
    truncate_file(pathlib.Path(path) / column)


def corrupt_checkpoint(path: str | pathlib.Path,
                       mode: str = "truncate") -> None:
    """Damage a live-controller checkpoint file in place. ``truncate`` cuts
    it mid-JSON (a torn copy), ``poison`` overwrites it with an unparseable
    payload that still *looks* like a checkpoint, ``bitflip`` flips one
    byte. The controller must respond with a
    ``repro_fallbacks_total{reason="checkpoint_corrupt"}`` and a cold
    start, never a crash (tests/test_live.py)."""
    if mode == "truncate":
        truncate_file(path, keep_fraction=0.4)
    elif mode == "poison":
        pathlib.Path(path).write_text('{"schema_version": 1, "tick": 3, "fr')
    elif mode == "bitflip":
        bitflip_file(path)
    else:
        raise ValueError(f"unknown corrupt_checkpoint mode {mode!r}")


def skew_shard(store, name: str, skew_s: float = -3600.0) -> None:
    """Backwards-timestamp / clock-skew corruptor: rewrite one shard with
    every timestamp shifted by ``skew_s`` (negative = the producer's clock
    stepped backwards), checksum recomputed — a byte-valid shard whose
    *semantics* are poisoned. Downstream, per-stream time-ordering checks
    (FleetAccumulator, the replayers, the IR builder) reject the stream;
    the live controller must degrade to serving its stale knee, flagged,
    instead of crashing."""
    from repro.telemetry.records import TelemetryFrame

    frame = store.read_shard(name)
    cols = dict(frame.columns)
    cols["timestamp"] = cols["timestamp"] + float(skew_s)
    store.rewrite_shard(name, TelemetryFrame(cols))
    store.save_manifest()


# --------------------------------------------------------------------------- #
# Worker fault plan (crash / hang inside pool workers)
# --------------------------------------------------------------------------- #
def install_plan(plan_dir: str | pathlib.Path, crash: tuple | list = (),
                 hang: tuple | list = (), hang_s: float = 60.0) -> pathlib.Path:
    """Write a fault plan and export ``REPRO_FAULT_PLAN`` so pool children
    (which inherit the environment) pick it up. ``crash``/``hang`` list the
    pipeline stage names (``"analyze"``, ``"sweep"``, ``"ir_build"``,
    ``"replay_ir"``) whose first worker submission should die/stall."""
    plan_dir = pathlib.Path(plan_dir)
    plan_dir.mkdir(parents=True, exist_ok=True)
    plan = {"installer_pid": os.getpid(), "dir": str(plan_dir),
            "crash": list(crash), "hang": list(hang),
            "hang_s": float(hang_s)}
    path = plan_dir / "fault_plan.json"
    path.write_text(json.dumps(plan))
    os.environ[ENV_PLAN] = str(path)
    return path


def clear_plan() -> None:
    os.environ.pop(ENV_PLAN, None)


@contextlib.contextmanager
def plan(plan_dir: str | pathlib.Path, **kwargs):
    """``with faults.plan(tmpdir, crash=["analyze"]): ...`` — install a
    fault plan for the duration of the block."""
    install_plan(plan_dir, **kwargs)
    try:
        yield
    finally:
        clear_plan()


def _claim(marker: pathlib.Path) -> bool:
    """Atomically claim a fire-once marker (O_CREAT|O_EXCL): exactly one
    process ever wins, so each planned fault fires once."""
    try:
        fd = os.open(marker, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        return False
    os.close(fd)
    return True


def check(stage: str, plan_path: str | None = None) -> None:
    """Fault hook, called at the top of every pool worker submission.
    No-op unless a plan is installed, the caller is *not* the installing
    process (so degraded in-process retries never kill the parent), and the
    stage's fire-once marker is still unclaimed.

    ``plan_path`` is normally passed explicitly, captured by the parent at
    submission time (see ``pipeline._fault_plan``) — forkserver workers
    inherit the fork server's original environment, so the env var alone
    cannot be trusted inside a pool child."""
    plan_path = plan_path or os.environ.get(ENV_PLAN)
    if not plan_path:
        return
    try:
        spec = json.loads(pathlib.Path(plan_path).read_text())
    except (OSError, ValueError):
        return
    if os.getpid() == spec.get("installer_pid"):
        return
    plan_dir = pathlib.Path(spec.get("dir", "."))
    if stage in spec.get("crash", ()) and _claim(
            plan_dir / f"crash_{stage}.fired"):
        os._exit(CRASH_EXIT_CODE)
    if stage in spec.get("hang", ()) and _claim(
            plan_dir / f"hang_{stage}.fired"):
        time.sleep(float(spec.get("hang_s", 60.0)))


# --------------------------------------------------------------------------- #
# Kill-mid-write
# --------------------------------------------------------------------------- #
class SimulatedKill(RuntimeError):
    """Raised in place of the atomic rename — the write never commits."""


@contextlib.contextmanager
def dying_renames():
    """Make every :func:`repro.telemetry.storage.atomic_replace` raise
    :class:`SimulatedKill`: the temp file is fully written, the rename never
    happens — the observable state of a process killed at the commit
    boundary. Atomicity tests assert the destination is untouched."""
    from repro.telemetry import storage

    original = storage.atomic_replace

    def die(tmp, dst):
        raise SimulatedKill(f"killed before rename of {dst}")

    storage.atomic_replace = die
    try:
        yield
    finally:
        storage.atomic_replace = original

"""Deterministic test harnesses for the robustness layer.

:mod:`repro.testing.faults` injects file corruption, crashing/hanging pool
workers and kill-mid-write into the pipelines — driving
``tests/test_robustness.py`` and the CI chaos lane.
"""

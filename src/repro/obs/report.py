"""Human/JSON reports over the recorded spans + metrics.

``benchmarks/run.py --obs DIR`` uses :func:`stage_breakdown` to attach a
per-stage timing table to bench JSON, and prints :func:`stage_report` to
stderr after the run.  ``examples/whatif_search.py`` prints the same tree
for its end-to-end ``ingest_to_knee`` staleness trace.
"""
from __future__ import annotations

from typing import Sequence

from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.spans import (SpanRecord, format_span_tree, spans,
                             stage_totals)


def stage_breakdown(records: Sequence[SpanRecord] | None = None) -> dict:
    """JSON-able per-stage summary: span name -> count/total seconds,
    sorted by total time descending."""
    totals = stage_totals(spans() if records is None else records)
    stages = {
        name: {"count": int(agg["count"]),
               "total_s": round(agg["total_s"], 6)}
        for name, agg in sorted(totals.items(),
                                key=lambda kv: -kv[1]["total_s"])
    }
    return {"stages": stages, "n_spans": len(records if records is not None
                                             else spans())}


def stage_report(records: Sequence[SpanRecord] | None = None,
                 registry: MetricsRegistry | None = None,
                 min_dur_s: float = 0.0) -> str:
    """Stage tree plus a one-line metrics inventory."""
    registry = REGISTRY if registry is None else registry
    tree = format_span_tree(spans() if records is None else records,
                            min_dur_s=min_dur_s)
    names = registry.names()
    footer = f"[obs] {len(names)} metric families recorded"
    return (tree + "\n" + footer) if tree else footer

"""Prometheus text-format exposition for the obs metrics registry.

Three consumers:

* :func:`write_textfile` — node-exporter "textfile collector" style drop,
  the batch-friendly path used by ``benchmarks/run.py --obs``.
* :func:`start_http_server` — optional stdlib-only ``/metrics`` endpoint
  for the future live daemon (ROADMAP: closed-loop controller).  Daemon
  thread, ephemeral port supported (``port=0``).
* :func:`lint_exposition` — a small text-format checker used by
  ``tests/prom_lint.py`` and the bench parse gate, so CI fails loudly if
  the renderer ever emits something a real scraper would reject.

No third-party client library: the renderer speaks the subset of the
`text exposition format <https://prometheus.io/docs/instrumenting/exposition_formats/>`_
that counters/gauges/histograms need (``# HELP``/``# TYPE``, cumulative
``le`` buckets, ``_sum``/``_count``).
"""
from __future__ import annotations

import http.server
import pathlib
import re
import threading

from repro.obs.metrics import REGISTRY, MetricsRegistry

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>[^ ]+)$")
_LABEL_RE = re.compile(
    r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\["\\n])*"$')


def _escape(value: str) -> str:
    return (value.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _labelstr(labels: tuple[tuple[str, str], ...],
              extra: tuple[tuple[str, str], ...] = ()) -> str:
    pairs = [f'{k}="{_escape(v)}"' for k, v in (*labels, *extra)]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _fmt(value: float) -> str:
    if value == int(value) and abs(value) < 1e15:
        return str(int(value))
    return repr(float(value))


def render_prometheus(registry: MetricsRegistry | None = None) -> str:
    """Render the registry as Prometheus text exposition (version 0.0.4)."""
    registry = REGISTRY if registry is None else registry
    lines: list[str] = []
    for fam in registry.collect():
        if fam.help:
            lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for key, metric in sorted(fam.metrics.items()):
            if fam.kind == "histogram":
                cum = 0
                for edge, n in zip(metric.edges, metric.counts):
                    cum += n
                    lines.append(
                        f"{fam.name}_bucket"
                        f"{_labelstr(key, (('le', f'{edge:.6g}'),))} {cum}")
                cum += metric.counts[-1]
                lines.append(
                    f"{fam.name}_bucket{_labelstr(key, (('le', '+Inf'),))}"
                    f" {cum}")
                lines.append(f"{fam.name}_sum{_labelstr(key)}"
                             f" {repr(metric.sum)}")
                lines.append(f"{fam.name}_count{_labelstr(key)}"
                             f" {metric.count}")
            else:
                lines.append(
                    f"{fam.name}{_labelstr(key)} {_fmt(metric.value)}")
    return "\n".join(lines) + "\n" if lines else ""


def write_textfile(path: str | pathlib.Path,
                   registry: MetricsRegistry | None = None) -> pathlib.Path:
    """Write the exposition to ``path`` (textfile-collector style)."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(render_prometheus(registry))
    return path


# ------------------------------------------------------------------ linter
def lint_exposition(text: str) -> list[str]:
    """Validate exposition text; returns a list of error strings (empty =
    clean).  Checks: sample syntax, float-parsable values, ``# TYPE``
    before samples, one TYPE per family, histograms carry a ``+Inf``
    bucket whose cumulative count equals ``_count``, bucket counts are
    monotonically non-decreasing."""
    errors: list[str] = []
    typed: dict[str, str] = {}
    hist: dict[str, dict] = {}

    def family_of(name: str) -> str:
        for suffix in ("_bucket", "_sum", "_count"):
            base = name[: -len(suffix)] if name.endswith(suffix) else None
            if base and typed.get(base) == "histogram":
                return base
        return name

    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                errors.append(f"line {i}: malformed TYPE line")
                continue
            name = parts[2]
            if name in typed:
                errors.append(f"line {i}: duplicate TYPE for {name}")
            typed[name] = parts[3]
            if parts[3] == "histogram":
                hist[name] = {"inf": None, "count": None, "last_cum": None}
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            errors.append(f"line {i}: unparseable sample: {line!r}")
            continue
        name, labels, value = m.group("name", "labels", "value")
        if not _NAME_RE.match(name):
            errors.append(f"line {i}: invalid metric name {name!r}")
        label_map: dict[str, str] = {}
        if labels:
            for pair in labels.split(","):
                if not _LABEL_RE.match(pair.strip()):
                    errors.append(f"line {i}: malformed label {pair!r}")
                else:
                    k, v = pair.strip().split("=", 1)
                    label_map[k] = v.strip('"')
        try:
            float(value) if value != "+Inf" else None
        except ValueError:
            if value not in ("+Inf", "-Inf", "NaN"):
                errors.append(f"line {i}: unparseable value {value!r}")
        fam = family_of(name)
        if fam not in typed:
            errors.append(f"line {i}: sample before TYPE for {fam}")
        if typed.get(fam) == "histogram":
            h = hist[fam]
            if name.endswith("_bucket"):
                cum = float(value)
                if h["last_cum"] is not None and cum < h["last_cum"] \
                        and label_map.get("le") != "+Inf":
                    pass  # different label-set series restart; tracked loosely
                h["last_cum"] = cum
                if label_map.get("le") == "+Inf":
                    h["inf"] = cum
            elif name.endswith("_count"):
                h["count"] = float(value)

    for fam, h in hist.items():
        if h["inf"] is None:
            errors.append(f"histogram {fam}: missing +Inf bucket")
        elif h["count"] is not None and h["inf"] != h["count"]:
            errors.append(f"histogram {fam}: +Inf bucket ({h['inf']}) != "
                          f"_count ({h['count']})")
    return errors


# ------------------------------------------------------------- HTTP server
class _MetricsHandler(http.server.BaseHTTPRequestHandler):
    registry: MetricsRegistry = REGISTRY

    def do_GET(self):  # noqa: N802 - stdlib handler API
        if self.path.rstrip("/") not in ("", "/metrics"):
            self.send_error(404)
            return
        body = render_prometheus(self.registry).encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args) -> None:  # silence per-request stderr spam
        pass


def start_http_server(port: int = 0, addr: str = "127.0.0.1",
                      registry: MetricsRegistry | None = None):
    """Serve ``/metrics`` on a daemon thread; returns the
    ``ThreadingHTTPServer`` (``.server_address[1]`` is the bound port,
    ``.shutdown()`` stops it)."""
    handler = type("_Handler", (_MetricsHandler,),
                   {"registry": REGISTRY if registry is None else registry})
    server = http.server.ThreadingHTTPServer((addr, port), handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="repro-obs-metrics", daemon=True)
    thread.start()
    return server

"""Process-wide metrics registry: counters, gauges, log-bucket histograms.

The registry is the numeric half of the self-observability layer (spans are
the other half, :mod:`repro.obs.spans`).  Contract:

* **Default-off.**  The module-level helpers (:func:`counter`,
  :func:`gauge`, :func:`observe`) are gated on :func:`enabled` and return
  immediately when observability is off — one attribute load and a branch,
  so instrumented hot paths stay near-free in production.
* **Bit-identical results.**  Instrumentation only *records*; it never
  feeds back into any computation, so every pipeline output is identical
  with obs on or off (asserted in ``tests/test_obs.py`` and the whatif
  bench).
* **Always-on escape hatch.**  Code whose counts are part of a behavioural
  contract (e.g. JIT retrace counts, which tests assert on) talks to
  :data:`REGISTRY` directly — registry objects themselves never gate.

Histogram bucket edges are a fixed log-scale ladder (:func:`default_buckets`)
so expositions from different runs and processes are mergeable sample-wise.
"""
from __future__ import annotations

import bisect
import re
import threading
from typing import Iterator

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


def default_buckets() -> tuple[float, ...]:
    """Fixed log-scale histogram edges: 31 upper bounds at ratio 10^(1/3)
    (~2.15x per step) spanning 1e-6 .. 1e4 — wide enough for microsecond
    kernel spans and multi-hour analyze stages alike.  A pure function of
    constants, so the edges are bit-stable across runs and processes
    (worker histograms merge bucket-wise; see ``MetricsRegistry.merge``).
    """
    return tuple(10.0 ** (k / 3.0) for k in range(-18, 13))


class Counter:
    """Monotonically increasing value."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0, got {amount}")
        self.value += amount


class Gauge:
    """Last-write-wins point-in-time value."""

    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Histogram:
    """Fixed-bucket histogram; per-bucket counts are *non*-cumulative in
    memory and cumulated only at exposition time (Prometheus ``le`` form)."""

    kind = "histogram"
    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: tuple[float, ...] | None = None) -> None:
        self.edges = tuple(edges) if edges is not None else default_buckets()
        if list(self.edges) != sorted(self.edges):
            raise ValueError("histogram bucket edges must be sorted")
        # one slot per edge plus the +Inf overflow slot
        self.counts = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_left(self.edges, value)] += 1
        self.sum += value
        self.count += 1


class _Family:
    """All label-variants of one metric name."""

    __slots__ = ("name", "kind", "help", "metrics")

    def __init__(self, name: str, kind: str, help: str) -> None:
        self.name = name
        self.kind = kind
        self.help = help
        # label tuple (sorted (k, v) pairs) -> metric instance
        self.metrics: dict[tuple[tuple[str, str], ...],
                           Counter | Gauge | Histogram] = {}


class MetricsRegistry:
    """Mapping of metric families, safe for concurrent readers (the HTTP
    exporter thread) against a single writer (the pipeline)."""

    def __init__(self) -> None:
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    # -------------------------------------------------------------- access
    def _get(self, name: str, kind: str, help: str,
             labels: dict[str, object], factory):
        fam = self._families.get(name)
        if fam is None:
            if not _NAME_RE.match(name):
                raise ValueError(f"invalid metric name: {name!r}")
            with self._lock:
                fam = self._families.setdefault(name, _Family(name, kind, help))
        if fam.kind != kind:
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind}, not {kind}")
        if help and not fam.help:
            fam.help = help
        key = tuple(sorted((k, str(v)) for k, v in labels.items()))
        metric = fam.metrics.get(key)
        if metric is None:
            with self._lock:
                metric = fam.metrics.setdefault(key, factory())
        return metric

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(name, "counter", help, labels, Counter)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(name, "gauge", help, labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] | None = None,
                  **labels) -> Histogram:
        return self._get(name, "histogram", help, labels,
                         lambda: Histogram(buckets))

    def family(self, name: str) -> _Family | None:
        return self._families.get(name)

    def collect(self) -> Iterator[_Family]:
        """Families in name order (snapshot of the family list)."""
        for name in sorted(self._families):
            yield self._families[name]

    def names(self) -> list[str]:
        return sorted(self._families)

    def reset(self) -> None:
        with self._lock:
            self._families.clear()

    # --------------------------------------------- worker-process transport
    def dump(self) -> list[dict]:
        """Picklable snapshot for shipping worker-side metrics back to the
        parent process (see :func:`repro.obs.spans.call_with_obs`)."""
        out = []
        for fam in self.collect():
            for key, metric in sorted(fam.metrics.items()):
                entry = {"name": fam.name, "kind": fam.kind, "help": fam.help,
                         "labels": dict(key)}
                if fam.kind == "histogram":
                    entry["edges"] = metric.edges
                    entry["counts"] = list(metric.counts)
                    entry["sum"] = metric.sum
                    entry["count"] = metric.count
                else:
                    entry["value"] = metric.value
                out.append(entry)
        return out

    def merge(self, entries: list[dict]) -> None:
        """Fold a :meth:`dump` from another process into this registry:
        counters and histograms add, gauges last-write-win."""
        for e in entries:
            labels = e.get("labels", {})
            if e["kind"] == "counter":
                self.counter(e["name"], e.get("help", ""), **labels).inc(
                    e["value"])
            elif e["kind"] == "gauge":
                self.gauge(e["name"], e.get("help", ""), **labels).set(
                    e["value"])
            else:
                h = self.histogram(e["name"], e.get("help", ""),
                                   buckets=tuple(e["edges"]), **labels)
                if tuple(h.edges) != tuple(e["edges"]):
                    raise ValueError(
                        f"histogram {e['name']!r}: bucket edges differ "
                        "between processes")
                for i, c in enumerate(e["counts"]):
                    h.counts[i] += c
                h.sum += e["sum"]
                h.count += e["count"]


#: The process-wide default registry. Everything in ``repro`` records here.
REGISTRY = MetricsRegistry()


class _ObsState:
    __slots__ = ("enabled",)

    def __init__(self) -> None:
        self.enabled = False


STATE = _ObsState()


def enable() -> None:
    """Turn recording on (module helpers + spans)."""
    STATE.enabled = True


def disable() -> None:
    STATE.enabled = False


def enabled() -> bool:
    return STATE.enabled


# ------------------------------------------------------------------ helpers
# Gated one-liners for instrumentation sites: near-free when disabled.

def counter(name: str, amount: float = 1.0, help: str = "", **labels) -> None:
    if not STATE.enabled:
        return
    REGISTRY.counter(name, help, **labels).inc(amount)


def gauge(name: str, value: float, help: str = "", **labels) -> None:
    if not STATE.enabled:
        return
    REGISTRY.gauge(name, help, **labels).set(value)


def observe(name: str, value: float, help: str = "", **labels) -> None:
    if not STATE.enabled:
        return
    REGISTRY.histogram(name, help, **labels).observe(value)


# ------------------------------------------------- degradation-ladder metrics
#: the robustness layer's metric families (name, kind, help) — preregistered
#: zero-valued by :func:`init_degradation_metrics` so expositions always
#: carry them even on fault-free runs (CI asserts presence; see
#: tests/prom_lint.py --require and the README "Robustness" section).
DEGRADATION_FAMILIES: tuple[tuple[str, str, str], ...] = (
    ("repro_fallbacks_total", "counter",
     "degradation-ladder transitions, labelled {from, to, reason}"),
    ("repro_shards_quarantined_total", "counter",
     "telemetry shards skipped or quarantined, by reason"),
    ("repro_shards_repaired_total", "counter",
     "telemetry shards repaired by the hygiene layer, by reason"),
    ("repro_partition_retries_total", "counter",
     "pool partition attempts that crashed/hung and were retried or degraded"),
    ("repro_coverage_fraction", "gauge",
     "rows analyzed / rows on disk for the last run, by stage"),
)


def fallback(frm: str, to: str, reason: str, amount: float = 1.0) -> None:
    """Record one degradation-ladder transition (``repro_fallbacks_total``):
    jax -> numpy, compact -> row, sidecar -> rebuild, pool -> in_process,
    manifest -> rescan. ``from`` is a Python keyword, hence the dict
    unpacking. Gated like every module helper — free when obs is off."""
    if not STATE.enabled:
        return
    REGISTRY.counter(
        "repro_fallbacks_total", DEGRADATION_FAMILIES[0][2],
        **{"from": frm, "to": to, "reason": reason}).inc(amount)


def init_degradation_metrics() -> None:
    """Pre-register the robustness families (zero-valued, unlabelled) so a
    fault-free exposition still exposes them — dashboards and the CI linter
    can then assert on presence instead of guessing whether a zero means
    'no faults' or 'not instrumented'."""
    _init_families(DEGRADATION_FAMILIES)


# ------------------------------------------------- incremental-IR metrics
#: the incremental IR-append families (name, kind, help) — emitted by
#: :meth:`repro.whatif.ir.IRBuilder.extend`, preregistered zero-valued by
#: :func:`init_ir_append_metrics` (CI asserts presence; same contract as
#: the degradation families above).
IR_APPEND_FAMILIES: tuple[tuple[str, str, str], ...] = (
    ("repro_ir_appends_total", "counter",
     "incremental IR extends (appends folded into an existing RunIR)"),
    ("repro_ir_append_rows_total", "counter",
     "telemetry rows folded into existing RunIRs by incremental extends"),
    ("repro_ir_suffix_rebuild_fraction", "gauge",
     "rows whose replay aggregates were re-derived / total rows, last extend"),
)


def init_ir_append_metrics() -> None:
    """Pre-register the incremental-IR families (zero-valued) so an
    exposition from a run that never appended still exposes them."""
    _init_families(IR_APPEND_FAMILIES)


# ------------------------------------------------- live-controller metrics
#: the live fleet controller's families (name, kind, help) — emitted by
#: :mod:`repro.live`, preregistered zero-valued by :func:`init_live_metrics`
#: (CI asserts presence; the histogram zero-registers too, exposing empty
#: ``_bucket``/``_sum``/``_count`` samples).
LIVE_FAMILIES: tuple[tuple[str, str, str], ...] = (
    ("repro_live_ticks_total", "counter",
     "live controller ticks, labelled {result} (refreshed/idle/stale)"),
    ("repro_live_staleness_seconds", "histogram",
     "seconds from shard landing to the refreshed knee being published"),
    ("repro_live_checkpoint_writes_total", "counter",
     "live controller checkpoints committed (atomic rename)"),
    ("repro_live_checkpoint_restores_total", "counter",
     "live controller restarts resumed from a valid checkpoint"),
    ("repro_live_coalesced_shards_total", "counter",
     "pending shards beyond the first folded into one extend (backpressure)"),
    ("repro_live_tick_retries_total", "counter",
     "tick attempts that failed and were retried on the same ladder rung"),
    ("repro_live_deadline_misses_total", "counter",
     "tick attempts abandoned at the per-tick deadline"),
)


def init_live_metrics() -> None:
    """Pre-register the live-controller families (zero-valued) so an
    exposition from a run that never ticked still exposes them."""
    _init_families(LIVE_FAMILIES)


def _init_families(families: tuple[tuple[str, str, str], ...]) -> None:
    if not STATE.enabled:
        return
    for name, kind, help_text in families:
        if kind == "counter":
            REGISTRY.counter(name, help_text)
        elif kind == "histogram":
            REGISTRY.histogram(name, help_text)
        else:
            REGISTRY.gauge(name, help_text)

"""Hierarchical wall-clock spans: the trace half of the observability layer.

``span(name)`` is a context manager that records a :class:`SpanRecord`
(start time, duration, parent link) into a process-local buffer.  Nesting is
tracked per-thread with an explicit stack; span ids are ``"{pid:x}-{seq}"``
so traces from process-pool workers re-parent cleanly into the parent
process's trace (see :func:`call_with_obs` / :func:`absorb` — the shim
``map_shard_partitions`` and ``replay_ir`` use to carry worker spans and
metrics home).

When observability is disabled, ``span()`` returns a shared no-op context
manager: one branch, zero allocation — cheap enough to leave in every stage
of the pipeline permanently.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import threading
import time
from typing import Callable, Sequence

from repro.obs import metrics as _metrics
from repro.obs.metrics import REGISTRY, STATE


@dataclasses.dataclass
class SpanRecord:
    """One finished span. ``t_start`` is wall-clock (``time.time``) so spans
    from different processes order sensibly; ``dur_s`` is measured with
    ``time.perf_counter`` for resolution."""

    span_id: str
    parent_id: str | None
    name: str
    t_start: float
    dur_s: float
    pid: int
    attrs: dict


_SPANS: list[SpanRecord] = []
_TLS = threading.local()
# Parent span id inherited from another process (set in pool workers so the
# worker's root span hangs off the submitting span in the parent trace).
_ROOT_PARENT: str | None = None
_SEQ_LOCK = threading.Lock()
_SEQ = 0


def _next_id() -> str:
    global _SEQ
    with _SEQ_LOCK:
        _SEQ += 1
        return f"{os.getpid():x}-{_SEQ}"


def _stack() -> list:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


class _NoopSpan:
    """Shared do-nothing span returned when observability is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "attrs", "span_id", "parent_id", "_t0", "_t_wall")

    def __init__(self, name: str, attrs: dict) -> None:
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> None:
        """Attach attributes discovered while the span is open."""
        self.attrs.update(attrs)

    def __enter__(self):
        stack = _stack()
        self.parent_id = stack[-1] if stack else _ROOT_PARENT
        self.span_id = _next_id()
        stack.append(self.span_id)
        self._t_wall = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        dur = time.perf_counter() - self._t0
        stack = _stack()
        if stack and stack[-1] == self.span_id:
            stack.pop()
        _SPANS.append(SpanRecord(self.span_id, self.parent_id, self.name,
                                 self._t_wall, dur, os.getpid(), self.attrs))
        return False


def span(name: str, **attrs):
    """Open a span; no-op (shared singleton) when obs is disabled."""
    if not STATE.enabled:
        return _NOOP
    return _Span(name, attrs)


def spans() -> list[SpanRecord]:
    """Snapshot of all spans recorded (and absorbed) so far."""
    return list(_SPANS)


def clear_spans() -> None:
    _SPANS.clear()


# ------------------------------------------------------------------ export
def dump_spans_jsonl(path: str | pathlib.Path) -> pathlib.Path:
    """Write one JSON object per span — loadable with
    :func:`load_spans_jsonl` and re-assemblable with :func:`span_tree`."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as fh:
        for s in _SPANS:
            fh.write(json.dumps(dataclasses.asdict(s)) + "\n")
    return path


def load_spans_jsonl(path: str | pathlib.Path) -> list[SpanRecord]:
    out = []
    for line in pathlib.Path(path).read_text().splitlines():
        if line.strip():
            out.append(SpanRecord(**json.loads(line)))
    return out


@dataclasses.dataclass
class SpanNode:
    span: SpanRecord
    children: list["SpanNode"]


def span_tree(records: Sequence[SpanRecord] | None = None) -> list[SpanNode]:
    """Reassemble the hierarchy: roots (no resolvable parent) in start
    order, children under their parents in start order."""
    records = _SPANS if records is None else records
    nodes = {r.span_id: SpanNode(r, []) for r in records}
    roots: list[SpanNode] = []
    for r in sorted(records, key=lambda r: (r.t_start, r.span_id)):
        node = nodes[r.span_id]
        parent = nodes.get(r.parent_id) if r.parent_id else None
        (parent.children if parent is not None else roots).append(node)
    return roots


def stage_totals(records: Sequence[SpanRecord] | None = None
                 ) -> dict[str, dict[str, float]]:
    """Aggregate spans by name: ``{name: {"count", "total_s"}}`` — the
    per-stage breakdown attached to bench JSON."""
    records = _SPANS if records is None else records
    out: dict[str, dict[str, float]] = {}
    for r in records:
        agg = out.setdefault(r.name, {"count": 0, "total_s": 0.0})
        agg["count"] += 1
        agg["total_s"] += r.dur_s
    return out


def format_span_tree(records: Sequence[SpanRecord] | None = None,
                     min_dur_s: float = 0.0) -> str:
    """Human-readable stage tree, e.g.::

        ingest_to_knee                      12.41s
          whatif.search                     12.40s
            whatif.evaluate configs=33       3.10s
              ir.build workers=2             1.92s
                ir_build.partition (pid 71)  0.95s
    """
    lines: list[str] = []

    def walk(node: SpanNode, depth: int) -> None:
        r = node.span
        if r.dur_s >= min_dur_s:
            attrs = " ".join(f"{k}={v}" for k, v in sorted(r.attrs.items()))
            label = "  " * depth + r.name + (f" {attrs}" if attrs else "")
            lines.append(f"{label:<56s} {r.dur_s:9.3f}s")
        for child in node.children:
            walk(child, depth + 1)

    for root in span_tree(records):
        walk(root, 0)
    return "\n".join(lines)


# --------------------------------------------- process-pool span transport
def worker_token(name: str = "worker") -> dict | None:
    """Context to ship to a pool worker so its spans/metrics rejoin this
    process's trace.  ``None`` (obs disabled) makes :func:`call_with_obs`
    a plain passthrough."""
    if not STATE.enabled:
        return None
    stack = _stack()
    return {"name": name, "parent_id": stack[-1] if stack else None}


def call_with_obs(token: dict | None, fn: Callable, *args):
    """Run ``fn(*args)`` in a (fresh) worker process, recording under
    ``token``'s parent span; returns ``(result, payload)`` where payload
    carries the worker's spans and metrics (``None`` when obs is off).

    Must stay module-level so pool submissions pickle.
    """
    if token is None:
        return fn(*args), None
    global _ROOT_PARENT
    # spawn/forkserver children start with obs off and empty buffers; enable
    # for the duration of the call and ship everything back explicitly.
    prev_enabled, prev_root = STATE.enabled, _ROOT_PARENT
    _metrics.enable()
    _ROOT_PARENT = token.get("parent_id")
    try:
        with span(token.get("name", "worker")):
            result = fn(*args)
        payload = {"spans": list(_SPANS), "metrics": REGISTRY.dump()}
    finally:
        _ROOT_PARENT = prev_root
        STATE.enabled = prev_enabled
    if not prev_enabled:
        # fresh worker: drop buffers we just shipped (workers are reused
        # across submissions within one pool)
        clear_spans()
        REGISTRY.reset()
    return result, payload


def absorb(payload: dict | None) -> None:
    """Parent side: fold a worker payload into this process's trace and
    registry. Worker span ids are pid-prefixed, so no collisions."""
    if payload is None:
        return
    _SPANS.extend(payload["spans"])
    REGISTRY.merge(payload["metrics"])

"""Self-observability for the repro pipeline: metrics + spans + exporters.

The paper's argument is that fleets burn energy in states nobody measures;
this package makes sure *our own* engine is not a black box.  Default-off,
near-free when disabled, and guaranteed not to change any result
(bit-identical frontiers with obs on or off — the production contract).

Quick start::

    import repro.obs as obs

    obs.enable()
    with obs.span("ingest_to_knee"):
        result = search_frontier(store, max_evals=64)
    print(obs.stage_report())                  # human stage tree
    obs.write_textfile("reports/metrics.prom")  # Prometheus exposition
    obs.dump_spans_jsonl("reports/spans.jsonl")

Layout: :mod:`~repro.obs.metrics` (registry: counters / gauges /
log-bucket histograms), :mod:`~repro.obs.spans` (hierarchical traces +
process-pool transport), :mod:`~repro.obs.prom` (text exposition, linter,
stdlib HTTP endpoint), :mod:`~repro.obs.report` (stage-tree reports).
"""
from repro.obs.metrics import (DEGRADATION_FAMILIES, IR_APPEND_FAMILIES,
                               LIVE_FAMILIES, REGISTRY, Counter, Gauge,
                               Histogram, MetricsRegistry, counter,
                               default_buckets, disable, enable, enabled,
                               fallback, gauge, init_degradation_metrics,
                               init_ir_append_metrics, init_live_metrics,
                               observe)
from repro.obs.prom import (lint_exposition, render_prometheus,
                            start_http_server, write_textfile)
from repro.obs.report import stage_breakdown, stage_report
from repro.obs.spans import (SpanNode, SpanRecord, absorb, call_with_obs,
                             clear_spans, dump_spans_jsonl, format_span_tree,
                             load_spans_jsonl, span, span_tree, spans,
                             stage_totals, worker_token)


def reset() -> None:
    """Clear all recorded metrics and spans (does not change enabled)."""
    REGISTRY.reset()
    clear_spans()


__all__ = [
    "DEGRADATION_FAMILIES", "IR_APPEND_FAMILIES", "LIVE_FAMILIES",
    "REGISTRY", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "SpanNode", "SpanRecord", "absorb", "call_with_obs",
    "clear_spans", "counter", "default_buckets", "disable",
    "dump_spans_jsonl", "enable", "enabled", "fallback", "format_span_tree",
    "gauge", "init_degradation_metrics", "init_ir_append_metrics",
    "init_live_metrics", "lint_exposition",
    "load_spans_jsonl", "observe", "render_prometheus", "reset", "span",
    "span_tree", "spans", "stage_breakdown", "stage_report", "stage_totals",
    "start_http_server", "worker_token", "write_textfile",
]

"""The always-on ingest → extend → search tick loop.

One :meth:`LiveController.tick` is the paper's recommendation loop made
live: poll the :class:`~repro.telemetry.storage.TelemetryStore` for shards
past the controller's watermark (O(1) — :meth:`TelemetryStore.refresh` is
one ``stat`` when nothing landed), fold the pending suffix into the
run-level IR via the :func:`repro.whatif.ir.get_ir` extend ladder (which
happens *inside* ``search_frontier``'s single IR acquisition — per-tick
cost O(new rows), not O(store)), re-run the Pareto search warm-started
from the previous frontier (``init_frontier=``), checkpoint, and publish
the refreshed knee.

Backpressure, not queueing: a tick that falls behind finds *all* pending
shards past the watermark and coalesces them into one extend + one search
(``repro_live_coalesced_shards_total`` counts the backlog beyond the
first). There is no queue to bound — the watermark is the queue.

Crash safety (see :mod:`repro.live.checkpoint` for the full ordering
argument): the tick commits its checkpoint *after* the search and *before*
the publish, and the controller warm-starts every search from the
JSON-round-tripped frontier — the exact bytes a restart would load — so a
resumed run and an uninterrupted run over the same shard sequence produce
**bit-identical** frontiers (property-tested across every tick-phase
boundary in tests/test_live.py).

Failure ladder (:mod:`repro.live.supervisor`): jax → numpy, warm → cold,
then serve the stale knee flagged (``result="stale"``) with the watermark
held — poisoned data (e.g. a clock-skewed shard,
:func:`repro.testing.faults.skew_shard`) degrades freshness, never
liveness. Unreadable shards don't even get that far: the live loop runs
``strict=False`` by default, so they are skipped with coverage accounting
(``TickResult.coverage < 1``) like every PR 8 pipeline.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional, Sequence

import repro.obs as obs
from repro.live.checkpoint import (Checkpoint, fault_hook, load_checkpoint,
                                   save_checkpoint)
from repro.live.supervisor import DEFAULT_TICK_FAULT, Rung, TickSupervisor
from repro.telemetry import storage
from repro.telemetry.pipeline import FaultTolerance
from repro.whatif.report import frontier_from_dict, frontier_to_dict
from repro.whatif.search import PenaltyBudget, find_knee, search_frontier
from repro.whatif.sweep import Frontier, PolicyOutcome

#: fault-plan stage fired after the poll found pending shards, before any
#: of them is folded in (post-ingest / pre-extend)
PRE_EXTEND_STAGE = "live_pre_extend"
#: fault-plan stage fired after extend+search, before the checkpoint commit
PRE_CHECKPOINT_STAGE = "live_pre_checkpoint"


@dataclasses.dataclass
class LiveConfig:
    """Controller knobs. ``search_kwargs`` passes straight through to
    :func:`repro.whatif.search.search_frontier` (e.g. ``max_rounds``,
    ``min_job_duration_s``, ``families``); ``fault`` supervises both the
    tick ladder and — threaded through the search — the pool partitions
    inside it."""

    backend: str = "numpy"
    max_evals: int = 64
    budget: Optional[PenaltyBudget] = None
    workers: int = 1
    mmap: bool = False
    strict: bool = False        # live loops skip bad shards, account coverage
    verify: bool = False
    fault: FaultTolerance = dataclasses.field(
        default_factory=lambda: DEFAULT_TICK_FAULT)
    search_kwargs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class TickResult:
    """What one tick did. ``result`` is ``"refreshed"`` (new knee
    published), ``"idle"`` (no shards past the watermark) or ``"stale"``
    (ladder exhausted: the previous knee is served, flagged, and the
    watermark did not advance)."""

    tick: int
    result: str
    n_new_shards: int = 0
    coalesced: int = 0
    rung: Optional[str] = None
    staleness_s: float = 0.0
    frontier: Optional[Frontier] = None
    knee: Optional[PolicyOutcome] = None
    coverage: float = 1.0
    error: Optional[str] = None

    @property
    def stale(self) -> bool:
        return self.result == "stale"


class LiveController:
    """The tick loop. Construct over a store (+ checkpoint path) and call
    :meth:`tick` forever — from :mod:`examples.live_controller`'s daemon, a
    scheduler, or a test driving it shard by shard. Restores itself from
    the checkpoint on construction (tolerantly: a corrupt checkpoint
    cold-starts with a ``repro_fallbacks_total{reason="checkpoint_corrupt"}``
    instead of crashing)."""

    def __init__(self, store, checkpoint_path, config: LiveConfig | None = None,
                 publish_path=None):
        self.store = store
        self.checkpoint_path = checkpoint_path
        self.config = config or LiveConfig()
        self.publish_path = publish_path
        self.supervisor = TickSupervisor(self.config.fault,
                                         self.config.backend)
        self.tick_no = 0
        self.n_shards = 0          # shard watermark: covered prefix length
        self.source_rows = 0       # rows in that prefix (validity check)
        self._frontier: Frontier | None = None
        ckpt = load_checkpoint(checkpoint_path, store) \
            if checkpoint_path is not None else None
        if ckpt is not None:
            self.tick_no = ckpt.tick
            self.n_shards = ckpt.n_shards
            self.source_rows = ckpt.source_rows
            if ckpt.frontier is not None:
                self._frontier = frontier_from_dict(ckpt.frontier)
            # publish is idempotent — a crash between checkpoint and
            # publish re-emits the same knee here
            self._publish(stale=False)

    # ------------------------------------------------------------- state
    @property
    def frontier(self) -> Frontier | None:
        return self._frontier

    @property
    def knee(self) -> PolicyOutcome | None:
        if self._frontier is None or not self._frontier.outcomes:
            return None
        return find_knee(self._frontier.outcomes)

    # -------------------------------------------------------------- tick
    def tick(self) -> TickResult:
        """One poll → extend → search → checkpoint → publish cycle."""
        cfg = self.config
        self.store.refresh()
        landed_at = self._manifest_mtime()
        pending = self.store.shards_since(self.n_shards)
        if not pending:
            obs.counter("repro_live_ticks_total", result="idle",
                        help="live controller ticks, labelled {result}")
            return TickResult(tick=self.tick_no, result="idle",
                              frontier=self._frontier, knee=self.knee)
        fault_hook(PRE_EXTEND_STAGE)
        coalesced = len(pending) - 1
        if coalesced:
            obs.counter("repro_live_coalesced_shards_total",
                        float(coalesced),
                        help="pending shards beyond the first folded into "
                             "one extend (backpressure)")
        # watermark target captured at poll time: exactly the shards this
        # tick folds in (the manifest snapshot is what the search reads)
        target_shards = len(self.store.manifest["shards"])
        target_rows = self.store.total_rows

        def attempt(rung: Rung):
            init = self._frontier if rung.warm else None
            return search_frontier(
                self.store, budget=cfg.budget, max_evals=cfg.max_evals,
                workers=cfg.workers, mmap=cfg.mmap, backend=rung.backend,
                init_frontier=init, strict=cfg.strict, verify=cfg.verify,
                fault=cfg.fault, **cfg.search_kwargs)

        res, rung, err = self.supervisor.run(attempt)
        fault_hook(PRE_CHECKPOINT_STAGE)
        if res is None:
            # ladder exhausted: serve the stale knee, flagged; the
            # watermark holds so the data stays pending — freshness
            # degrades, liveness doesn't
            reason = type(err).__name__ if err is not None else "deadline"
            obs.fallback("live_tick", "stale_knee", reason)
            obs.counter("repro_live_ticks_total", result="stale",
                        help="live controller ticks, labelled {result}")
            self._publish(stale=True)
            return TickResult(
                tick=self.tick_no, result="stale",
                n_new_shards=len(pending), coalesced=coalesced,
                frontier=self._frontier, knee=self.knee,
                error=reason if err is None else f"{reason}: {err}")

        # normalize through the checkpoint codec so the in-memory
        # continuation and a restart warm-start from byte-identical state
        # (the crux of the bit-identical-resume contract)
        payload = frontier_to_dict(res.frontier)
        self._frontier = frontier_from_dict(payload)
        self.tick_no += 1
        self.n_shards = target_shards
        self.source_rows = target_rows
        if self.checkpoint_path is not None:
            save_checkpoint(
                Checkpoint(tick=self.tick_no, n_shards=self.n_shards,
                           source_rows=self.source_rows,
                           generation=self.store.generation,
                           frontier=payload),
                self.checkpoint_path)
        self._publish(stale=False)
        staleness = max(0.0, time.time() - landed_at)
        obs.observe("repro_live_staleness_seconds", staleness,
                    help="seconds from shard landing to the refreshed knee "
                         "being published")
        obs.counter("repro_live_ticks_total", result="refreshed",
                    help="live controller ticks, labelled {result}")
        return TickResult(
            tick=self.tick_no, result="refreshed",
            n_new_shards=len(pending), coalesced=coalesced,
            rung=rung.name if rung is not None else None,
            staleness_s=staleness, frontier=self._frontier, knee=self.knee,
            coverage=res.frontier.coverage)

    def run(self, max_ticks: int, interval_s: float = 0.0,
            stop_when_idle: bool = False) -> list[TickResult]:
        """Drive up to ``max_ticks`` ticks (the daemon loop's inner body);
        ``stop_when_idle`` exits on the first idle tick — the drain-then-
        stop shape batch tests and the bench use."""
        results = []
        for _ in range(max_ticks):
            r = self.tick()
            results.append(r)
            if stop_when_idle and r.result == "idle":
                break
            if interval_s > 0:
                time.sleep(interval_s)
        return results

    # ----------------------------------------------------------- helpers
    def _manifest_mtime(self) -> float:
        """Landing time of the newest append: the manifest's mtime (every
        append commits through the manifest rename) — the staleness clock's
        start."""
        try:
            return os.stat(self.store.root / storage.MANIFEST_NAME).st_mtime
        except OSError:
            return time.time()

    def _publish(self, stale: bool) -> None:
        """Atomically publish the current knee (idempotent: a pure function
        of the checkpointed frontier, so re-publishing after a restart
        re-emits the same artifact)."""
        if self.publish_path is None:
            return
        knee = self.knee
        if knee is None:
            return
        import json
        import pathlib
        path = pathlib.Path(self.publish_path)
        path.parent.mkdir(parents=True, exist_ok=True)
        payload = {"tick": self.tick_no, "stale": bool(stale),
                   "params": knee.params,
                   "energy_saved_j": knee.energy_saved_j,
                   "saved_fraction": knee.saved_fraction,
                   "penalty_s": knee.penalty_s}
        tmp = path.with_name(path.name + ".tmp")
        tmp.write_text(json.dumps(payload, sort_keys=True, default=str) + "\n")
        storage.atomic_replace(tmp, path)

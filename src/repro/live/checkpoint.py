"""Atomic live-controller checkpoints: watermark + frontier + tick counter.

The checkpoint is the *only* durable controller state. Everything else the
tick loop touches is either the telemetry store itself (append-only,
producer-owned) or derived data that is safe at any staleness (run-IR
sidecars re-validate their own shard watermark; the published knee is a
pure function of the checkpointed frontier). That makes the crash-point
analysis short — after ``kill -9`` at *any* instant, restart state is one
of exactly two things:

* **the previous checkpoint** (crash anywhere before the rename commits,
  including mid-checkpoint-write: the temp file is orphaned, the
  destination untouched) — the controller re-polls, sees the same shards
  past its watermark, and re-runs the tick. Ingest is at-least-once, but
  the watermark makes it idempotent: the re-run tick folds the same shard
  suffix into the same IR (``IRBuilder.extend`` == rebuild, bit-identical)
  and re-runs the same deterministic search warm-started from the same
  serialized frontier, producing the same frontier it would have produced
  uninterrupted;
* **the new checkpoint** (crash after the rename, e.g. before the knee
  republish) — the controller resumes past the tick and republishes the
  knee from the checkpointed frontier, which is the same artifact.

Bit-identity across the restart additionally requires that warm-starting
from a *deserialized* frontier equals warm-starting from the in-memory
one; the controller guarantees that by construction — it round-trips every
frontier through this codec before using it as ``init_frontier``, so the
uninterrupted run and the resumed run seed round 0 from byte-identical
state (see :class:`repro.live.controller.LiveController`).

Writes commit through :func:`repro.telemetry.storage.atomic_replace` — the
same single commit point as every manifest/shard/sidecar write — so the
fault harness (:func:`repro.testing.faults.dying_renames`) and the chaos
lane's fire-once ``kill -9`` plan (stage :data:`MID_CHECKPOINT_STAGE`,
fired between the temp write and the rename) exercise the torn-write case
deterministically. Loads are tolerant: a corrupt or unparsable checkpoint
counts ``repro_fallbacks_total{reason="checkpoint_corrupt"}`` and returns
``None`` (cold start); a checkpoint whose shard watermark no longer
matches the store's covered prefix (a rewritten or quarantined shard
inside it) counts ``reason="watermark_broken"`` and also cold-starts.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib

import repro.obs as obs
from repro.telemetry import storage

SCHEMA_VERSION = 1
#: fault-plan stage name for the kill point between the checkpoint temp
#: write and its atomic rename (see repro.testing.faults.check)
MID_CHECKPOINT_STAGE = "live_mid_checkpoint"


def fault_hook(stage: str) -> None:
    """Tick-phase fault-injection point: delegates to
    :func:`repro.testing.faults.check` only when a plan is active
    (``REPRO_FAULT_PLAN``), so the production path never imports the
    harness. Module-level — like ``storage.atomic_replace`` — so
    in-process tests patch one name to simulate a crash at any tick-phase
    boundary; the chaos lane's fire-once plans make it a real
    ``os._exit`` in a child process."""
    plan = os.environ.get("REPRO_FAULT_PLAN")
    if plan:
        from repro.testing import faults
        faults.check(stage, plan)


@dataclasses.dataclass(frozen=True)
class Checkpoint:
    """One committed controller state.

    ``n_shards``/``source_rows`` are the shard watermark: the covered
    prefix length of the append-only ``manifest["shards"]`` list plus the
    row total of that prefix (the validity check — rewriting or
    quarantining a covered shard changes the sum and voids the
    checkpoint). ``frontier`` is the :func:`repro.whatif.report
    .frontier_to_dict` payload of the last published frontier (``None``
    until the first successful tick)."""

    tick: int
    n_shards: int
    source_rows: int
    generation: int
    frontier: dict | None


def save_checkpoint(ckpt: Checkpoint,
                    path: str | pathlib.Path) -> pathlib.Path:
    """Commit a checkpoint atomically: temp write, the
    ``live_mid_checkpoint`` fault hook (fires after the temp file is fully
    written but before the rename — the torn-write instant), then the
    rename through ``storage.atomic_replace``."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {"schema_version": SCHEMA_VERSION,
               "tick": ckpt.tick, "n_shards": ckpt.n_shards,
               "source_rows": ckpt.source_rows,
               "generation": ckpt.generation,
               "frontier": ckpt.frontier}
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(payload, separators=(",", ":")) + "\n")
    fault_hook(MID_CHECKPOINT_STAGE)
    storage.atomic_replace(tmp, path)
    obs.counter("repro_live_checkpoint_writes_total",
                help="live controller checkpoints committed (atomic rename)")
    return path


def load_checkpoint(path: str | pathlib.Path,
                    store=None) -> Checkpoint | None:
    """Tolerant restore. ``None`` means cold start: no checkpoint on disk,
    a corrupt one (``repro_fallbacks_total{reason="checkpoint_corrupt"}``),
    or — when a ``store`` is given — a watermark that no longer matches the
    store's covered shard prefix (``reason="watermark_broken"``)."""
    path = pathlib.Path(path)
    if not path.exists():
        return None
    try:
        payload = json.loads(path.read_text())
        if not isinstance(payload, dict):
            raise ValueError("checkpoint is not an object")
        ckpt = Checkpoint(
            tick=int(payload["tick"]), n_shards=int(payload["n_shards"]),
            source_rows=int(payload["source_rows"]),
            generation=int(payload.get("generation", 0)),
            frontier=payload.get("frontier"))
        if ckpt.frontier is not None and not isinstance(ckpt.frontier, dict):
            raise ValueError("checkpoint frontier is not an object")
        if ckpt.n_shards < 0 or ckpt.source_rows < 0:
            raise ValueError("negative watermark")
    except (OSError, ValueError, KeyError, TypeError) as e:
        obs.fallback("checkpoint", "cold_start", "checkpoint_corrupt")
        obs.counter("repro_live_checkpoint_corrupt_total",
                    reason=type(e).__name__,
                    help="checkpoint loads rejected as corrupt")
        return None
    if store is not None and not watermark_valid(ckpt, store):
        obs.fallback("checkpoint", "cold_start", "watermark_broken")
        return None
    obs.counter("repro_live_checkpoint_restores_total",
                help="live controller restarts resumed from a valid "
                     "checkpoint")
    return ckpt


def watermark_valid(ckpt: Checkpoint, store) -> bool:
    """True iff the checkpoint's covered shard prefix still exists
    unchanged: at least ``n_shards`` manifest entries, and their rows sum
    to ``source_rows`` (same invariant the run-IR sidecar watermark uses,
    :func:`repro.whatif.ir._try_extend`)."""
    shards = store.manifest["shards"]
    if ckpt.n_shards > len(shards):
        return False
    covered = sum(int(s["rows"]) for s in shards[:ckpt.n_shards])
    return covered == ckpt.source_rows


def remove_checkpoint(path: str | pathlib.Path) -> None:
    """Delete a checkpoint (and any orphaned temp file) — test helper and
    operator reset."""
    path = pathlib.Path(path)
    for p in (path, path.with_name(path.name + ".tmp")):
        try:
            os.unlink(p)
        except OSError:
            pass

"""Shard producers: what feeds the live controller.

Three feeds, one contract — each ``step()`` appends whole shards to a
:class:`~repro.telemetry.storage.TelemetryStore` in per-stream time order
(the ordering every streaming reader requires) and returns how many rows
landed (0 = exhausted / nothing new):

* :class:`SimulatorProducer` — the §2.1 cluster simulator's fleet frame,
  time-sliced into windows and drip-fed shard by shard: the exact rows a
  one-shot ``generate_cluster(store=...)`` emission would write, arriving
  live.
* :class:`SyntheticProducer` — fleet scale without fleet memory:
  ``n_streams`` constant-state streams generated one window at a time
  (O(window) memory), deterministic per ``(seed, window)``, highly
  run-compressible — the 10⁴-stream staleness bench's feed.
* :class:`DcgmDirectoryProducer` — the real-telemetry adapter: polls a
  directory of DCGM / ``power.json``-layout dumps (the file shape of
  kserve-vllm-mini's 1 Hz DCGM collector) and feeds each *new* file
  through the PR 8 hygiene gate (:func:`repro.telemetry.hygiene
  .ingest_dcgm`), so repairs and quarantines apply before anything
  becomes a shard. Re-polling is idempotent (seen-set keyed by file name).
"""
from __future__ import annotations

import json
import pathlib
from typing import Mapping

import numpy as np

from repro.telemetry.hygiene import (DEFAULT_CONTRACT, HygieneContract,
                                     ingest_dcgm)
from repro.telemetry.records import TelemetryFrame


class SimulatorProducer:
    """Drip-feed a simulated fleet: generate the cluster sample once, then
    append one ``window_s``-wide time slice per :meth:`step`, split per
    host label exactly like ``generate_cluster``'s own chunked emission
    (``h{hostname}``). Window slicing preserves each (job, host, device)
    stream's time order, so the fed store analyzes bit-identically to the
    one-shot frame."""

    def __init__(self, store, n_devices: int = 8, horizon_s: int = 3600,
                 window_s: int = 600, seed: int = 0, min_job_s: int = 600):
        from repro.cluster import generate_cluster
        if window_s <= 0:
            raise ValueError(f"window_s must be positive, got {window_s}")
        self.store = store
        self.window_s = window_s
        sample = generate_cluster(n_devices=n_devices, horizon_s=horizon_s,
                                  seed=seed, min_job_s=min_job_s)
        self._frame = sample.frame
        self._ts = sample.frame["timestamp"]
        self._t_next = float(self._ts.min()) if len(self._frame) else 0.0
        self._t_end = float(self._ts.max()) + 1.0 if len(self._frame) else 0.0

    @property
    def exhausted(self) -> bool:
        return self._t_next >= self._t_end

    def step(self) -> int:
        """Append the next window (one shard per host label present in
        it). Returns rows appended; 0 = exhausted."""
        if self.exhausted:
            return 0
        t0, t1 = self._t_next, self._t_next + self.window_s
        self._t_next = t1
        window = self._frame.select((self._ts >= t0) & (self._ts < t1))
        if len(window) == 0:
            return 0
        rows = 0
        for h in np.unique(window["hostname"]):
            part = window.select(window["hostname"] == h)
            self.store.append(part, host=f"h{int(h)}")
            rows += len(part)
        return rows


class SyntheticProducer:
    """Fleet-scale feed with O(window) memory: ``n_streams`` streams, each
    constant within a window (alternating active/idle phases keyed on
    ``(stream, window)``), one shard per window. Deterministic — no RNG,
    no wall-clock — so two producers with the same parameters feed
    byte-identical shard sequences (the chaos tests' requirement)."""

    def __init__(self, store, n_streams: int = 1000, window_s: int = 60,
                 dt_s: float = 1.0, seed: int = 0, host: str = "fleet0",
                 active_w: float = 350.0, idle_w: float = 75.0):
        if n_streams <= 0 or window_s <= 0:
            raise ValueError("n_streams and window_s must be positive")
        self.store = store
        self.n_streams = n_streams
        self.window_s = window_s
        self.dt_s = dt_s
        self.seed = seed
        self.host = host
        self.active_w = active_w
        self.idle_w = idle_w
        self.window = 0

    def step(self) -> int:
        """Append the next window as one shard (stream-major rows)."""
        w = self.window
        self.window += 1
        n_samples = max(1, int(round(self.window_s / self.dt_s)))
        s = np.arange(self.n_streams)
        t0 = w * self.window_s
        # stream-major layout: each stream's window rows are contiguous
        ts = (t0 + self.dt_s * np.arange(n_samples, dtype=np.float64))
        ts = np.tile(ts, self.n_streams)
        stream = np.repeat(s, n_samples)
        # alternating phases, staggered per stream and shifted by the seed:
        # constant within a window, so the run-IR compacts each window to
        # one run per stream
        active = ((stream + w + self.seed) % 4) < 2
        frame = TelemetryFrame({
            "timestamp": ts,
            "hostname": (stream % 251).astype(np.int32),
            "device_id": stream.astype(np.int32),
            "platform": (stream % 3).astype(np.int32),
            "power": np.where(active, self.active_w, self.idle_w),
            "sm": np.where(active, 60.0, 0.0),
            "job_id": (stream + 1).astype(np.int64),
            "program_resident": np.ones(stream.shape[0], np.int8),
        })
        self.store.append(frame, host=self.host)
        return len(frame)


class DcgmDirectoryProducer:
    """Poll a directory of raw collector dumps and hygiene-ingest each new
    file. Two JSON layouts are accepted per file:

    * a DCGM column dump — ``{"DCGM_FI_DEV_POWER_USAGE": [...], ...}``
      with optional ``timestamp`` / identity keys alongside;
    * a ``power.json`` sample list — ``{"samples": [{"ts": ...,
      "power_w": ..., "sm_pct": ...}, ...]}`` (or a bare list), the
      per-sample shape kserve-vllm-mini's collector writes.

    Unparseable files are skipped once and counted through the quarantine
    counter (never retried, never fatal); parseable ones go through
    :func:`repro.telemetry.hygiene.ingest_dcgm`, so the contract's repairs
    and quarantine rules apply before the rows become a shard."""

    def __init__(self, store, directory,
                 contract: HygieneContract = DEFAULT_CONTRACT,
                 host: str = "dcgm0", pattern: str = "*.json"):
        self.store = store
        self.directory = pathlib.Path(directory)
        self.contract = contract
        self.host = host
        self.pattern = pattern
        self.seen: set[str] = set()
        self.verdicts: list = []

    def step(self) -> int:
        """Ingest every file not seen yet (sorted-name order). Returns the
        number of files processed this poll."""
        import repro.obs as obs
        done = 0
        for path in sorted(self.directory.glob(self.pattern)):
            if path.name in self.seen:
                continue
            self.seen.add(path.name)
            done += 1
            try:
                payload = json.loads(path.read_text())
                columns, kwargs = parse_power_json(payload)
            except (OSError, ValueError, KeyError, TypeError):
                obs.counter("repro_shards_quarantined_total",
                            reason="unparseable_dump",
                            help="telemetry shards skipped or quarantined, "
                                 "by reason")
                continue
            verdict = ingest_dcgm(self.store, columns, self.contract,
                                  host=self.host, **kwargs)
            self.verdicts.append(verdict)
        return done


def parse_power_json(payload) -> tuple[Mapping, dict]:
    """Normalize a collector dump to ``(dcgm_columns, frame_kwargs)`` for
    :func:`repro.telemetry.hygiene.dcgm_to_frame`. Raises ``ValueError``
    on a shape that is neither layout."""
    if isinstance(payload, list):
        payload = {"samples": payload}
    if not isinstance(payload, dict):
        raise ValueError("collector dump must be an object or sample list")
    if any(str(k).startswith("DCGM_FI_") for k in payload):
        columns = {k: v for k, v in payload.items()
                   if str(k).startswith("DCGM_FI_")}
        kwargs = {}
        if "timestamp" in payload:
            kwargs["timestamp"] = payload["timestamp"]
        for ident in ("hostname", "device_id", "platform", "job_id"):
            if ident in payload:
                kwargs[ident] = int(payload[ident])
        return columns, kwargs
    samples = payload.get("samples")
    if not isinstance(samples, list) or not samples:
        raise ValueError("no DCGM_FI_* columns and no samples list")
    ts = [float(s["ts"]) for s in samples]
    power = [float(s.get("power_w", float("nan"))) for s in samples]
    # sm_pct is already percent; the DCGM map scales PROF ratios by 100
    sm = [float(s.get("sm_pct", float("nan"))) / 100.0 for s in samples]
    columns = {"DCGM_FI_DEV_POWER_USAGE": power,
               "DCGM_FI_PROF_SM_ACTIVE": sm}
    kwargs: dict = {"timestamp": ts}
    dev = samples[0].get("device")
    if dev is not None:
        kwargs["device_id"] = int(dev)
    return columns, kwargs

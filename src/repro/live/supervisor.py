"""Tick watchdog: per-tick deadline, retry-with-backoff, degradation ladder.

Reuses the fault-supervision policy object of the pool layer
(:class:`repro.telemetry.pipeline.FaultTolerance`) one level up: *inside*
a tick, pool partitions are already supervised by
:func:`repro.telemetry.pipeline.run_supervised` (the controller threads
its ``fault`` through ``search_frontier`` → ``evaluate`` →
``map_shard_partitions``, so a crashed pool worker retries and degrades to
in-process exactly as in PR 8); *around* a tick, this module walks a
degradation ladder when the whole search attempt fails or blows its
deadline:

1. the configured backend, warm-started from the previous frontier;
2. ``jax`` → ``numpy`` (skipped when the controller already runs numpy);
3. warm → cold (no ``init_frontier`` — a poisoned warm seed or a
   divergent refinement cannot wedge the loop);
4. ladder exhausted → the caller serves its **stale knee, flagged**
   (``TickResult.result == "stale"``) and leaves the watermark where it
   was, so the data stays pending and the operator sees staleness grow
   instead of a crash loop.

Every rung transition is counted via :func:`repro.obs.fallback`
(``repro_fallbacks_total{from=..., to=..., reason=...}``); same-rung
retries count ``repro_live_tick_retries_total`` and abandoned attempts
``repro_live_deadline_misses_total``.

``FaultTolerance.timeout_s`` is the wall-clock budget for the *whole*
ladder walk (mirroring ``run_supervised``'s shared pool-round deadline).
When set, each attempt runs on a daemon worker thread and is abandoned —
not killed; Python cannot — once the remaining budget is spent; the
abandoned attempt's result is discarded even if it eventually finishes.
``timeout_s=None`` (the default) runs attempts inline with zero threading
overhead.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Sequence

import repro.obs as obs
from repro.telemetry.pipeline import FaultTolerance

#: default tick supervision: one same-rung retry, no deadline
DEFAULT_TICK_FAULT = FaultTolerance(max_retries=1, timeout_s=None,
                                    backoff_s=0.05)


@dataclasses.dataclass(frozen=True)
class Rung:
    """One degradation-ladder step: which backend, and whether the search
    warm-starts from the previous frontier."""

    name: str
    backend: str
    warm: bool


def ladder(backend: str) -> tuple[Rung, ...]:
    """The tick ladder for a configured backend: warm on that backend,
    warm on numpy (when distinct), then cold on numpy."""
    rungs = []
    if backend != "numpy":
        rungs.append(Rung(f"warm_{backend}", backend, True))
    rungs.append(Rung("warm_numpy", "numpy", True))
    rungs.append(Rung("cold_numpy", "numpy", False))
    return tuple(rungs)


class TickSupervisor:
    """Run one tick attempt function down the degradation ladder.

    ``attempt`` is called with a :class:`Rung` and must either return the
    tick's result or raise; :meth:`run` returns ``(result, rung, None)`` on
    the first success, or ``(None, None, last_error)`` when every rung is
    exhausted (the serve-stale signal). Deterministic apart from wall-clock
    timeouts: with no deadline and a deterministic ``attempt``, the rung
    walk is a pure function of which rungs raise.
    """

    def __init__(self, fault: FaultTolerance | None = None,
                 backend: str = "numpy",
                 rungs: Sequence[Rung] | None = None):
        self.fault = fault or DEFAULT_TICK_FAULT
        self.rungs = tuple(rungs) if rungs is not None else ladder(backend)
        if not self.rungs:
            raise ValueError("supervisor needs at least one ladder rung")

    def run(self, attempt: Callable[[Rung], object]):
        fault = self.fault
        deadline = (time.monotonic() + fault.timeout_s
                    if fault.timeout_s is not None else None)
        last_err: BaseException | None = None
        prev_rung: Rung | None = None
        for rung in self.rungs:
            if prev_rung is not None:
                reason = ("deadline" if last_err is None
                          else type(last_err).__name__)
                obs.fallback(prev_rung.name, rung.name, reason)
            for try_no in range(fault.max_retries + 1):
                budget = None
                if deadline is not None:
                    budget = deadline - time.monotonic()
                    if budget <= 0:
                        return None, None, last_err
                ok, value, err, timed_out = _call(attempt, rung, budget)
                if ok:
                    return value, rung, None
                if timed_out:
                    # a hung attempt: don't retry the rung that hung —
                    # descend with whatever budget remains
                    obs.counter(
                        "repro_live_deadline_misses_total",
                        help="tick attempts abandoned at the per-tick "
                             "deadline")
                    last_err = None
                    break
                last_err = err
                if try_no < fault.max_retries:
                    obs.counter(
                        "repro_live_tick_retries_total",
                        help="tick attempts that failed and were retried "
                             "on the same ladder rung")
                    if fault.backoff_s > 0:
                        time.sleep(min(fault.backoff_s * (2 ** try_no), 2.0))
            prev_rung = rung
        return None, None, last_err


def _call(attempt: Callable[[Rung], object], rung: Rung,
          budget_s: float | None):
    """One attempt, optionally under a wall-clock budget. Returns
    ``(ok, value, error, timed_out)``."""
    if budget_s is None:
        try:
            return True, attempt(rung), None, False
        except Exception as e:
            return False, None, e, False
    box: dict = {}

    def runner() -> None:
        try:
            box["value"] = attempt(rung)
        except BaseException as e:      # noqa: BLE001 — shipped to caller
            box["error"] = e

    t = threading.Thread(target=runner, daemon=True,
                         name=f"live-tick-{rung.name}")
    t.start()
    t.join(budget_s)
    if t.is_alive():
        return False, None, None, True
    if "error" in box:
        return False, None, box["error"], False
    return True, box.get("value"), None, False

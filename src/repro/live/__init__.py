"""Live fleet controller: the always-on ingest → extend → search loop.

The offline engine (PRs 1–9) answers "what should the fleet do" from a
frozen store; this package keeps the answer fresh against a store that
never stops growing, and keeps its failure behavior boring:

* :mod:`~repro.live.controller` — the tick loop (poll watermark → coalesce
  pending shards into one IR extend → warm-started ``search_frontier`` →
  checkpoint → publish knee);
* :mod:`~repro.live.checkpoint` — atomic checkpoints (shard watermark +
  serialized frontier + tick counter) with the crash-point ordering that
  makes ``kill -9`` at any instant resume to a bit-identical frontier;
* :mod:`~repro.live.supervisor` — the tick watchdog: per-tick deadline,
  retry-with-backoff, degradation ladder (jax→numpy, warm→cold,
  serve-stale-knee-with-flag);
* :mod:`~repro.live.producer` — what feeds it: the simulator drip-fed by
  window, a fleet-scale synthetic stream generator, and the DCGM /
  ``power.json`` real-telemetry adapter.

See the README "Live controller" section for the tick diagram, checkpoint
format and staleness SLO, and ``examples/live_controller.py`` for the
daemon.
"""
from repro.live.checkpoint import (Checkpoint, load_checkpoint,
                                   remove_checkpoint, save_checkpoint,
                                   watermark_valid)
from repro.live.controller import (LiveConfig, LiveController, TickResult,
                                   fault_hook)
from repro.live.producer import (DcgmDirectoryProducer, SimulatorProducer,
                                 SyntheticProducer, parse_power_json)
from repro.live.supervisor import (DEFAULT_TICK_FAULT, Rung, TickSupervisor,
                                   ladder)

__all__ = [
    "Checkpoint", "DEFAULT_TICK_FAULT", "DcgmDirectoryProducer",
    "LiveConfig", "LiveController", "Rung", "SimulatorProducer",
    "SyntheticProducer", "TickResult", "TickSupervisor", "fault_hook",
    "ladder", "load_checkpoint", "parse_power_json", "remove_checkpoint",
    "save_checkpoint", "watermark_valid",
]

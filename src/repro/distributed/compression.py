"""Gradient compression with error feedback for the cross-pod reduction.

int8 block-quantized all-reduce: each pod computes local grads, quantizes
(per-block scale, symmetric int8), sums int32 across the `pod` axis, and
dequantizes. Quantization error is carried in an error-feedback buffer so the
compression is unbiased over time (Karimireddy et al., EF-SGD).

Cuts the cross-pod gradient traffic 4x (bf16->int8 payload + f32 scales per
block of 256), which attacks the collective roofline term of multi-pod
training — see EXPERIMENTS.md §Perf.

The reduction runs inside ``shard_map`` manual over the pod axis only
(other axes stay auto), so it composes with the FSDP/TP shardings.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map

BLOCK = 256


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-block int8. Returns (q int8 [n], scales f32 [blocks], shape)."""
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], shape


def dequantize_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compressed_psum(tree, axis: str, error_buf=None):
    """psum(tree) over `axis` with int8 wire format + error feedback.

    Each pod transmits int8 payload + f32 per-block scales via all_gather
    (int8 on the wire — the 4x traffic cut vs a bf16 ring all-reduce), then
    dequantizes and sums locally. Returns (summed_tree, new_error_buf).
    Call inside shard_map manual on ``axis``.
    """
    leaves, treedef = jax.tree.flatten(tree)
    err_leaves = (jax.tree.leaves(error_buf) if error_buf is not None
                  else [jnp.zeros_like(l, dtype=jnp.float32) for l in leaves])
    out, new_err = [], []
    for g, e in zip(leaves, err_leaves):
        g32 = g.astype(jnp.float32) + e
        q, scale, shape = quantize_int8(g32)
        local_dq = dequantize_int8(q, scale, shape)
        new_err.append(g32 - local_dq)                     # error feedback
        q_all = jax.lax.all_gather(q, axis)                # (P, blocks, BLOCK) int8
        s_all = jax.lax.all_gather(scale, axis)            # (P, blocks) f32
        summed = jnp.sum(q_all.astype(jnp.float32) * s_all[..., None], axis=0)
        n = local_dq.size
        out.append(summed.reshape(-1)[:n].reshape(shape).astype(g.dtype))
    return (jax.tree.unflatten(treedef, out),
            jax.tree.unflatten(treedef, new_err))


def make_compressed_allreduce(mesh, pod_axis: str = "pod"):
    """Returns f(grads, err) -> (reduced_grads, err) running the EF-int8
    reduction across pods, manual only on the pod axis."""
    other = tuple(a for a in mesh.axis_names if a != pod_axis)

    def reduce_fn(grads, err):
        def body(g, e):
            summed, new_e = compressed_psum(g, pod_axis, e)
            n_pods = mesh.shape[pod_axis]
            summed = jax.tree.map(lambda x: x / n_pods, summed)  # mean
            return summed, new_e

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False,
            axis_names=frozenset({pod_axis}),
        )(grads, err)

    return reduce_fn

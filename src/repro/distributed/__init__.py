"""Distribution substrate: context, sharding rules, gradient compression."""
from repro.distributed.context import DistContext, LOCAL  # noqa: F401

"""JAX version compatibility.

`shard_map` graduated from ``jax.experimental.shard_map`` into the ``jax``
namespace, renaming ``check_rep`` -> ``check_vma`` and replacing the ``auto``
set (axes left automatic) with ``axis_names`` (axes made manual). Importing
from here works on both sides of that move.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma=True, axis_names=None):
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh, in_specs, out_specs,
                      check_rep=check_vma, auto=auto)

"""Distribution context threaded through model code.

Keeps model code mesh-agnostic: when ``mesh`` is None (CPU smoke tests)
all constraints are no-ops and MoE uses the dense fallback; when a mesh is
present, activations get explicit sharding constraints and MoE dispatch runs
expert-parallel over the ``model`` axis via shard_map + all_to_all.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Mesh | None = None
    #: axes that shard the global batch (("pod","data") on the multi-pod mesh)
    batch_axes: tuple[str, ...] = ("data",)
    #: axis used for tensor/expert/sequence parallelism
    model_axis: str = "model"

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    @property
    def dp_size(self) -> int:
        if not self.enabled:
            return 1
        return int(
            jax.numpy.prod(jax.numpy.array(
                [self.mesh.shape[a] for a in self.batch_axes])))

    @property
    def ep_size(self) -> int:
        return self.mesh.shape[self.model_axis] if self.enabled else 1

    # ------------------------------------------------------------------ #
    def batch_spec(self, *rest) -> P:
        return P(self.batch_axes, *rest)

    def constraint(self, x, spec: P):
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def sharding(self, spec: P) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, spec)


#: default single-process context (no mesh)
LOCAL = DistContext()

"""Sharding rules: param / batch / cache PartitionSpecs per (arch x shape).

Strategy (baseline; hillclimbed variants live in launch/dryrun options):

* **Tensor parallel** over ``model``: column-parallel in-projections
  (wq/wk/wv/w_gate/w_up/...), row-parallel out-projections (wo/w_down/...).
* **FSDP** over ``data`` (+ ``pod``): the non-TP weight dim is sharded over
  the batch axes; XLA all-gathers per scanned layer and reduce-scatters grads.
* **Expert parallel**: expert-stacked weights sharded on the expert dim over
  ``model`` (matches the shard_map dispatch in models/moe.py).
* **Vocab parallel**: embedding (V, D) -> (model, data); tied logits come out
  vocab-sharded and the cross-entropy's logsumexp/gather reduce over `model`.
* **Decode caches**: batch over batch axes; sequence dim over ``model`` when
  kv_heads < |model| (distributed-softmax decode), else kv-heads over
  ``model``. Uneven dims are allowed (GSPMD pads); shard_map inputs are the
  only place that requires exact divisibility.

The what-if replay backend reuses the same mesh/axis conventions for a
much simpler layout — an embarrassingly-parallel 1-D shard of the policy
config axis over the batch axis (:func:`repro.whatif.backend.config_mesh`,
padded to exact divisibility like shard_map inputs here).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.context import DistContext

# leaf-name rule sets (matched on the last string key in the tree path)
_COL = {
    "wq", "wk", "wv", "wg", "wr", "w_gate", "w_up", "tm_w1", "cm_wk",
    "in_proj", "w_dq", "w_uq", "w_dkv", "w_ukv", "x_wq", "x_wk", "x_wv",
    "proj", "dt_proj",
}
_ROW = {"wo", "w_down", "cm_wv", "cm_wr", "ssm_out_proj", "x_proj", "x_wo", "head"}
_BIAS_MODEL = {"bq", "bk", "bv", "b_up"}
_EXPERT_IN = {"we_gate", "we_up"}
_EXPERT_OUT = {"we_down"}


def _tail(rank: int, *axes) -> P:
    """PartitionSpec acting on the trailing ``len(axes)`` dims."""
    axes = list(axes)
    if len(axes) > rank:
        axes = axes[len(axes) - rank:]
    return P(*([None] * (rank - len(axes)) + axes))


def _leaf_spec(name: str, rank: int, dist: DistContext) -> P:
    b = dist.batch_axes if len(dist.batch_axes) > 1 else dist.batch_axes[0]
    m = dist.model_axis
    if name == "embed":
        return _tail(rank, m, b)
    if name == "out_head":
        return _tail(rank, b, m)
    if name == "router":
        return _tail(rank, b, None)
    if name in _EXPERT_IN:
        return _tail(rank, m, b, None)
    if name in _EXPERT_OUT:
        return _tail(rank, m, None, b)
    if name in _COL:
        return _tail(rank, b, m)
    if name in _ROW:
        return _tail(rank, m, b)
    if name in _BIAS_MODEL:
        return _tail(rank, m)
    if name in ("conv_w",):
        return _tail(rank, None, m)
    if name in ("a_log",):
        return _tail(rank, m, None)
    if name in ("d_skip", "dt_bias"):
        return _tail(rank, m)
    return P()  # norms, gates, scalars, small LoRAs: replicated


def _path_leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def param_specs(params_tree, dist: DistContext):
    """PartitionSpec pytree matching ``params_tree`` (abstract or concrete).

    jit in/out shardings require exact divisibility, so placements on dims
    that don't divide the axis size are dropped (e.g. 49155/32001-row
    embeddings, hymba's 25-head projections)."""

    def _axis_size(ax) -> int:
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= dist.mesh.shape[a]
            return n
        return dist.mesh.shape[ax]

    def spec(path, leaf):
        name = _path_leaf_name(path)
        rank = len(leaf.shape)
        raw = _leaf_spec(name, rank, dist)
        if not dist.enabled:
            return raw
        axes = list(raw) + [None] * (rank - len(tuple(raw)))
        out = []
        for dim, ax in zip(leaf.shape, axes):
            if ax is None:
                out.append(None)
            else:
                out.append(ax if dim % _axis_size(ax) == 0 else None)
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, params_tree)


# --------------------------------------------------------------------------- #
# batches
# --------------------------------------------------------------------------- #
def batch_specs(cfg: ModelConfig, dist: DistContext, global_batch: int | None = None):
    b = dist.batch_axes if _batch_fits(dist, global_batch) else None
    out = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.family == "encdec":
        out["frames"] = P(b, None, None)
    if cfg.family == "vlm":
        out["vision"] = P(b, None, None)
    return out


def _batch_fits(dist: DistContext, global_batch: int | None) -> bool:
    if global_batch is None or not dist.enabled:
        return True
    return global_batch % max(dist.dp_size, 1) == 0


def token_specs(dist: DistContext, global_batch: int | None = None):
    b = dist.batch_axes if _batch_fits(dist, global_batch) else None
    return P(b, None)


# --------------------------------------------------------------------------- #
# decode caches
# --------------------------------------------------------------------------- #
def cache_specs(cfg: ModelConfig, cache_tree, dist: DistContext):
    """Spec tree matching ``init_cache``'s structure for each family.

    jit in/out shardings require exact divisibility, so every placement is
    checked against the actual leaf shape and dropped (replicated) if the dim
    does not divide — e.g. whisper's 1500-frame cross cache or rwkv's 40
    heads on a 16-wide model axis.
    """
    b = dist.batch_axes
    m = dist.model_axis
    ep = max(dist.ep_size, 1)
    dp = max(dist.dp_size, 1)
    heads_divisible = cfg.n_kv_heads % ep == 0 and dist.ep_size > 1

    def _axis_size(ax) -> int:
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= dist.mesh.shape[a]
            return n
        return dist.mesh.shape[ax]

    def _fit(leaf, spec: P) -> P:
        """Drop axis placements whose dim size doesn't divide evenly."""
        axes = list(spec) + [None] * (len(leaf.shape) - len(spec))
        out = []
        for dim, ax in zip(leaf.shape, axes):
            if ax is None:
                out.append(None)
            else:
                out.append(ax if dim % _axis_size(ax) == 0 else None)
        return P(*out)

    from repro.models import tuning

    def spec(path, leaf):
        name = _path_leaf_name(path)
        if name == "len" or len(leaf.shape) == 0:
            return P()
        if tuning.ACTIVE.decode_cache_data_only:
            # batch-only sharding: keeps the per-step dynamic-update-slice
            # local (GSPMD re-gathers model-sharded seq dims on update)
            if cfg.family == "hybrid":
                batch_dim = 0
            elif cfg.family == "vlm" and name in ("k", "v"):
                batch_dim = 2
            else:
                batch_dim = 1
            spec_axes = [None] * len(leaf.shape)
            if leaf.shape[batch_dim] % max(dp, 1) == 0:
                spec_axes[batch_dim] = b
            return P(*spec_axes)
        if cfg.family in ("dense", "moe"):
            # (L, B, S, KV, hd)
            raw = (P(None, b, None, m, None) if heads_divisible
                   else P(None, b, m, None, None))
        elif cfg.family == "mla_moe":
            raw = P(None, b, m, None)            # ckv/krope (L, B, S, r)
        elif cfg.family == "rwkv":
            if name == "wkv":                     # (L, B, H, K, V)
                raw = P(None, b, None, m, None)
            else:                                 # shifts (L, B, 1, D)
                raw = P(None, b, None, m)
        elif cfg.family == "hybrid":
            if name in ("k", "v"):                # (B, size, KV, hd)
                raw = P(b, m, None, None)
            elif name == "conv":                  # (B, K-1, I)
                raw = P(b, None, m)
            elif name == "ssm":                   # (B, I, N)
                raw = P(b, m, None)
            else:
                raw = P()
        elif cfg.family == "encdec":
            raw = P(None, b, m, None, None)       # (L,B,S,H,hd) / (L,B,F,H,hd)
        elif cfg.family == "vlm":
            if name in ("k", "v"):                # (G, P, B, S, KV, hd)
                raw = P(None, None, b, m, None, None)
            else:                                 # xk/xv (G, B, Nv, KV, hd)
                raw = P(None, b, m, None, None)
        else:
            raw = P()
        return _fit(leaf, raw)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def named(dist: DistContext, spec_tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(lambda s: dist.sharding(s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------- #
# activation-sharding hook (models.common.set_shard_hook)
# --------------------------------------------------------------------------- #
def make_shard_hook(cfg: ModelConfig, dist: DistContext):
    """Turn models.common.hint(x, kind) calls into sharding constraints.

    Without these, GSPMD's internal propagation is free to replicate
    activations (observed: full-batch score buffers at 256-chip scale).
    """
    if not dist.enabled:
        return None
    b = dist.batch_axes
    m = dist.model_axis
    ep = dist.ep_size
    heads_ok = cfg.n_heads % ep == 0
    kv_ok = cfg.n_kv_heads % ep == 0

    from repro.models import tuning

    def hook(x, kind: str):
        if kind == "act_bsd":
            if tuning.ACTIVE.seq_parallel and x.shape[1] % ep == 0:
                return dist.constraint(x, P(b, m, None))
            return dist.constraint(x, P(b, None, None))
        if kind == "act_bshd":
            spec = P(b, None, m, None) if heads_ok else P(b, m, None, None)
            return dist.constraint(x, spec)
        if kind == "kv_bskd":
            spec = P(b, None, m, None) if kv_ok else P(b, None, None, None)
            return dist.constraint(x, spec)
        if kind == "kv_cache_bskd":
            spec = P(b, None, m, None) if kv_ok else P(b, m, None, None)
            return dist.constraint(x, spec)
        if kind == "logits":
            return dist.constraint(x, P(b, None, m))
        return x

    return hook

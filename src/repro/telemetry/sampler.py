"""First-party runtime telemetry sampler.

On GPUs the paper polls NVML/DCGM passively. In this framework the runtime
*is* ours, so the trainer/server push activity deltas into a
:class:`RuntimeSampler`, which integrates them into per-second Table-1 rows.
This realizes the paper's §6 "workload-power interface": the workload reports
its own phase structure instead of the power layer inferring it.

Usage (training loop):

    sampler = RuntimeSampler(device=SimulatedDevice(TPU_V5E), job_id=7)
    ...
    with sampler.phase("step", compute_util=0.85, hbm_util=0.55,
                       ici_gbs=12.0):    # wall-time measured by the context
        loss = train_step(...)
    sampler.idle_until(t_next)           # blocking on input pipeline

The sampler emits one row per elapsed second with activity = the utilization
of whatever phase covered that second (fractional seconds are blended).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Iterator

import numpy as np

from repro.core.power_model import SimulatedDevice
from repro.telemetry.records import TelemetryFrame


@dataclasses.dataclass
class _PhaseAccum:
    """Per-second accumulators (time-weighted activity within the second)."""

    busy_s: float = 0.0
    sm: float = 0.0
    tensor: float = 0.0
    dram: float = 0.0
    ici_tx: float = 0.0
    ici_rx: float = 0.0
    pcie_rx: float = 0.0
    nic_rx: float = 0.0
    cpu: float = 0.0


class RuntimeSampler:
    """Integrates runtime-reported phases into 1 Hz telemetry rows."""

    def __init__(
        self,
        device: SimulatedDevice,
        job_id: int = 0,
        device_id: int = 0,
        hostname: int = 0,
        platform_id: int = 0,
        use_wall_clock: bool = False,
    ):
        self.device = device
        self.job_id = job_id
        self.device_id = device_id
        self.hostname = hostname
        self.platform_id = platform_id
        self.use_wall_clock = use_wall_clock
        self._now = time.monotonic() if use_wall_clock else 0.0
        self._sec_start = self._now
        self._accum = _PhaseAccum()
        self._rows: list[dict[str, object]] = []
        self._last: dict[str, object] | None = None
        self.resident = False

    # ------------------------------------------------------------------ #
    @property
    def now(self) -> float:
        return self._now

    def load_program(self) -> None:
        self.resident = True

    def unload_program(self) -> None:
        self.resident = False

    def _flush_second(self) -> None:
        a = self._accum
        util = min(a.busy_s, 1.0)
        sm_pct = 100.0 * a.sm
        row = {
            "timestamp": self._sec_start,
            "hostname": self.hostname,
            "device_id": self.device_id,
            "platform": self.platform_id,
            "job_id": self.job_id,
            "program_resident": int(self.resident),
            "sm": sm_pct,
            "tensor": 100.0 * a.tensor,
            "dram": 100.0 * a.dram,
            "fp16": np.nan, "fp32": np.nan, "fp64": np.nan,
            "ici_tx": a.ici_tx, "ici_rx": a.ici_rx,
            "pcie_tx": 0.0, "pcie_rx": a.pcie_rx,
            "nvlink_tx": np.nan, "nvlink_rx": np.nan,
            "nic_tx": 0.0, "nic_rx": a.nic_rx,
            "cpu_util": 100.0 * a.cpu,
            "host_mem_util": 0.0,
            "power": self.device.power_w(self._sec_start, a.sm, self.resident),
            "sm_clk": self.device.platform.sm_clk_mhz[int(self.device.clocks()[0])],
            "mem_clk": self.device.platform.mem_clk_mhz[int(self.device.clocks()[1])],
        }
        self._rows.append(row)
        self._last = row
        self._accum = _PhaseAccum()
        self._sec_start += 1.0

    def _advance(self, duration_s: float, **activity: float) -> None:
        """Advance simulated time, spreading `activity` over covered seconds."""
        remaining = duration_s
        while remaining > 0:
            sec_end = self._sec_start + 1.0
            chunk = min(remaining, sec_end - self._now)
            frac = chunk  # fraction of the current second
            a = self._accum
            a.busy_s += frac if activity.get("compute_util", 0.0) > 0 else 0.0
            a.sm += frac * activity.get("compute_util", 0.0)
            a.tensor += frac * activity.get("tensor_util",
                                            activity.get("compute_util", 0.0))
            a.dram += frac * activity.get("hbm_util", 0.0)
            a.ici_tx += frac * activity.get("ici_gbs", 0.0)
            a.ici_rx += frac * activity.get("ici_gbs", 0.0)
            a.pcie_rx += frac * activity.get("pcie_gbs", 0.0)
            a.nic_rx += frac * activity.get("nic_gbs", 0.0)
            a.cpu += frac * activity.get("cpu_util", 0.0)
            self._now += chunk
            remaining -= chunk
            if self._now >= sec_end - 1e-12:
                self._flush_second()

    # ------------------------------------------------------------------ #
    # Public phase API
    # ------------------------------------------------------------------ #
    def busy(self, duration_s: float, compute_util: float = 0.9,
             hbm_util: float = 0.5, ici_gbs: float = 0.0,
             pcie_gbs: float = 0.0, nic_gbs: float = 0.0,
             cpu_util: float = 0.3) -> None:
        """Record a busy phase of known duration (simulated time)."""
        self._advance(duration_s, compute_util=compute_util, hbm_util=hbm_util,
                      ici_gbs=ici_gbs, pcie_gbs=pcie_gbs, nic_gbs=nic_gbs,
                      cpu_util=cpu_util)

    def idle(self, duration_s: float, pcie_gbs: float = 0.0,
             nic_gbs: float = 0.0, cpu_util: float = 0.02) -> None:
        """Record a loaded-but-inactive phase (the execution-idle producer)."""
        self._advance(duration_s, compute_util=0.0, hbm_util=0.0,
                      pcie_gbs=pcie_gbs, nic_gbs=nic_gbs, cpu_util=cpu_util)

    @contextlib.contextmanager
    def phase(self, name: str, compute_util: float = 0.9, hbm_util: float = 0.5,
              ici_gbs: float = 0.0) -> Iterator[None]:
        """Wall-clock-measured busy phase (for live runs on CPU)."""
        t0 = time.monotonic()
        yield
        self.busy(time.monotonic() - t0, compute_util=compute_util,
                  hbm_util=hbm_util, ici_gbs=ici_gbs)

    # ------------------------------------------------------------------ #
    def last_row(self) -> dict[str, object] | None:
        """Most recent emitted Table-1 row, or None before the first flush.

        O(1) — controllers polling every tick must not rebuild the whole
        frame just to read the newest sample. Survives :meth:`drain`, so a
        periodically drained engine's controller keeps seeing its last
        sample.
        """
        return dict(self._last) if self._last is not None else None

    def frame(self) -> TelemetryFrame:
        return TelemetryFrame.from_rows(self._rows)

    def drain(self) -> TelemetryFrame:
        frame = self.frame()
        self._rows = []
        return frame

    def drain_to(self, store, host: str = "host0",
                 flush_manifest: bool = True) -> int:
        """Drain buffered rows into a :class:`TelemetryStore` shard.

        The out-of-core producer hookup: long replays call this periodically
        so telemetry goes straight to storage shards (in time order, ready
        for the streaming analysis/what-if paths) instead of accumulating
        the whole run in memory. Returns the number of rows drained; an
        empty buffer appends nothing.
        """
        n = len(self._rows)
        store.append(self.drain(), host=host, flush_manifest=flush_manifest)
        return n

"""Compressed telemetry log storage (paper §2.1: 20–100 MB/server/day).

Two shard formats behind one manifest:

* ``npz`` (default) — columnar zip-deflate ``.npz``, smallest on disk;
* ``npy_dir`` — one raw ``.npy`` per column in a shard directory, readable
  with ``np.load(mmap_mode="r")`` so ``iter_shards(mmap=True)`` is
  zero-copy: columns a pass never touches (e.g. host counters during a
  what-if sweep) are never faulted into memory.

Append-oriented: writers append shards labelled (host, day) — possibly
several per label, e.g. one per device or per flush — and a reader
concatenates (or streams) shards in manifest order.

Run-IR sidecars
---------------
Next to the shards, the what-if engine may persist **run-level IR
sidecars** (``run_ir_<hash>.npz``, written by
:func:`repro.whatif.ir.save_sidecar`): the store's rows collapsed, per
(job, host, device) stream, into maximal runs of constant
``(device_state, low_activity)`` — run table (state/low/length/power_sum),
per-stream metadata (host label, platform, first timestamp, row/run
counts) and the raw power samples — so repeat sweeps skip stream grouping,
classification and run-length encoding entirely. Sidecars are keyed in the
manifest under ``manifest["run_ir"][<classifier-config hash>]``; the entry
records the ``source_rows`` the sidecar was built from plus a **shard
watermark**: ``n_shards`` (the covered prefix length of the append-only
``manifest["shards"]`` list) and per-host ``watermarks`` (covered row
counts per host label). A different classifier config hashes to a
different sidecar. Appending shards makes the sidecar *stale*, not dead:
:func:`repro.whatif.ir.get_ir` reloads it (``allow_stale=True``), checks
that the covered prefix still sums to ``source_rows``, and folds only the
uncovered suffix shards in via :meth:`repro.whatif.ir.IRBuilder.extend` —
store growth invalidates the appended-to streams' tails, not the world. A
rewritten, quarantined or reordered shard *inside* the covered prefix
breaks the watermark and forces a full rebuild. Sidecars are derived
data — deleting the files and the manifest key is always safe.

Robustness (see the README "Robustness & dirty telemetry" section)
------------------------------------------------------------------
Real telemetry shards get truncated, bit-flipped and orphaned. Every write
that could tear (manifest, ``npz`` shard, sidecar) goes through temp-file +
:func:`atomic_replace`; every read raises a single typed
:class:`ShardReadError` carrying a machine-readable ``reason``
(``missing_file`` / ``corrupt`` / ``checksum_mismatch``) instead of leaking
``FileNotFoundError`` / ``zipfile.BadZipFile``. ``write_shard`` records a
sha256 per shard (``verify=True`` reads recompute it), ``iter_shards`` /
``read_shard_or_skip`` take ``strict=False`` to skip bad shards with
coverage accounting, :meth:`TelemetryStore.quarantine_shard` moves a bad
shard into ``quarantine/`` with a manifest record, and a corrupt manifest
JSON is recovered by rescanning the shard files on disk. The repair /
quarantine *policies* live in :mod:`repro.telemetry.hygiene`.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import re
import shutil
import zipfile
import zlib
from typing import Iterable, Iterator

import numpy as np

import repro.obs as obs
from repro.telemetry.records import FIELDS, TelemetryFrame

MANIFEST_NAME = "manifest.json"
SHARD_FORMATS = ("npz", "npy_dir")
QUARANTINE_DIR = "quarantine"
_SHARD_STEM_RE = re.compile(r"^telemetry_(?P<host>.+)_d(?P<day>\d{3})_\d{5}$")


class ShardReadError(RuntimeError):
    """One shard could not be read. ``reason`` is machine-readable —
    ``missing_file`` (manifest/disk drift), ``corrupt`` (truncated or
    bit-flipped archive, ragged columns), ``checksum_mismatch`` (recorded
    sha256 disagrees with the bytes on disk)."""

    def __init__(self, shard: str, reason: str, detail: str = ""):
        self.shard = shard
        self.reason = reason
        msg = f"shard {shard!r}: {reason}"
        super().__init__(msg + (f" ({detail})" if detail else ""))


def atomic_replace(tmp: pathlib.Path, dst: pathlib.Path) -> None:
    """The single commit point of every storage write (manifest, ``npz``
    shard, run-IR sidecar): rename a fully-written temp file over the
    destination. Kept module-level — and always called as
    ``storage.atomic_replace`` / a module global, never ``from``-imported —
    so the fault-injection harness can simulate a kill at the rename
    boundary by patching one name (:func:`repro.testing.faults.dying_renames`)."""
    os.replace(str(tmp), str(dst))


def _write_atomic_text(path: pathlib.Path, text: str) -> None:
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(text)
    atomic_replace(tmp, path)


def _write_atomic_npz(path: pathlib.Path, arrays: dict) -> None:
    # savez_compressed on an open handle: a string temp path without the
    # .npz suffix would get one silently appended
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as fh:
        np.savez_compressed(fh, **arrays)
    atomic_replace(tmp, path)


#: reader-side exceptions that mean "this archive is damaged", mapped to
#: ShardReadError(reason="corrupt"): truncated zip central directory
#: (BadZipFile), truncated .npy payload / ragged columns (ValueError),
#: deflate stream damage (zlib.error), short reads (EOFError/OSError)
_CORRUPT_ERRORS = (zipfile.BadZipFile, ValueError, zlib.error, EOFError,
                   OSError, KeyError)


def checksum_shard(path: pathlib.Path) -> str:
    """sha256 of a shard's bytes; ``npy_dir`` shards hash the sorted
    ``(column file name, column sha256)`` pairs so the digest is stable
    against directory-listing order."""
    if path.is_dir():
        outer = hashlib.sha256()
        for col in sorted(p.name for p in path.glob("*.npy")):
            outer.update(f"{col}:{_file_sha256(path / col)}\n".encode())
        return outer.hexdigest()
    return _file_sha256(path)


def _file_sha256(path: pathlib.Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as fh:
        for block in iter(lambda: fh.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


class TelemetryStore:
    def __init__(self, root: str | pathlib.Path,
                 shard_format: str | None = None):
        """``shard_format=None`` adopts an existing store's persisted format
        (so reopening an ``npy_dir`` store for append keeps appending
        ``npy_dir`` shards), defaulting to ``npz`` for new stores; passing a
        format that contradicts the persisted one raises."""
        if shard_format is not None and shard_format not in SHARD_FORMATS:
            raise ValueError(
                f"unknown shard_format {shard_format!r}; known: {SHARD_FORMATS}")
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._manifest_path = self.root / MANIFEST_NAME
        self._manifest_stat: tuple[int, int] | None = None
        if self._manifest_path.exists():
            self._manifest_stat = self._stat_manifest()
            try:
                manifest = json.loads(self._manifest_path.read_text())
                if not isinstance(manifest, dict) \
                        or not isinstance(manifest.get("shards"), list):
                    raise ValueError("manifest is not a shard mapping")
                self.manifest = manifest
            except (ValueError, OSError) as e:
                # poisoned/truncated manifest JSON: rebuild it from the
                # shard files on disk rather than failing the whole store
                obs.fallback("manifest", "rescan", type(e).__name__)
                self.manifest = self._recover_manifest()
        else:
            self.manifest = {"shards": []}
        persisted = self.manifest.get("shard_format")
        if shard_format is None:
            self.shard_format = persisted or "npz"
        else:
            if persisted is not None and persisted != shard_format:
                raise ValueError(
                    f"store at {self.root} persists shard_format "
                    f"{persisted!r}; cannot reopen as {shard_format!r}")
            self.shard_format = shard_format
        self.manifest["shard_format"] = self.shard_format

    def _recover_manifest(self) -> dict:
        """Rebuild a manifest by rescanning ``telemetry_*`` shard files on
        disk: readable shards are re-listed (rows and sha256 recomputed),
        unreadable ones are moved to the quarantine area. The recovered
        manifest is flushed immediately, marked ``{"recovered": true}``."""
        shards: list[dict] = []
        quarantine: list[dict] = []
        fmt = None
        for path in sorted(self.root.iterdir()):
            stem = path.name[:-4] if path.name.endswith(".npz") else path.name
            m = _SHARD_STEM_RE.match(stem)
            if m is None or path.name.endswith(".tmp"):
                continue
            entry = {"file": path.name, "host": m.group("host"),
                     "day": int(m.group("day")),
                     "format": "npy_dir" if path.is_dir() else "npz"}
            try:
                rows = len(self._read_shard_file(path))
            except ShardReadError as e:
                entry["reason"] = e.reason
                quarantine.append(entry)
                self._move_to_quarantine(path)
                continue
            entry["rows"] = rows
            entry["sha256"] = checksum_shard(path)
            fmt = fmt or entry["format"]
            shards.append(entry)
        manifest: dict = {"shards": shards, "recovered": True,
                          "generation": len(shards) + len(quarantine)}
        if quarantine:
            manifest["quarantine"] = quarantine
        if fmt is not None:
            manifest["shard_format"] = fmt
        _write_atomic_text(self._manifest_path,
                           json.dumps(manifest, indent=1))
        self._manifest_stat = self._stat_manifest()
        return manifest

    def _move_to_quarantine(self, path: pathlib.Path) -> None:
        qdir = self.root / QUARANTINE_DIR
        qdir.mkdir(exist_ok=True)
        try:
            shutil.move(str(path), str(qdir / path.name))
        except OSError:
            pass                        # drift: file vanished under us

    def _stat_manifest(self) -> tuple[int, int] | None:
        try:
            st = os.stat(self._manifest_path)
        except OSError:
            return None
        return (st.st_mtime_ns, st.st_size)

    @property
    def generation(self) -> int:
        """Monotonic shard-list mutation counter, persisted in the
        manifest: bumped on every append/rewrite/quarantine, *not* on
        derived-data merges (:meth:`merge_manifest_key`). Pollers (the live
        controller) compare generations instead of diffing shard lists —
        paired with :meth:`refresh`, new-shard detection is one ``stat``
        per tick on an unchanged store."""
        return int(self.manifest.get("generation", 0))

    def _bump_generation(self) -> None:
        self.manifest["generation"] = self.generation + 1

    def refresh(self) -> bool:
        """Cheap cross-process poll: re-read the manifest only when its
        file stat changed since this handle last loaded or saved it —
        O(1) (one ``stat``) on the no-change path. Returns True when the
        shard set actually changed (generation or shard count moved). A
        torn or unparsable on-disk manifest keeps the current snapshot and
        reports no change — the writer commits through
        :func:`atomic_replace`, so the next poll sees a whole file."""
        stat_now = self._stat_manifest()
        if stat_now is None or stat_now == self._manifest_stat:
            return False
        try:
            manifest = json.loads(self._manifest_path.read_text())
        except (OSError, ValueError):
            return False
        if not isinstance(manifest, dict) \
                or not isinstance(manifest.get("shards"), list):
            return False
        changed = (int(manifest.get("generation", 0)) != self.generation
                   or len(manifest["shards"]) != len(self.manifest["shards"]))
        self.manifest = manifest
        self.manifest.setdefault("shard_format", self.shard_format)
        self._manifest_stat = stat_now
        return changed

    def shards_since(self, watermark: int) -> list[dict]:
        """Manifest entries past a covered prefix of ``watermark`` shards —
        the live controller's pending set. ``manifest["shards"]`` is
        append-only (quarantine removes, but that breaks watermarks by
        design), so this is a slice, not a diff."""
        if watermark < 0:
            raise ValueError(f"watermark must be >= 0, got {watermark}")
        return self.manifest["shards"][watermark:]

    def save_manifest(self) -> None:
        """Persist the manifest atomically (temp file + rename): a process
        killed mid-save leaves the previous manifest intact, never a torn
        JSON (tests/test_robustness.py kill-mid-write suite)."""
        _write_atomic_text(self._manifest_path,
                           json.dumps(self.manifest, indent=1))
        self._manifest_stat = self._stat_manifest()

    def merge_manifest_key(self, key: str, subkey: str, value) -> None:
        """Atomically merge ``manifest[key][subkey] = value`` into the
        **on-disk** manifest: re-read it fresh, update the one entry, and
        temp-file + rename. For derived-data writers (run-IR sidecars) on a
        store another process may be appending to — a plain
        :meth:`save_manifest` would re-serialize this handle's possibly
        stale snapshot and silently drop shards appended since it opened.
        """
        try:
            current = json.loads(self._manifest_path.read_text())
        except (OSError, ValueError):
            current = self.manifest
        if not isinstance(current, dict) \
                or not isinstance(current.get("shards"), list):
            current = self.manifest      # poisoned on-disk copy: ours wins
        if not isinstance(current.get(key), dict):
            current[key] = {}            # tolerate a poisoned subtree
        current[key][subkey] = value
        _write_atomic_text(self._manifest_path, json.dumps(current, indent=1))
        if not isinstance(self.manifest.get(key), dict):
            self.manifest[key] = {}
        self.manifest[key][subkey] = value

    def write_shard(self, frame: TelemetryFrame, host: str = "host0",
                    day: int = 0, flush_manifest: bool = True) -> pathlib.Path:
        """Append one shard (format = the store's ``shard_format``). Bulk
        writers (e.g. the cluster simulator's chunked emission) pass
        ``flush_manifest=False`` and call :meth:`save_manifest` once at the
        end — rewriting the growing JSON manifest per shard is O(shards^2)."""
        stem = f"telemetry_{host}_d{day:03d}_{len(self.manifest['shards']):05d}"
        path = self._write_shard_file(stem, frame)
        self.manifest["shards"].append(
            {"file": path.name, "host": host, "day": day, "rows": len(frame),
             "format": self.shard_format, "sha256": checksum_shard(path)})
        self._bump_generation()
        if flush_manifest:
            self.save_manifest()
        return path

    def _write_shard_file(self, stem: str,
                          frame: TelemetryFrame) -> pathlib.Path:
        if self.shard_format == "npy_dir":
            path = self.root / stem
            # overwrite semantics matching the npz branch: a leftover shard
            # dir (e.g. from a crashed bulk write that never flushed its
            # manifest) is replaced, stale columns included. Directory
            # shards cannot be renamed into place atomically; a crash here
            # leaves a dir the manifest never references, which the orphan
            # scan (verify_manifest) surfaces.
            path.mkdir(exist_ok=True)
            for stale in path.glob("*.npy"):
                stale.unlink()
            for f, col in frame.columns.items():
                np.save(path / f"{f}.npy", col)
            return path
        path = self.root / f"{stem}.npz"
        _write_atomic_npz(path, frame.columns)
        return path

    def rewrite_shard(self, name: str, frame: TelemetryFrame) -> pathlib.Path:
        """Replace an existing shard's contents in place (the hygiene
        layer's repair writer): same file name, manifest entry updated with
        the new row count and checksum."""
        entry = self._shard_entry(name)
        if entry is None:
            raise KeyError(f"shard {name!r} is not in the manifest")
        stem = name[:-4] if name.endswith(".npz") else name
        path = self._write_shard_file(stem, frame)
        entry["rows"] = len(frame)
        entry["sha256"] = checksum_shard(path)
        self._bump_generation()
        return path

    def _shard_entry(self, name: str) -> dict | None:
        for s in self.manifest["shards"]:
            if s["file"] == name:
                return s
        return None

    def append(self, frame: TelemetryFrame, host: str = "host0",
               flush_manifest: bool = True) -> pathlib.Path | None:
        """Append a frame as one shard, deriving the day label from its first
        timestamp — the drain target for live producers
        (:meth:`repro.telemetry.sampler.RuntimeSampler.drain_to`, the DES's
        periodic spill): each drain appends in time order, which is exactly
        the per-stream ordering the streaming readers require. Empty frames
        are dropped (a no-op drain must not create empty shards)."""
        if len(frame) == 0:
            return None
        day = int(frame["timestamp"][0]) // 86400
        return self.write_shard(frame, host=host, day=day,
                                flush_manifest=flush_manifest)

    def read_shard(self, name: str, mmap: bool = False,
                   verify: bool = False) -> TelemetryFrame:
        """Read one shard by manifest name.

        ``mmap=True`` memory-maps ``npy_dir`` columns (zero-copy until a
        column is actually gathered); ``npz`` shards are deflate-compressed,
        which cannot be mapped, so they fall back to a normal load.

        A missing or unreadable shard raises :class:`ShardReadError` with a
        machine-readable ``reason`` (never a raw ``FileNotFoundError`` /
        ``BadZipFile``). ``verify=True`` additionally recomputes the shard's
        sha256 against the one recorded at write time (shards written before
        checksums existed just skip the check) — the only way a bit-flip in
        an *uncompressed* ``npy_dir`` column is detectable, since raw
        ``np.load`` has no payload CRC.
        """
        path = self.root / name
        try:
            if path.is_dir():
                if verify:
                    self._verify_checksum(name, path)
                mode = "r" if mmap else None
                return TelemetryFrame({
                    f: np.load(path / f"{f}.npy", mmap_mode=mode)
                    for f in FIELDS if (path / f"{f}.npy").exists()})
            if not path.exists():
                raise ShardReadError(name, "missing_file",
                                     "manifest entry with no file on disk")
            if verify:
                self._verify_checksum(name, path)
            with np.load(path) as z:
                return TelemetryFrame({f: z[f] for f in FIELDS if f in z})
        except ShardReadError:
            raise
        except _CORRUPT_ERRORS as e:
            raise ShardReadError(
                name, "corrupt", f"{type(e).__name__}: {e}") from e

    def _verify_checksum(self, name: str, path: pathlib.Path) -> None:
        entry = self._shard_entry(name)
        recorded = entry.get("sha256") if entry else None
        if recorded and checksum_shard(path) != recorded:
            raise ShardReadError(name, "checksum_mismatch",
                                 "bytes on disk differ from write-time sha256")

    def read_shard_or_skip(self, name: str, skips: list,
                           mmap: bool = False, strict: bool = True,
                           verify: bool = False) -> TelemetryFrame | None:
        """:meth:`read_shard`, but with ``strict=False`` a bad shard returns
        ``None`` and appends a skip record ``{"file", "host", "rows",
        "reason"}`` to ``skips`` (rows from the manifest — the coverage
        denominator the pipelines account against). The shared read step of
        every fault-tolerant worker body."""
        try:
            return self.read_shard(name, mmap=mmap, verify=verify)
        except ShardReadError as e:
            if strict:
                raise
            entry = self._shard_entry(name) or {}
            skips.append({"file": name, "host": entry.get("host", ""),
                          "rows": int(entry.get("rows", 0)),
                          "reason": e.reason})
            obs.counter("repro_shards_quarantined_total", reason=e.reason,
                        help="telemetry shards skipped or quarantined, "
                             "by reason")
            return None

    def iter_shards(self, hosts: Iterable[str] | None = None,
                    mmap: bool = False, strict: bool = True,
                    verify: bool = False,
                    skips: list | None = None) -> Iterator[TelemetryFrame]:
        """Yield shard frames one at a time, in manifest (append) order.

        The streaming analysis path (``telemetry.pipeline.analyze_store``)
        and the what-if sweep consume this so that at most one shard is
        materialized; writers append each stream's shards in time order,
        which is exactly the per-stream ordering :class:`FleetAccumulator`
        requires. With ``mmap=True``, ``npy_dir`` shards arrive as
        ``np.memmap``-backed columns — cold columns are never read off disk
        (note ``TelemetryFrame.group_streams`` gathers every column it
        sorts, so the win is for passes that slice or subset columns).

        ``strict=False`` skips missing/corrupt shards instead of raising,
        appending one record per skip to ``skips`` (when given) so callers
        can account coverage; ``verify=True`` checks recorded sha256s.
        """
        hosts = set(hosts) if hosts is not None else None
        sink = skips if skips is not None else []
        for s in self.manifest["shards"]:
            if hosts is None or s["host"] in hosts:
                frame = self.read_shard_or_skip(
                    s["file"], sink, mmap=mmap, strict=strict, verify=verify)
                if frame is not None:
                    yield frame

    def quarantine_shard(self, name: str, reason: str,
                         flush_manifest: bool = True) -> None:
        """Move a shard out of the readable set: file relocated to
        ``quarantine/``, manifest entry moved from ``shards`` to the
        ``quarantine`` list (with the reason), so analysis never sees it
        again but a human can inspect or restore it."""
        entry = self._shard_entry(name)
        if entry is not None:
            self.manifest["shards"].remove(entry)
        record = dict(entry or {"file": name})
        record["reason"] = reason
        self.manifest.setdefault("quarantine", []).append(record)
        self._bump_generation()
        self._move_to_quarantine(self.root / name)
        obs.counter("repro_shards_quarantined_total", reason=reason,
                    help="telemetry shards skipped or quarantined, by reason")
        if flush_manifest:
            self.save_manifest()

    def verify_manifest(self) -> list[dict]:
        """Detect manifest<->disk drift without reading shard payloads:
        returns one record per problem — ``{"file", "reason":
        "missing_file"}`` for a manifest entry whose file vanished,
        ``{"file", "reason": "orphan_file"}`` for a ``telemetry_*`` file
        with no manifest entry (e.g. a crashed bulk write). Clean store ==
        empty list."""
        drift: list[dict] = []
        known = {s["file"] for s in self.manifest["shards"]}
        for s in self.manifest["shards"]:
            path = self.root / s["file"]
            if not (path.exists() or path.is_dir()):
                drift.append({"file": s["file"], "host": s.get("host", ""),
                              "rows": int(s.get("rows", 0)),
                              "reason": "missing_file"})
        for path in sorted(self.root.iterdir()):
            stem = path.name[:-4] if path.name.endswith(".npz") else path.name
            if (_SHARD_STEM_RE.match(stem) and not path.name.endswith(".tmp")
                    and path.name not in known):
                drift.append({"file": path.name, "reason": "orphan_file"})
        return drift

    def _read_shard_file(self, path: pathlib.Path) -> TelemetryFrame:
        """Read a shard by path only (no manifest entry required) — the
        manifest-recovery scan's reader."""
        return self.read_shard(path.name)

    def read_all(self, hosts: Iterable[str] | None = None) -> TelemetryFrame:
        return TelemetryFrame.concat(list(self.iter_shards(hosts)))

    def partition_hosts(self, workers: int,
                        hosts: Iterable[str] | None = None) -> list[list[str]]:
        """Split host labels into at most ``workers`` row-balanced partitions
        (greedy, heaviest host first — deterministic).

        Host labels are the parallelism unit for process-pool analysis:
        every (job, host, device) stream lives entirely under one host
        label, so partitions hold disjoint streams and per-stream carry
        state never crosses workers.
        """
        host_filter = set(hosts) if hosts is not None else None
        rows_per_host: dict[str, int] = {}
        for s in self.manifest["shards"]:
            if host_filter is None or s["host"] in host_filter:
                rows_per_host[s["host"]] = (
                    rows_per_host.get(s["host"], 0) + s["rows"])
        ordered = sorted(rows_per_host, key=lambda h: (-rows_per_host[h], h))
        n_parts = max(1, min(workers, len(ordered)))
        parts: list[list[str]] = [[] for _ in range(n_parts)]
        loads = [0] * n_parts
        for h in ordered:
            i = loads.index(min(loads))
            parts[i].append(h)
            loads[i] += rows_per_host[h]
        return parts

    def shard_files(self, hosts: Iterable[str] | None = None) -> list[str]:
        """Manifest-ordered shard file names, optionally host-filtered."""
        host_filter = set(hosts) if hosts is not None else None
        return [s["file"] for s in self.manifest["shards"]
                if host_filter is None or s["host"] in host_filter]

    @property
    def total_rows(self) -> int:
        return sum(s["rows"] for s in self.manifest["shards"])

    def rows_on_disk(self, hosts: Iterable[str] | None = None) -> int:
        """Manifest row total, optionally host-filtered — the denominator of
        every coverage fraction (rows analyzed / rows on disk)."""
        host_filter = set(hosts) if hosts is not None else None
        return sum(s["rows"] for s in self.manifest["shards"]
                   if host_filter is None or s["host"] in host_filter)

"""Compressed telemetry log storage (paper §2.1: 20–100 MB/server/day).

Columnar `.npz` (zip-deflate) with a JSON sidecar manifest. Append-oriented:
one shard per (host, day); a reader concatenates shards.
"""
from __future__ import annotations

import json
import pathlib
from typing import Iterable

import numpy as np

from repro.telemetry.records import FIELDS, TelemetryFrame

MANIFEST_NAME = "manifest.json"


class TelemetryStore:
    def __init__(self, root: str | pathlib.Path):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._manifest_path = self.root / MANIFEST_NAME
        if self._manifest_path.exists():
            self.manifest = json.loads(self._manifest_path.read_text())
        else:
            self.manifest = {"shards": []}

    def _save_manifest(self) -> None:
        self._manifest_path.write_text(json.dumps(self.manifest, indent=1))

    def write_shard(self, frame: TelemetryFrame, host: str = "host0",
                    day: int = 0) -> pathlib.Path:
        name = f"telemetry_{host}_d{day:03d}_{len(self.manifest['shards']):05d}.npz"
        path = self.root / name
        np.savez_compressed(path, **frame.columns)
        self.manifest["shards"].append(
            {"file": name, "host": host, "day": day, "rows": len(frame)})
        self._save_manifest()
        return path

    def read_shard(self, name: str) -> TelemetryFrame:
        with np.load(self.root / name) as z:
            return TelemetryFrame({f: z[f] for f in FIELDS if f in z})

    def read_all(self, hosts: Iterable[str] | None = None) -> TelemetryFrame:
        hosts = set(hosts) if hosts is not None else None
        frames = [
            self.read_shard(s["file"])
            for s in self.manifest["shards"]
            if hosts is None or s["host"] in hosts
        ]
        return TelemetryFrame.concat(frames)

    @property
    def total_rows(self) -> int:
        return sum(s["rows"] for s in self.manifest["shards"])

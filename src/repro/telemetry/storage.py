"""Compressed telemetry log storage (paper §2.1: 20–100 MB/server/day).

Columnar `.npz` (zip-deflate) with a JSON sidecar manifest. Append-oriented:
writers append shards labelled (host, day) — possibly several per label,
e.g. one per device or per flush — and a reader concatenates (or streams)
shards in manifest order.
"""
from __future__ import annotations

import json
import pathlib
from typing import Iterable, Iterator

import numpy as np

from repro.telemetry.records import FIELDS, TelemetryFrame

MANIFEST_NAME = "manifest.json"


class TelemetryStore:
    def __init__(self, root: str | pathlib.Path):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._manifest_path = self.root / MANIFEST_NAME
        if self._manifest_path.exists():
            self.manifest = json.loads(self._manifest_path.read_text())
        else:
            self.manifest = {"shards": []}

    def save_manifest(self) -> None:
        self._manifest_path.write_text(json.dumps(self.manifest, indent=1))

    def write_shard(self, frame: TelemetryFrame, host: str = "host0",
                    day: int = 0, flush_manifest: bool = True) -> pathlib.Path:
        """Append one shard. Bulk writers (e.g. the cluster simulator's
        chunked emission) pass ``flush_manifest=False`` and call
        :meth:`save_manifest` once at the end — rewriting the growing JSON
        manifest per shard is O(shards^2)."""
        name = f"telemetry_{host}_d{day:03d}_{len(self.manifest['shards']):05d}.npz"
        path = self.root / name
        np.savez_compressed(path, **frame.columns)
        self.manifest["shards"].append(
            {"file": name, "host": host, "day": day, "rows": len(frame)})
        if flush_manifest:
            self.save_manifest()
        return path

    def read_shard(self, name: str) -> TelemetryFrame:
        with np.load(self.root / name) as z:
            return TelemetryFrame({f: z[f] for f in FIELDS if f in z})

    def iter_shards(self, hosts: Iterable[str] | None = None
                    ) -> Iterator[TelemetryFrame]:
        """Yield shard frames one at a time, in manifest (append) order.

        The streaming analysis path (``telemetry.pipeline.analyze_store``)
        consumes this so that at most one shard is materialized; writers
        append each stream's shards in time order, which is exactly the
        per-stream ordering :class:`FleetAccumulator` requires.
        """
        hosts = set(hosts) if hosts is not None else None
        for s in self.manifest["shards"]:
            if hosts is None or s["host"] in hosts:
                yield self.read_shard(s["file"])

    def read_all(self, hosts: Iterable[str] | None = None) -> TelemetryFrame:
        return TelemetryFrame.concat(list(self.iter_shards(hosts)))

    @property
    def total_rows(self) -> int:
        return sum(s["rows"] for s in self.manifest["shards"])

"""Compressed telemetry log storage (paper §2.1: 20–100 MB/server/day).

Two shard formats behind one manifest:

* ``npz`` (default) — columnar zip-deflate ``.npz``, smallest on disk;
* ``npy_dir`` — one raw ``.npy`` per column in a shard directory, readable
  with ``np.load(mmap_mode="r")`` so ``iter_shards(mmap=True)`` is
  zero-copy: columns a pass never touches (e.g. host counters during a
  what-if sweep) are never faulted into memory.

Append-oriented: writers append shards labelled (host, day) — possibly
several per label, e.g. one per device or per flush — and a reader
concatenates (or streams) shards in manifest order.

Run-IR sidecars
---------------
Next to the shards, the what-if engine may persist **run-level IR
sidecars** (``run_ir_<hash>.npz``, written by
:func:`repro.whatif.ir.save_sidecar`): the store's rows collapsed, per
(job, host, device) stream, into maximal runs of constant
``(device_state, low_activity)`` — run table (state/low/length/power_sum),
per-stream metadata (host label, platform, first timestamp, row/run
counts) and the raw power samples — so repeat sweeps skip stream grouping,
classification and run-length encoding entirely. Sidecars are keyed in the
manifest under ``manifest["run_ir"][<classifier-config hash>]`` with the
``source_rows`` they were built from: a different classifier config hashes
to a different sidecar, and appending shards invalidates (``source_rows``
no longer matches, so :func:`repro.whatif.ir.get_ir` rebuilds). Sidecars
are derived data — deleting the files and the manifest key is always safe.
"""
from __future__ import annotations

import json
import pathlib
from typing import Iterable, Iterator

import numpy as np

from repro.telemetry.records import FIELDS, TelemetryFrame

MANIFEST_NAME = "manifest.json"
SHARD_FORMATS = ("npz", "npy_dir")


class TelemetryStore:
    def __init__(self, root: str | pathlib.Path,
                 shard_format: str | None = None):
        """``shard_format=None`` adopts an existing store's persisted format
        (so reopening an ``npy_dir`` store for append keeps appending
        ``npy_dir`` shards), defaulting to ``npz`` for new stores; passing a
        format that contradicts the persisted one raises."""
        if shard_format is not None and shard_format not in SHARD_FORMATS:
            raise ValueError(
                f"unknown shard_format {shard_format!r}; known: {SHARD_FORMATS}")
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._manifest_path = self.root / MANIFEST_NAME
        if self._manifest_path.exists():
            self.manifest = json.loads(self._manifest_path.read_text())
        else:
            self.manifest = {"shards": []}
        persisted = self.manifest.get("shard_format")
        if shard_format is None:
            self.shard_format = persisted or "npz"
        else:
            if persisted is not None and persisted != shard_format:
                raise ValueError(
                    f"store at {self.root} persists shard_format "
                    f"{persisted!r}; cannot reopen as {shard_format!r}")
            self.shard_format = shard_format
        self.manifest["shard_format"] = self.shard_format

    def save_manifest(self) -> None:
        self._manifest_path.write_text(json.dumps(self.manifest, indent=1))

    def merge_manifest_key(self, key: str, subkey: str, value) -> None:
        """Atomically merge ``manifest[key][subkey] = value`` into the
        **on-disk** manifest: re-read it fresh, update the one entry, and
        temp-file + rename. For derived-data writers (run-IR sidecars) on a
        store another process may be appending to — a plain
        :meth:`save_manifest` would re-serialize this handle's possibly
        stale snapshot and silently drop shards appended since it opened.
        """
        try:
            current = json.loads(self._manifest_path.read_text())
        except (OSError, ValueError):
            current = self.manifest
        current.setdefault(key, {})[subkey] = value
        tmp = self._manifest_path.with_name(MANIFEST_NAME + ".tmp")
        tmp.write_text(json.dumps(current, indent=1))
        tmp.replace(self._manifest_path)
        self.manifest.setdefault(key, {})[subkey] = value

    def write_shard(self, frame: TelemetryFrame, host: str = "host0",
                    day: int = 0, flush_manifest: bool = True) -> pathlib.Path:
        """Append one shard (format = the store's ``shard_format``). Bulk
        writers (e.g. the cluster simulator's chunked emission) pass
        ``flush_manifest=False`` and call :meth:`save_manifest` once at the
        end — rewriting the growing JSON manifest per shard is O(shards^2)."""
        stem = f"telemetry_{host}_d{day:03d}_{len(self.manifest['shards']):05d}"
        if self.shard_format == "npy_dir":
            path = self.root / stem
            # overwrite semantics matching the npz branch: a leftover shard
            # dir (e.g. from a crashed bulk write that never flushed its
            # manifest) is replaced, stale columns included
            path.mkdir(exist_ok=True)
            for stale in path.glob("*.npy"):
                stale.unlink()
            for f, col in frame.columns.items():
                np.save(path / f"{f}.npy", col)
            name = stem
        else:
            name = f"{stem}.npz"
            path = self.root / name
            np.savez_compressed(path, **frame.columns)
        self.manifest["shards"].append(
            {"file": name, "host": host, "day": day, "rows": len(frame),
             "format": self.shard_format})
        if flush_manifest:
            self.save_manifest()
        return path

    def append(self, frame: TelemetryFrame, host: str = "host0",
               flush_manifest: bool = True) -> pathlib.Path | None:
        """Append a frame as one shard, deriving the day label from its first
        timestamp — the drain target for live producers
        (:meth:`repro.telemetry.sampler.RuntimeSampler.drain_to`, the DES's
        periodic spill): each drain appends in time order, which is exactly
        the per-stream ordering the streaming readers require. Empty frames
        are dropped (a no-op drain must not create empty shards)."""
        if len(frame) == 0:
            return None
        day = int(frame["timestamp"][0]) // 86400
        return self.write_shard(frame, host=host, day=day,
                                flush_manifest=flush_manifest)

    def read_shard(self, name: str, mmap: bool = False) -> TelemetryFrame:
        """Read one shard by manifest name.

        ``mmap=True`` memory-maps ``npy_dir`` columns (zero-copy until a
        column is actually gathered); ``npz`` shards are deflate-compressed,
        which cannot be mapped, so they fall back to a normal load.
        """
        path = self.root / name
        if path.is_dir():
            mode = "r" if mmap else None
            return TelemetryFrame({
                f: np.load(path / f"{f}.npy", mmap_mode=mode)
                for f in FIELDS if (path / f"{f}.npy").exists()})
        with np.load(path) as z:
            return TelemetryFrame({f: z[f] for f in FIELDS if f in z})

    def iter_shards(self, hosts: Iterable[str] | None = None,
                    mmap: bool = False) -> Iterator[TelemetryFrame]:
        """Yield shard frames one at a time, in manifest (append) order.

        The streaming analysis path (``telemetry.pipeline.analyze_store``)
        and the what-if sweep consume this so that at most one shard is
        materialized; writers append each stream's shards in time order,
        which is exactly the per-stream ordering :class:`FleetAccumulator`
        requires. With ``mmap=True``, ``npy_dir`` shards arrive as
        ``np.memmap``-backed columns — cold columns are never read off disk
        (note ``TelemetryFrame.group_streams`` gathers every column it
        sorts, so the win is for passes that slice or subset columns).
        """
        hosts = set(hosts) if hosts is not None else None
        for s in self.manifest["shards"]:
            if hosts is None or s["host"] in hosts:
                yield self.read_shard(s["file"], mmap=mmap)

    def read_all(self, hosts: Iterable[str] | None = None) -> TelemetryFrame:
        return TelemetryFrame.concat(list(self.iter_shards(hosts)))

    def partition_hosts(self, workers: int,
                        hosts: Iterable[str] | None = None) -> list[list[str]]:
        """Split host labels into at most ``workers`` row-balanced partitions
        (greedy, heaviest host first — deterministic).

        Host labels are the parallelism unit for process-pool analysis:
        every (job, host, device) stream lives entirely under one host
        label, so partitions hold disjoint streams and per-stream carry
        state never crosses workers.
        """
        host_filter = set(hosts) if hosts is not None else None
        rows_per_host: dict[str, int] = {}
        for s in self.manifest["shards"]:
            if host_filter is None or s["host"] in host_filter:
                rows_per_host[s["host"]] = (
                    rows_per_host.get(s["host"], 0) + s["rows"])
        ordered = sorted(rows_per_host, key=lambda h: (-rows_per_host[h], h))
        n_parts = max(1, min(workers, len(ordered)))
        parts: list[list[str]] = [[] for _ in range(n_parts)]
        loads = [0] * n_parts
        for h in ordered:
            i = loads.index(min(loads))
            parts[i].append(h)
            loads[i] += rows_per_host[h]
        return parts

    def shard_files(self, hosts: Iterable[str] | None = None) -> list[str]:
        """Manifest-ordered shard file names, optionally host-filtered."""
        host_filter = set(hosts) if hosts is not None else None
        return [s["file"] for s in self.manifest["shards"]
                if host_filter is None or s["host"] in host_filter]

    @property
    def total_rows(self) -> int:
        return sum(s["rows"] for s in self.manifest["shards"])

"""Telemetry hygiene: validate, repair or quarantine shards at ingest.

Real fleet telemetry is dirty in boring, recurring ways: collectors emit
NaN timestamps during clock steps, power rails read negative or physically
impossible during PSU glitches, 1 Hz samplers drop samples and then replay
duplicates after reconnecting, and whole shards arrive truncated. This
module is the *policy* layer over the storage primitives
(:mod:`repro.telemetry.storage`): an explicit :class:`HygieneContract`
every shard is validated against, a per-shard :class:`ShardVerdict`
(``ok`` / ``repaired`` / ``quarantined``, with machine-readable reasons),
and deterministic repairs — identical input bytes always produce identical
verdicts and identical repaired shards.

Repairs are *subtractive only*: rows are dropped (non-finite timestamps,
out-of-range power) or deduplicated (same stream, same timestamp —
keep-first), never interpolated or invented; a shard needing more than
``max_repair_fraction`` of its rows dropped is quarantined instead. Gaps
wider than ``max_gap_s`` are *reported* (``gap_segments:<n>``) but the rows
are kept: the downstream pipelines already treat a gapped stream as
irregularly sampled (row-path replay, no run-IR), which is the correct
semantics for a hole — fabricating fill samples is not.

Entry points: :func:`check_frame` (pure), :func:`scrub_store` (whole-store
sweep using :meth:`TelemetryStore.rewrite_shard` /
:meth:`TelemetryStore.quarantine_shard`), :func:`ingest_frame` (validate
*before* a frame ever becomes a shard) and the tolerant DCGM-layout
adapter :func:`dcgm_to_frame` / :func:`ingest_dcgm` for 1 Hz
``DCGM_FI_*`` column dumps with ragged/missing samples.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Mapping, Sequence

import numpy as np

import repro.obs as obs
from repro.telemetry.records import TelemetryFrame, _DTYPES
from repro.telemetry.storage import ShardReadError

if TYPE_CHECKING:
    from repro.telemetry.storage import TelemetryStore


@dataclasses.dataclass(frozen=True)
class HygieneContract:
    """What a telemetry shard must look like to be analyzed as-is.

    ``required_fields`` must carry real data (an all-NaN float column means
    the signal was never recorded — identity, power and residency cannot be
    defaulted the way optional activity counters can). ``max_power_w``
    bounds plausible board power (no single accelerator package draws 2 kW;
    readings above it are sensor glitches, not samples). ``max_gap_s`` is
    the widest sampling hole that is still reported as a gap rather than
    silently accepted. ``max_repair_fraction`` caps how much of a shard the
    repairs may drop before the shard is quarantined wholesale — a shard
    that is mostly garbage is evidence of a broken producer, not noise.
    """

    required_fields: tuple[str, ...] = (
        "timestamp", "hostname", "device_id", "platform", "power",
        "job_id", "program_resident")
    max_power_w: float = 2000.0
    max_gap_s: float = 300.0
    dt_s: float = 1.0
    max_repair_fraction: float = 0.5


DEFAULT_CONTRACT = HygieneContract()


@dataclasses.dataclass(frozen=True)
class ShardVerdict:
    """One shard's hygiene outcome.

    ``status`` is ``"ok"`` (analyzed as-is), ``"repaired"`` (rows dropped /
    deduplicated; ``repairs`` counts each kind) or ``"quarantined"``
    (unusable; ``reasons`` says why). ``rows_in``/``rows_out`` are the
    before/after row counts — their difference is exactly what the coverage
    accounting loses."""

    shard: str
    status: str
    reasons: tuple[str, ...] = ()
    rows_in: int = 0
    rows_out: int = 0
    repairs: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return self.status != "quarantined"


def check_columns(columns: Mapping[str, Sequence],
                  contract: HygieneContract = DEFAULT_CONTRACT,
                  shard: str = "") -> ShardVerdict:
    """Validate a raw column mapping *before* it becomes a
    :class:`TelemetryFrame`: required columns present, lengths consistent,
    values numeric. Returns a verdict only — construction-level failures
    (ragged, non-numeric) cannot be repaired row-wise."""
    reasons = []
    lengths = set()
    for f in contract.required_fields:
        if f not in columns:
            reasons.append(f"missing_required:{f}")
    for f, col in columns.items():
        arr = np.asarray(col)
        lengths.add(arr.shape[0] if arr.ndim else 0)
        if arr.dtype.kind not in "fiub":
            reasons.append(f"bad_dtype:{f}")
    if len(lengths) > 1:
        reasons.append("ragged_columns")
    n = max(lengths) if lengths else 0
    if reasons:
        return ShardVerdict(shard, "quarantined", tuple(reasons), n, 0)
    return ShardVerdict(shard, "ok", (), n, n)


def check_frame(frame: TelemetryFrame,
                contract: HygieneContract = DEFAULT_CONTRACT,
                shard: str = "") -> tuple[TelemetryFrame | None, ShardVerdict]:
    """Validate one frame against the contract; return ``(repaired_frame,
    verdict)``.

    Deterministic, subtractive repairs in a fixed order: (1) drop rows with
    non-finite timestamps; (2) drop rows whose power is non-finite,
    negative or above ``max_power_w``; (3) deduplicate rows sharing a
    (job, hostname, device, timestamp) key — keep the first occurrence, in
    input order. Gaps wider than ``max_gap_s`` within a stream are counted
    into the reasons but their rows are kept (see the module docstring).
    A clean frame is returned **unchanged** (same object), so the zero-
    fault path is bit-identical to not running hygiene at all; a frame
    needing more than ``max_repair_fraction`` of its rows dropped comes
    back as ``(None, quarantined-verdict)``.
    """
    rows_in = len(frame)
    if rows_in == 0:
        return frame, ShardVerdict(shard, "ok", (), 0, 0)
    reasons: list[str] = []
    repairs: dict[str, int] = {}

    # a required float signal that is all-NaN was never recorded at all
    # (TelemetryFrame fills absent columns with NaN) — not repairable
    for f in contract.required_fields:
        col = frame[f]
        if col.dtype.kind == "f" and not np.isfinite(
                np.asarray(col, dtype=np.float64)).any():
            reasons.append(f"missing_required:{f}")
    if reasons:
        return None, ShardVerdict(shard, "quarantined", tuple(reasons),
                                  rows_in, 0)

    ts = np.asarray(frame["timestamp"], dtype=np.float64)
    keep = np.isfinite(ts)
    n_bad_ts = int(rows_in - keep.sum())
    if n_bad_ts:
        repairs["nonfinite_timestamp"] = n_bad_ts

    power = np.asarray(frame["power"], dtype=np.float64)
    bad_power = (~np.isfinite(power)) | (power < 0.0) \
        | (power > contract.max_power_w)
    n_bad_p = int((bad_power & keep).sum())
    if n_bad_p:
        repairs["bad_power"] = n_bad_p
    keep &= ~bad_power

    out = frame if bool(keep.all()) else frame.select(keep)

    # duplicate samples: same stream key and timestamp, keep-first. The
    # trailing arange key makes the sort stable in *input* order, so the
    # survivor is always the first-seen row.
    n = len(out)
    if n:
        j = out["job_id"]
        h = out["hostname"]
        d = out["device_id"]
        t = out["timestamp"]
        order = np.lexsort((np.arange(n), t, d, h, j))
        sj, sh, sd, st = j[order], h[order], d[order], t[order]
        dup = np.concatenate([[False],
                              (np.diff(st) == 0) & (np.diff(sd) == 0)
                              & (np.diff(sh) == 0) & (np.diff(sj) == 0)])
        if dup.any():
            repairs["duplicate_timestamp"] = int(dup.sum())
            survivors = np.sort(order[~dup])   # back to input order
            out = out.select(survivors)

    # gap accounting (report, never fill)
    gap_runs = 0
    for _, seg in out.group_streams():
        dts = np.diff(np.asarray(seg["timestamp"], dtype=np.float64))
        gap_runs += int(np.sum(dts > contract.max_gap_s))
    if gap_runs:
        reasons.append(f"gap_segments:{gap_runs}")

    rows_out = len(out)
    dropped = rows_in - rows_out
    if dropped / rows_in > contract.max_repair_fraction:
        reasons.append("excessive_repair")
        return None, ShardVerdict(shard, "quarantined", tuple(reasons),
                                  rows_in, rows_out, repairs)
    status = "repaired" if repairs else "ok"
    return out, ShardVerdict(shard, status, tuple(reasons),
                             rows_in, rows_out, repairs)


def scrub_store(store: "TelemetryStore",
                contract: HygieneContract = DEFAULT_CONTRACT,
                dry_run: bool = False,
                verify: bool = False) -> list[ShardVerdict]:
    """Sweep every shard of a store through the hygiene contract.

    Unreadable shards (:class:`ShardReadError`) and contract-quarantined
    shards are moved to the store's ``quarantine/`` area with a manifest
    record; repairable shards are rewritten in place
    (:meth:`TelemetryStore.rewrite_shard` — same name, new rows+checksum).
    ``dry_run=True`` computes the verdicts without touching anything;
    ``verify=True`` additionally checksums each read. The manifest is
    flushed once at the end, and one verdict per shard (in manifest order)
    is returned.
    """
    verdicts: list[ShardVerdict] = []
    changed = False
    for name in list(store.shard_files()):
        try:
            frame = store.read_shard(name, verify=verify)
        except ShardReadError as e:
            verdicts.append(ShardVerdict(name, "quarantined", (e.reason,)))
            if not dry_run:
                store.quarantine_shard(name, e.reason, flush_manifest=False)
                changed = True
            continue
        fixed, verdict = check_frame(frame, contract, shard=name)
        verdicts.append(verdict)
        if verdict.status == "quarantined":
            if not dry_run:
                store.quarantine_shard(name, verdict.reasons[0],
                                       flush_manifest=False)
                changed = True
        elif verdict.status == "repaired":
            for reason, count in verdict.repairs.items():
                obs.counter("repro_shards_repaired_total", reason=reason,
                            help="telemetry shards repaired by the hygiene "
                                 "layer, by reason")
            if not dry_run:
                store.rewrite_shard(name, fixed)
                changed = True
    if changed:
        store.save_manifest()
    return verdicts


def ingest_frame(store: "TelemetryStore", frame: TelemetryFrame,
                 contract: HygieneContract = DEFAULT_CONTRACT,
                 host: str = "host0") -> ShardVerdict:
    """Hygiene-gated append: validate/repair a frame *before* it ever
    becomes a shard. Quarantined frames are never written (the verdict says
    why); ok/repaired frames append through :meth:`TelemetryStore.append`
    (which derives the day label and records the checksum)."""
    fixed, verdict = check_frame(frame, contract, shard="<ingest>")
    if verdict.status == "repaired":
        for reason in verdict.repairs:
            obs.counter("repro_shards_repaired_total", reason=reason,
                        help="telemetry shards repaired by the hygiene "
                             "layer, by reason")
    if verdict.status == "quarantined":
        obs.counter("repro_shards_quarantined_total",
                    reason=verdict.reasons[0],
                    help="telemetry shards skipped or quarantined, "
                         "by reason")
        return verdict
    store.append(fixed, host=host)
    return verdict


# --------------------------------------------------------------------------- #
# Tolerant DCGM-layout adapter (1 Hz DCGM_FI_* column dumps)
# --------------------------------------------------------------------------- #
#: DCGM field id -> (schema field, scale). PROF ratios are 0–1 and scale to
#: the schema's percent convention; byte counters scale to GB/s.
DCGM_FIELD_MAP: dict[str, tuple[str, float]] = {
    "DCGM_FI_DEV_POWER_USAGE": ("power", 1.0),
    "DCGM_FI_PROF_SM_ACTIVE": ("sm", 100.0),
    "DCGM_FI_PROF_PIPE_TENSOR_ACTIVE": ("tensor", 100.0),
    "DCGM_FI_PROF_PIPE_FP16_ACTIVE": ("fp16", 100.0),
    "DCGM_FI_PROF_PIPE_FP32_ACTIVE": ("fp32", 100.0),
    "DCGM_FI_PROF_PIPE_FP64_ACTIVE": ("fp64", 100.0),
    "DCGM_FI_PROF_DRAM_ACTIVE": ("dram", 100.0),
    "DCGM_FI_DEV_SM_CLOCK": ("sm_clk", 1.0),
    "DCGM_FI_DEV_MEM_CLOCK": ("mem_clk", 1.0),
    "DCGM_FI_PROF_PCIE_TX_BYTES": ("pcie_tx", 1e-9),
    "DCGM_FI_PROF_PCIE_RX_BYTES": ("pcie_rx", 1e-9),
    "DCGM_FI_PROF_NVLINK_TX_BYTES": ("nvlink_tx", 1e-9),
    "DCGM_FI_PROF_NVLINK_RX_BYTES": ("nvlink_rx", 1e-9),
}


def dcgm_to_frame(columns: Mapping[str, Sequence],
                  timestamp: Sequence | None = None,
                  hostname: int = 0, device_id: int = 0, platform: int = 0,
                  job_id: int = 0, program_resident: int = 1,
                  dt_s: float = 1.0) -> TelemetryFrame:
    """Adapt a 1 Hz DCGM field-value dump (``{"DCGM_FI_*": samples}``) to a
    :class:`TelemetryFrame`, tolerantly:

    * unknown field ids are ignored (collectors ship whatever was enabled);
    * ragged columns — a collector that missed samples on one field — are
      padded with NaN to the longest column (the classifier already treats
      NaN as "signal unavailable", never as violated);
    * a missing ``timestamp`` is synthesized at ``dt_s`` spacing starting
      at 0 (DCGM dumps are fixed-rate by construction).

    Identity/attribution metadata (host, device, platform, job, residency)
    is not in the DCGM layout, so it arrives as scalar arguments and is
    broadcast. The result should go through :func:`ingest_frame` (or
    :func:`ingest_dcgm`, which does exactly that) so contract repairs —
    duplicate timestamps after a collector reconnect, glitched power — are
    applied before the frame becomes a shard.
    """
    mapped: dict[str, np.ndarray] = {}
    n = 0
    for fid, raw in columns.items():
        target = DCGM_FIELD_MAP.get(fid)
        if target is None:
            continue
        field, scale = target
        arr = np.asarray(raw, dtype=np.float64) * scale
        mapped[field] = arr
        n = max(n, arr.shape[0])
    if timestamp is not None:
        ts = np.asarray(timestamp, dtype=np.float64)
        n = max(n, ts.shape[0])
    else:
        ts = None
    for field, arr in mapped.items():
        if arr.shape[0] < n:            # missed samples: pad, don't invent
            mapped[field] = np.concatenate(
                [arr, np.full(n - arr.shape[0], np.nan)])
    if ts is None:
        ts = dt_s * np.arange(n, dtype=np.float64)
    elif ts.shape[0] < n:
        # extend a short timestamp column at the nominal rate: timestamps
        # are identity, not a measurement, so extrapolation is safe
        start = ts[-1] if ts.shape[0] else 0.0
        extra = start + dt_s * np.arange(1, n - ts.shape[0] + 1)
        ts = np.concatenate([ts, extra])
    mapped["timestamp"] = ts
    mapped["hostname"] = np.full(n, hostname, dtype=_DTYPES["hostname"])
    mapped["device_id"] = np.full(n, device_id, dtype=_DTYPES["device_id"])
    mapped["platform"] = np.full(n, platform, dtype=_DTYPES["platform"])
    mapped["job_id"] = np.full(n, job_id, dtype=_DTYPES["job_id"])
    mapped["program_resident"] = np.full(
        n, program_resident, dtype=_DTYPES["program_resident"])
    return TelemetryFrame(mapped)


def ingest_dcgm(store: "TelemetryStore", columns: Mapping[str, Sequence],
                contract: HygieneContract = DEFAULT_CONTRACT,
                host: str = "host0", **frame_kwargs) -> ShardVerdict:
    """:func:`dcgm_to_frame` + :func:`ingest_frame` in one call — the
    shortest path from a raw DCGM dump to a hygiene-clean shard."""
    frame = dcgm_to_frame(columns, **frame_kwargs)
    return ingest_frame(store, frame, contract, host=host)

"""Telemetry substrate: Table-1 records, runtime sampler, alignment, storage."""
from repro.telemetry.records import TelemetryFrame, FIELDS, SCHEMA  # noqa: F401
from repro.telemetry.sampler import RuntimeSampler  # noqa: F401
from repro.telemetry.pipeline import (  # noqa: F401
    analyze_job,
    analyze_fleet,
    analyze_store,
    classify_frame,
    per_job_fraction_cdf,
    tail_share,
    DEFAULT_FAULT_TOLERANCE,
    FaultTolerance,
    FleetAccumulator,
    JobAnalysis,
    FleetAnalysis,
)
from repro.telemetry.storage import (  # noqa: F401
    ShardReadError,
    TelemetryStore,
)
from repro.telemetry.hygiene import (  # noqa: F401
    HygieneContract,
    ShardVerdict,
    check_frame,
    dcgm_to_frame,
    ingest_dcgm,
    ingest_frame,
    scrub_store,
)

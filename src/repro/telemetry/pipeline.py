"""Alignment + job attribution + analysis entry points (paper §2.1–2.2).

Takes raw telemetry frames (from the cluster simulator, the serving DES, or
live RuntimeSamplers), attributes each sample to a job, classifies states,
and produces per-job / fleet-level :class:`EnergyBreakdown`s — the exact
computation behind the paper's headline 19.7% / 10.7% numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

from repro.core.energy import EnergyBreakdown, integrate, merge
from repro.core.intervals import Interval, extract_intervals
from repro.core.states import ClassifierConfig, DEFAULT_CLASSIFIER, DeviceState, classify_series
from repro.telemetry.records import TelemetryFrame


@dataclasses.dataclass(frozen=True)
class JobAnalysis:
    job_id: int
    duration_s: float
    states: np.ndarray
    breakdown: EnergyBreakdown
    intervals: list[Interval]

    @property
    def exec_idle_time_fraction(self) -> float:
        return self.breakdown.exec_idle_time_fraction

    @property
    def exec_idle_energy_fraction(self) -> float:
        return self.breakdown.exec_idle_energy_fraction


@dataclasses.dataclass(frozen=True)
class FleetAnalysis:
    jobs: list[JobAnalysis]
    fleet: EnergyBreakdown              # job-attributed samples only
    unattributed_energy_j: float        # samples with job_id < 0 (Fig 3a 7%)
    n_intervals: int

    @property
    def in_execution_time_fraction(self) -> float:
        return self.fleet.exec_idle_time_fraction

    @property
    def in_execution_energy_fraction(self) -> float:
        return self.fleet.exec_idle_energy_fraction


def classify_frame(frame: TelemetryFrame,
                   config: ClassifierConfig = DEFAULT_CLASSIFIER) -> np.ndarray:
    return classify_series(
        frame["program_resident"].astype(bool),
        frame.activity_pct(),
        frame.comm_gbs(),
        config,
    )


def analyze_job(frame: TelemetryFrame,
                job_id: int,
                min_duration_s: float = 5.0,
                config: ClassifierConfig = DEFAULT_CLASSIFIER) -> JobAnalysis:
    states = classify_frame(frame, config)
    breakdown = integrate(states, frame["power"], min_duration_s=min_duration_s)
    intervals = extract_intervals(states, DeviceState.EXECUTION_IDLE, min_duration_s)
    return JobAnalysis(job_id=job_id, duration_s=float(len(frame)),
                       states=states, breakdown=breakdown, intervals=intervals)


def analyze_fleet(
    frame: TelemetryFrame,
    min_job_duration_s: float = 2 * 3600.0,
    min_interval_s: float = 5.0,
    config: ClassifierConfig = DEFAULT_CLASSIFIER,
) -> FleetAnalysis:
    """Group samples by (job, device) stream and analyze each (paper §2.1).

    Jobs shorter than ``min_job_duration_s`` are excluded (the paper's ≥2 h
    long-job filter); samples with job_id < 0 count as unattributed.
    """
    job_ids = frame["job_id"]
    device_ids = frame["device_id"]
    hostnames = frame["hostname"]

    unattributed = float(np.sum(frame["power"][job_ids < 0]))

    jobs: list[JobAnalysis] = []
    keys = np.stack([job_ids, hostnames, device_ids], axis=1)
    attributed = keys[job_ids >= 0]
    if attributed.size:
        uniq = np.unique(attributed, axis=0)
        for jid, host, dev in uniq:
            mask = (job_ids == jid) & (hostnames == host) & (device_ids == dev)
            sub = frame.select(mask)
            order = np.argsort(sub["timestamp"], kind="stable")
            sub = sub.select(order)
            if len(sub) < min_job_duration_s:
                continue
            jobs.append(analyze_job(sub, int(jid), min_interval_s, config))

    fleet = merge([j.breakdown for j in jobs]) if jobs else merge([])
    n_intervals = sum(len(j.intervals) for j in jobs)
    return FleetAnalysis(jobs=jobs, fleet=fleet,
                         unattributed_energy_j=unattributed,
                         n_intervals=n_intervals)


def per_job_fraction_cdf(jobs: Iterable[JobAnalysis]) -> dict[str, np.ndarray]:
    """Per-job execution-idle time/energy fractions (Fig 7)."""
    t = np.array([j.exec_idle_time_fraction for j in jobs])
    e = np.array([j.exec_idle_energy_fraction for j in jobs])
    return {"time_fraction": np.sort(t), "energy_fraction": np.sort(e)}


def tail_share(fractions: np.ndarray, threshold: float) -> float:
    """Share of jobs whose fraction exceeds `threshold` (Fig 7 quotes)."""
    fractions = np.asarray(fractions)
    return float(np.mean(fractions > threshold)) if fractions.size else 0.0

"""Alignment + job attribution + analysis entry points (paper §2.1–2.2).

Takes raw telemetry frames (from the cluster simulator, the serving DES, or
live RuntimeSamplers), attributes each sample to a job, classifies states,
and produces per-job / fleet-level :class:`EnergyBreakdown`s — the exact
computation behind the paper's headline 19.7% / 10.7% numbers.

Two entry points share one accounting implementation:

* :func:`analyze_fleet` — monolithic: one in-memory frame, analyzed as a
  single chunk.
* :func:`analyze_store` / :class:`FleetAccumulator` — streaming: chunks of
  any size (e.g. one storage shard at a time) fed through ``update``; per-job
  run state is carried across chunk boundaries, so results are bit-identical
  to the monolithic path while peak memory stays bounded by one chunk.

:func:`analyze_store` additionally fronts both with the **run-level IR**
(:mod:`repro.whatif.ir`, the "One IR to rule the stack" substrate): by
default it acquires the store's :class:`~repro.whatif.ir.RunIR` via
``get_ir`` and reduces run tables instead of re-classifying rows —
O(runs) per pass after the one-off compaction, with per-state times,
durations, interval lists and counts **bit-identical** to the row engine
and energies within float summation order (<= 1e-9 relative; the row path
stays available as the bit-exactness oracle via ``compact=False`` and as
the automatic fallback for irregular or quarantined streams).
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import TYPE_CHECKING, Iterable

import numpy as np

import repro.obs as obs
from repro.core.energy import EnergyBreakdown, StreamingIntegrator, integrate, merge
from repro.core.intervals import Interval, extract_intervals
from repro.core.states import ClassifierConfig, DEFAULT_CLASSIFIER, DeviceState, classify_series
from repro.telemetry.records import TelemetryFrame

if TYPE_CHECKING:
    from repro.telemetry.storage import TelemetryStore


@dataclasses.dataclass(frozen=True)
class JobAnalysis:
    job_id: int
    duration_s: float
    states: np.ndarray | None      # None on the streaming path (out-of-core)
    breakdown: EnergyBreakdown
    intervals: list[Interval]
    platform: int = -1             # platform id of the stream's device

    @property
    def exec_idle_time_fraction(self) -> float:
        return self.breakdown.exec_idle_time_fraction

    @property
    def exec_idle_energy_fraction(self) -> float:
        return self.breakdown.exec_idle_energy_fraction


@dataclasses.dataclass(frozen=True)
class FleetAnalysis:
    jobs: list[JobAnalysis]
    fleet: EnergyBreakdown              # job-attributed samples only
    unattributed_energy_j: float        # samples with job_id < 0 (Fig 3a 7%)
    n_intervals: int
    coverage: float = 1.0               # rows analyzed / rows on disk
    skipped: tuple = ()                 # shard skip records (strict=False)
    #: per-platform fleet breakdowns (platform id -> merged breakdown over
    #: that platform's surviving jobs) — the §4 per-platform aggregates
    platforms: dict = dataclasses.field(default_factory=dict)

    @property
    def in_execution_time_fraction(self) -> float:
        return self.fleet.exec_idle_time_fraction

    @property
    def in_execution_energy_fraction(self) -> float:
        return self.fleet.exec_idle_energy_fraction


def classify_frame(frame: TelemetryFrame,
                   config: ClassifierConfig = DEFAULT_CLASSIFIER) -> np.ndarray:
    return classify_series(
        frame["program_resident"].astype(bool),
        frame.activity_pct(),
        frame.comm_gbs(),
        config,
    )


def analyze_job(frame: TelemetryFrame,
                job_id: int,
                min_duration_s: float = 5.0,
                config: ClassifierConfig = DEFAULT_CLASSIFIER) -> JobAnalysis:
    states = classify_frame(frame, config)
    breakdown = integrate(states, frame["power"], min_duration_s=min_duration_s)
    intervals = extract_intervals(states, DeviceState.EXECUTION_IDLE, min_duration_s)
    return JobAnalysis(job_id=job_id, duration_s=float(len(frame)),
                       states=states, breakdown=breakdown, intervals=intervals)


def _platform_breakdowns(jobs: list[JobAnalysis]) -> dict:
    """Per-platform merged breakdowns over the surviving jobs, merged in
    jobs-list order (sorted stream keys on every path, so row- and
    run-level analyses accumulate in the same sequence — bit-identical)."""
    by_platform: dict[int, list[EnergyBreakdown]] = {}
    for j in jobs:
        by_platform.setdefault(j.platform, []).append(j.breakdown)
    return {p: merge(by_platform[p]) for p in sorted(by_platform)}


@dataclasses.dataclass
class _GroupState:
    """Per-(job, host, device) partial state carried across chunks."""

    integrator: StreamingIntegrator
    n_rows: int = 0
    ts_first: float = math.inf
    ts_last: float = -math.inf
    state_pieces: list[np.ndarray] | None = None
    platform: int = -1


class FleetAccumulator:
    """Out-of-core fleet analysis: feed chunks, finalize once.

    Chunks may hold any mix of jobs/hosts/devices and any number of rows;
    the only requirement is that, per (job, host, device) stream, chunks
    arrive in time order (each chunk is internally time-sorted by
    ``TelemetryFrame.group_streams``). Per-job partial state is O(1) per
    group plus the pending power samples of each group's unfinished trailing
    run, so peak memory is bounded by one chunk — never the whole dataset.

    ``finalize`` yields the exact :class:`FleetAnalysis` the monolithic
    :func:`analyze_fleet` computes on the concatenated data (see
    :class:`repro.core.energy.StreamingIntegrator` for why this is
    bit-identical); only ``unattributed_energy_j`` may differ in the last
    ulp, since its partial sums follow the chunk partition.
    """

    def __init__(
        self,
        min_job_duration_s: float = 2 * 3600.0,
        min_interval_s: float = 5.0,
        config: ClassifierConfig = DEFAULT_CLASSIFIER,
        dt_s: float = 1.0,
        keep_states: bool = False,
    ):
        self.min_job_duration_s = min_job_duration_s
        self.min_interval_s = min_interval_s
        self.config = config
        self.dt_s = dt_s
        self.keep_states = keep_states
        self._groups: dict[tuple[int, int, int], _GroupState] = {}
        self._unattributed_pieces: list[float] = []
        self.n_rows = 0
        self.n_chunks = 0

    def update(self, chunk: TelemetryFrame) -> None:
        """Fold one chunk of telemetry into the running analysis."""
        if len(chunk) == 0:
            return
        self.n_chunks += 1
        self.n_rows += len(chunk)
        obs.counter("repro_analyze_rows_total", float(len(chunk)),
                    help="telemetry rows folded into fleet analysis")
        obs.counter("repro_analyze_chunks_total",
                    help="telemetry chunks (shards) folded into fleet analysis")

        job_ids = chunk["job_id"]
        neg = job_ids < 0
        if np.any(neg):
            self._unattributed_pieces.append(float(np.sum(chunk["power"][neg])))

        for key, seg in chunk.group_streams():
            if key[0] < 0:
                continue
            g = self._groups.get(key)
            if g is None:
                g = self._groups[key] = _GroupState(
                    integrator=StreamingIntegrator(
                        min_duration_s=self.min_interval_s, dt_s=self.dt_s),
                    state_pieces=[] if self.keep_states else None,
                    platform=int(seg["platform"][0]),
                )
            ts = seg["timestamp"]
            # `<` (not `<=`): the monolithic path's stable sort accepts
            # duplicate timestamps, and the any-chunking equivalence contract
            # must hold wherever the boundary falls — so an exactly re-fed
            # abutting shard is NOT detectable here; genuine reordering is
            if float(ts[0]) < g.ts_last:
                raise ValueError(
                    f"chunks for stream {key} are not time-ordered: got "
                    f"t={float(ts[0])} after t={g.ts_last}")
            g.ts_first = min(g.ts_first, float(ts[0]))
            g.ts_last = float(ts[-1])
            g.n_rows += len(seg)

            states = classify_series(
                seg["program_resident"].astype(bool),
                seg.activity_pct(),
                seg.comm_gbs(),
                self.config,
            )
            if g.state_pieces is not None:
                g.state_pieces.append(states)
            g.integrator.update(states, seg["power"])

    def merge(self, other: "FleetAccumulator") -> "FleetAccumulator":
        """Absorb an accumulator that processed a *disjoint* set of streams.

        This is the reduction step of process-pool shard analysis
        (``analyze_store(workers=N)``): each worker accumulates a
        host-label partition, the main process merges. Overlapping stream
        keys raise — per-stream run carry is sequential and cannot be
        joined after the fact. ``finalize`` after merging is bit-identical
        to the serial pass: per-stream results are computed identically,
        streams are re-sorted globally, and the unattributed total is
        ``math.fsum`` (exact, hence order-independent) over the same
        per-chunk partial sums.
        """
        overlap = self._groups.keys() & other._groups.keys()
        if overlap:
            raise ValueError(
                "cannot merge accumulators with overlapping streams: "
                f"{sorted(overlap)[:3]}...")
        if (other.min_job_duration_s, other.min_interval_s, other.config,
                other.dt_s) != (self.min_job_duration_s, self.min_interval_s,
                                self.config, self.dt_s):
            raise ValueError("cannot merge accumulators with different configs")
        self._groups.update(other._groups)
        self._unattributed_pieces.extend(other._unattributed_pieces)
        self.n_rows += other.n_rows
        self.n_chunks += other.n_chunks
        return self

    def finalize(self) -> FleetAnalysis:
        """Flush carried run state and assemble the :class:`FleetAnalysis`."""
        jobs: list[JobAnalysis] = []
        for key in sorted(self._groups):
            g = self._groups[key]
            breakdown, intervals = g.integrator.finalize()
            # duration by timestamp span (+dt for the last sample), NOT row
            # count — row count only equals seconds at exactly 1 Hz
            span_s = g.ts_last - g.ts_first + self.dt_s
            if span_s < self.min_job_duration_s:
                continue
            states = (np.concatenate(g.state_pieces)
                      if g.state_pieces is not None else None)
            jobs.append(JobAnalysis(
                job_id=key[0],
                duration_s=float(span_s),
                states=states,
                breakdown=breakdown,
                intervals=intervals,
                platform=g.platform,
            ))
        unattributed = math.fsum(self._unattributed_pieces)
        # clear ALL accumulated state, not just groups — a reused accumulator
        # must start from zero, never mix epochs
        self._groups.clear()
        self._unattributed_pieces.clear()
        self.n_rows = 0
        self.n_chunks = 0
        fleet = merge([j.breakdown for j in jobs])
        return FleetAnalysis(
            jobs=jobs,
            fleet=fleet,
            unattributed_energy_j=unattributed,
            n_intervals=sum(len(j.intervals) for j in jobs),
            platforms=_platform_breakdowns(jobs),
        )


def analyze_fleet(
    frame: TelemetryFrame,
    min_job_duration_s: float = 2 * 3600.0,
    min_interval_s: float = 5.0,
    config: ClassifierConfig = DEFAULT_CLASSIFIER,
    dt_s: float = 1.0,
) -> FleetAnalysis:
    """Group samples by (job, host, device) stream and analyze each (§2.1).

    Monolithic entry point: the whole frame as one chunk through
    :class:`FleetAccumulator` (single lexsort-based grouping pass — not a
    boolean mask per group). Jobs whose timestamp span is shorter than
    ``min_job_duration_s`` are excluded (the paper's ≥2 h long-job filter);
    samples with job_id < 0 count as unattributed.
    """
    acc = FleetAccumulator(
        min_job_duration_s=min_job_duration_s,
        min_interval_s=min_interval_s,
        config=config,
        dt_s=dt_s,
        keep_states=True,
    )
    acc.update(frame)
    return acc.finalize()


def _pool_context():
    """forkserver where available, spawn elsewhere — never plain fork, so a
    parent with live JAX/XLA threads is safe. Both start methods re-execute
    the caller's main module in each worker, so scripts calling
    ``workers > 1`` entry points at top level need the standard
    ``if __name__ == "__main__":`` guard (as in examples/whatif_sweep.py)."""
    import multiprocessing
    try:
        return multiprocessing.get_context("forkserver")
    except ValueError:
        return multiprocessing.get_context("spawn")


@dataclasses.dataclass(frozen=True)
class FaultTolerance:
    """Fault-supervisor policy for process-pool stages.

    ``max_retries`` bounds how many times a *crashed* (BrokenProcessPool) or
    *timed-out* partition is resubmitted — with exponential backoff starting
    at ``backoff_s`` — before it degrades to in-process execution in the
    parent (recorded as a ``pool -> in_process`` fallback). ``timeout_s``
    is the wall-clock budget for one pool round (``None`` = never time out;
    hung workers then hang the stage, exactly as before this layer existed).
    Worker-raised exceptions are *not* retried: a deterministic error (a
    corrupt shard under ``strict=True``, a bad config) propagates
    immediately with its original type.
    """

    max_retries: int = 2
    timeout_s: float | None = None
    backoff_s: float = 0.05


DEFAULT_FAULT_TOLERANCE = FaultTolerance()


def _fault_plan() -> str | None:
    """The active fault-plan path, captured in the *parent* at submission
    time. It must travel as a task argument, not ambiently: forkserver
    children inherit the fork server's environment from when it first
    launched, so a plan installed later would be invisible to them."""
    return os.environ.get("REPRO_FAULT_PLAN")   # == faults.ENV_PLAN


def _partition_body(stage, plan, worker, root, shard_files, *extra):
    """Pool submission wrapper: give the fault-injection harness its hook,
    then run the worker. The plan check keeps the harness import (and any
    file reads) entirely off the production path."""
    if plan:
        from repro.testing import faults
        faults.check(stage, plan)
    return worker(root, shard_files, *extra)


def _shutdown_pool(pool, hard: bool) -> None:
    if hard:
        # hung or crashed round: terminate live workers (a hung worker
        # never exits on its own) and abandon queued futures
        for proc in list((getattr(pool, "_processes", None) or {}).values()):
            try:
                proc.terminate()
            except Exception:
                pass
        pool.shutdown(wait=False, cancel_futures=True)
    else:
        pool.shutdown(wait=True)


def run_supervised(fn, task_args: list[tuple], stage: str,
                   fault: FaultTolerance | None = None) -> list:
    """Run ``fn(*args)`` for each args-tuple in a process pool under the
    bounded-retry fault supervisor; returns results **in task order**.

    Crash/hang handling: a task whose worker dies (``BrokenProcessPool``)
    or exceeds ``fault.timeout_s`` is retried in a fresh pool up to
    ``fault.max_retries`` times with exponential backoff, then degraded to
    in-process execution in the parent — so one bad worker can no longer
    take down an entire ``analyze_store``/``run_sweep``. Note a broken pool
    fails *every* in-flight task of that round; innocent tasks are simply
    retried and succeed. Worker-raised exceptions propagate immediately
    (they are deterministic; retrying cannot help). Obs payloads are
    absorbed in task order after all tasks settle, preserving the
    bit-identical obs-on/obs-off contract.
    """
    from concurrent.futures import TimeoutError as FutTimeout
    from concurrent.futures import ProcessPoolExecutor
    from concurrent.futures.process import BrokenProcessPool

    fault = fault or DEFAULT_FAULT_TOLERANCE
    n = len(task_args)
    results: dict[int, object] = {}
    payloads: dict[int, object] = {}
    attempts = [0] * n
    token = obs.worker_token(f"{stage}.partition")
    pending = list(range(n))
    while pending:
        pool = ProcessPoolExecutor(max_workers=len(pending),
                                   mp_context=_pool_context())
        futures = {i: pool.submit(obs.call_with_obs, token, fn, *task_args[i])
                   for i in pending}
        deadline = (time.monotonic() + fault.timeout_s
                    if fault.timeout_s is not None else None)
        failed: list[tuple[int, BaseException]] = []
        error: BaseException | None = None
        for i in pending:
            if error is not None:
                futures[i].cancel()
                continue
            try:
                budget = (None if deadline is None
                          else max(deadline - time.monotonic(), 0.0))
                results[i], payloads[i] = futures[i].result(timeout=budget)
            except (BrokenProcessPool, FutTimeout) as e:
                failed.append((i, e))
            except BaseException as e:
                error = e               # worker-raised: not retryable
        _shutdown_pool(pool, hard=bool(failed) or error is not None)
        if error is not None:
            raise error
        pending = []
        backoff_round = 0
        for i, exc in failed:
            attempts[i] += 1
            reason = type(exc).__name__
            obs.counter("repro_partition_retries_total",
                        stage=stage, reason=reason,
                        help="pool partition attempts that crashed/hung and "
                             "were retried or degraded")
            if attempts[i] <= fault.max_retries:
                pending.append(i)
                backoff_round = max(backoff_round, attempts[i])
            else:
                obs.fallback("pool", "in_process", reason)
                with obs.span(f"{stage}.partition", degraded=True):
                    results[i] = fn(*task_args[i])
                payloads[i] = None
        if pending and fault.backoff_s > 0:
            time.sleep(min(fault.backoff_s * (2 ** (backoff_round - 1)), 2.0))
    for i in range(n):
        obs.absorb(payloads.get(i))
    return [results[i] for i in range(n)]


def map_shard_partitions(store, hosts, workers, worker, extra_args, merge,
                         stage: str = "pipeline",
                         fault: FaultTolerance | None = None):
    """Run ``worker(root, shard_files, *extra_args)`` over host-label
    partitions of a store and fold the results with ``merge(acc, part)``.
    Every worker body returns ``(obj, skips)`` — its result plus the shard
    skip records its ``strict=False`` reads produced — and this returns the
    folded ``(result, skips)`` with skips concatenated in partition order.

    The shared scaffold of ``analyze_store(workers=N)`` and
    ``repro.whatif.sweep.run_sweep``. Determinism contract: partitions are
    disjoint in streams (see :meth:`TelemetryStore.partition_hosts`) and
    results are merged **in submit order**, so for order-exact reductions
    (``math.fsum`` pieces, sorted stream keys) any worker count is
    bit-identical to the serial pass. With one partition or ``workers <= 1``
    the worker runs in-process.

    Pool rounds run under the :func:`run_supervised` fault supervisor
    (crashed/hung partitions retry with backoff, then degrade to
    in-process; policy via ``fault``, default
    :data:`DEFAULT_FAULT_TOLERANCE`).

    When observability is enabled (:mod:`repro.obs`), each pool submission
    is wrapped in :func:`repro.obs.call_with_obs`: the worker runs under a
    ``{stage}.partition`` span in its own process, and its spans/metrics
    are folded back into the parent trace in submit order.  Obs off, the
    wrapper is a pure passthrough, and merge order is unchanged either way.
    """
    # materialize: `hosts` may be a one-shot iterable, and it is consumed
    # both by partition_hosts and by the serial fallback below
    hosts = list(hosts) if hosts is not None else None
    partitions = store.partition_hosts(workers, hosts) if workers > 1 else []
    if len(partitions) <= 1:
        obs.gauge("repro_pool_workers", 1.0, stage=stage,
                  help="process-pool fan-out per stage (1 = in-process)")
        with obs.span(f"{stage}.partition", serial=True):
            return _partition_body(stage, _fault_plan(), worker,
                                   str(store.root),
                                   store.shard_files(hosts), *extra_args)
    obs.gauge("repro_pool_workers", float(len(partitions)), stage=stage,
              help="process-pool fan-out per stage (1 = in-process)")
    parts = run_supervised(
        _partition_body,
        [(stage, _fault_plan(), worker, str(store.root),
          store.shard_files(part), *extra_args) for part in partitions],
        stage=stage, fault=fault)
    result, skips = None, []
    for part, part_skips in parts:
        skips.extend(part_skips)
        result = part if result is None else merge(result, part)
    return result, skips


def _accumulate_shards(
    root: str,
    shard_files: list[str],
    mmap: bool,
    acc_kwargs: dict,
    strict: bool = True,
    verify: bool = False,
) -> tuple[FleetAccumulator, list[dict]]:
    """Process-pool worker body: accumulate one shard subset (must stay
    module-level picklable). Returns ``(accumulator, skip_records)`` —
    under ``strict=False`` unreadable shards are skipped and recorded
    instead of raising (see :meth:`TelemetryStore.read_shard_or_skip`)."""
    from repro.telemetry.storage import TelemetryStore
    store = TelemetryStore(root)
    acc = FleetAccumulator(**acc_kwargs)
    skips: list[dict] = []
    for name in shard_files:
        frame = store.read_shard_or_skip(name, skips, mmap=mmap,
                                         strict=strict, verify=verify)
        if frame is not None:
            acc.update(frame)
    return acc, skips


def _analyze_ir(ir, hosts, min_job_duration_s: float,
                min_interval_s: float | None, dt_s: float) -> FleetAnalysis:
    """Run-algebra fleet analysis over a prebuilt :class:`RunIR`.

    Per stream, per-state occupancy, execution-idle intervals and the
    §2.2 sustain relabel reduce over the run table
    (:func:`repro.core.energy.integrate_runs_with_intervals`) instead of
    re-classifying rows. Contract vs the row engine on the same data:
    per-state times, job durations, interval bounds/counts and the
    per-platform grouping are **bit-identical** (integer sample sums and
    timestamp arithmetic over the same scalar ops); energies agree within
    float summation order; ``unattributed_energy_j`` is exactly equal
    (``math.fsum`` over the same per-chunk partials). Coverage/skip
    accounting is the caller's job (:func:`analyze_store`).
    """
    min_samples = (0 if min_interval_s is None
                   else int(np.ceil(min_interval_s / dt_s)))
    host_set = set(hosts) if hosts is not None else None
    jobs: list[JobAnalysis] = []
    for s in ir.select(hosts):
        # same duration arithmetic as the row path: the reconstructed
        # ts_last bit-equals the recorded column (regularity is validated
        # at IR build time), so the span filter cannot diverge
        span_s = s.ts_last - s.ts_first + dt_s
        if span_s < min_job_duration_s:
            continue
        from repro.core.energy import integrate_runs_with_intervals
        breakdowns, intervals = integrate_runs_with_intervals(
            s.state, s.power_sum[None, :], s.length, min_samples, dt_s)
        jobs.append(JobAnalysis(
            job_id=s.key[0],
            duration_s=float(span_s),
            states=None,
            breakdown=breakdowns[0],
            intervals=intervals,
            platform=s.platform_id,
        ))
    unattributed = math.fsum(
        v for h, v in ir.unattributed
        if host_set is None or h in host_set)
    fleet = merge([j.breakdown for j in jobs])
    return FleetAnalysis(
        jobs=jobs,
        fleet=fleet,
        unattributed_energy_j=unattributed,
        n_intervals=sum(len(j.intervals) for j in jobs),
        platforms=_platform_breakdowns(jobs),
    )


def analyze_store(
    store: "TelemetryStore",
    hosts: Iterable[str] | None = None,
    min_job_duration_s: float = 2 * 3600.0,
    min_interval_s: float = 5.0,
    config: ClassifierConfig = DEFAULT_CLASSIFIER,
    dt_s: float = 1.0,
    workers: int = 1,
    mmap: bool = False,
    strict: bool = True,
    verify: bool = False,
    fault: FaultTolerance | None = None,
    compact: bool | None = None,
    ir=None,
) -> FleetAnalysis:
    """Streaming fleet analysis: one shard in memory at a time.

    Bit-identical to ``analyze_fleet(store.read_all(hosts))`` (modulo the
    last ulp of ``unattributed_energy_j`` on the row engine, and of the
    per-state energies between engines) with peak memory bounded by the
    largest shard, so 162 GB-scale datasets analyze on a laptop.

    **Engine selection** (``compact``): by default (``None``) the analysis
    runs over the store's run-level IR (:func:`repro.whatif.ir.get_ir` —
    memory/sidecar cached, incrementally extended on append), reducing run
    tables instead of re-classifying rows, and falls back to the row
    engine automatically when the store cannot be compacted (irregular
    sampling, quarantined mid-stream shards) — recorded as a
    ``compact -> row`` fallback. ``compact=False`` pins the row engine
    (the bit-exactness oracle); ``compact=True`` demands the IR engine and
    propagates its errors instead of falling back. A prebuilt ``ir``
    handle (e.g. shared with a sweep/search over the same store) skips
    acquisition entirely; it must match ``config``/``dt_s``. Between the
    engines, per-state times, durations, intervals, platform grouping and
    ``unattributed_energy_j`` are bit-identical; energies agree within
    1e-9 relative (float summation order).

    ``workers > 1`` spreads host-label partitions over a process pool
    (streams never span host labels, so partitions are disjoint) and merges
    the partial accumulators — bit-identical to the serial pass, including
    ``unattributed_energy_j`` (see :meth:`FleetAccumulator.merge`).
    ``mmap=True`` memory-maps ``npy_dir`` shards (zero-copy reads; see
    :meth:`TelemetryStore.iter_shards`).

    Robustness: ``strict=False`` skips unreadable shards instead of raising
    — the result is bit-identical to analyzing the clean subset, with the
    skipped shards recorded in ``result.skipped`` and ``result.coverage``
    reporting rows analyzed / rows on disk. ``verify=True`` additionally
    checksums every shard read (on the compact path that is the shard
    reads IR acquisition performs; cached IRs were verified when built).
    ``fault`` tunes the pool's crash/hang supervisor (see
    :class:`FaultTolerance`).
    """
    hosts = list(hosts) if hosts is not None else None
    t0 = time.perf_counter()
    result = None
    n_rows = n_chunks = n_runs = 0
    with obs.span("analyze_store", workers=workers):
        if compact is not False:
            # local import: whatif.ir imports core/* which pipeline feeds
            from repro.telemetry.storage import ShardReadError
            from repro.whatif import ir as ir_mod
            try:
                ir_obj = ir
                if ir_obj is not None:
                    if (ir_obj.config.classifier != config
                            or ir_obj.config.dt_s != dt_s):
                        raise ir_mod.IRUnsupportedError(
                            "prebuilt IR was compacted under a different "
                            "classifier config or dt_s")
                    if ir_obj.skipped and strict:
                        raise ir_mod.IRUnsupportedError(
                            "prebuilt IR carries skipped shards; pass "
                            "strict=False to accept degraded coverage")
                else:
                    ir_obj = ir_mod.get_ir(
                        store,
                        ir_mod.IRConfig(classifier=config, dt_s=dt_s),
                        workers=workers, mmap=mmap, strict=strict,
                        verify=verify, fault=fault)
                skips = [dict(s) for s in ir_obj.skipped
                         if hosts is None or s.get("host", "") in set(hosts)]
                with obs.span("analyze.reduce_runs"):
                    result = _analyze_ir(ir_obj, hosts, min_job_duration_s,
                                         min_interval_s, dt_s)
                n_runs = sum(s.n_runs for s in ir_obj.select(hosts))
            except (ir_mod.IRUnsupportedError, ShardReadError) as e:
                if compact:
                    raise
                reason = ("ir_unsupported"
                          if isinstance(e, ir_mod.IRUnsupportedError)
                          else "shard_read_error")
                obs.fallback("compact", "row", reason)
        if result is None:
            acc_kwargs = dict(
                min_job_duration_s=min_job_duration_s,
                min_interval_s=min_interval_s,
                config=config,
                dt_s=dt_s,
            )
            acc, skips = map_shard_partitions(
                store, hosts, workers, _accumulate_shards,
                (mmap, acc_kwargs, strict, verify),
                merge=lambda a, b: a.merge(b), stage="analyze", fault=fault)
            n_rows, n_chunks = acc.n_rows, acc.n_chunks
            with obs.span("analyze.finalize"):
                result = acc.finalize()
        expected = store.rows_on_disk(hosts)
        skip_rows = sum(s["rows"] for s in skips)
        coverage = (1.0 if expected <= 0
                    else max(0.0, 1.0 - skip_rows / expected))
        result = dataclasses.replace(result, coverage=coverage,
                                     skipped=tuple(skips))
        if not n_rows:
            n_rows = max(expected - skip_rows, 0)
        obs.gauge("repro_coverage_fraction", coverage, stage="analyze",
                  help="rows analyzed / rows on disk for the last run")
    if obs.enabled():
        dt = max(time.perf_counter() - t0, 1e-12)
        obs.observe("repro_analyze_seconds", dt,
                    help="wall time of analyze_store calls")
        obs.gauge("repro_analyze_rows_per_s", n_rows / dt,
                  help="row throughput of the last analyze_store")
        if n_chunks:
            obs.gauge("repro_analyze_shards_per_s", n_chunks / dt,
                      help="shard throughput of the last analyze_store")
        if n_runs:
            obs.gauge("repro_analyze_runs_per_s", n_runs / dt,
                      help="run-table throughput of the last compact "
                           "analyze_store")
        obs.gauge("repro_analyze_jobs", float(len(result.jobs)),
                  help="jobs surviving the min-duration filter")
    return result


def per_job_fraction_cdf(jobs: Iterable[JobAnalysis]) -> dict[str, np.ndarray]:
    """Per-job execution-idle time/energy fractions (Fig 7)."""
    t = np.array([j.exec_idle_time_fraction for j in jobs])
    e = np.array([j.exec_idle_energy_fraction for j in jobs])
    return {"time_fraction": np.sort(t), "energy_fraction": np.sort(e)}


def tail_share(fractions: np.ndarray, threshold: float) -> float:
    """Share of jobs whose fraction exceeds `threshold` (Fig 7 quotes)."""
    fractions = np.asarray(fractions)
    return float(np.mean(fractions > threshold)) if fractions.size else 0.0

"""Telemetry sample schema (paper Table 1).

One record = one second of behaviour on one allocated device for one job.
Columnar storage as NumPy arrays; ``nan`` marks signals unavailable on a
platform (the classifier omits them rather than treating them as violated).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Mapping

import numpy as np

#: (field, dtype, unit, source-analogue) — mirrors paper Table 1.
SCHEMA: tuple[tuple[str, str, str, str], ...] = (
    # identity
    ("timestamp", "f8", "s", "profiler"),
    ("hostname", "i4", "-", "scheduler"),      # interned id
    ("device_id", "i4", "-", "scheduler"),
    ("platform", "i4", "-", "nvml/runtime"),   # interned platform name
    # power
    ("power", "f8", "W", "nvml/model"),
    # activity (percent)
    ("sm", "f8", "%", "dcgm/runtime"),
    ("tensor", "f8", "%", "dcgm/runtime"),
    ("fp16", "f8", "%", "dcgm/runtime"),
    ("fp32", "f8", "%", "dcgm/runtime"),
    ("fp64", "f8", "%", "dcgm/runtime"),
    ("dram", "f8", "%", "dcgm/runtime"),
    # clocks
    ("sm_clk", "f8", "MHz", "nvml/model"),
    ("mem_clk", "f8", "MHz", "nvml/model"),
    # communication (GB/s)
    ("pcie_tx", "f8", "GB/s", "nvml/runtime"),
    ("pcie_rx", "f8", "GB/s", "nvml/runtime"),
    ("nvlink_tx", "f8", "GB/s", "nvml/runtime"),
    ("nvlink_rx", "f8", "GB/s", "nvml/runtime"),
    ("ici_tx", "f8", "GB/s", "runtime"),
    ("ici_rx", "f8", "GB/s", "runtime"),
    # host
    ("cpu_util", "f8", "%", "psutil/runtime"),
    ("host_mem_util", "f8", "%", "psutil/runtime"),
    ("nic_tx", "f8", "GB/s", "os-counters"),
    ("nic_rx", "f8", "GB/s", "os-counters"),
    # job metadata
    ("job_id", "i8", "-", "scheduler"),
    ("program_resident", "i1", "bool", "runtime"),
)

FIELDS: tuple[str, ...] = tuple(f for f, *_ in SCHEMA)
_DTYPES: dict[str, str] = {f: d for f, d, *_ in SCHEMA}

ACTIVITY_FIELDS = ("sm", "tensor", "fp16", "fp32", "fp64", "dram")
COMM_FIELDS = ("pcie_tx", "pcie_rx", "nvlink_tx", "nvlink_rx", "ici_tx", "ici_rx")


@dataclasses.dataclass
class TelemetryFrame:
    """Columnar batch of samples, aligned by row."""

    columns: dict[str, np.ndarray]

    def __post_init__(self) -> None:
        lengths = {k: v.shape[0] for k, v in self.columns.items()}
        if len(set(lengths.values())) > 1:
            raise ValueError(f"ragged columns: {lengths}")
        for f in FIELDS:
            if f not in self.columns:
                n = len(self)
                fill = np.nan if _DTYPES[f].startswith("f") else 0
                self.columns[f] = np.full(n, fill, dtype=_DTYPES[f])

    def __len__(self) -> int:
        return 0 if not self.columns else next(iter(self.columns.values())).shape[0]

    def __getitem__(self, field: str) -> np.ndarray:
        return self.columns[field]

    def row(self, i: int) -> dict[str, object]:
        out: dict[str, object] = {k: v[i] for k, v in self.columns.items()}
        out["program_resident"] = bool(out["program_resident"])
        return out

    def select(self, mask: np.ndarray) -> "TelemetryFrame":
        return TelemetryFrame({k: v[mask] for k, v in self.columns.items()})

    def iter_chunks(self, chunk_rows: int) -> Iterator["TelemetryFrame"]:
        """Yield consecutive row-slices of at most ``chunk_rows`` rows.

        Slices are zero-copy views; useful for exercising / benchmarking the
        streaming analysis path against an in-memory frame.
        """
        if chunk_rows <= 0:
            raise ValueError(f"chunk_rows must be positive, got {chunk_rows}")
        n = len(self)
        for s in range(0, n, chunk_rows):
            yield TelemetryFrame(
                {k: v[s:s + chunk_rows] for k, v in self.columns.items()})

    def group_streams(
        self,
    ) -> Iterator[tuple[tuple[int, int, int], "TelemetryFrame"]]:
        """Yield per-(job_id, hostname, device_id) streams, time-sorted.

        One lexsort + one gather per column replaces the O(groups x rows)
        per-group boolean masking: after sorting by (job, host, device,
        timestamp) every stream is a contiguous block, so each yielded frame
        is a zero-copy slice view of the sorted columns. Groups arrive in
        ascending (job_id, hostname, device_id) order; rows within a group are
        sorted by timestamp (stable, so equal timestamps keep input order).
        """
        n = len(self)
        if n == 0:
            return
        jid = self.columns["job_id"]
        host = self.columns["hostname"]
        dev = self.columns["device_id"]
        order = np.lexsort((self.columns["timestamp"], dev, host, jid))
        cols = {k: v[order] for k, v in self.columns.items()}
        sj, sh, sd = cols["job_id"], cols["hostname"], cols["device_id"]
        change = np.flatnonzero(
            (np.diff(sj) != 0) | (np.diff(sh) != 0) | (np.diff(sd) != 0)) + 1
        starts = np.concatenate([[0], change])
        ends = np.concatenate([change, [n]])
        for s, e in zip(starts, ends):
            key = (int(sj[s]), int(sh[s]), int(sd[s]))
            yield key, TelemetryFrame({k: v[s:e] for k, v in cols.items()})

    def activity_pct(self) -> dict[str, np.ndarray]:
        return {k: self.columns[k] for k in ACTIVITY_FIELDS}

    def comm_gbs(self) -> dict[str, np.ndarray]:
        return {k: self.columns[k] for k in COMM_FIELDS}

    @staticmethod
    def from_rows(rows: Iterable[Mapping[str, object]]) -> "TelemetryFrame":
        rows = list(rows)
        cols: dict[str, np.ndarray] = {}
        for f in FIELDS:
            dt = _DTYPES[f]
            default = np.nan if dt.startswith("f") else 0
            cols[f] = np.array([r.get(f, default) for r in rows], dtype=dt)
        return TelemetryFrame(cols)

    @staticmethod
    def concat(frames: list["TelemetryFrame"]) -> "TelemetryFrame":
        if not frames:
            return TelemetryFrame({f: np.empty(0, dtype=_DTYPES[f]) for f in FIELDS})
        return TelemetryFrame({
            f: np.concatenate([fr.columns[f] for fr in frames]) for f in FIELDS
        })

"""Performance-tuning knobs (§Perf hillclimbing).

Module-level switches read at TRACE time; the dry-run CLI sets them before
lowering so baseline and optimized artifacts can be produced from the same
model code. Every knob corresponds to one hypothesis -> change -> measure
cycle recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Tuning:
    #: cast softmax probabilities to bf16 before the AV matmul (halves the
    #: dominant score-traffic term; f32 row-stats retained)
    attn_probs_bf16: bool = False
    #: remat each attention q-block (stops the backward from stacking
    #: per-block probs into (n_blocks, ...) residual buffers)
    attn_block_remat: bool = False
    #: Megatron-style sequence parallelism: residual-stream activations
    #: sharded (batch, model, None) between blocks; TP collectives become
    #: all-gather + reduce-scatter pairs instead of all-reduces
    seq_parallel: bool = False
    #: decode KV caches sharded over batch axes only (GSPMD turns a
    #: dynamic-update-slice into a model-sharded seq dim into a full
    #: gather/re-shard of the cache every step)
    decode_cache_data_only: bool = False
    #: grouped-query attention without KV expansion: contract per KV group
    #: with bf16 operands + f32 accumulation (preferred_element_type) instead
    #: of materializing an f32, q_per_kv-times-repeated copy of K/V
    attn_grouped: bool = False
    #: q-block length used by blocked attention
    q_block: int = 1024

    def describe(self) -> str:
        on = [f.name for f in dataclasses.fields(self)
              if f.name != "q_block" and getattr(self, f.name)]
        if self.q_block != 1024:
            on.append(f"qblk{self.q_block}")
        return "+".join(on) if on else "baseline"


#: the active configuration (mutated by launch code before tracing)
ACTIVE = Tuning()


def set_tuning(**kwargs) -> Tuning:
    global ACTIVE
    ACTIVE = Tuning(**kwargs)
    return ACTIVE


def reset() -> None:
    global ACTIVE
    ACTIVE = Tuning()

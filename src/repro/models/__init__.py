"""Model zoo: 7 families covering the 10 assigned architectures."""
from repro.models import api  # noqa: F401

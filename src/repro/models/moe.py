"""Mixture-of-Experts FFN + the granite-moe architecture.

Two dispatch paths with identical semantics:

* **dense fallback** (no mesh / model axis == 1): every expert computed for
  every token, masked by the top-k gates. Exact; O(E) FLOPs — used only by
  CPU smoke tests and the `ref` oracle.
* **expert-parallel** (production): tokens are sequence-sharded over the
  `model` axis, routed into fixed-capacity per-expert buffers, exchanged with
  `all_to_all` inside `shard_map` (DeepSeek-style EP), processed as batched
  per-expert GEMMs, and combined on the way back. Capacity overflow drops
  tokens (standard GShard behaviour; capacity_factor controls the rate).

Experts are zero-padded to a multiple of the model-axis size (granite's 40
experts -> 48 on a 16-wide axis); padded experts get -inf router logits.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map

from repro.configs.base import ModelConfig
from repro.distributed.context import DistContext, LOCAL
from repro.models import common as cm


# --------------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------------- #
def padded_experts(cfg: ModelConfig, ep_size: int) -> int:
    return int(math.ceil(cfg.n_experts / ep_size) * ep_size)


def init_moe_ffn(key, cfg: ModelConfig, ep_size: int = 1, n_layers: int | None = None):
    """Stacked-over-layers MoE FFN params. d_expert is the per-expert width."""
    dt = jnp.dtype(cfg.dtype)
    l = cfg.n_layers if n_layers is None else n_layers
    d, fe, e = cfg.d_model, cfg.d_expert, padded_experts(cfg, ep_size)
    ks = cm.split_keys(key, 7)

    def stack(k, *shape, fan_in, dtype=None):
        scale = 1.0 / jnp.sqrt(fan_in)
        arr = jax.random.normal(k, (l, *shape), jnp.float32) * scale
        return arr.astype(dt if dtype is None else dtype)

    params = {
        "router": stack(ks[0], d, e, fan_in=d, dtype=jnp.float32),
        "we_gate": stack(ks[1], e, d, fe, fan_in=d),
        "we_up": stack(ks[2], e, d, fe, fan_in=d),
        "we_down": stack(ks[3], e, fe, d, fan_in=fe),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_expert * cfg.n_shared_experts
        params["ws_gate"] = stack(ks[4], d, fs, fan_in=d)
        params["ws_up"] = stack(ks[5], d, fs, fan_in=d)
        params["ws_down"] = stack(ks[6], fs, d, fan_in=fs)
    return params


# --------------------------------------------------------------------------- #
# routing
# --------------------------------------------------------------------------- #
def router_topk(x, w_router, cfg: ModelConfig):
    """Returns (gates (..., k) f32, ids (..., k) int32, aux_loss scalar)."""
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)
    e_pad = w_router.shape[-1]
    if e_pad > cfg.n_experts:  # mask padded experts
        pad_mask = jnp.arange(e_pad) >= cfg.n_experts
        logits = jnp.where(pad_mask, -jnp.inf, logits)
    top_logits, ids = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(top_logits, axis=-1)

    # switch-style load-balance auxiliary loss over real experts
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs.reshape(-1, e_pad), axis=0)
    assign = jax.nn.one_hot(ids, e_pad, dtype=jnp.float32).sum(axis=-2)
    ce = jnp.mean(assign.reshape(-1, e_pad), axis=0) / cfg.top_k
    aux = cfg.n_experts * jnp.sum(me * ce)
    return gates, ids.astype(jnp.int32), aux


# --------------------------------------------------------------------------- #
# dense fallback dispatch
# --------------------------------------------------------------------------- #
def moe_ffn_dense(x, p, cfg: ModelConfig):
    """All-experts compute, gate-masked. x: (B, S, D). Exact oracle."""
    gates, ids, aux = router_topk(x, p["router"], cfg)
    e_pad = p["router"].shape[-1]
    one_hot = jax.nn.one_hot(ids, e_pad, dtype=jnp.float32)       # (B,S,k,E)
    combine = jnp.einsum("bske,bsk->bse", one_hot, gates)         # (B,S,E)
    h = jnp.einsum("bsd,edf->bsef", x, p["we_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, p["we_up"])
    y = jnp.einsum("bsef,efd->bsed", cm.act_fn(cfg.act)(h) * u, p["we_down"])
    out = jnp.einsum("bsed,bse->bsd", y.astype(jnp.float32), combine).astype(x.dtype)
    return out, aux


# --------------------------------------------------------------------------- #
# expert-parallel dispatch (shard_map + all_to_all)
# --------------------------------------------------------------------------- #
def _ep_block(x_loc, router, we_gate, we_up, we_down, *, cfg: ModelConfig,
              ep_axis: str, ep_size: int, capacity_factor: float,
              all_axes: tuple[str, ...]):
    """Per-shard body. x_loc: (b_loc, s_loc, D); expert weights are the LOCAL
    slice (e_loc, D, F). Returns (out_loc, aux_loss_local)."""
    b, s, d = x_loc.shape
    e_pad = router.shape[-1]
    e_loc = e_pad // ep_size
    k = cfg.top_k
    n_tok = b * s
    n_assign = n_tok * k
    cap = max(1, int(math.ceil(n_tok * k / e_pad * capacity_factor)))

    xf = x_loc.reshape(n_tok, d)
    gates, ids, aux = router_topk(xf, router, cfg)                 # (n,k)
    flat_ids = ids.reshape(-1)                                     # (n*k,)
    flat_gates = gates.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(n_tok), k)

    # position of each assignment within its expert's capacity buffer
    order = jnp.argsort(flat_ids)                                  # stable
    sorted_ids = flat_ids[order]
    counts = jnp.zeros((e_pad,), jnp.int32).at[flat_ids].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(n_assign, dtype=jnp.int32) - starts[sorted_ids]
    keep = pos_in_e < cap
    slot = jnp.where(keep, pos_in_e, cap)                          # overflow -> dropped row

    # scatter tokens into (E, cap+1, D); slot `cap` catches drops
    send = jnp.zeros((e_pad, cap + 1, d), x_loc.dtype)
    send = send.at[sorted_ids, slot].set(xf[tok_idx[order]], mode="drop")
    send = send[:, :cap]                                           # (E, cap, D)

    # exchange: (ep, e_loc, cap, D) -> recv[src] on each expert shard
    send = send.reshape(ep_size, e_loc, cap, d)
    recv = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False)                          # (ep_src, e_loc, cap, D)
    hbuf = recv.transpose(1, 0, 2, 3).reshape(e_loc, ep_size * cap, d)

    g = cm.act_fn(cfg.act)(jnp.einsum("ecd,edf->ecf", hbuf, we_gate))
    u = jnp.einsum("ecd,edf->ecf", hbuf, we_up)
    y = jnp.einsum("ecf,efd->ecd", g * u, we_down)                  # (e_loc, ep*cap, D)

    y = y.reshape(e_loc, ep_size, cap, d).transpose(1, 0, 2, 3)     # (ep, e_loc, cap, D)
    back = jax.lax.all_to_all(y, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False)                          # (ep, e_loc, cap, D)
    back = back.reshape(e_pad, cap, d)

    # gather per-assignment results and combine with gates
    pad_row = jnp.zeros((e_pad, 1, d), back.dtype)
    back = jnp.concatenate([back, pad_row], axis=1)                 # slot `cap` -> zeros
    y_sorted = back[sorted_ids, slot]                               # (n*k, D)
    y_assign = jnp.zeros_like(y_sorted).at[order].set(y_sorted)
    out = jax.ops.segment_sum(
        y_assign.astype(jnp.float32) * flat_gates[:, None], tok_idx,
        num_segments=n_tok)
    # mean aux across every mesh axis so the P() out-spec is truly replicated
    aux = jax.lax.pmean(aux, all_axes)
    return out.reshape(b, s, d).astype(x_loc.dtype), aux


def moe_ffn_ep(x, p, cfg: ModelConfig, dist: DistContext,
               capacity_factor: float = 1.25):
    """Expert-parallel MoE FFN. x: (B, S, D) sharded (batch_axes, None, None).

    Train/prefill (S divisible by the model axis): sequence-shard x over
    `model` so every device dispatches a distinct token slice. Decode (S=1):
    tokens stay replicated over `model` — every expert shard receives the
    same dispatch, computes its local experts, and the combine discards the
    duplicates; correct, with redundant expert FLOPs proportional to ep_size
    (a decode-path optimization target recorded in EXPERIMENTS.md §Perf).
    """
    mesh = dist.mesh
    assert mesh is not None
    ep_axis = dist.model_axis
    ep_size = dist.ep_size
    seq_shard = x.shape[1] % ep_size == 0 and x.shape[1] >= ep_size
    x_spec = (P(dist.batch_axes, ep_axis, None) if seq_shard
              else P(dist.batch_axes, None, None))

    x = dist.constraint(x, x_spec)
    block = functools.partial(
        _ep_block, cfg=cfg, ep_axis=ep_axis, ep_size=ep_size,
        capacity_factor=capacity_factor,
        all_axes=tuple(mesh.axis_names))

    in_specs = (
        x_spec,                                  # x: batch (+ seq) sharded
        P(),                                     # router replicated
        P(ep_axis, None, None),                  # expert weights sharded on E
        P(ep_axis, None, None),
        P(ep_axis, None, None),
    )
    out_specs = (x_spec, P())
    out, aux = shard_map(
        block, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    out = dist.constraint(out, P(dist.batch_axes, None, None))
    return out, aux


def moe_ffn(x, p, cfg: ModelConfig, dist: DistContext = LOCAL,
            capacity_factor: float = 1.25):
    """Routed experts + optional shared experts. Returns (out, aux_loss)."""
    if dist.enabled and dist.ep_size > 1:
        out, aux = moe_ffn_ep(x, p, cfg, dist, capacity_factor)
    else:
        out, aux = moe_ffn_dense(x, p, cfg)
    if cfg.n_shared_experts:
        out = out + cm.glu_mlp(x, p["ws_gate"], p["ws_up"], p["ws_down"], cfg.act)
    return out, aux


# =========================================================================== #
# granite-moe architecture: GQA attention blocks with MoE FFNs
# =========================================================================== #
from repro.models import dense as _dense  # noqa: E402  (shares attention code)


def init_params(key, cfg: ModelConfig, ep_size: int = 1):
    k1, k2 = jax.random.split(key)
    params = _dense.init_params(k1, cfg)
    layers = params["layers"]
    # replace the dense FFN with MoE FFN params
    for name in ("w_gate", "w_up", "w_down"):
        del layers[name]
    layers.update(init_moe_ffn(k2, cfg, ep_size))
    return params


def abstract_params(cfg: ModelConfig, ep_size: int = 1):
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg, ep_size))


def _moe_block(x, lp, cfg: ModelConfig, positions, dist: DistContext,
               q_block: int = 1024):
    x = cm.hint(x, "act_bsd")
    h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _dense._qkv(h, lp, cfg)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    attn = cm.attention(q, k, v, causal=True, q_block=q_block)
    x = x + attn.reshape(x.shape[0], x.shape[1], -1) @ lp["wo"]
    h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    y, aux = moe_ffn(h, lp, cfg, dist)
    return x + y, aux


def loss_fn(params, batch, cfg: ModelConfig, dist: DistContext = LOCAL):
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s)

    block = functools.partial(_moe_block, cfg=cfg, positions=positions, dist=dist)
    block = jax.checkpoint(block)

    def body(carry, lp):
        x, aux_sum = carry
        x, aux = block(x, lp)
        return (x, aux_sum + aux), None

    (x, aux_sum), _ = jax.lax.scan(body, (x, 0.0), params["layers"])
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x, params["embed"], params.get("out_head"))
    ce = cm.cross_entropy(logits, labels)
    aux = cfg.router_aux_coef * aux_sum / cfg.n_layers
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


init_cache = _dense.init_cache


def prefill(params, tokens, cfg: ModelConfig, dist: DistContext = LOCAL,
            q_block: int = 1024):
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s)

    def body(carry, lp):
        x = carry
        h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _dense._qkv(h, lp, cfg)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        attn = cm.attention(q, k, v, causal=True, q_block=q_block)
        x = x + attn.reshape(b, s, -1) @ lp["wo"]
        h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        y, _ = moe_ffn(h, lp, cfg, dist)
        return x + y, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x[:, -1:], params["embed"], params.get("out_head"))
    return {"k": ks, "v": vs, "len": jnp.asarray(s, jnp.int32)}, logits


def decode_step(params, cache, tokens, cfg: ModelConfig, dist: DistContext = LOCAL):
    b = tokens.shape[0]
    x = params["embed"][tokens]
    pos = cache["len"]
    positions = jnp.full((b, 1), pos, jnp.int32)

    def body(carry, layer_in):
        x = carry
        lp, k_cache, v_cache = layer_in
        h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _dense._qkv(h, lp, cfg)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))
        attn = cm.decode_attention(q, k_cache, v_cache, pos + 1)
        x = x + attn.reshape(b, 1, -1) @ lp["wo"]
        h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        y, _ = moe_ffn(h, lp, cfg, dist)
        return x + y, (k_cache, v_cache)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x, params["embed"], params.get("out_head"))
    return {"k": ks, "v": vs, "len": cache["len"] + 1}, logits

"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
per-channel decay.

Each layer = time-mix (token shift + 5-way data-dependent lerp via LoRA,
WKV linear recurrence with decay w_t = exp(-exp(.)) and bonus u) +
channel-mix (token shift + squared-ReLU FFN). LayerNorms per RWKV convention.
Decode state is O(1) in sequence length: (heads, head_k, head_v) matrix per
layer plus two token-shift vectors — which is why rwkv6-3b is a `long_500k`
architecture.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm


# --------------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------------- #
def init_params(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    l, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    ml, dl = cfg.rwkv_mix_lora, cfg.rwkv_decay_lora
    ks = cm.split_keys(key, 14)

    def stack(k, *shape, fan_in):
        scale = 1.0 / jnp.sqrt(fan_in)
        return (jax.random.normal(k, (l, *shape), jnp.float32) * scale).astype(dt)

    layers = {
        "ln1_w": jnp.ones((l, d), dt), "ln1_b": jnp.zeros((l, d), dt),
        "ln2_w": jnp.ones((l, d), dt), "ln2_b": jnp.zeros((l, d), dt),
        # time-mix lerp anchors + LoRA
        "mu_x": jnp.full((l, d), 0.5, dt),
        "mu": jnp.full((l, 5, d), 0.5, dt),            # w,k,v,r,g anchors
        "tm_w1": stack(ks[0], d, 5 * ml, fan_in=d),
        "tm_w2": stack(ks[1], 5, ml, d, fan_in=ml),
        # decay
        "decay_base": jnp.full((l, d), -4.0, jnp.float32),
        "dw1": stack(ks[2], d, dl, fan_in=d),
        "dw2": stack(ks[3], dl, d, fan_in=dl),
        "u": jnp.zeros((l, d), jnp.float32),            # per-channel bonus
        # projections
        "wr": stack(ks[4], d, d, fan_in=d),
        "wk": stack(ks[5], d, d, fan_in=d),
        "wv": stack(ks[6], d, d, fan_in=d),
        "wg": stack(ks[7], d, d, fan_in=d),
        "wo": stack(ks[8], d, d, fan_in=d),
        "gn_w": jnp.ones((l, d), dt), "gn_b": jnp.zeros((l, d), dt),
        # channel-mix
        "cm_mu_k": jnp.full((l, d), 0.5, dt),
        "cm_mu_r": jnp.full((l, d), 0.5, dt),
        "cm_wk": stack(ks[9], d, f, fan_in=d),
        "cm_wv": stack(ks[10], f, d, fan_in=f),
        "cm_wr": stack(ks[11], d, d, fan_in=d),
    }
    return {
        "embed": cm.embed_init(ks[12], cfg.vocab_size, d, dt),
        "ln0_w": jnp.ones((d,), dt), "ln0_b": jnp.zeros((d,), dt),
        "final_ln_w": jnp.ones((d,), dt), "final_ln_b": jnp.zeros((d,), dt),
        "head": cm.dense_init(ks[13], d, cfg.vocab_size, dt),
        "layers": layers,
    }


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# --------------------------------------------------------------------------- #
# WKV recurrence
# --------------------------------------------------------------------------- #
def wkv_scan(r, k, v, w, u):
    """Sequential WKV. r/k/v/w: (B,S,H,K); u: (H,K). Returns (y, final_state).

    y_t = r_t . (S_t + u * k_t v_t^T);  S_{t+1} = diag(w_t) S_t + k_t v_t^T
    """
    b, s, h, kd = r.shape
    state0 = jnp.zeros((b, h, kd, kd), jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp                     # (B,H,K)
        kv = k_t[..., :, None] * v_t[..., None, :]   # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[None, :, :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    state, ys = cm.chunked_scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), state             # (B,S,H,V), (B,H,K,V)


def wkv_step(r, k, v, w, u, state):
    """Single-token WKV. r/k/v/w: (B,H,K); state: (B,H,K,V) f32."""
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", r, state + u[None, :, :, None] * kv)
    state = w[..., :, None] * state + kv
    return y, state


# --------------------------------------------------------------------------- #
# time-mix / channel-mix
# --------------------------------------------------------------------------- #
def _token_shift(x, prev):
    """prev: (B,1,D) last token of previous chunk. Returns shifted x."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(x, dx, lp):
    """Data-dependent 5-way lerp (w,k,v,r,g inputs). Returns 5 mixed tensors."""
    b, s, d = x.shape
    ml = lp["tm_w1"].shape[-1] // 5
    xxx = x + dx * lp["mu_x"]
    ws = jnp.tanh(xxx @ lp["tm_w1"]).reshape(b, s, 5, ml)
    offs = jnp.einsum("bsim,imd->bsid", ws, lp["tm_w2"])      # (B,S,5,D)
    mix = lp["mu"][None, None] + offs                          # (B,S,5,D)
    return tuple(x + dx * mix[:, :, i] for i in range(5))


def time_mix(x, lp, cfg: ModelConfig, shift_prev, wkv_state=None):
    """Full-sequence time-mix. Returns (out, new_shift, new_wkv_state)."""
    b, s, d = x.shape
    h, kd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    prev = _token_shift(x, shift_prev)
    dx = prev - x
    xw, xk, xv, xr, xg = _ddlerp(x, dx, lp)

    r = (xr @ lp["wr"]).reshape(b, s, h, kd)
    k = (xk @ lp["wk"]).reshape(b, s, h, kd)
    v = (xv @ lp["wv"]).reshape(b, s, h, kd)
    g = jax.nn.silu(xg @ lp["wg"])

    decay = lp["decay_base"] + jnp.tanh(xw.astype(jnp.float32) @ lp["dw1"].astype(jnp.float32)) @ lp["dw2"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay)).reshape(b, s, h, kd)
    u = lp["u"].reshape(h, kd)

    if wkv_state is None:
        wkv_state = jnp.zeros((b, h, kd, kd), jnp.float32)
    # fold the carried state in by treating it as S_0 of the scan
    y, new_state = _wkv_with_state(r, k, v, w, u, wkv_state)
    y = y.reshape(b, s, d).astype(x.dtype)
    y = cm.groupnorm_heads(y, lp["gn_w"], lp["gn_b"], h) * g
    return y @ lp["wo"], x[:, -1:], new_state


def _wkv_with_state(r, k, v, w, u, state0):
    def step(state, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[None, :, :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    state, ys = cm.chunked_scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), state


def channel_mix(x, lp, shift_prev):
    prev = _token_shift(x, shift_prev)
    dx = prev - x
    xk = x + dx * lp["cm_mu_k"]
    xr = x + dx * lp["cm_mu_r"]
    r = jax.nn.sigmoid(xr @ lp["cm_wr"])
    k = jnp.square(jax.nn.relu(xk @ lp["cm_wk"]))
    return r * (k @ lp["cm_wv"]), x[:, -1:]


def _block(x, lp, cfg: ModelConfig, tm_shift=None, cm_shift=None, wkv_state=None):
    x = cm.hint(x, "act_bsd")
    b = x.shape[0]
    d = x.shape[-1]
    if tm_shift is None:
        tm_shift = jnp.zeros((b, 1, d), x.dtype)
    if cm_shift is None:
        cm_shift = jnp.zeros((b, 1, d), x.dtype)
    h = cm.layernorm(x, lp["ln1_w"], lp["ln1_b"])
    y, new_tm, new_state = time_mix(h, lp, cfg, tm_shift, wkv_state)
    x = x + y
    h = cm.layernorm(x, lp["ln2_w"], lp["ln2_b"])
    y, new_cm = channel_mix(h, lp, cm_shift)
    return x + y, new_tm, new_cm, new_state


# --------------------------------------------------------------------------- #
# training / serving
# --------------------------------------------------------------------------- #
def loss_fn(params, batch, cfg: ModelConfig):
    tokens, labels = batch["tokens"], batch["labels"]
    x = params["embed"][tokens]
    x = cm.layernorm(x, params["ln0_w"], params["ln0_b"])

    block = jax.checkpoint(functools.partial(_block, cfg=cfg))

    def body(carry, lp):
        x, _, _, _ = block(carry, lp)
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = cm.layernorm(x, params["final_ln_w"], params["final_ln_b"])
    logits = x @ params["head"]
    loss = cm.cross_entropy(logits, labels)
    return loss, {"loss": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0):
    """O(1)-in-sequence cache; max_len ignored (kept for API parity)."""
    l, d = cfg.n_layers, cfg.d_model
    h, kd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    dt = jnp.dtype(cfg.dtype)
    return {
        "wkv": jnp.zeros((l, batch, h, kd, kd), jnp.float32),
        "tm_shift": jnp.zeros((l, batch, 1, d), dt),
        "cm_shift": jnp.zeros((l, batch, 1, d), dt),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg: ModelConfig):
    b, s = tokens.shape
    x = params["embed"][tokens]
    x = cm.layernorm(x, params["ln0_w"], params["ln0_b"])

    def body(carry, lp):
        x = carry
        x, tm, cmix, state = _block(x, lp, cfg)
        return x, (tm, cmix, state)

    x, (tms, cms, states) = jax.lax.scan(body, x, params["layers"])
    x = cm.layernorm(x, params["final_ln_w"], params["final_ln_b"])
    logits = x[:, -1:] @ params["head"]
    cache = {"wkv": states, "tm_shift": tms, "cm_shift": cms,
             "len": jnp.asarray(s, jnp.int32)}
    return cache, logits


def decode_step(params, cache, tokens, cfg: ModelConfig):
    b = tokens.shape[0]
    x = params["embed"][tokens]
    x = cm.layernorm(x, params["ln0_w"], params["ln0_b"])

    def body(carry, layer_in):
        x = carry
        lp, tm_shift, cm_shift, state = layer_in
        x, new_tm, new_cm, new_state = _block(x, lp, cfg, tm_shift, cm_shift, state)
        return x, (new_tm, new_cm, new_state)

    x, (tms, cms, states) = jax.lax.scan(
        body, x, (params["layers"], cache["tm_shift"], cache["cm_shift"], cache["wkv"]))
    x = cm.layernorm(x, params["final_ln_w"], params["final_ln_b"])
    logits = x @ params["head"]
    new_cache = {"wkv": states, "tm_shift": tms, "cm_shift": cms,
                 "len": cache["len"] + 1}
    return new_cache, logits

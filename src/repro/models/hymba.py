"""Hymba (arXiv:2411.13676): hybrid-head LM — parallel attention + Mamba
(SSM) heads in every layer.

Each layer runs a GQA attention branch and a Mamba selective-scan branch on
the same normed input; branch outputs are RMS-normalized, averaged with
learned per-branch scales, and added to the residual, followed by a SwiGLU
MLP. Most layers use sliding-window attention (``cfg.window``); layers in
``cfg.global_layers`` use full attention — so decode state is
O(window + ssm_state) except for the few global layers, which is why
hymba-1.5b qualifies for ``long_500k``.

Layers are NOT weight-stacked (mixed window/global cache shapes); a Python
loop over 32 layers keeps the HLO acceptable.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm


# --------------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------------- #
def _init_layer(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    di, n = cfg.d_inner, cfg.ssm_state
    dt_rank = max(1, math.ceil(d / 16))
    ks = cm.split_keys(key, 12)
    p = {
        "attn_norm": jnp.ones((d,), dt),
        # attention branch
        "wq": cm.dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": cm.dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": cm.dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": cm.dense_init(ks[3], cfg.n_heads * hd, d, dt),
        "attn_out_norm": jnp.ones((d,), dt),
        # mamba branch
        "in_proj": cm.dense_init(ks[4], d, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[5], (cfg.conv_kernel, di), jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": cm.dense_init(ks[6], di, dt_rank + 2 * n, dt),
        "dt_proj": cm.dense_init(ks[7], dt_rank, di, dt),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "ssm_out_proj": cm.dense_init(ks[8], di, d, dt),
        "ssm_out_norm": jnp.ones((d,), dt),
        # fusion + MLP
        "beta_attn": jnp.ones((), jnp.float32),
        "beta_ssm": jnp.ones((), jnp.float32),
        "mlp_norm": jnp.ones((d,), dt),
        "w_gate": cm.dense_init(ks[9], d, cfg.d_ff, dt),
        "w_up": cm.dense_init(ks[10], d, cfg.d_ff, dt),
        "w_down": cm.dense_init(ks[11], cfg.d_ff, d, dt),
    }
    return p


def init_params(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    keys = cm.split_keys(key, cfg.n_layers + 2)
    return {
        "embed": cm.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "layers": [_init_layer(keys[i + 1], cfg) for i in range(cfg.n_layers)],
    }


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# --------------------------------------------------------------------------- #
# mamba branch
# --------------------------------------------------------------------------- #
def _causal_conv(x, w, b):
    """Depthwise causal 1D conv. x: (B,S,I); w: (K,I)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i:i + x.shape[1]] * w[i]
    return out + b


def selective_scan(u, dt, a, b_t, c_t, d_skip, h0=None):
    """u/dt: (B,S,I); a: (I,N); b_t/c_t: (B,S,N). Returns (y, h_final)."""
    bsz, s, di = u.shape
    n = a.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)

    def step(h, inp):
        u_t, dt_t, bt, ct = inp                               # (B,I),(B,I),(B,N),(B,N)
        da = jnp.exp(dt_t[..., None] * a)                     # (B,I,N)
        dbu = dt_t[..., None] * bt[:, None, :] * u_t[..., None]
        h = da * h + dbu
        y = jnp.einsum("bin,bn->bi", h, ct)
        return h, y

    xs = (
        jnp.moveaxis(u.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b_t.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c_t.astype(jnp.float32), 1, 0),
    )
    h, ys = cm.chunked_scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + u.astype(jnp.float32) * d_skip
    return y, h


def mamba_branch(x, lp, cfg: ModelConfig, conv_state=None, ssm_state=None):
    """Full-sequence Mamba. Returns (out, new_conv_state, new_ssm_state)."""
    b, s, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    dt_rank = lp["dt_proj"].shape[0]
    xz = x @ lp["in_proj"]
    u, z = xz[..., :di], xz[..., di:]

    if conv_state is not None:  # prepend carried (K-1) inputs
        u_ext = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
        conv_out = _causal_conv(u_ext, lp["conv_w"], lp["conv_b"])[:, conv_state.shape[1]:]
    else:
        conv_out = _causal_conv(u, lp["conv_w"], lp["conv_b"])
    new_conv_state = (jnp.concatenate([conv_state, u], axis=1)[:, -(cfg.conv_kernel - 1):]
                      if conv_state is not None else u[:, -(cfg.conv_kernel - 1):])
    u = jax.nn.silu(conv_out)

    proj = u @ lp["x_proj"]
    dt_in, b_t, c_t = (proj[..., :dt_rank], proj[..., dt_rank:dt_rank + n],
                       proj[..., dt_rank + n:])
    dt = jax.nn.softplus(dt_in @ lp["dt_proj"] + lp["dt_bias"])
    a = -jnp.exp(lp["a_log"])
    y, new_ssm = selective_scan(u, dt, a, b_t, c_t, lp["d_skip"], ssm_state)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ lp["ssm_out_proj"]
    return y, new_conv_state, new_ssm


def mamba_step(x, lp, cfg: ModelConfig, conv_state, ssm_state):
    """Single-token Mamba. x: (B,1,D); conv_state: (B,K-1,I); ssm: (B,I,N)."""
    b = x.shape[0]
    di, n = cfg.d_inner, cfg.ssm_state
    dt_rank = lp["dt_proj"].shape[0]
    xz = x @ lp["in_proj"]
    u, z = xz[..., :di], xz[..., di:]

    window = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)  # (B,K,I)
    conv_out = jnp.einsum("bki,ki->bi", window, lp["conv_w"]) + lp["conv_b"]
    new_conv_state = window[:, 1:]
    u1 = jax.nn.silu(conv_out)[:, None, :]                              # (B,1,I)

    proj = u1 @ lp["x_proj"]
    dt_in, b_t, c_t = (proj[..., :dt_rank], proj[..., dt_rank:dt_rank + n],
                       proj[..., dt_rank + n:])
    dt = jax.nn.softplus(dt_in @ lp["dt_proj"] + lp["dt_bias"])
    a = -jnp.exp(lp["a_log"])
    da = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * a)
    dbu = (dt[:, 0, :, None] * b_t[:, 0, None, :] * u1[:, 0, :, None]).astype(jnp.float32)
    h = da * ssm_state + dbu
    y = jnp.einsum("bin,bn->bi", h, c_t[:, 0].astype(jnp.float32))
    y = y + u1[:, 0].astype(jnp.float32) * lp["d_skip"]
    y = (y[:, None, :].astype(x.dtype) * jax.nn.silu(z)) @ lp["ssm_out_proj"]
    return y, new_conv_state, h


# --------------------------------------------------------------------------- #
# layer
# --------------------------------------------------------------------------- #
def _attn_qkv(h, lp, cfg: ModelConfig):
    b, s, _ = h.shape
    hd = cfg.resolved_head_dim
    q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


def _fuse(attn_out, ssm_out, lp, cfg: ModelConfig):
    dt = attn_out.dtype  # f32 betas must not promote the residual stream
    a = cm.rmsnorm(attn_out, lp["attn_out_norm"], cfg.norm_eps) * \
        lp["beta_attn"].astype(dt)
    m = cm.rmsnorm(ssm_out, lp["ssm_out_norm"], cfg.norm_eps) * \
        lp["beta_ssm"].astype(dt)
    return (0.5 * (a + m)).astype(dt)


def _layer_full(x, lp, cfg: ModelConfig, positions, is_global: bool,
                q_block: int = 1024):
    """Full-sequence hybrid layer (training path)."""
    x = cm.hint(x, "act_bsd")
    b, s, _ = x.shape
    h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _attn_qkv(h, lp, cfg)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    window = 0 if is_global else cfg.window
    attn = cm.attention(q, k, v, causal=True, window=window, q_block=q_block)
    attn_out = attn.reshape(b, s, -1) @ lp["wo"]
    ssm_out, _, _ = mamba_branch(h, lp, cfg)
    x = x + _fuse(attn_out, ssm_out, lp, cfg)
    h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    return x + cm.glu_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)


def loss_fn(params, batch, cfg: ModelConfig):
    tokens, labels = batch["tokens"], batch["labels"]
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])
    for i, lp in enumerate(params["layers"]):
        layer = jax.checkpoint(
            lambda x, lp, g=(i in cfg.global_layers): _layer_full(
                x, lp, cfg, positions, g))
        x = layer(x, lp)
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x, params["embed"])
    loss = cm.cross_entropy(logits, labels)
    return loss, {"loss": loss}


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Window KV caches for local layers, full caches for global layers,
    plus per-layer conv/ssm state."""
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    cache: dict[str, object] = {"len": jnp.zeros((), jnp.int32), "layers": []}
    for i in range(cfg.n_layers):
        size = max_len if i in cfg.global_layers else min(cfg.window, max_len)
        cache["layers"].append({
            "k": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dt),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dt),
            "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        })
    return cache


def prefill(params, tokens, cfg: ModelConfig, q_block: int = 1024):
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s)
    layers_cache = []
    for i, lp in enumerate(params["layers"]):
        is_global = i in cfg.global_layers
        h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _attn_qkv(h, lp, cfg)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        window = 0 if is_global else cfg.window
        attn = cm.attention(q, k, v, causal=True, window=window, q_block=q_block)
        attn_out = attn.reshape(b, s, -1) @ lp["wo"]
        ssm_out, conv_state, ssm_state = mamba_branch(h, lp, cfg)
        x = x + _fuse(attn_out, ssm_out, lp, cfg)
        hm = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + cm.glu_mlp(hm, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
        keep = s if is_global else min(cfg.window, s)
        layers_cache.append({
            "k": k[:, -keep:], "v": v[:, -keep:],
            "conv": conv_state, "ssm": ssm_state,
        })
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x[:, -1:], params["embed"])
    return {"len": jnp.asarray(s, jnp.int32), "layers": layers_cache}, logits


def decode_step(params, cache, tokens, cfg: ModelConfig):
    b = tokens.shape[0]
    x = params["embed"][tokens]
    pos = cache["len"]
    positions = jnp.full((b, 1), pos, jnp.int32)
    new_layers = []
    for i, lp in enumerate(params["layers"]):
        is_global = i in cfg.global_layers
        lc = cache["layers"][i]
        h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _attn_qkv(h, lp, cfg)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        size = lc["k"].shape[1]
        slot = pos % size if not is_global else pos
        k_cache = jax.lax.dynamic_update_slice(lc["k"], k, (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(lc["v"], v, (0, slot, 0, 0))
        attn = cm.decode_attention(q, k_cache, v_cache, pos + 1,
                                   window=0 if is_global else size)
        attn_out = attn.reshape(b, 1, -1) @ lp["wo"]
        ssm_out, conv_state, ssm_state = mamba_step(h, lp, cfg, lc["conv"], lc["ssm"])
        x = x + _fuse(attn_out, ssm_out, lp, cfg)
        hm = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + cm.glu_mlp(hm, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
        new_layers.append({"k": k_cache, "v": v_cache,
                           "conv": conv_state, "ssm": ssm_state})
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x, params["embed"])
    return {"len": cache["len"] + 1, "layers": new_layers}, logits

"""Llama-3.2-Vision-style VLM backbone (cross-attention image layers).

The vision tower is a STUB per the assignment: the model consumes precomputed
patch embeddings (B, n_vision_tokens, d_model). The 100-layer stack is
organized as ``n_groups = n_layers // cross_every`` groups, each = an inner
scan over (cross_every - 1) self-attention blocks followed by one gated
cross-attention block (tanh-gated, llama-3.2 style) — a two-level scan keeps
the HLO compact at 100 layers.

Batch for training: {"vision": (B,Nv,D), "tokens": (B,S), "labels": (B,S)}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import dense as _dense


def _groups(cfg: ModelConfig) -> tuple[int, int]:
    assert cfg.n_layers % cfg.cross_every == 0
    n_groups = cfg.n_layers // cfg.cross_every
    per_group = cfg.cross_every - 1          # self layers per group
    return n_groups, per_group


# --------------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------------- #
def _self_stack(key, cfg: ModelConfig, n: int):
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    d, f = cfg.d_model, cfg.d_ff
    ks = cm.split_keys(key, 7)

    def stack(k, d_in, d_out):
        scale = 1.0 / jnp.sqrt(d_in)
        return (jax.random.normal(k, (n, d_in, d_out), jnp.float32) * scale).astype(dt)

    return {
        "attn_norm": jnp.ones((n, d), dt),
        "wq": stack(ks[0], d, cfg.n_heads * hd),
        "wk": stack(ks[1], d, cfg.n_kv_heads * hd),
        "wv": stack(ks[2], d, cfg.n_kv_heads * hd),
        "wo": stack(ks[3], cfg.n_heads * hd, d),
        "mlp_norm": jnp.ones((n, d), dt),
        "w_gate": stack(ks[4], d, f),
        "w_up": stack(ks[5], d, f),
        "w_down": stack(ks[6], f, d),
    }


def init_params(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    n_groups, per_group = _groups(cfg)
    d = cfg.d_model
    ks = cm.split_keys(key, 4)

    self_flat = _self_stack(ks[0], cfg, n_groups * per_group)
    self_layers = jax.tree.map(
        lambda a: a.reshape(n_groups, per_group, *a.shape[1:]), self_flat)

    cross = _self_stack(ks[1], cfg, n_groups)  # reuse shapes; add gates
    cross["gate_attn"] = jnp.zeros((n_groups,), jnp.float32)
    cross["gate_mlp"] = jnp.zeros((n_groups,), jnp.float32)

    return {
        "embed": cm.embed_init(ks[2], cfg.vocab_size, d, dt),
        "out_head": cm.dense_init(ks[3], d, cfg.vocab_size, dt),
        "final_norm": jnp.ones((d,), dt),
        "self_layers": self_layers,   # (G, P, ...)
        "cross_layers": cross,        # (G, ...)
    }


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# --------------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------------- #
def _cross_block(x, lp, vision_kv, cfg: ModelConfig, q_block: int = 1024):
    """Gated cross-attention block. vision_kv: (k, v) each (B,Nv,KV,hd)."""
    x = cm.hint(x, "act_bsd")
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, hd)
    k, v = vision_kv
    attn = cm.attention(q, k, v, causal=False, q_block=q_block)
    gate_a = jnp.tanh(lp["gate_attn"]).astype(x.dtype)
    x = x + gate_a * (attn.reshape(b, s, -1) @ lp["wo"])
    h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    mlp = cm.glu_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
    gate_m = jnp.tanh(lp["gate_mlp"]).astype(x.dtype)
    return x + gate_m * mlp


def _vision_kv(vision, lp, cfg: ModelConfig):
    """Project vision embeddings with this cross layer's wk/wv."""
    b, nv, _ = vision.shape
    hd = cfg.resolved_head_dim
    k = (vision @ lp["wk"]).reshape(b, nv, cfg.n_kv_heads, hd)
    v = (vision @ lp["wv"]).reshape(b, nv, cfg.n_kv_heads, hd)
    return k, v


def loss_fn(params, batch, cfg: ModelConfig):
    vision, tokens, labels = batch["vision"], batch["tokens"], batch["labels"]
    vision = vision.astype(jnp.dtype(cfg.dtype))
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s)

    self_block = jax.checkpoint(functools.partial(
        _dense._block, cfg=cfg, positions=positions))
    cross_block = jax.checkpoint(functools.partial(_cross_block, cfg=cfg))

    def group_body(carry, group_params):
        x = carry
        self_lp, cross_lp = group_params

        def self_body(c, lp):
            return self_block(c, lp), None

        x, _ = jax.lax.scan(self_body, x, self_lp)
        kv = _vision_kv(vision, cross_lp, cfg)
        x = cross_block(x, cross_lp, kv)
        return x, None

    x, _ = jax.lax.scan(group_body, x, (params["self_layers"], params["cross_layers"]))
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["out_head"]
    loss = cm.cross_entropy(logits, labels)
    return loss, {"loss": loss}


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    n_groups, per_group = _groups(cfg)
    return {
        "k": jnp.zeros((n_groups, per_group, batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((n_groups, per_group, batch, max_len, cfg.n_kv_heads, hd), dt),
        "xk": jnp.zeros((n_groups, batch, cfg.n_vision_tokens, cfg.n_kv_heads, hd), dt),
        "xv": jnp.zeros((n_groups, batch, cfg.n_vision_tokens, cfg.n_kv_heads, hd), dt),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg: ModelConfig, vision=None, q_block: int = 1024):
    b, s = tokens.shape
    if vision is None:
        vision = jnp.zeros((b, cfg.n_vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    vision = vision.astype(jnp.dtype(cfg.dtype))
    x = params["embed"][tokens]
    positions = jnp.arange(s)
    hd = cfg.resolved_head_dim

    def group_body(carry, group_params):
        x = carry
        self_lp, cross_lp = group_params

        def self_body(c, lp):
            xx = c
            h = cm.rmsnorm(xx, lp["attn_norm"], cfg.norm_eps)
            q, k, v = _dense._qkv(h, lp, cfg)
            q = cm.apply_rope(q, positions, cfg.rope_theta)
            k = cm.apply_rope(k, positions, cfg.rope_theta)
            attn = cm.attention(q, k, v, causal=True, q_block=q_block)
            xx = xx + attn.reshape(b, s, -1) @ lp["wo"]
            h = cm.rmsnorm(xx, lp["mlp_norm"], cfg.norm_eps)
            xx = xx + cm.glu_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
            return xx, (k, v)

        x, (ks, vs) = jax.lax.scan(self_body, x, self_lp)
        xk, xv = _vision_kv(vision, cross_lp, cfg)
        x = _cross_block(x, cross_lp, (xk, xv), cfg, q_block)
        return x, (ks, vs, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(
        group_body, x, (params["self_layers"], params["cross_layers"]))
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1:] @ params["out_head"])
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs,
             "len": jnp.asarray(s, jnp.int32)}
    return cache, logits


def decode_step(params, cache, tokens, cfg: ModelConfig):
    b = tokens.shape[0]
    pos = cache["len"]
    positions = jnp.full((b, 1), pos, jnp.int32)
    x = params["embed"][tokens]
    hd = cfg.resolved_head_dim

    def group_body(carry, group_in):
        x = carry
        self_lp, cross_lp, k_caches, v_caches, xk, xv = group_in

        def self_body(c, layer_in):
            xx = c
            lp, k_c, v_c = layer_in
            h = cm.rmsnorm(xx, lp["attn_norm"], cfg.norm_eps)
            q, k, v = _dense._qkv(h, lp, cfg)
            q = cm.apply_rope(q, positions, cfg.rope_theta)
            k = cm.apply_rope(k, positions, cfg.rope_theta)
            k_c = jax.lax.dynamic_update_slice(k_c, k, (0, pos, 0, 0))
            v_c = jax.lax.dynamic_update_slice(v_c, v, (0, pos, 0, 0))
            attn = cm.decode_attention(q, k_c, v_c, pos + 1)
            xx = xx + attn.reshape(b, 1, -1) @ lp["wo"]
            h = cm.rmsnorm(xx, lp["mlp_norm"], cfg.norm_eps)
            xx = xx + cm.glu_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
            return xx, (k_c, v_c)

        x, (ks, vs) = jax.lax.scan(self_body, x, (self_lp, k_caches, v_caches))
        # gated cross block against precomputed vision KV
        h = cm.rmsnorm(x, cross_lp["attn_norm"], cfg.norm_eps)
        q = (h @ cross_lp["wq"]).reshape(b, 1, cfg.n_heads, hd)
        attn = cm.decode_attention(q, xk, xv, xk.shape[1])
        gate_a = jnp.tanh(cross_lp["gate_attn"]).astype(x.dtype)
        x = x + gate_a * (attn.reshape(b, 1, -1) @ cross_lp["wo"])
        h = cm.rmsnorm(x, cross_lp["mlp_norm"], cfg.norm_eps)
        mlp = cm.glu_mlp(h, cross_lp["w_gate"], cross_lp["w_up"],
                         cross_lp["w_down"], cfg.act)
        gate_m = jnp.tanh(cross_lp["gate_mlp"]).astype(x.dtype)
        x = x + gate_m * mlp
        return x, (ks, vs)

    x, (ks, vs) = jax.lax.scan(
        group_body, x,
        (params["self_layers"], params["cross_layers"],
         cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["out_head"]
    new_cache = dict(cache, k=ks, v=vs, len=cache["len"] + 1)
    return new_cache, logits

"""DeepSeek-V3-style model: MLA attention + (shared + routed) MoE + MTP.

MLA (Multi-head Latent Attention, arXiv:2412.19437): queries through a
low-rank bottleneck (q_lora_rank), keys/values through a compressed latent
(kv_lora_rank) plus a shared RoPE key. Training/prefill run the *expanded*
form; decode runs the *absorbed* form, attending directly in latent space so
the KV cache is (kv_lora + rope) wide instead of 2*H*head_dim.

Layer stack: first ``first_k_dense`` layers use a dense GLU FFN (width d_ff),
the rest use 1 shared + n_experts routed top-k MoE (width d_expert).
One MTP module (depth 1) predicts token t+2 (dense-FFN block — see DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import DistContext, LOCAL
from repro.models import common as cm
from repro.models import moe as moe_mod

MTP_LOSS_WEIGHT = 0.3


# --------------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------------- #
def _init_mla_attn(key, cfg: ModelConfig, n_layers: int):
    dt = jnp.dtype(cfg.dtype)
    d, h = cfg.d_model, cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = cm.split_keys(key, 5)

    def stack(k, d_in, d_out):
        scale = 1.0 / jnp.sqrt(d_in)
        return (jax.random.normal(k, (n_layers, d_in, d_out), jnp.float32) * scale).astype(dt)

    return {
        "attn_norm": jnp.ones((n_layers, d), dt),
        "w_dq": stack(ks[0], d, ql),
        "q_norm": jnp.ones((n_layers, ql), dt),
        "w_uq": stack(ks[1], ql, h * (nope + rope)),
        "w_dkv": stack(ks[2], d, kvl + rope),
        "kv_norm": jnp.ones((n_layers, kvl), dt),
        "w_ukv": stack(ks[3], kvl, h * (nope + vd)),
        "wo": stack(ks[4], h * vd, d),
    }


def init_params(key, cfg: ModelConfig, ep_size: int = 1):
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    n_dense, n_moe = cfg.first_k_dense, cfg.n_layers - cfg.first_k_dense
    keys = cm.split_keys(key, 8)

    def glu_stack(k, n_layers, width):
        ks = cm.split_keys(k, 3)
        scale_in, scale_out = 1.0 / jnp.sqrt(d), 1.0 / jnp.sqrt(width)
        return {
            "mlp_norm": jnp.ones((n_layers, d), dt),
            "w_gate": (jax.random.normal(ks[0], (n_layers, d, width), jnp.float32) * scale_in).astype(dt),
            "w_up": (jax.random.normal(ks[1], (n_layers, d, width), jnp.float32) * scale_in).astype(dt),
            "w_down": (jax.random.normal(ks[2], (n_layers, width, d), jnp.float32) * scale_out).astype(dt),
        }

    dense_layers = {**_init_mla_attn(keys[0], cfg, n_dense),
                    **glu_stack(keys[1], n_dense, cfg.d_ff)}
    moe_layers = {**_init_mla_attn(keys[2], cfg, n_moe),
                  "mlp_norm": jnp.ones((n_moe, d), dt),
                  **moe_mod.init_moe_ffn(keys[3], cfg, ep_size, n_layers=n_moe)}

    params = {
        "embed": cm.embed_init(keys[4], cfg.vocab_size, d, dt),
        "final_norm": jnp.ones((d,), dt),
        "dense_layers": dense_layers,
        "moe_layers": moe_layers,
    }
    if cfg.mtp_depth > 0:
        mtp_attn = _init_mla_attn(keys[5], cfg, 1)
        mtp = {**mtp_attn, **glu_stack(keys[6], 1, cfg.d_ff)}
        params["mtp"] = {
            "norm_h": jnp.ones((d,), dt),
            "norm_e": jnp.ones((d,), dt),
            "proj": cm.dense_init(keys[7], 2 * d, d, dt),
            "layer": jax.tree.map(lambda a: a[0], mtp),
        }
    return params


def abstract_params(cfg: ModelConfig, ep_size: int = 1):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg, ep_size))


# --------------------------------------------------------------------------- #
# MLA attention — expanded form (train / prefill)
# --------------------------------------------------------------------------- #
def mla_attention(x, lp, cfg: ModelConfig, positions, q_block: int = 1024):
    """Returns (attn_out (B,S,D), (ckv, k_rope) latents for the cache)."""
    b, s, d = x.shape
    h, nope, rope, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank

    cq = cm.rmsnorm(x @ lp["w_dq"], lp["q_norm"], cfg.norm_eps)
    q = (cq @ lp["w_uq"]).reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = x @ lp["w_dkv"]
    ckv = cm.rmsnorm(ckv_full[..., :kvl], lp["kv_norm"], cfg.norm_eps)
    k_rope = cm.apply_rope(ckv_full[..., kvl:].reshape(b, s, 1, rope),
                           positions, cfg.rope_theta)

    kv = (ckv @ lp["w_ukv"]).reshape(b, s, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    attn = cm.attention(q, k, v, causal=True, q_block=q_block)
    out = attn.reshape(b, s, h * vd) @ lp["wo"]
    return out, (ckv, k_rope[:, :, 0, :])


# --------------------------------------------------------------------------- #
# MLA attention — absorbed form (decode against latent cache)
# --------------------------------------------------------------------------- #
def mla_decode_attention(x, lp, cfg: ModelConfig, ckv_cache, krope_cache, pos):
    """x: (B,1,D); caches: (B,S,kvl) / (B,S,rope). Returns (out, new latents)."""
    b = x.shape[0]
    h, nope, rope, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    positions = jnp.full((b, 1), pos, jnp.int32)

    cq = cm.rmsnorm(x @ lp["w_dq"], lp["q_norm"], cfg.norm_eps)
    q = (cq @ lp["w_uq"]).reshape(b, 1, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = x @ lp["w_dkv"]
    ckv_new = cm.rmsnorm(ckv_full[..., :kvl], lp["kv_norm"], cfg.norm_eps)
    krope_new = cm.apply_rope(ckv_full[..., kvl:].reshape(b, 1, 1, rope),
                              positions, cfg.rope_theta)[:, :, 0, :]

    ckv_cache = jax.lax.dynamic_update_slice(ckv_cache, ckv_new, (0, pos, 0))
    krope_cache = jax.lax.dynamic_update_slice(krope_cache, krope_new, (0, pos, 0))

    w_ukv = lp["w_ukv"].reshape(kvl, h, nope + vd)
    w_uk, w_uv = w_ukv[..., :nope], w_ukv[..., nope:]
    # absorb W_UK into the query: q_abs (B,1,H,kvl)
    q_abs = jnp.einsum("bqhn,khn->bqhk", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))

    scale = 1.0 / jnp.sqrt(nope + rope)
    scores = (
        jnp.einsum("bqhk,bsk->bhqs", q_abs, ckv_cache.astype(jnp.float32))
        + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                     krope_cache.astype(jnp.float32))
    ) * scale
    valid = jnp.arange(ckv_cache.shape[1]) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, cm.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)

    ctx = jnp.einsum("bhqs,bsk->bqhk", probs, ckv_cache.astype(jnp.float32))
    v_out = jnp.einsum("bqhk,khv->bqhv", ctx, w_uv.astype(jnp.float32))
    out = v_out.reshape(b, 1, h * vd).astype(x.dtype) @ lp["wo"]
    return out, ckv_cache, krope_cache


# --------------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------------- #
def _dense_block(x, lp, cfg: ModelConfig, positions):
    x = cm.hint(x, "act_bsd")
    h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    attn, _ = mla_attention(h, lp, cfg, positions)
    x = x + attn
    h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    return x + cm.glu_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)


def _moe_block(x, lp, cfg: ModelConfig, positions, dist: DistContext):
    x = cm.hint(x, "act_bsd")
    h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    attn, _ = mla_attention(h, lp, cfg, positions)
    x = x + attn
    h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    y, aux = moe_mod.moe_ffn(h, lp, cfg, dist)
    return x + y, aux


# --------------------------------------------------------------------------- #
# training loss (+ MTP)
# --------------------------------------------------------------------------- #
def loss_fn(params, batch, cfg: ModelConfig, dist: DistContext = LOCAL):
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s)

    dense_block = jax.checkpoint(functools.partial(
        _dense_block, cfg=cfg, positions=positions))
    moe_block = jax.checkpoint(functools.partial(
        _moe_block, cfg=cfg, positions=positions, dist=dist))

    def dense_body(carry, lp):
        return dense_block(carry, lp), None

    x, _ = jax.lax.scan(dense_body, x, params["dense_layers"])

    def moe_body(carry, lp):
        x, aux_sum = carry
        x, aux = moe_block(x, lp)
        return (x, aux_sum + aux), None

    (x, aux_sum), _ = jax.lax.scan(moe_body, (x, 0.0), params["moe_layers"])

    hidden = x
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x, params["embed"])
    ce = cm.cross_entropy(logits, labels)
    aux = cfg.router_aux_coef * aux_sum / max(cfg.n_layers - cfg.first_k_dense, 1)
    loss = ce + aux
    metrics = {"ce": ce, "aux": aux}

    if cfg.mtp_depth > 0:
        mtp = params["mtp"]
        # token t+1 embedding at position t (shift left, pad with last)
        emb_next = params["embed"][jnp.roll(tokens, -1, axis=1)]
        mtp_in = jnp.concatenate(
            [cm.rmsnorm(hidden, mtp["norm_h"], cfg.norm_eps),
             cm.rmsnorm(emb_next, mtp["norm_e"], cfg.norm_eps)], axis=-1
        ) @ mtp["proj"]
        h_mtp = _dense_block(mtp_in, mtp["layer"], cfg, positions)
        h_mtp = cm.rmsnorm(h_mtp, params["final_norm"], cfg.norm_eps)
        logits_mtp = cm.lm_logits(h_mtp, params["embed"])
        labels_mtp = jnp.roll(labels, -1, axis=1)
        mask = jnp.ones((b, s), bool).at[:, -2:].set(False)
        ce_mtp = cm.cross_entropy(logits_mtp, labels_mtp, mask)
        loss = loss + MTP_LOSS_WEIGHT * ce_mtp
        metrics["ce_mtp"] = ce_mtp

    metrics["loss"] = loss
    return loss, metrics


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = jnp.dtype(cfg.dtype)
    l = cfg.n_layers
    return {
        "ckv": jnp.zeros((l, batch, max_len, cfg.kv_lora_rank), dt),
        "krope": jnp.zeros((l, batch, max_len, cfg.qk_rope_dim), dt),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg: ModelConfig, dist: DistContext = LOCAL,
            q_block: int = 1024):
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s)

    def dense_body(carry, lp):
        x = carry
        h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        attn, (ckv, krope) = mla_attention(h, lp, cfg, positions, q_block)
        x = x + attn
        h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + cm.glu_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
        return x, (ckv, krope)

    def moe_body(carry, lp):
        x = carry
        h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        attn, (ckv, krope) = mla_attention(h, lp, cfg, positions, q_block)
        x = x + attn
        h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        y, _ = moe_mod.moe_ffn(h, lp, cfg, dist)
        return x + y, (ckv, krope)

    x, (ckv_d, krope_d) = jax.lax.scan(dense_body, x, params["dense_layers"])
    x, (ckv_m, krope_m) = jax.lax.scan(moe_body, x, params["moe_layers"])
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x[:, -1:], params["embed"])
    cache = {
        "ckv": jnp.concatenate([ckv_d, ckv_m], axis=0),
        "krope": jnp.concatenate([krope_d, krope_m], axis=0),
        "len": jnp.asarray(s, jnp.int32),
    }
    return cache, logits


def decode_step(params, cache, tokens, cfg: ModelConfig, dist: DistContext = LOCAL):
    b = tokens.shape[0]
    x = params["embed"][tokens]
    pos = cache["len"]
    nd = cfg.first_k_dense

    def dense_body(carry, layer_in):
        x = carry
        lp, ckv_c, krope_c = layer_in
        h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        attn, ckv_c, krope_c = mla_decode_attention(h, lp, cfg, ckv_c, krope_c, pos)
        x = x + attn
        h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + cm.glu_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
        return x, (ckv_c, krope_c)

    def moe_body(carry, layer_in):
        x = carry
        lp, ckv_c, krope_c = layer_in
        h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        attn, ckv_c, krope_c = mla_decode_attention(h, lp, cfg, ckv_c, krope_c, pos)
        x = x + attn
        h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        y, _ = moe_mod.moe_ffn(h, lp, cfg, dist)
        return x + y, (ckv_c, krope_c)

    x, (ckv_d, krope_d) = jax.lax.scan(
        dense_body, x,
        (params["dense_layers"], cache["ckv"][:nd], cache["krope"][:nd]))
    x, (ckv_m, krope_m) = jax.lax.scan(
        moe_body, x,
        (params["moe_layers"], cache["ckv"][nd:], cache["krope"][nd:]))
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x, params["embed"])
    new_cache = {
        "ckv": jnp.concatenate([ckv_d, ckv_m], axis=0),
        "krope": jnp.concatenate([krope_d, krope_m], axis=0),
        "len": cache["len"] + 1,
    }
    return new_cache, logits

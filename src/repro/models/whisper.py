"""Whisper-style encoder-decoder (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings (B, n_frames, d_model). Encoder: bidirectional
MHA + GELU MLP, pre-LN. Decoder: causal self-attention + cross-attention to
encoder states. Positions are sinusoidal on both sides (whisper's decoder
uses a learned table capped at 448; sinusoidal keeps every assigned decode
length valid — noted in DESIGN.md).

Batch for training: {"frames": (B,F,D), "tokens": (B,S), "labels": (B,S)}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common as cm


def sinusoidal(positions, d: int):
    """positions: (S,) or (B,S) -> (..., d) f32."""
    half = d // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# --------------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------------- #
def _attn_stack(key, n_layers: int, d: int, n_heads: int, hd: int, dt):
    ks = cm.split_keys(key, 4)

    def stack(k, d_in, d_out):
        scale = 1.0 / jnp.sqrt(d_in)
        return (jax.random.normal(k, (n_layers, d_in, d_out), jnp.float32) * scale).astype(dt)

    return {
        "wq": stack(ks[0], d, n_heads * hd), "bq": jnp.zeros((n_layers, n_heads * hd), dt),
        "wk": stack(ks[1], d, n_heads * hd),
        "wv": stack(ks[2], d, n_heads * hd), "bv": jnp.zeros((n_layers, n_heads * hd), dt),
        "wo": stack(ks[3], n_heads * hd, d), "bo": jnp.zeros((n_layers, d), dt),
    }


def _mlp_stack(key, n_layers: int, d: int, f: int, dt):
    k1, k2 = jax.random.split(key)
    s_in, s_out = 1.0 / jnp.sqrt(d), 1.0 / jnp.sqrt(f)
    return {
        "w_up": (jax.random.normal(k1, (n_layers, d, f), jnp.float32) * s_in).astype(dt),
        "b_up": jnp.zeros((n_layers, f), dt),
        "w_down": (jax.random.normal(k2, (n_layers, f, d), jnp.float32) * s_out).astype(dt),
        "b_down": jnp.zeros((n_layers, d), dt),
    }


def _ln(n_layers: int, d: int, dt, name: str):
    return {f"{name}_w": jnp.ones((n_layers, d), dt),
            f"{name}_b": jnp.zeros((n_layers, d), dt)}


def init_params(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ne, nd = cfg.n_enc_layers, cfg.n_layers
    ks = cm.split_keys(key, 8)
    enc = {**_ln(ne, d, dt, "ln1"), **_attn_stack(ks[0], ne, d, cfg.n_heads, hd, dt),
           **_ln(ne, d, dt, "ln2"), **_mlp_stack(ks[1], ne, d, cfg.d_ff, dt)}
    dec = {**_ln(nd, d, dt, "ln1"), **_attn_stack(ks[2], nd, d, cfg.n_heads, hd, dt),
           **_ln(nd, d, dt, "ln_x")}
    cross = _attn_stack(ks[3], nd, d, cfg.n_heads, hd, dt)
    dec.update({f"x_{k}": v for k, v in cross.items()})
    dec.update({**_ln(nd, d, dt, "ln2"), **_mlp_stack(ks[4], nd, d, cfg.d_ff, dt)})
    return {
        "embed": cm.embed_init(ks[5], cfg.vocab_size, d, dt),
        "enc_ln_w": jnp.ones((d,), dt), "enc_ln_b": jnp.zeros((d,), dt),
        "dec_ln_w": jnp.ones((d,), dt), "dec_ln_b": jnp.zeros((d,), dt),
        "enc_layers": enc,
        "dec_layers": dec,
    }


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# --------------------------------------------------------------------------- #
# attention helpers (bias MHA, no RoPE)
# --------------------------------------------------------------------------- #
def _heads(x, n_heads: int):
    b, s, dd = x.shape
    return x.reshape(b, s, n_heads, dd // n_heads)


def _mha(x, kv_src, lp, cfg: ModelConfig, prefix: str = "", causal: bool = False,
         q_block: int = 1024):
    h = cfg.n_heads
    q = _heads(x @ lp[prefix + "wq"] + lp[prefix + "bq"], h)
    k = _heads(kv_src @ lp[prefix + "wk"], h)
    v = _heads(kv_src @ lp[prefix + "wv"] + lp[prefix + "bv"], h)
    out = cm.attention(q, k, v, causal=causal, q_block=q_block)
    return out.reshape(x.shape) @ lp[prefix + "wo"] + lp[prefix + "bo"], (k, v)


# --------------------------------------------------------------------------- #
# encoder
# --------------------------------------------------------------------------- #
def encode(params, frames, cfg: ModelConfig):
    """frames: (B, F, D) stub embeddings -> encoder states (B, F, D)."""
    b, f, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + sinusoidal(
        jnp.arange(f), d).astype(jnp.dtype(cfg.dtype))

    def body(x, lp):
        x = cm.hint(x, "act_bsd")
        h = cm.layernorm(x, lp["ln1_w"], lp["ln1_b"])
        attn, _ = _mha(h, h, lp, cfg, causal=False)
        x = x + attn
        h = cm.layernorm(x, lp["ln2_w"], lp["ln2_b"])
        x = x + cm.dense_mlp(h, lp["w_up"], lp["b_up"], lp["w_down"], lp["b_down"])
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return cm.layernorm(x, params["enc_ln_w"], params["enc_ln_b"])


# --------------------------------------------------------------------------- #
# decoder (training)
# --------------------------------------------------------------------------- #
def _dec_block(x, lp, enc_out, cfg: ModelConfig, q_block: int = 1024):
    x = cm.hint(x, "act_bsd")
    h = cm.layernorm(x, lp["ln1_w"], lp["ln1_b"])
    attn, _ = _mha(h, h, lp, cfg, causal=True, q_block=q_block)
    x = x + attn
    h = cm.layernorm(x, lp["ln_x_w"], lp["ln_x_b"])
    attn, _ = _mha(h, enc_out, lp, cfg, prefix="x_", causal=False, q_block=q_block)
    x = x + attn
    h = cm.layernorm(x, lp["ln2_w"], lp["ln2_b"])
    return x + cm.dense_mlp(h, lp["w_up"], lp["b_up"], lp["w_down"], lp["b_down"])


def loss_fn(params, batch, cfg: ModelConfig):
    frames, tokens, labels = batch["frames"], batch["tokens"], batch["labels"]
    enc_out = encode(params, frames, cfg)
    b, s = tokens.shape
    x = params["embed"][tokens] + sinusoidal(
        jnp.arange(s), cfg.d_model).astype(jnp.dtype(cfg.dtype))

    block = jax.checkpoint(functools.partial(_dec_block, enc_out=enc_out, cfg=cfg))

    def body(carry, lp):
        return block(carry, lp), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = cm.layernorm(x, params["dec_ln_w"], params["dec_ln_b"])
    logits = cm.lm_logits(x, params["embed"])
    loss = cm.cross_entropy(logits, labels)
    return loss, {"loss": loss}


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    l = cfg.n_layers
    return {
        "k": jnp.zeros((l, batch, max_len, cfg.n_heads, hd), dt),
        "v": jnp.zeros((l, batch, max_len, cfg.n_heads, hd), dt),
        "xk": jnp.zeros((l, batch, cfg.n_frames, cfg.n_heads, hd), dt),
        "xv": jnp.zeros((l, batch, cfg.n_frames, cfg.n_heads, hd), dt),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg: ModelConfig, frames=None, q_block: int = 1024):
    """tokens: (B,S) decoder prompt; frames: (B,F,D) stub audio embeddings."""
    b, s = tokens.shape
    if frames is None:
        frames = jnp.zeros((b, cfg.n_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    enc_out = encode(params, frames, cfg)
    x = params["embed"][tokens] + sinusoidal(
        jnp.arange(s), cfg.d_model).astype(jnp.dtype(cfg.dtype))

    def body(carry, lp):
        x = carry
        h = cm.layernorm(x, lp["ln1_w"], lp["ln1_b"])
        attn, (k, v) = _mha(h, h, lp, cfg, causal=True, q_block=q_block)
        x = x + attn
        h = cm.layernorm(x, lp["ln_x_w"], lp["ln_x_b"])
        attn, (xk, xv) = _mha(h, enc_out, lp, cfg, prefix="x_", causal=False,
                              q_block=q_block)
        x = x + attn
        h = cm.layernorm(x, lp["ln2_w"], lp["ln2_b"])
        x = x + cm.dense_mlp(h, lp["w_up"], lp["b_up"], lp["w_down"], lp["b_down"])
        return x, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec_layers"])
    x = cm.layernorm(x, params["dec_ln_w"], params["dec_ln_b"])
    logits = cm.lm_logits(x[:, -1:], params["embed"])
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs,
             "len": jnp.asarray(s, jnp.int32)}
    return cache, logits


def decode_step(params, cache, tokens, cfg: ModelConfig):
    b = tokens.shape[0]
    pos = cache["len"]
    x = params["embed"][tokens] + sinusoidal(
        jnp.full((b, 1), pos), cfg.d_model).astype(jnp.dtype(cfg.dtype))
    h_heads = cfg.n_heads

    def body(carry, layer_in):
        x = carry
        lp, k_c, v_c, xk, xv = layer_in
        h = cm.layernorm(x, lp["ln1_w"], lp["ln1_b"])
        q = _heads(h @ lp["wq"] + lp["bq"], h_heads)
        k = _heads(h @ lp["wk"], h_heads)
        v = _heads(h @ lp["wv"] + lp["bv"], h_heads)
        k_c = jax.lax.dynamic_update_slice(k_c, k, (0, pos, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v, (0, pos, 0, 0))
        attn = cm.decode_attention(q, k_c, v_c, pos + 1)
        x = x + attn.reshape(b, 1, -1) @ lp["wo"] + lp["bo"]
        h = cm.layernorm(x, lp["ln_x_w"], lp["ln_x_b"])
        q = _heads(h @ lp["x_wq"] + lp["x_bq"], h_heads)
        attn = cm.decode_attention(q, xk, xv, xk.shape[1])
        x = x + attn.reshape(b, 1, -1) @ lp["x_wo"] + lp["x_bo"]
        h = cm.layernorm(x, lp["ln2_w"], lp["ln2_b"])
        x = x + cm.dense_mlp(h, lp["w_up"], lp["b_up"], lp["w_down"], lp["b_down"])
        return x, (k_c, v_c)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = cm.layernorm(x, params["dec_ln_w"], params["dec_ln_b"])
    logits = cm.lm_logits(x, params["embed"])
    new_cache = dict(cache, k=ks, v=vs, len=cache["len"] + 1)
    return new_cache, logits

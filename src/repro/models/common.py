"""Shared neural building blocks for the model zoo.

Pure-functional JAX; params are nested dicts of arrays. All families use:
RMSNorm (f32 accumulation), RoPE, GQA/MQA attention with blocked softmax
(bounded memory at 32k prefill), SwiGLU/GeGLU MLPs, and a vocab-parallel
cross-entropy that never materializes one-hot labels.

Activation sharding: model code stays mesh-agnostic but calls
``hint(x, kind)`` at layout-critical points; the launcher installs a hook
(``set_shard_hook``) that turns hints into ``with_sharding_constraint``s.
Without a hook, hints are no-ops (CPU smoke tests).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------- #
# activation-sharding hints (installed by launch/distributed code)
# --------------------------------------------------------------------------- #
_SHARD_HOOK: Callable | None = None


def set_shard_hook(fn: Callable | None) -> None:
    """fn(x, kind) -> x with sharding constraint. kinds:
    'act_bsd' (B,S,D), 'act_bshd' (B,S,H,hd), 'kv_bskd' (B,S,KV,hd),
    'logits' (B,S,V)."""
    global _SHARD_HOOK
    _SHARD_HOOK = fn


def hint(x, kind: str):
    if _SHARD_HOOK is None:
        return x
    return _SHARD_HOOK(x, kind)


# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #
def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / np.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #
def rmsnorm(x, weight, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * weight.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, weight, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    normed = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (normed * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def groupnorm_heads(x, weight, bias, n_heads: int, eps: float = 1e-5):
    """GroupNorm over head groups; x: (..., n_heads * head_dim). Used by RWKV."""
    shape = x.shape
    xh = x.reshape(*shape[:-1], n_heads, shape[-1] // n_heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xn = ((xh - mu) * jax.lax.rsqrt(var + eps)).reshape(shape)
    return (xn * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- #
# rotary embeddings
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, hd); positions: (S,) or (B, S). Half-rotation convention."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    if positions.ndim == 1:
        angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]     # (S, hd/2)
        angles = angles[None, :, None, :]                                    # (1, S, 1, hd/2)
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs            # (B, S, hd/2)
        angles = angles[:, :, None, :]                                       # (B, S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #
NEG_INF = -1e30


def _expand_kv(k, q_per_kv: int):
    """(B, S, KV, hd) -> (B, S, KV*q_per_kv, hd) by repeat (GQA)."""
    if q_per_kv == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.repeat(k, q_per_kv, axis=2)


def attention(
    q, k, v,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    q_block: int = 1024,
    logits_soft_cap: float = 0.0,
):
    """Blocked multi-head attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) with H % KV == 0.
    Processes queries in blocks of ``q_block`` so peak score memory is
    O(Sk * q_block) per head — required at 32k prefill. ``window > 0`` adds a
    sliding-window constraint (keys within [pos - window + 1, pos]).
    ``q_offset`` is the absolute position of q[0] relative to k[0].
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    vd = v.shape[-1]             # may differ from hd (MLA: q/k 192, v 128)
    q = hint(q, "act_bshd")
    k = hint(k, "kv_bskd")
    v = hint(v, "kv_bskd")
    from repro.models import tuning
    grouped = tuning.ACTIVE.attn_grouped
    if not grouped:
        k = _expand_kv(k, h // kv)
        v = _expand_kv(v, h // kv)
    scale = 1.0 / np.sqrt(hd)

    def _mask(bq, blk_start, extra_dims):
        q_pos = q_offset + blk_start + jnp.arange(bq)
        k_pos = jnp.arange(k.shape[1])
        mask = jnp.ones((bq, k.shape[1]), dtype=bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        return mask.reshape((1,) * extra_dims + mask.shape)

    def block_attn(q_blk, blk_start):
        # q_blk: (B, Bq, H, hd)
        if grouped:
            bq = q_blk.shape[1]
            qg = q_blk.reshape(b, bq, kv, h // kv, hd)
            scores = jnp.einsum("bqgpd,bkgd->bgpqk", qg, k,
                                preferred_element_type=jnp.float32) * scale
            if logits_soft_cap > 0.0:
                scores = logits_soft_cap * jnp.tanh(scores / logits_soft_cap)
            scores = jnp.where(_mask(bq, blk_start, 3), scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1)
            if tuning.ACTIVE.attn_probs_bf16:
                probs = probs.astype(jnp.bfloat16)
            out = jnp.einsum("bgpqk,bkgd->bqgpd", probs.astype(v.dtype), v,
                             preferred_element_type=jnp.float32)
            return out.reshape(b, bq, h, vd).astype(q_blk.dtype)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q_blk.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        if logits_soft_cap > 0.0:
            scores = logits_soft_cap * jnp.tanh(scores / logits_soft_cap)
        scores = jnp.where(_mask(q_blk.shape[1], blk_start, 2), scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        if tuning.ACTIVE.attn_probs_bf16:
            probs = probs.astype(jnp.bfloat16)  # halves the score traffic
        return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)

    if tuning.ACTIVE.attn_block_remat:
        block_attn = jax.checkpoint(block_attn, static_argnums=(1,))
    q_block = tuning.ACTIVE.q_block if tuning.ACTIVE.q_block != 1024 else q_block

    if sq <= q_block or sq % q_block:
        # short or non-divisible sequences: one block (whisper's 1500 frames)
        return block_attn(q, 0)

    n_blocks = sq // q_block
    q_blocks = q.reshape(b, n_blocks, q_block, h, hd).transpose(1, 0, 2, 3, 4)

    def body(i, _):
        return None, block_attn(q_blocks[i], i * q_block)

    _, out = jax.lax.scan(lambda c, i: body(i, c), None, jnp.arange(n_blocks))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, vd)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0):
    """Single-token attention against a (possibly ring-buffered) KV cache.

    q: (B, 1, H, hd); caches: (B, S, KV, hd); cache_len: scalar int — number
    of valid entries (absolute position of the new token is cache_len).
    For ``window > 0`` the cache is a ring buffer of size S=window and all
    entries are valid once full.
    """
    from repro.models import tuning
    b, s, kv, hd = k_cache.shape
    h = q.shape[2]
    q = hint(q, "act_bshd")
    k_cache = hint(k_cache, "kv_cache_bskd")
    v_cache = hint(v_cache, "kv_cache_bskd")
    scale = 1.0 / np.sqrt(hd)
    positions = jnp.arange(s)
    if window > 0:
        valid = positions < jnp.minimum(cache_len, s)
    else:
        valid = positions < cache_len

    if tuning.ACTIVE.attn_grouped:
        # per-group contraction: no q_per_kv-times KV copy, bf16 operands,
        # f32 accumulation
        qg = q.reshape(b, 1, kv, h // kv, hd)[:, 0]          # (B, KV, qpk, hd)
        scores = jnp.einsum("bgpd,bsgd->bgps", qg, k_cache,
                            preferred_element_type=jnp.float32) * scale
        scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        if tuning.ACTIVE.attn_probs_bf16:
            probs = probs.astype(jnp.bfloat16)
        out = jnp.einsum("bgps,bsgd->bgpd", probs.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
        return out.reshape(b, 1, h, hd).astype(q.dtype)

    kc = _expand_kv(k_cache, h // kv)
    vc = _expand_kv(v_cache, h // kv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kc.astype(jnp.float32)) * scale     # (B, H, 1, S)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(vc.dtype), vc)


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #
def act_fn(name: str) -> Callable:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def glu_mlp(x, w_gate, w_up, w_down, act: str = "silu"):
    """SwiGLU / GeGLU: down(act(gate(x)) * up(x))."""
    g = act_fn(act)(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def dense_mlp(x, w_up, b_up, w_down, b_down, act: str = "gelu"):
    """Plain 2-layer MLP with biases (whisper)."""
    return act_fn(act)(x @ w_up + b_up) @ w_down + b_down


# --------------------------------------------------------------------------- #
# losses
# --------------------------------------------------------------------------- #
def cross_entropy(logits, labels, mask=None):
    """logits: (..., V) any float dtype; labels int (...,). Mean over mask.

    Vocab-parallel-friendly: the label score uses an iota-compare select that
    partitions cleanly when V is sharded (each shard reduces its slice, then
    one small all-reduce) — a gather here would make GSPMD replicate the
    full logits tensor.
    """
    logits = hint(logits.astype(jnp.float32), "logits")
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    picked = jnp.where(vocab_iota == labels[..., None], logits, 0.0)
    label_logit = jnp.sum(picked, axis=-1)
    nll = lse - label_logit
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_logits(x, embed, out_head=None):
    """Project hidden states to vocabulary (tied embeddings by default)."""
    w = embed.T if out_head is None else out_head
    return x @ w


# --------------------------------------------------------------------------- #
# scan utilities
# --------------------------------------------------------------------------- #
def chunked_scan(step, init, xs, chunk: int = 64):
    """`lax.scan` with chunk-boundary checkpointing.

    Equivalent to ``lax.scan(step, init, xs)`` but the backward pass stores the
    carry only at chunk boundaries and rematerializes within chunks — required
    for long recurrences (WKV, selective scan) whose carries are large.
    xs: pytree with leading (time) axis; falls back to a plain scan when the
    time axis is not divisible by ``chunk``.
    """
    length = jax.tree.leaves(xs)[0].shape[0]
    if chunk <= 1 or length % chunk or length <= chunk:
        return jax.lax.scan(step, init, xs)
    n = length // chunk
    xs_r = jax.tree.map(lambda a: a.reshape(n, chunk, *a.shape[1:]), xs)

    @jax.checkpoint
    def inner(carry, xc):
        return jax.lax.scan(step, carry, xc)

    final, ys = jax.lax.scan(inner, init, xs_r)
    ys = jax.tree.map(lambda a: a.reshape(length, *a.shape[2:]), ys)
    return final, ys

"""Dense decoder-only transformer (gemma-2b, granite-3-8b, qwen1.5-*, llama-13b).

GQA/MQA attention with RoPE (optional QKV bias for qwen), SwiGLU/GeGLU MLP,
RMSNorm, tied embeddings optional. Layer weights are stacked on axis 0 and the
stack is traversed with ``lax.scan`` (compact HLO at any depth) with
activation rematerialization per layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm


def param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------------- #
def init_params(key, cfg: ModelConfig):
    dt = param_dtype(cfg)
    hd = cfg.resolved_head_dim
    l, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    keys = cm.split_keys(key, 12)

    def stack(initializer, *shape):
        def one(k):
            return initializer(k, *shape)
        return jax.vmap(one)(jax.random.split(keys.pop(), l))

    layers = {
        "attn_norm": jnp.ones((l, d), dt),
        "wq": stack(lambda k: cm.dense_init(k, d, cfg.n_heads * hd, dt)),
        "wk": stack(lambda k: cm.dense_init(k, d, cfg.n_kv_heads * hd, dt)),
        "wv": stack(lambda k: cm.dense_init(k, d, cfg.n_kv_heads * hd, dt)),
        "wo": stack(lambda k: cm.dense_init(k, cfg.n_heads * hd, d, dt)),
        "mlp_norm": jnp.ones((l, d), dt),
        "w_gate": stack(lambda k: cm.dense_init(k, d, f, dt)),
        "w_up": stack(lambda k: cm.dense_init(k, d, f, dt)),
        "w_down": stack(lambda k: cm.dense_init(k, f, d, dt)),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((l, cfg.n_heads * hd), dt)
        layers["bk"] = jnp.zeros((l, cfg.n_kv_heads * hd), dt)
        layers["bv"] = jnp.zeros((l, cfg.n_kv_heads * hd), dt)

    params = {
        "embed": cm.embed_init(keys.pop(), cfg.vocab_size, d, dt),
        "final_norm": jnp.ones((d,), dt),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["out_head"] = cm.dense_init(keys.pop(), d, cfg.vocab_size, dt)
    return params


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# --------------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------------- #
def _qkv(x, lp, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    q = x @ lp["wq"]
    k = x @ lp["wk"]
    v = x @ lp["wv"]
    if cfg.qkv_bias:
        q = q + lp["bq"]
        k = k + lp["bk"]
        v = v + lp["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


def _block(x, lp, cfg: ModelConfig, positions, q_block: int = 1024):
    """One pre-norm transformer block over a full sequence."""
    x = cm.hint(x, "act_bsd")
    h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(h, lp, cfg)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    attn = cm.attention(q, k, v, causal=True, q_block=q_block)
    x = x + attn.reshape(x.shape[0], x.shape[1], -1) @ lp["wo"]
    h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + cm.glu_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
    return x


def _scan_blocks(x, layers, cfg: ModelConfig, positions, remat: bool = True):
    block = functools.partial(_block, cfg=cfg, positions=positions)
    if remat:
        block = jax.checkpoint(block)

    def body(carry, lp):
        return block(carry, lp), None

    x, _ = jax.lax.scan(body, x, layers)
    return x


# --------------------------------------------------------------------------- #
# training loss
# --------------------------------------------------------------------------- #
def loss_fn(params, batch, cfg: ModelConfig):
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s)
    x = _scan_blocks(x, params["layers"], cfg, positions)
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x, params["embed"], params.get("out_head"))
    loss = cm.cross_entropy(logits, labels)
    return loss, {"loss": loss}


# --------------------------------------------------------------------------- #
# serving: prefill + decode
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    dt = param_dtype(cfg)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg: ModelConfig, q_block: int = 1024):
    """Full-sequence forward that also populates the KV cache.

    Returns (cache, logits_last) — logits for the final position only.
    """
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s)

    def body(carry, lp):
        x = cm.hint(carry, "act_bsd")
        h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(h, lp, cfg)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        attn = cm.attention(q, k, v, causal=True, q_block=q_block)
        x = x + attn.reshape(b, s, -1) @ lp["wo"]
        h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + cm.glu_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x[:, -1:], params["embed"], params.get("out_head"))
    cache = {"k": ks, "v": vs, "len": jnp.asarray(s, jnp.int32)}
    return cache, logits


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """One decode step. tokens: (B, 1). Returns (new_cache, logits)."""
    b = tokens.shape[0]
    x = params["embed"][tokens]
    pos = cache["len"]
    positions = jnp.full((b, 1), pos, jnp.int32)

    def body(carry, layer_in):
        x = carry
        lp, k_cache, v_cache = layer_in
        h = cm.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(h, lp, cfg)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))
        attn = cm.decode_attention(q, k_cache, v_cache, pos + 1)
        x = x + attn.reshape(b, 1, -1) @ lp["wo"]
        h = cm.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + cm.glu_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
        return x, (k_cache, v_cache)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.lm_logits(x, params["embed"], params.get("out_head"))
    new_cache = {"k": ks, "v": vs, "len": cache["len"] + 1}
    return new_cache, logits

"""Uniform LM interface over all model families.

Every family module provides:
    init_params(key, cfg[, ep_size])          -> params pytree
    abstract_params(cfg[, ep_size])            -> ShapeDtypeStruct pytree
    loss_fn(params, batch, cfg[, dist])        -> (loss, metrics)
    init_cache(cfg, batch, max_len)            -> cache pytree
    prefill(params, tokens, cfg, ...)          -> (cache, last_logits)
    decode_step(params, cache, tokens, cfg, ...)-> (cache, logits)

This module dispatches on ``cfg.family`` and normalizes the extra-arg
differences (dist context for MoE families; frames/vision stubs for
multimodal families).
"""
from __future__ import annotations

import inspect
from types import ModuleType

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import DistContext, LOCAL
from repro.models import dense, hymba, mla, moe, rwkv, vlm, whisper

_FAMILY_MODULES: dict[str, ModuleType] = {
    "dense": dense,
    "moe": moe,
    "mla_moe": mla,
    "rwkv": rwkv,
    "hybrid": hymba,
    "encdec": whisper,
    "vlm": vlm,
}


def family_module(cfg: ModelConfig) -> ModuleType:
    return _FAMILY_MODULES[cfg.family]


def _accepts(fn, name: str) -> bool:
    return name in inspect.signature(fn).parameters


# --------------------------------------------------------------------------- #
def init_params(key, cfg: ModelConfig, ep_size: int = 1):
    fn = family_module(cfg).init_params
    if _accepts(fn, "ep_size"):
        return fn(key, cfg, ep_size=ep_size)
    return fn(key, cfg)


def abstract_params(cfg: ModelConfig, ep_size: int = 1):
    fn = family_module(cfg).abstract_params
    if _accepts(fn, "ep_size"):
        return fn(cfg, ep_size=ep_size)
    return fn(cfg)


def loss_fn(params, batch, cfg: ModelConfig, dist: DistContext = LOCAL):
    fn = family_module(cfg).loss_fn
    if _accepts(fn, "dist"):
        return fn(params, batch, cfg, dist=dist)
    return fn(params, batch, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return family_module(cfg).init_cache(cfg, batch, max_len)


def prefill(params, tokens, cfg: ModelConfig, dist: DistContext = LOCAL,
            frames=None, vision=None):
    fn = family_module(cfg).prefill
    kwargs = {}
    if _accepts(fn, "dist"):
        kwargs["dist"] = dist
    if _accepts(fn, "frames"):
        kwargs["frames"] = frames
    if _accepts(fn, "vision"):
        kwargs["vision"] = vision
    return fn(params, tokens, cfg, **kwargs)


def decode_step(params, cache, tokens, cfg: ModelConfig, dist: DistContext = LOCAL):
    fn = family_module(cfg).decode_step
    if _accepts(fn, "dist"):
        return fn(params, cache, tokens, cfg, dist=dist)
    return fn(params, cache, tokens, cfg)


def pad_cache(cfg: ModelConfig, cache, max_len: int):
    """Grow a prefill-sized cache so decode_step has room for new tokens.

    decode_step writes at position cache['len']; a cache whose sequence dim
    equals the prefill length has no free slot (dynamic_update_slice would
    clamp and corrupt the last entry). Families with O(1) state (rwkv) are
    returned unchanged; hymba pads only its global-attention layers (window
    layers are ring buffers).
    """
    import jax.numpy as jnp

    def pad(leaf, axis: int, target: int):
        cur = leaf.shape[axis]
        if cur >= target:
            return leaf
        width = [(0, 0)] * leaf.ndim
        width[axis] = (0, target - cur)
        return jnp.pad(leaf, width)

    if cfg.family in ("dense", "moe"):
        return dict(cache, k=pad(cache["k"], 2, max_len),
                    v=pad(cache["v"], 2, max_len))
    if cfg.family == "mla_moe":
        return dict(cache, ckv=pad(cache["ckv"], 2, max_len),
                    krope=pad(cache["krope"], 2, max_len))
    if cfg.family == "encdec":
        return dict(cache, k=pad(cache["k"], 2, max_len),
                    v=pad(cache["v"], 2, max_len))
    if cfg.family == "vlm":
        return dict(cache, k=pad(cache["k"], 3, max_len),
                    v=pad(cache["v"], 3, max_len))
    if cfg.family == "hybrid":
        layers = []
        for i, lc in enumerate(cache["layers"]):
            if i in cfg.global_layers:
                layers.append(dict(lc, k=pad(lc["k"], 1, max_len),
                                   v=pad(lc["v"], 1, max_len)))
            else:
                layers.append(lc)
        return dict(cache, layers=layers)
    return cache  # rwkv: O(1) state


# --------------------------------------------------------------------------- #
def make_batch(cfg: ModelConfig, batch: int, seq: int, key=None):
    """A synthetic training batch matching the family's input signature."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    labels = jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    out = {"tokens": tokens, "labels": labels}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            k3, (batch, cfg.n_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        out["vision"] = jax.random.normal(
            k3, (batch, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    return out


def count_params(params) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(params))


def count_params_abstract(cfg: ModelConfig, ep_size: int = 1) -> int:
    import numpy as np
    tree = abstract_params(cfg, ep_size)
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def active_params_abstract(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: top_k + shared experts only)."""
    total = count_params_abstract(cfg)
    if not cfg.is_moe:
        return total
    n_moe_layers = cfg.n_layers - cfg.first_k_dense
    per_expert = 3 * cfg.d_model * cfg.d_expert
    import math
    e_pad = math.ceil(cfg.n_experts / 1) if True else 0
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive

"""Tuning knobs must not change semantics (optimized == baseline numerics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import api, tuning
from repro.models import common as cm


@pytest.fixture(autouse=True)
def _reset_tuning():
    tuning.reset()
    yield
    tuning.reset()


def test_grouped_attention_matches_baseline():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 256, 8, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 256, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 256, 2, 64))
    for kwargs in (dict(causal=True), dict(causal=True, window=64),
                   dict(causal=False)):
        tuning.reset()
        base = cm.attention(q, k, v, q_block=128, **kwargs)
        tuning.set_tuning(attn_grouped=True)
        opt = cm.attention(q, k, v, q_block=128, **kwargs)
        np.testing.assert_allclose(np.asarray(opt), np.asarray(base),
                                   rtol=1e-5, atol=1e-5)


def test_grouped_decode_matches_baseline():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 1, 8, 64))
    kc = jax.random.normal(jax.random.PRNGKey(3), (2, 512, 2, 64))
    vc = jax.random.normal(jax.random.PRNGKey(4), (2, 512, 2, 64))
    base = cm.decode_attention(q, kc, vc, 300)
    tuning.set_tuning(attn_grouped=True)
    opt = cm.decode_attention(q, kc, vc, 300)
    np.testing.assert_allclose(np.asarray(opt), np.asarray(base),
                               rtol=1e-5, atol=1e-5)


def test_block_remat_preserves_grads():
    cfg = get_smoke_config("gemma-2b")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = api.make_batch(cfg, 2, 32)

    def loss(p):
        return api.loss_fn(p, batch, cfg)[0]

    base_loss, base_grads = jax.value_and_grad(loss)(params)
    tuning.set_tuning(attn_grouped=True, attn_probs_bf16=True,
                      attn_block_remat=True)
    opt_loss, opt_grads = jax.value_and_grad(loss)(params)
    # bf16 probs change rounding slightly; loss must agree to bf16 precision
    assert abs(float(base_loss) - float(opt_loss)) < 2e-2
    gb = jnp.concatenate([g.astype(jnp.float32).ravel()
                          for g in jax.tree.leaves(base_grads)])
    go = jnp.concatenate([g.astype(jnp.float32).ravel()
                          for g in jax.tree.leaves(opt_grads)])
    cos = float(jnp.dot(gb, go) / (jnp.linalg.norm(gb) * jnp.linalg.norm(go)))
    assert cos > 0.99


def test_tuning_describe():
    assert tuning.Tuning().describe() == "baseline"
    t = tuning.Tuning(attn_grouped=True, seq_parallel=True)
    assert "attn_grouped" in t.describe() and "seq_parallel" in t.describe()

"""Optimizers, data pipeline, trainer integration."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_smoke_config
from repro.models import api
from repro.train.data import SyntheticDataset
from repro.train.optimizer import adafactor, adamw
from repro.train.trainer import Trainer, TrainerConfig


def quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.0]), "b": jnp.array(5.0)}


@pytest.mark.parametrize("make_opt", [lambda: adamw(lr=0.05, weight_decay=0.0),
                                      lambda: adafactor(lr=0.1)])
def test_optimizer_minimizes_quadratic(make_opt):
    opt = make_opt()
    params = quadratic_params()
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum(jnp.square(p["w"])) + jnp.square(p["b"])

    for _ in range(200):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = opt.step(params, grads, state)
    assert float(loss_fn(params)) < 0.5


def test_adamw_grad_clip():
    opt = adamw(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, stats = opt.step(params, huge, state)
    assert float(stats["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_adafactor_factored_state_is_small():
    opt = adafactor()
    params = {"w": jnp.zeros((512, 512)), "b": jnp.zeros(512)}
    state = opt.init(params)
    w_stats = state["stats"]["w"]
    assert set(w_stats) == {"vr", "vc"}
    assert w_stats["vr"].shape == (512,)
    b_stats = state["stats"]["b"]
    assert set(b_stats) == {"v"}


@given(st.integers(0, 1000), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_dataset_deterministic_and_step_dependent(step_a, step_b):
    cfg = get_smoke_config("qwen1.5-0.5b")
    ds = SyntheticDataset(cfg, global_batch=2, seq_len=16, seed=5)
    a1 = ds.batch_at(step_a)
    a2 = ds.batch_at(step_a)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])
    if step_a != step_b:
        b = ds.batch_at(step_b)
        assert not np.array_equal(a1["tokens"], b["tokens"])


def test_dataset_labels_are_shifted_tokens():
    cfg = get_smoke_config("qwen1.5-0.5b")
    ds = SyntheticDataset(cfg, global_batch=2, seq_len=16, seed=1)
    b = ds.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_trainer_telemetry_and_controller_integration():
    cfg = get_smoke_config("gemma-2b")
    tr = Trainer(cfg, TrainerConfig(steps=4), global_batch=2, seq_len=16,
                 controller=True)
    report = tr.run()
    assert report.steps_run == 4
    assert np.isfinite(report.final_loss)
    frame = tr.sampler.frame()
    # telemetry exists and power stays within the platform envelope
    if len(frame):
        assert (frame["power"] >= 0).all()
        assert (frame["power"] <= tr.device.platform.tdp_w + 1).all()


def test_checkpoint_restart_exact_state():
    from repro.train import checkpoint as ckpt
    cfg = get_smoke_config("qwen1.5-0.5b")
    with tempfile.TemporaryDirectory() as d:
        t1 = Trainer(cfg, TrainerConfig(steps=4, checkpoint_every=2,
                                        checkpoint_dir=d),
                     global_batch=2, seq_len=16)
        t1.run()
        assert ckpt.latest_step(d) == 4
        # a fresh trainer resumes exactly at step 4 and matches t1's params
        t2 = Trainer(cfg, TrainerConfig(steps=4, checkpoint_every=2,
                                        checkpoint_dir=d),
                     global_batch=2, seq_len=16)
        rep2 = t2.run()
        assert rep2.resumed_from == 4 and rep2.steps_run == 0
        for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t2.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-6)
